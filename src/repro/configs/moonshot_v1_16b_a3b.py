"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf] — kimi/moonlight,
deepseek-family fine-grained MoE, 64 routed top-6 (+2 shared)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, head_dim=128,
    n_experts=64, experts_per_token=6, n_shared_experts=2, moe_d_ff=1408,
    first_k_dense=1,
)
