"""internvl2-2b [arXiv:2404.16821; hf] — InternLM2-backbone VLM; the
InternViT frontend is a stub (input_specs feeds precomputed patch
embeddings, 256 media tokens)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab=92553, head_dim=128, frontend="vit_stub", num_media_tokens=256,
)
