"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained MoE, 2 shared + 64
routed experts top-6, first layer dense."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, head_dim=128,
    n_experts=64, experts_per_token=6, n_shared_experts=2, moe_d_ff=1408,
    first_k_dense=1,
)
