"""The paper's own system: sparse HDC iEEG seizure-detection classifier.

Paper-exact parameters (Sec. II / IV-B): D=1024, 8 segments (one 1-bit each,
p = 0.78%), 64 electrodes, 6-bit LBP codes, 256-cycle temporal window,
temporal threshold 130 (20-30% max density operating point — the purple
star of Fig. 4), spatial bundling WITHOUT thinning (the proposed design),
2 classes, one-shot training with 50% class-HV density.

Variants (--override variant=...):
  sparse_compim  (default) the optimized accelerator (CompIM + OR bundling)
  sparse_naive   the baseline accelerator (Fig. 3a)
  dense          the dense-HDC comparison system of [1]
All three (and the jnp/pallas backend choice) are routed by the unified
repro.core.pipeline.HDCPipeline surface.
"""

from repro.core.classifier import HDCConfig

CONFIG = HDCConfig(
    dim=1024,
    segments=8,
    channels=64,
    lbp_bits=6,
    window=256,
    variant="sparse_compim",
    spatial_thinning=False,
    temporal_threshold=130,
    n_classes=2,
    class_density=0.5,
)

BASELINE = HDCConfig(
    dim=1024, segments=8, channels=64, lbp_bits=6, window=256,
    variant="sparse_naive", spatial_thinning=True, spatial_threshold=1,
    temporal_threshold=130, n_classes=2, class_density=0.5,
)
