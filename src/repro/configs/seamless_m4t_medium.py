"""seamless-m4t-medium [arXiv:2308.11596; hf] — encoder-decoder; the audio
frontend is a stub (input_specs feeds precomputed frame embeddings)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, enc_layers=12, cross_attention=True,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    head_dim=64, frontend="audio_stub",
)
