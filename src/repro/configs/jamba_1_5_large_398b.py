"""jamba-1.5-large-398b [arXiv:2403.19887; hf] — hybrid Mamba+attention 1:7
interleave (1 attention per 8-layer period block), MoE 16e top-2 on every
other layer.  bf16 optimizer state (optim.OptConfig.state_dtype) is the
intended training mode at this size (fp32 state would not fit the assumed
fleet)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128, attn_period=8,
    n_experts=16, experts_per_token=2, moe_period=2, moe_d_ff=24576,
    ssm_state=16, ssm_expand=2, ssm_conv=4,
)
