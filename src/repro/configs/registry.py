"""Architecture registry: ``--arch <id>`` -> ArchConfig.

Also owns the per-arch shape applicability matrix (which of the four
assigned input shapes each architecture runs; the matrix in this module is
the single source of truth).
"""

from __future__ import annotations

import importlib

from repro.data.lm import SHAPES, ShapeSpec
from repro.models.config import ArchConfig

ARCH_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen3-0.6b": "qwen3_0_6b",
    "command-r-35b": "command_r_35b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-2b": "internvl2_2b",
}

ARCH_IDS = tuple(ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped).  Per assignment: long_500k only for
    sub-quadratic archs; decode only for archs with a decoder (all of ours
    have one — seamless is enc-dec, not encoder-only)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention at 524,288 ctx — skipped per assignment"
    return True, ""


def all_cells():
    """Every (arch x shape) cell with its applicability."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            yield arch_id, cfg, shape, ok, reason
