"""Shared vectorized multi-patient dispatch machinery.

Both the batched ``ServingEngine`` and the streaming ``StreamingFleet`` serve
MANY patients against ONE device computation.  Two shared tricks:

* **Pre-bound codebooks.**  Binding is a pure function of (channel, LBP code)
  — the data HV and the electrode HV are both design-time constants — so the
  serving path precomputes the BOUND packed HV per (channel, code) once per
  patient (the CompIM observation, pushed one stage further: position-domain
  binding collapses into the table build).  Per cycle, spatial encoding is
  then just a gather + OR-tree (or adder-tree for the thinning/dense
  variants), with no per-cycle decode/shift/pack work.
* **Owner gathering.**  The per-patient tables stack along a leading
  unique-params axis and each stream's rows are gathered INSIDE the lookup,
  so a single jitted call encodes any mix of patients — no Python
  per-patient loop, and no per-stream copy of the tables is materialized.

Per-patient configs must agree on the datapath (``datapath_key``); the
temporal threshold — the per-patient register the paper calibrates — rides
along as a traced ``(B,)`` array instead of a static config field.

Everything here is bit-exact with the per-pipeline reference datapaths (the
bound-table equivalence is the paper's Sec. III-A binding-domain argument:
``shift(onehot(p_item), p_elec) == onehot((p_item + p_elec) mod L)``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import replace
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binding, bundling, classifier, hv
from repro.core.pipeline import HDCConfig, HDCPipeline


def datapath_key(cfg: HDCConfig) -> HDCConfig:
    """Normalize a per-patient config to its shared-datapath key.

    ``temporal_threshold`` is the per-patient programmed register (carried as
    a traced array by the dispatchers), ``backend`` is a deployment choice
    (the backends are bit-exact) and ``class_density`` only affects training;
    everything else selects the datapath and must agree across a bank.
    """
    return replace(cfg, temporal_threshold=0, backend="jnp", class_density=0.5)


def validate_bank(pipelines: Mapping[Hashable, HDCPipeline]) -> HDCConfig:
    """Check a patient -> trained-pipeline bank shares one datapath.

    Returns the normalized datapath config (hashable, safe as a jit static).
    """
    if not pipelines:
        raise ValueError("need at least one pipeline")
    first = next(iter(pipelines.values()))
    key = datapath_key(first.cfg)
    for pid, p in pipelines.items():
        if p.class_hvs is None:
            raise ValueError(
                f"patient {pid!r}: pipeline is untrained "
                "(call train_one_shot before serving)"
            )
        other = datapath_key(p.cfg)
        if other != key:
            bad = [
                f.name
                for f in dataclasses.fields(HDCConfig)
                if getattr(other, f.name) != getattr(key, f.name)
            ]
            raise ValueError(
                f"patient {pid!r}: {'/'.join(bad)} mismatch in bank "
                "(per-patient configs may differ only in temporal_threshold, "
                "backend and class_density)"
            )
    return key


def bound_table(params, cfg: HDCConfig) -> jax.Array:
    """Pre-bound codebook for one patient: (channels, codes, W) uint32.

    Entry [c, k] is the packed HV of channel c's code k AFTER binding with
    the channel's electrode HV — sparse variants via the position-domain
    identity, dense via XOR.  Built once at bank construction.
    """
    if cfg.variant == "dense":
        return jnp.bitwise_xor(params.item_packed, params.elec_packed[:, None])
    pos = binding.bind_positions(
        params.item_pos, params.elec_pos[:, None], cfg.seg_len
    )
    return hv.positions_to_packed(pos, cfg.dim, cfg.segments)


def stack_bound_tables(pipes: Sequence[HDCPipeline]) -> tuple[jax.Array, np.ndarray]:
    """Stack the unique per-patient pre-bound codebooks into one bank.

    Returns ``(tables, rows)``: ``tables`` is (P_unique, channels, codes, W)
    over the UNIQUE params objects (patients sharing one codebook share one
    row), and ``rows[i]`` is pipeline ``i``'s row index.
    """
    row_of: dict[int, int] = {}
    unique: list[jax.Array] = []
    rows: list[int] = []
    for p in pipes:
        k = id(p.params)
        if k not in row_of:
            row_of[k] = len(unique)
            unique.append(bound_table(p.params, datapath_key(p.cfg)))
        rows.append(row_of[k])
    return jnp.stack(unique), np.asarray(rows, np.int32)


def owner_gather_bound(
    tables: jax.Array, owner: jax.Array, codes: jax.Array
) -> jax.Array:
    """Gather each stream's pre-bound rows: ``(B, ..., channels)`` codes ->
    ``(B, ..., C, W)`` packed bound HVs (the fused fleet kernel's input)."""
    ch = jnp.arange(tables.shape[1])
    o = owner.reshape((-1,) + (1,) * (codes.ndim - 1))
    return tables[o, ch, codes.astype(jnp.int32)]


def owner_spatial_encode(
    tables: jax.Array, owner: jax.Array, codes: jax.Array, cfg: HDCConfig
) -> jax.Array:
    """Owner-gathered spatial encode: ``(B, ..., channels)`` -> ``(B, ..., W)``.

    ``tables`` is the stacked pre-bound codebook bank; ``owner`` (B,) selects
    each stream's row.  Bit-exact with ``pipeline.spatial_encode`` on each
    stream's own params, for every variant.
    """
    bound = owner_gather_bound(tables, owner, codes)  # (B, ..., C, W)
    if cfg.variant == "dense":
        counts = hv.unpacked_counts(bound, axis=-2, dim=cfg.dim)
        return hv.majority_pack(counts, cfg.channels, cfg.dim)
    if cfg.variant == "sparse_naive" or cfg.spatial_thinning:
        return bundling.spatial_bundle_thinned(bound, cfg.dim, cfg.spatial_threshold)
    return hv.or_reduce(bound, axis=-2)


def spatial_block_len(t_pad: int, cfg: HDCConfig) -> int:
    """Largest divisor of t_pad <= min(cap, window): the time-block of the
    scanned spatial encode.

    Blocks bound the per-iteration temporaries of the vectorized spatial
    encode (the bit-domain variants materialize a (S, block, channels, D)
    expansion, so they get a tighter cap than the position-domain default).
    """
    cap = min(8 if cfg.variant == "sparse_compim" else 4, cfg.window, t_pad)
    return max(b for b in range(1, cap + 1) if t_pad % b == 0)


def owner_spatial_words(
    tables: jax.Array, owner: jax.Array, codes: jax.Array, cfg: HDCConfig
) -> jax.Array:
    """Blockwise-scanned spatial encode of a chunk batch: (S, T, channels)
    codes -> (S, T, W) per-cycle packed HVs.

    A lax.scan over fixed time blocks bounds the channel-gather temporary,
    and the gather runs CHANNEL-major over a flattened (P*C*codes, W) table
    (one jnp.take with contiguous rows): the bundling tree then reduces a
    leading axis with dense slices instead of strided (..., C, W) ones,
    which is ~40% faster on CPU and identical bit-for-bit.  The packed
    per-cycle stream feeds the bit-plane temporal bundler
    (kernels/hdc_fleet)."""
    s, t, c = codes.shape
    p, _, k, w = tables.shape
    block = spatial_block_len(t, cfg)
    nb = t // block
    blocks = codes.reshape(s, nb, block, c).transpose(1, 0, 2, 3)
    flat = tables.reshape(p * c * k, w)
    ob = owner[None, :, None] * (c * k)                    # (1, S, 1)
    cbase = (jnp.arange(c) * k)[:, None, None]             # (C, 1, 1)

    def body(_, cb):
        idx = ob + cbase + cb.transpose(2, 0, 1).astype(jnp.int32)
        bound = jnp.take(flat, idx, axis=0)                # (C, S, block, W)
        if cfg.variant == "dense":
            counts = hv.unpacked_counts(bound, axis=0, dim=cfg.dim)
            return None, hv.majority_pack(counts, cfg.channels, cfg.dim)
        if cfg.variant == "sparse_naive" or cfg.spatial_thinning:
            counts = hv.unpacked_counts(bound, axis=0, dim=cfg.dim)
            return None, hv.threshold_pack(counts, cfg.spatial_threshold)
        return None, hv.or_reduce(bound, axis=0)

    _, out = jax.lax.scan(body, None, blocks)              # (nb, S, block, W)
    return out.transpose(1, 0, 2, 3).reshape(s, t, cfg.words)


def owner_encode_frames(
    tables: jax.Array,
    owner: jax.Array,
    thresholds: jax.Array,
    codes: jax.Array,
    cfg: HDCConfig,
) -> jax.Array:
    """Vectorized multi-patient ``encode_frames``: (B, T, ch) -> (B, F, W).

    ``thresholds`` is the per-stream (B,) temporal-threshold register bank;
    bit-exact with each stream's own ``pipeline.encode_frames`` (jnp backend).
    """
    framed = classifier.frame_view(codes, cfg.window)  # (B, F, win, C)
    spatial = owner_spatial_encode(tables, owner, framed, cfg)
    counts = bundling.temporal_counts(spatial, cfg.dim)  # (B, F, D)
    if cfg.variant == "dense":
        return hv.majority_pack(counts, cfg.window, cfg.dim)
    return hv.threshold_pack(counts, thresholds[:, None, None])


def owner_am_scores(
    frames: jax.Array, class_rows: jax.Array, cfg: HDCConfig
) -> jax.Array:
    """(..., W) frames vs (..., C, W) owner-gathered class HVs -> (..., C).

    The per-patient AM rows are gathered BEFORE scoring, so the cost is
    O(streams * C), independent of the provisioned-patient count P.
    """
    q = frames[..., None, :]
    if cfg.variant == "dense":
        return cfg.dim - hv.hamming(q, class_rows)
    return hv.overlap(q, class_rows)
