"""Shared vectorized multi-patient dispatch machinery.

Both the batched ``ServingEngine`` and the streaming ``StreamingFleet`` serve
MANY patients against ONE device computation.  Two shared tricks:

* **Pre-bound codebooks.**  Binding is a pure function of (channel, LBP code)
  — the data HV and the electrode HV are both design-time constants — so the
  serving path precomputes the BOUND packed HV per (channel, code) once per
  patient (the CompIM observation, pushed one stage further: position-domain
  binding collapses into the table build).  Per cycle, spatial encoding is
  then just a gather + OR-tree (or adder-tree for the thinning/dense
  variants), with no per-cycle decode/shift/pack work.  The serving device
  step consumes RAW uint8 codes end to end (``owner_spatial_codes`` / the
  fused ``kernels/hdc_fleet`` kernel): the gather and the bundling reduce
  are fused, so the per-cycle bound ``(..., C, W)`` expansion is never
  materialized and the host ships one byte per (cycle, channel).
* **Owner gathering.**  The per-patient tables stack along a leading
  unique-params axis and each stream's rows are gathered INSIDE the lookup,
  so a single jitted call encodes any mix of patients — no Python
  per-patient loop, and no per-stream copy of the tables is materialized.

Per-patient configs must agree on the datapath (``datapath_key``); the
temporal threshold — the per-patient register the paper calibrates — rides
along as a traced ``(B,)`` array instead of a static config field.

Everything here is bit-exact with the per-pipeline reference datapaths (the
bound-table equivalence is the paper's Sec. III-A binding-domain argument:
``shift(onehot(p_item), p_elec) == onehot((p_item + p_elec) mod L)``).

**Channel masking (electrode-fault tolerance).**  Every spatial encode here
optionally takes a per-stream ``chan_mask`` (B/S, channels) uint8 operand
(1 = live, 0 = quarantined).  The spatial bundle is a symmetric reduction
over channel HVs, so a masked channel is a droppable TERM, not a retrain:

* OR tree: the masked channel's gathered rows are zeroed — the OR
  identity — so the term vanishes exactly as if the electrode were absent.
* adder tree (thinning): zeroed rows add nothing and the thinning
  threshold renormalizes to the live channel count
  (``effective_spatial_threshold``), keeping the spatial HV density at the
  configured operating point as electrodes fail.
* dense majority: zeroed rows add nothing and the majority denominator
  becomes the per-stream live count.

The mask is a TRACED operand — walking masks never recompiles — and the
masked output is bit-exact with the same pipeline built on the physically
reduced channel set (``reduced_channel_config``), which is the oracle the
property tests hold it to.
"""

from __future__ import annotations

import dataclasses
from dataclasses import replace
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binding, bundling, hv
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.reliability import ecc


def datapath_key(cfg: HDCConfig) -> HDCConfig:
    """Normalize a per-patient config to its shared-datapath key.

    ``temporal_threshold`` is the per-patient programmed register (carried as
    a traced array by the dispatchers), ``backend`` is a deployment choice
    (the backends are bit-exact) and ``class_density`` only affects training;
    everything else selects the datapath and must agree across a bank.
    """
    return replace(cfg, temporal_threshold=0, backend="jnp", class_density=0.5)


def validate_bank(pipelines: Mapping[Hashable, HDCPipeline]) -> HDCConfig:
    """Check a patient -> trained-pipeline bank shares one datapath.

    Returns the normalized datapath config (hashable, safe as a jit static).
    """
    if not pipelines:
        raise ValueError("need at least one pipeline")
    first = next(iter(pipelines.values()))
    key = datapath_key(first.cfg)
    for pid, p in pipelines.items():
        if p.class_hvs is None:
            raise ValueError(
                f"patient {pid!r}: pipeline is untrained "
                "(call train_one_shot before serving)"
            )
        other = datapath_key(p.cfg)
        if other != key:
            bad = [
                f.name
                for f in dataclasses.fields(HDCConfig)
                if getattr(other, f.name) != getattr(key, f.name)
            ]
            raise ValueError(
                f"patient {pid!r}: {'/'.join(bad)} mismatch in bank "
                "(per-patient configs may differ only in temporal_threshold, "
                "backend and class_density)"
            )
    return key


def bound_table(params, cfg: HDCConfig) -> jax.Array:
    """Pre-bound codebook for one patient: (channels, codes, W) uint32.

    Entry [c, k] is the packed HV of channel c's code k AFTER binding with
    the channel's electrode HV — sparse variants via the position-domain
    identity, dense via XOR.  Built once at bank construction.
    """
    if cfg.variant == "dense":
        return jnp.bitwise_xor(params.item_packed, params.elec_packed[:, None])
    pos = binding.bind_positions(
        params.item_pos, params.elec_pos[:, None], cfg.seg_len
    )
    return hv.positions_to_packed(pos, cfg.dim, cfg.segments)


def stack_bound_tables(pipes: Sequence[HDCPipeline]) -> tuple[jax.Array, np.ndarray]:
    """Stack the unique per-patient pre-bound codebooks into one bank.

    Returns ``(tables, rows)``: ``tables`` is (P_unique, channels, codes, W)
    over the UNIQUE params objects (patients sharing one codebook share one
    row), and ``rows[i]`` is pipeline ``i``'s row index.
    """
    row_of: dict[int, int] = {}
    unique: list[jax.Array] = []
    rows: list[int] = []
    for p in pipes:
        k = id(p.params)
        if k not in row_of:
            row_of[k] = len(unique)
            unique.append(bound_table(p.params, datapath_key(p.cfg)))
        rows.append(row_of[k])
    return jnp.stack(unique), np.asarray(rows, np.int32)


def effective_spatial_threshold(live: jax.Array, cfg: HDCConfig) -> jax.Array:
    """Thinning threshold renormalized to the live channel count.

    ``ceil(spatial_threshold * live / channels)``, floored at 1: the
    adder-tree thinning threshold tracks the shrinking channel population so
    the surviving spatial HV density stays near the configured operating
    point instead of collapsing as electrodes fail.  With every channel
    live this is exactly ``cfg.spatial_threshold``.
    """
    live = live.astype(jnp.int32)
    c = cfg.channels
    return jnp.maximum(1, (cfg.spatial_threshold * live + c - 1) // c)


def reduced_channel_config(cfg: HDCConfig, live: int) -> HDCConfig:
    """The config of the reduced-channel ORACLE for a mask with ``live``
    channels alive: the pipeline an implant with the dead electrodes
    physically absent would run.  Masked encodes are bit-exact with it."""
    thr = max(1, -(-cfg.spatial_threshold * live // cfg.channels))
    return replace(cfg, channels=live, spatial_threshold=thr)


def owner_spatial_encode(
    tables: jax.Array,
    owner: jax.Array,
    codes: jax.Array,
    cfg: HDCConfig,
    chan_mask: jax.Array | None = None,
) -> jax.Array:
    """Owner-gathered spatial encode: ``(B, ..., channels)`` -> ``(B, ..., W)``.

    ``tables`` is the stacked pre-bound codebook bank; ``owner`` (B,) selects
    each stream's row.  Bit-exact with ``pipeline.spatial_encode`` on each
    stream's own params, for every variant.  This is the REFERENCE
    formulation (it materializes the full ``(B, ..., C, W)`` bound
    expansion); the serving paths run ``owner_spatial_codes``, which is
    bit-exact with it and never materializes the expansion.
    """
    ch = jnp.arange(tables.shape[1], dtype=jnp.int32)
    o = owner.reshape((-1,) + (1,) * (codes.ndim - 1))
    bound = tables[o, ch, codes.astype(jnp.int32)]  # (B, ..., C, W)
    if chan_mask is not None:
        c = tables.shape[1]
        m = chan_mask.astype(jnp.uint32).reshape(
            (-1,) + (1,) * (codes.ndim - 2) + (c, 1))
        bound = bound * m
        live = chan_mask.astype(jnp.int32).sum(axis=1, dtype=jnp.int32)
        live = live.reshape((-1,) + (1,) * (codes.ndim - 1))
    if cfg.variant == "dense":
        counts = hv.unpacked_counts(bound, axis=-2, dim=cfg.dim)
        n = cfg.channels if chan_mask is None else live
        return hv.majority_pack(counts, n, cfg.dim)
    if cfg.variant == "sparse_naive" or cfg.spatial_thinning:
        if chan_mask is None:
            return bundling.spatial_bundle_thinned(
                bound, cfg.dim, cfg.spatial_threshold)
        counts = hv.unpacked_counts(bound, axis=-2, dim=cfg.dim)
        return hv.threshold_pack(counts, effective_spatial_threshold(live, cfg))
    return hv.or_reduce(bound, axis=-2)


def spatial_block_len(t_pad: int, cfg: HDCConfig) -> int:
    """Largest divisor of t_pad <= min(8, window): the time-block of the
    scanned count-domain spatial encode.

    Blocks bound the per-iteration channel-gather temporary of the
    adder-tree variants to ``(channels, S, block, W)`` packed words.  The
    old tighter bit-domain cap is gone: the code-domain path channel-pads
    the gathered stack to a 32-multiple so the reduction always runs on the
    bit-plane popcount adder — no ``(S, block, channels, D)`` unpacked
    expansion exists on any variant anymore.  The OR-tree variant takes the
    scan-free whole-chunk path and never calls this.
    """
    cap = min(8, cfg.window, t_pad)
    return max(b for b in range(1, cap + 1) if t_pad % b == 0)


def owner_spatial_codes(
    tables: jax.Array,
    owner: jax.Array,
    codes: jax.Array,
    cfg: HDCConfig,
    chan_mask: jax.Array | None = None,
) -> jax.Array:
    """Code-domain fused gather+bind+bundle: (S, T, channels) uint8 codes ->
    (S, T, W) per-cycle packed spatial HVs.

    The device-side spatial stage of the fleet/engine ``backend="jnp"``
    datapath.  Binding is already folded into the pre-bound table build
    (``bound_table``), so the whole spatial encode is table lookups feeding
    a reduction — and the reduction is fused into the gather consumer, so
    the ``(S, T, C, W)`` bound expansion is never materialized:

    * OR tree (optimized sparse): one flattened contiguous ``jnp.take`` per
      CHANNEL over the whole chunk, pairwise-OR-reduced as a tree so XLA
      overlaps independent gather+OR pairs.  The gathers clamp
      (``mode="clip"``, the same OOB rule as the reference's advanced
      indexing — and ~2x cheaper than the default fill mode, which
      materializes a select+broadcast per gather).  The peak temporary is
      one tree level of channel rows, and there is no scan (the scan
      carry/stacking overhead dominated the old blockwise path).
    * adder tree (naive sparse / thinning / dense majority): a scan over
      ``spatial_block_len`` time blocks; per block one c-major flattened
      take, channel-padded to a 32-multiple so the per-bit counts always
      run on the bit-plane popcount adder (no unpacked channel expansion),
      then threshold/majority pack.

    Bit-exact with ``owner_spatial_encode`` for every variant (OR and
    integer adds are associative/commutative; zero pad rows add nothing).

    ``chan_mask`` (S, channels) uint8, when given, drops quarantined
    channels from the bundle (see the module docstring): the masked output
    is bit-exact with the same encode on the physically-reduced channel
    set.  ``chan_mask=None`` leaves the program byte-identical to the
    mask-free datapath.
    """
    s, t, c = codes.shape
    p, _, k, w = tables.shape
    flat = tables.reshape(p * c * k, w)
    if t == 0:
        return jnp.zeros((s, 0, w), jnp.uint32)

    # clamp BEFORE flattening the (patient, channel, code) index: an
    # out-of-alphabet code (hostile input, stale staging bytes) must clip
    # within its channel's rows like the reference's advanced indexing,
    # not spill into the next channel's table
    codes = jnp.minimum(codes, jnp.asarray(k - 1, codes.dtype))

    if cfg.variant == "sparse_compim" and not cfg.spatial_thinning:
        ob = (owner.astype(jnp.int32) * (c * k))[:, None]  # (S, 1)
        ci32 = codes.astype(jnp.int32)
        lvl = [jnp.take(flat, ob + ci * k + ci32[:, :, ci], axis=0,
                        mode="clip")
               for ci in range(c)]                          # C x (S, T, W)
        if chan_mask is not None:  # OR identity: masked terms vanish
            m = chan_mask.astype(jnp.uint32)
            lvl = [r * m[:, ci, None, None] for ci, r in enumerate(lvl)]
        while len(lvl) > 1:
            nxt = [a | b for a, b in zip(lvl[0::2], lvl[1::2])]
            if len(lvl) % 2:
                nxt.append(lvl[-1])
            lvl = nxt
        return lvl[0]

    block = spatial_block_len(t, cfg)
    nb = t // block
    blocks = codes.reshape(s, nb, block, c).transpose(1, 0, 2, 3)
    ob = owner[None, :, None].astype(jnp.int32) * (c * k)  # (1, S, 1)
    cbase = (jnp.arange(c, dtype=jnp.int32) * k)[:, None, None]  # (C, 1, 1)
    c32 = -(-c // 32) * 32
    if chan_mask is not None:
        cm = chan_mask.astype(jnp.uint32).T[:, :, None, None]  # (C, S, 1, 1)
        live = chan_mask.astype(jnp.int32).sum(axis=1, dtype=jnp.int32)[:, None, None]
        denom = (live if cfg.variant == "dense"
                 else effective_spatial_threshold(live, cfg))

    def body(_, cb):
        idx = ob + cbase + cb.transpose(2, 0, 1).astype(jnp.int32)
        bound = jnp.take(flat, idx, axis=0, mode="clip")   # (C, S, block, W)
        if chan_mask is not None:  # zeroed rows count nothing below
            bound = bound * cm
        if c32 != c:  # zero rows count nothing; keeps the bit-plane route
            bound = jnp.pad(bound, ((0, c32 - c), (0, 0), (0, 0), (0, 0)))
        counts = hv.unpacked_counts(bound, axis=0, dim=cfg.dim)
        if cfg.variant == "dense":
            n = cfg.channels if chan_mask is None else denom
            return None, hv.majority_pack(counts, n, cfg.dim)
        thr = cfg.spatial_threshold if chan_mask is None else denom
        return None, hv.threshold_pack(counts, thr)

    _, out = jax.lax.scan(body, None, blocks)              # (nb, S, block, W)
    return out.transpose(1, 0, 2, 3).reshape(s, t, cfg.words)


def owner_encode_frames(
    tables: jax.Array,
    owner: jax.Array,
    thresholds: jax.Array,
    codes: jax.Array,
    cfg: HDCConfig,
    chan_mask: jax.Array | None = None,
) -> jax.Array:
    """Vectorized multi-patient ``encode_frames``: (B, T, ch) -> (B, F, W).

    ``thresholds`` is the per-stream (B,) temporal-threshold register bank;
    bit-exact with each stream's own ``pipeline.encode_frames`` (jnp
    backend).  Runs the code-domain spatial stage (``owner_spatial_codes``)
    over the whole truncated stream, then frames the packed per-cycle HVs —
    batched serving never materializes per-frame bound expansions either.
    """
    b, t, _ = codes.shape
    f = t // cfg.window
    words = owner_spatial_codes(tables, owner, codes[:, : f * cfg.window], cfg,
                                chan_mask)
    spatial = words.reshape(b, f, cfg.window, cfg.words)
    counts = bundling.temporal_counts(spatial, cfg.dim)  # (B, F, D)
    if cfg.variant == "dense":
        return hv.majority_pack(counts, cfg.window, cfg.dim)
    return hv.threshold_pack(counts, thresholds[:, None, None])


def owner_am_scores(
    frames: jax.Array, class_rows: jax.Array, cfg: HDCConfig
) -> jax.Array:
    """(..., W) frames vs (..., C, W) owner-gathered class HVs -> (..., C).

    The per-patient AM rows are gathered BEFORE scoring, so the cost is
    O(streams * C), independent of the provisioned-patient count P.
    """
    q = frames[..., None, :]
    if cfg.variant == "dense":
        return cfg.dim - hv.hamming(q, class_rows)
    return hv.overlap(q, class_rows)


def owner_am_scores_protected(
    frames: jax.Array, rows: jax.Array, check: jax.Array, cfg: HDCConfig,
    scheme: str
) -> tuple[jax.Array, jax.Array]:
    """AM scoring through the ECC word codec (reliability.ecc).

    ``rows`` (S, C, W) are the possibly-corrupted stored class rows and
    ``check`` their (possibly-corrupted) per-word check bits; every word is
    decoded once per step — the storage-read model: the fleet's fault
    injection corrupts READS, never the stored rows — and the CORRECTED
    rows score the (S, K, W) frames.  Returns ``(scores (S, K, C),
    counters (S, 3))`` with counters = per-session word counts of
    [corrected, detected, uncorrectable] this read (detected = corrected +
    uncorrectable for SECDED; parity only detects).
    """
    corrected, status = ecc.decode(rows, check, scheme)
    scores = owner_am_scores(frames, corrected[:, None], cfg)
    red = tuple(range(1, status.ndim))
    counters = jnp.stack([
        jnp.sum(status == ecc.CORRECTED, axis=red, dtype=jnp.int32),
        jnp.sum(status != ecc.CLEAN, axis=red, dtype=jnp.int32),
        jnp.sum(status == ecc.UNCORRECTABLE, axis=red, dtype=jnp.int32),
    ], axis=-1)
    return scores, counters
