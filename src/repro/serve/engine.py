"""Batched multi-patient serving engine for the unified HDC pipeline.

Serves a fleet of implant streams against one accelerator:

* ``ServingEngine`` — request batching across patients.  Requests are
  ``(patient_id, codes)``; the engine gathers them by patient id, runs ONE
  encode per distinct patient datapath (patients may carry different
  calibrated temporal thresholds — encoding everything with one config is the
  correctness hazard the old example had) and ONE batched AM search per
  service call: each request's own patient's class HVs are gathered from the
  stacked (P, n_classes, W) AM bank into a (B, n_classes, W) operand and all
  B x F frames are scored in a single batched popcount op — O(B*F*n_classes)
  work, independent of the provisioned-patient count P.
* ``SeizureSession`` — streaming stateful per-patient API.  ``push(codes)``
  accepts arbitrary-length sub-window chunks and carries the temporal-bundling
  accumulator (the hardware's D x 8-bit counter file) across calls, emitting
  one decision per completed window; chunked pushes are bit-exact with the
  one-shot encoder.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

import functools

from repro.core import am, hv
from repro.core.pipeline import HDCConfig, HDCPipeline, spatial_encode


@functools.partial(jax.jit, static_argnames=("dense", "dim"))
def _gathered_am_scores(frames: jax.Array, owner_classes: jax.Array, *,
                        dense: bool, dim: int) -> jax.Array:
    """(B, F, W) frames vs per-request (B, C, W) class HVs -> (B, F, C).

    The per-patient AM bank is gathered per request BEFORE scoring, so the
    batched search costs O(B*F*C) regardless of how many patients are
    provisioned (scoring the whole bank and discarding the other patients'
    rows would be O(B*F*P*C))."""
    q = frames[:, :, None, :]            # (B, F, 1, W)
    c = owner_classes[:, None, :, :]     # (B, 1, C, W)
    return dim - hv.hamming(q, c) if dense else hv.overlap(q, c)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chunk_spatial_bits(params, chunk: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(t, channels) codes -> (t, D) uint8 per-cycle spatial bits.

    Jitted per caller chunk length (ONE compile for a steady stream);
    window-boundary splitting happens on the concrete result array, so odd
    chunk/window ratios do not fan out into per-residue recompiles."""
    spat = spatial_encode(params, chunk, cfg)
    return hv.unpack_bits(spat, cfg.dim)


@dataclass(frozen=True)
class Decision:
    """Result for one request: per-frame scores/predictions (+ the frame HVs,
    exposed for regression testing and downstream post-processing)."""
    request_id: int
    patient_id: Hashable
    scores: np.ndarray       # (F, n_classes)
    predictions: np.ndarray  # (F,) int32; 1 = ictal for the 2-class system
    frames: np.ndarray       # (F, W) packed frame HVs


class ServingEngine:
    """Batched serving over a bank of trained per-patient pipelines.

    All pipelines must be trained (``class_hvs`` set) and agree on ``dim``,
    ``n_classes``, ``window`` and the sparse/dense family (one AM similarity
    mode and one frame rate per bank).  Per-patient configs may differ
    otherwise — in particular each patient keeps its own calibrated
    ``temporal_threshold``.
    """

    def __init__(self, pipelines: Mapping[Hashable, HDCPipeline]):
        if not pipelines:
            raise ValueError("ServingEngine needs at least one pipeline")
        self._pipelines = dict(pipelines)
        self._pids = list(self._pipelines)
        self._pid_index = {pid: i for i, pid in enumerate(self._pids)}
        first = next(iter(self._pipelines.values()))
        for pid, p in self._pipelines.items():
            if p.class_hvs is None:
                raise ValueError(f"patient {pid!r}: pipeline is untrained "
                                 "(call train_one_shot before serving)")
            mismatched = [f for f in ("dim", "n_classes", "window",
                                      "channels", "lbp_bits")
                          if getattr(p.cfg, f) != getattr(first.cfg, f)]
            if mismatched:
                raise ValueError(f"patient {pid!r}: {'/'.join(mismatched)} "
                                 "mismatch in bank")
            if (p.cfg.variant == "dense") != (first.cfg.variant == "dense"):
                raise ValueError("cannot mix dense and sparse pipelines in one "
                                 "AM bank (different similarity modes)")
        self._cfg = first.cfg
        self._n_classes = first.cfg.n_classes
        # stacked per-patient AM bank; serve() gathers rows per request
        self._bank = jnp.stack([self._pipelines[pid].class_hvs
                                for pid in self._pids])      # (P, C, W)

    @property
    def patient_ids(self) -> list:
        return list(self._pids)

    def serve(self, requests: Sequence[tuple[Hashable, jax.Array]]) -> list[Decision]:
        """Serve one batch of ``(patient_id, codes)`` requests.

        ``codes``: (T, channels) uint8 LBP codes, same T across the batch,
        T >= window (sub-window chunks belong to ``SeizureSession``); cycles
        past the last full window are truncated, like ``encode_frames``.
        Returns one Decision per request, in request order.
        """
        if not requests:
            return []
        pids, codes = zip(*requests)
        for pid in pids:
            if pid not in self._pid_index:
                raise KeyError(f"unknown patient id {pid!r}")
        shapes = {tuple(jnp.shape(c)) for c in codes}
        if len(shapes) > 1:
            # a shorter request's frames would silently broadcast into the
            # (B, F, W) buffer below — reject loudly instead
            raise ValueError(f"all requests in a batch must share one codes "
                             f"shape; got {sorted(shapes)}")
        t = next(iter(shapes))[0]
        if t < self._cfg.window:
            raise ValueError(
                f"request codes span {t} cycles < one {self._cfg.window}-cycle "
                "window, which would yield zero frames; use SeizureSession "
                "for sub-window streaming chunks")

        # gather request indices by patient id, then merge patients whose
        # datapath (params + config) is identical into one encode batch
        by_datapath: dict[tuple, list[int]] = {}
        for i, pid in enumerate(pids):
            p = self._pipelines[pid]
            by_datapath.setdefault((id(p.params), p.cfg), []).append(i)

        frames = None                                      # (B, F, W)
        for (_, _cfg), idxs in by_datapath.items():
            pipe = self._pipelines[pids[idxs[0]]]
            batch = jnp.stack([jnp.asarray(codes[i]) for i in idxs])
            group_frames = pipe.encode_frames(batch)       # (B_g, F, W)
            if frames is None:
                frames = jnp.zeros((len(requests), *group_frames.shape[1:]),
                                   group_frames.dtype)
            frames = frames.at[jnp.asarray(idxs)].set(group_frames)

        # ONE batched AM search: gather each request's own patient's class
        # HVs from the stacked bank, score all B x F frames in one op
        owner = jnp.asarray([self._pid_index[pid] for pid in pids])   # (B,)
        scores = _gathered_am_scores(frames, self._bank[owner],
                                     dense=self._cfg.variant == "dense",
                                     dim=self._cfg.dim)               # (B, F, C)
        preds = am.am_predict(scores)

        frames_np, scores_np, preds_np = (np.asarray(x) for x in
                                          (frames, scores, preds))
        return [Decision(request_id=i, patient_id=pid, scores=scores_np[i],
                         predictions=preds_np[i], frames=frames_np[i])
                for i, pid in enumerate(pids)]


# ---------------------------------------------------------------------------
# streaming sessions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FrameDecision:
    frame_index: int
    scores: np.ndarray        # (n_classes,)
    prediction: int           # argmax class id
    frame_hv: np.ndarray      # (W,) packed


class SeizureSession:
    """Stateful streaming detector for one patient.

    Mirrors the hardware's always-on operation: LBP codes arrive a few cycles
    at a time, the temporal accumulator integrates them, and every ``window``
    cycles a frame HV is thresholded out and scored.  ``push`` accepts chunks
    of ANY length (sub-window, window-crossing, multi-window) and returns the
    decisions completed by that chunk; accumulator state carries over, so
    chunked pushes are bit-exact with a one-shot ``encode_frames`` of the
    concatenated stream.
    """

    def __init__(self, pipeline: HDCPipeline):
        if pipeline.class_hvs is None:
            raise ValueError("SeizureSession needs a trained pipeline")
        self._pipe = pipeline
        cfg = pipeline.cfg
        self._counts = np.zeros((cfg.dim,), np.int32)
        self._filled = 0
        self._frame_index = 0

    @property
    def cycles_buffered(self) -> int:
        """Cycles accumulated toward the next (incomplete) frame."""
        return self._filled

    def _emit_frame(self) -> FrameDecision:
        cfg = self._pipe.cfg
        counts = jnp.asarray(self._counts[None])
        if cfg.variant == "dense":
            frame = hv.majority_pack(counts, cfg.window, cfg.dim)[0]
        else:
            frame = hv.threshold_pack(counts, cfg.temporal_threshold)[0]
        scores = np.asarray(self._pipe.scores(frame[None]))[0]
        dec = FrameDecision(frame_index=self._frame_index, scores=scores,
                            prediction=int(np.argmax(scores)),
                            frame_hv=np.asarray(frame))
        self._counts = np.zeros_like(self._counts)
        self._filled = 0
        self._frame_index += 1
        return dec

    def push(self, codes: jax.Array) -> list[FrameDecision]:
        """Feed (t, channels) uint8 codes; returns decisions for every frame
        completed by this chunk (possibly empty)."""
        codes = jnp.asarray(codes)
        t = codes.shape[0]
        cfg = self._pipe.cfg
        out: list[FrameDecision] = []
        if t == 0:
            return out
        bits = np.asarray(_chunk_spatial_bits(self._pipe.params, codes, cfg))
        pos = 0
        while pos < t:
            take = min(cfg.window - self._filled, t - pos)
            self._counts += bits[pos:pos + take].sum(axis=0, dtype=np.int32)
            self._filled += take
            pos += take
            if self._filled == cfg.window:
                out.append(self._emit_frame())
        return out
