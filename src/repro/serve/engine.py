"""Batched multi-patient serving engine for the unified HDC pipeline.

Serves a fleet of implant streams against one accelerator:

* ``ServingEngine`` — request batching across patients.  Requests are
  ``(patient_id, codes)``; the engine stacks every patient's design-time
  codebooks and class HVs into device-resident banks at construction, then
  serves each batch with ONE padded jitted dispatch (serve/dispatch.py): the
  per-request params/class rows are gathered from the banks INSIDE the
  computation, so a batch mixing any number of distinct patient datapaths
  costs one compile + one device call — the old per-datapath-group Python
  loop is gone.  Batch sizes are padded to power-of-two buckets so request
  traffic does not fan out recompiles.
* ``SeizureSession`` — streaming stateful per-patient API.  ``push(codes)``
  accepts arbitrary-length sub-window chunks and carries the temporal-bundling
  accumulator (the hardware's D x 8-bit counter file) across calls, emitting
  one decision per completed window; chunked pushes are bit-exact with the
  one-shot encoder.  For thousands of concurrent streams use
  ``serve.fleet.StreamingFleet`` — one jitted step for the whole fleet.

The batched encode path is code-domain end to end: the spatial stage is the
fused gather+bind+bundle over the pre-bound codebook bank
(``dispatch.owner_spatial_codes`` — the request's uint8 codes are the only
per-cycle operand, and the (B, F, win, C, W) bound expansion is never
materialized), and temporal bundling runs on the bit-plane popcount adder
(``hv.unpacked_counts`` routes window-length reductions through
``hv.bitplane_counts``), so no unpacked (..., window, D) expansion is
materialized either.

All per-patient configs in a bank must share one datapath
(``dispatch.datapath_key``): per-patient calibrated ``temporal_threshold``
(and training-only / deployment-only fields) may differ, anything that
changes the encoder datapath may not.
"""

from __future__ import annotations

import functools
import hashlib
import io
import json
from dataclasses import dataclass
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import am, hv, online
from repro.core.pipeline import HDCConfig, HDCPipeline, _scores, spatial_encode
from repro.runtime import aot as aot_mod
from repro.serve import dispatch


@functools.partial(jax.jit, static_argnames=("cfg",))
def _serve_dispatch(tables, class_bank, param_owner, owner, thresholds,
                    codes, cfg: HDCConfig):
    """One padded batch: encode + gathered AM search + argmax, all jitted.

    codes: (B_pad, T, channels); owner: (B_pad,) patient rows into the class
    bank; param_owner: (B_pad,) rows into the stacked pre-bound codebook
    bank; thresholds: (B_pad,) per-request temporal-threshold registers."""
    frames = dispatch.owner_encode_frames(tables, param_owner, thresholds,
                                          codes, cfg)             # (B, F, W)
    cls = class_bank[owner]                                       # (B, C, W)
    scores = dispatch.owner_am_scores(frames, cls[:, None], cfg)  # (B, F, C)
    return frames, scores, am.am_predict(scores)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _session_adapt(state, class_hvs, frame_hv, scores, label, margin,
                   cfg: HDCConfig):
    """One gated online update for one session (core.online): feed the true
    label of the last emitted frame, refresh the class HVs from the counter
    file when the gate fires.  Returns (state, class_hvs, applied)."""
    bits = hv.unpack_bits(frame_hv, cfg.dim)
    new_state, applied = online.update(state, bits, label, scores,
                                       margin=margin)
    chvs = online.class_hvs_from_state(
        new_state, cfg, density=jnp.float32(cfg.class_density))
    return new_state, jnp.where(applied, chvs, class_hvs), applied


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chunk_spatial_bits(params, chunk: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(t, channels) codes -> (t, D) uint8 per-cycle spatial bits.

    Jitted per caller chunk length (ONE compile for a steady stream);
    window-boundary splitting happens on the concrete result array, so odd
    chunk/window ratios do not fan out into per-residue recompiles."""
    spat = spatial_encode(params, chunk, cfg)
    return hv.unpack_bits(spat, cfg.dim)


@dataclass(frozen=True)
class Decision:
    """Result for one request: per-frame scores/predictions (+ the frame HVs,
    exposed for regression testing and downstream post-processing)."""
    request_id: int
    patient_id: Hashable
    scores: np.ndarray       # (F, n_classes)
    predictions: np.ndarray  # (F,) int32; 1 = ictal for the 2-class system
    frames: np.ndarray       # (F, W) packed frame HVs


class ServingEngine:
    """Batched serving over a bank of trained per-patient pipelines.

    All pipelines must be trained (``class_hvs`` set) and share one datapath
    (``dispatch.datapath_key``); each patient keeps its own calibrated
    ``temporal_threshold`` and its own codebooks.  The dispatch runs the
    vectorized pure-XLA datapath, which is bit-exact with both pipeline
    backends.
    """

    def __init__(self, pipelines: Mapping[Hashable, HDCPipeline]):
        if not pipelines:
            raise ValueError("ServingEngine needs at least one pipeline")
        self._pipelines = dict(pipelines)
        self._cfg = dispatch.validate_bank(self._pipelines)
        self._pids = list(self._pipelines)
        self._pid_index = {pid: i for i, pid in enumerate(self._pids)}
        pipes = [self._pipelines[pid] for pid in self._pids]
        # stacked pre-bound codebook bank + per-patient row indices
        self._tables, self._param_rows = dispatch.stack_bound_tables(pipes)
        # stacked per-patient AM bank; the dispatch gathers rows per request
        self._bank = jnp.stack([p.class_hvs for p in pipes])      # (P, C, W)
        self._thresholds = np.asarray(
            [p.cfg.temporal_threshold for p in pipes], np.int32)
        # AOT executables (runtime/aot.py): ``prewarm`` fills these with
        # pre-compiled dispatches keyed by (padded batch, T); ``serve``
        # prefers them and falls back to the jitted dispatch
        self._exec: dict[tuple[int, int], jax.stages.Compiled] = {}

    @property
    def patient_ids(self) -> list:
        return list(self._pids)

    @property
    def aot_count(self) -> int:
        """Dispatch executables installed by ``prewarm`` (the jit cache
        stays cold when these serve)."""
        return len(self._exec)

    # -- ahead-of-time compilation (runtime/aot.py) ---------------------------

    def _aot_sig(self) -> str:
        h = hashlib.sha256()
        h.update(repr(self._cfg).encode())
        h.update(str(tuple(jnp.shape(self._tables))).encode())
        h.update(str(tuple(jnp.shape(self._bank))).encode())
        h.update(str(bool(jax.config.jax_enable_x64)).encode())
        return h.hexdigest()[:10]

    def _aot_name(self, b_pad: int, t: int) -> str:
        return f"engine.{self._cfg.variant}.b{b_pad}.t{t}.{self._aot_sig()}"

    def _dispatch_avals(self, b_pad: int, t: int) -> tuple:
        def sds(x):
            return jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype)

        return (
            sds(self._tables),
            sds(self._bank),
            jax.ShapeDtypeStruct((b_pad,), self._param_rows.dtype),
            jax.ShapeDtypeStruct((b_pad,), jnp.int32),
            jax.ShapeDtypeStruct((b_pad,), self._thresholds.dtype),
            jax.ShapeDtypeStruct((b_pad, t, self._cfg.channels), jnp.uint8),
        )

    @staticmethod
    def _pow2_buckets(max_batch: int) -> list[int]:
        top = 1 << (max(1, int(max_batch)) - 1).bit_length()
        return [1 << i for i in range(top.bit_length())]

    def aot_entries(self, batch_sizes: Sequence[int], t: int
                    ) -> list[aot_mod.AOTEntry]:
        """AOT entries for this engine's dispatch at the power-of-two batch
        buckets covering ``batch_sizes`` and request length ``t`` (cfg rides
        along as the jit's static argument)."""
        buckets = sorted({1 << (max(1, int(b)) - 1).bit_length()
                          for b in batch_sizes})
        return [aot_mod.AOTEntry(
                    name=self._aot_name(b, t),
                    fn=_serve_dispatch,
                    args=self._dispatch_avals(b, t),
                    static=(self._cfg,))
                for b in buckets]

    def prewarm(self, max_batch: int, t: int,
                *, aot: aot_mod.AOTArtifact | None = None) -> dict[str, int]:
        """Build the dispatch executable for every power-of-two batch bucket
        up to ``max_batch`` (request length ``t``) before traffic arrives —
        loaded from a deploy artifact when one is given, pre-lowered and
        compiled otherwise.  Returns {"loaded", "compiled", "skipped"}."""
        stats = {"loaded": 0, "compiled": 0, "skipped": 0}
        for b_pad in self._pow2_buckets(max_batch):
            key = (b_pad, t)
            if key in self._exec:
                stats["skipped"] += 1
                continue
            compiled = None
            if aot is not None:
                compiled = aot.compile(self._aot_name(b_pad, t),
                                       *self._dispatch_avals(b_pad, t))
                if compiled is not None:
                    stats["loaded"] += 1
            if compiled is None:
                compiled = _serve_dispatch.lower(
                    *self._dispatch_avals(b_pad, t), self._cfg).compile()
                stats["compiled"] += 1
            self._exec[key] = compiled
        return stats

    def serve(self, requests: Sequence[tuple[Hashable, jax.Array]]) -> list[Decision]:
        """Serve one batch of ``(patient_id, codes)`` requests.

        ``codes``: (T, channels) uint8 LBP codes, same T across the batch,
        T >= window (sub-window chunks belong to ``SeizureSession``); cycles
        past the last full window are truncated, like ``encode_frames``.
        Returns one Decision per request, in request order.
        """
        if not requests:
            return []
        pids, codes = zip(*requests)
        for pid in pids:
            if pid not in self._pid_index:
                raise KeyError(f"unknown patient id {pid!r}")
        shapes = {tuple(jnp.shape(c)) for c in codes}
        if len(shapes) > 1:
            # a shorter request's frames would silently broadcast into the
            # (B, F, W) buffer below — reject loudly instead
            raise ValueError(f"all requests in a batch must share one codes "
                             f"shape; got {sorted(shapes)}")
        t = next(iter(shapes))[0]
        if t < self._cfg.window:
            raise ValueError(
                f"request codes span {t} cycles < one {self._cfg.window}-cycle "
                "window, which would yield zero frames; use SeizureSession "
                "for sub-window streaming chunks")

        # pad the batch to a power-of-two bucket (padded rows replay patient
        # row 0 on zero codes) so batch-size traffic compiles once per bucket
        b = len(requests)
        b_pad = 1 << (b - 1).bit_length()
        owner = np.zeros(b_pad, np.int32)
        owner[:b] = [self._pid_index[pid] for pid in pids]
        first = np.asarray(codes[0])
        batch = np.zeros((b_pad, *first.shape), first.dtype)
        for i, c in enumerate(codes):
            batch[i] = np.asarray(c)

        args = (self._tables, self._bank,
                jnp.asarray(self._param_rows[owner]), jnp.asarray(owner),
                jnp.asarray(self._thresholds[owner]), jnp.asarray(batch))
        out = None
        fn = self._exec.get((b_pad, t))
        if fn is not None:  # prewarmed executable; JIT is the safety net
            try:
                out = fn(*args)
            except AssertionError:
                # sanitizer verdicts (guards.GuardViolation) must surface,
                # not silently demote the executable to a JIT recompile
                raise
            except Exception:
                self._exec.pop((b_pad, t), None)
        if out is None:
            out = _serve_dispatch(*args, self._cfg)
        frames, scores, preds = out

        frames_np, scores_np, preds_np = (np.asarray(x) for x in
                                          (frames, scores, preds))
        return [Decision(request_id=i, patient_id=pid, scores=scores_np[i],
                         predictions=preds_np[i], frames=frames_np[i])
                for i, pid in enumerate(pids)]


# ---------------------------------------------------------------------------
# streaming sessions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FrameDecision:
    frame_index: int
    scores: np.ndarray        # (n_classes,)
    prediction: int           # argmax class id
    frame_hv: np.ndarray      # (W,) packed


@dataclass(frozen=True)
class SessionSnapshot:
    """Compact host-side capture of ONE streaming session's full state.

    This is the unit of reconnect-with-state: ``SeizureSession.snapshot()``
    and ``ElasticFleet.evict(..., with_state=True)`` both produce one, and
    either consumer (``SeizureSession.from_snapshot`` or
    ``ElasticFleet.admit(pid, snapshot=...)``) resumes the stream
    bit-exactly where it left off — mid-window accumulator, adapted AM
    counter files, and the last emitted frame (so ``adapt`` feedback
    survives the reconnect) all round-trip.  The nine array/scalar fields
    mirror one row of ``serve.fleet.FleetState``; ``channel_mask``
    additionally carries the session's electrode quarantine (a
    ``channel_masking`` fleet's ``set_channel_mask`` row — electrode
    health must survive a reconnect) and stays None for sessions without
    one, keeping old blobs loadable.

    ``to_bytes``/``from_bytes`` serialize through one compressed ``.npz``
    blob (a few KB at paper geometry) for transport or queueing; the
    patient id must be JSON-representable to cross that boundary.
    """

    patient_id: Hashable
    counts: np.ndarray             # (D,) int32 temporal accumulator
    filled: int                    # cycles toward the next frame (< window)
    frame_index: int               # frames emitted so far
    class_rows: np.ndarray         # (C, W) uint32 (possibly adapted) AM
    am_counts: np.ndarray | None   # (C, D) int32 online counter file
    am_n: np.ndarray | None        # (C,) int32 frames bundled per class
    last_frame: np.ndarray         # (W,) uint32 last emitted frame HV
    last_scores: np.ndarray        # (C,) int32 its AM scores
    has_frame: int                 # 1 once a frame has been emitted
    channel_mask: np.ndarray | None = None  # (channels,) uint8 live mask

    def to_bytes(self) -> bytes:
        arrays = {
            "counts": np.asarray(self.counts, np.int32),
            "class_rows": np.asarray(self.class_rows, np.uint32),
            "last_frame": np.asarray(self.last_frame, np.uint32),
            "last_scores": np.asarray(self.last_scores, np.int32),
            "scalars": np.asarray(
                [self.filled, self.frame_index, self.has_frame,
                 int(self.am_counts is not None)], np.int64),
            "pid": np.frombuffer(
                json.dumps(self.patient_id).encode(), np.uint8),
        }
        if self.am_counts is not None:
            arrays["am_counts"] = np.asarray(self.am_counts, np.int32)
            arrays["am_n"] = np.asarray(self.am_n, np.int32)
        if self.channel_mask is not None:
            arrays["channel_mask"] = np.asarray(self.channel_mask, np.uint8)
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, blob: bytes) -> "SessionSnapshot":
        with np.load(io.BytesIO(blob)) as d:
            filled, fidx, has_frame, has_am = (int(x) for x in d["scalars"])
            return cls(
                patient_id=json.loads(bytes(d["pid"]).decode()),
                counts=d["counts"], filled=filled, frame_index=fidx,
                class_rows=d["class_rows"],
                am_counts=d["am_counts"] if has_am else None,
                am_n=d["am_n"] if has_am else None,
                last_frame=d["last_frame"], last_scores=d["last_scores"],
                has_frame=has_frame,
                # key-presence guard: blobs from before channel masking
                # (or from unmasked sessions) simply lack the array
                channel_mask=(d["channel_mask"]
                              if "channel_mask" in d.files else None))


class SeizureSession:
    """Stateful streaming detector for one patient.

    Mirrors the hardware's always-on operation: LBP codes arrive a few cycles
    at a time, the temporal accumulator integrates them, and every ``window``
    cycles a frame HV is thresholded out and scored.  ``push`` accepts chunks
    of ANY length (sub-window, window-crossing, multi-window) and returns the
    decisions completed by that chunk; accumulator state carries over, so
    chunked pushes are bit-exact with a one-shot ``encode_frames`` of the
    concatenated stream.

    ``adapt(label)`` feeds back the true label of the LAST emitted frame:
    a confidence-gated online update (core.online) adds the frame's bits to
    the true class's counter file, subtracts them from the rival's, and
    re-thresholds this session's class HVs in place — the pipeline object
    itself stays immutable.  Requires a pipeline trained via
    ``train_one_shot`` / ``fit_iterative`` (they carry the ``am_state``
    counter file the update continues from).

    One Python object + one jit dispatch per stream per push: for
    population-scale concurrency use ``serve.fleet.StreamingFleet``, which is
    bit-exact with this class (including ``adapt``) and advances every
    stream in one jitted step.
    """

    def __init__(self, pipeline: HDCPipeline):
        if pipeline.class_hvs is None:
            raise ValueError("SeizureSession needs a trained pipeline")
        self._pipe = pipeline
        cfg = pipeline.cfg
        self._counts = np.zeros((cfg.dim,), np.int32)
        self._filled = 0
        self._frame_index = 0
        # per-session adaptive AM: seeded from the pipeline, updated by adapt
        self._class_hvs = pipeline.class_hvs
        self._online = pipeline.am_state
        self._last: FrameDecision | None = None

    @property
    def cycles_buffered(self) -> int:
        """Cycles accumulated toward the next (incomplete) frame."""
        return self._filled

    @property
    def class_hvs(self) -> jax.Array:
        """This session's (possibly adapted) class HVs."""
        return self._class_hvs

    @property
    def am_state(self) -> online.OnlineAMState | None:
        """This session's (possibly adapted) AM counter-file state."""
        return self._online

    def _emit_frame(self) -> FrameDecision:
        cfg = self._pipe.cfg
        counts = jnp.asarray(self._counts[None])
        if cfg.variant == "dense":
            frame = hv.majority_pack(counts, cfg.window, cfg.dim)[0]
        else:
            frame = hv.threshold_pack(counts, cfg.temporal_threshold)[0]
        scores = np.asarray(_scores(frame[None], self._class_hvs, cfg))[0]
        dec = FrameDecision(frame_index=self._frame_index, scores=scores,
                            prediction=int(np.argmax(scores)),
                            frame_hv=np.asarray(frame))
        self._counts = np.zeros_like(self._counts)
        self._filled = 0
        self._frame_index += 1
        self._last = dec
        return dec

    def adapt(self, label: int, *, margin: float = 0.0) -> bool:
        """Online update from the true label of the last emitted frame.

        Returns True when the gated update fired (prediction wrong, or its
        score lead over the rival class below ``margin``); the session's
        class HVs are refreshed from the updated counter file.  Bit-exact
        with ``StreamingFleet.adapt`` on the same stream."""
        if self._last is None:
            raise ValueError("no frame emitted yet; adapt() labels the most "
                             "recent decision")
        if self._online is None:
            raise ValueError(
                "pipeline carries no am_state counter file; train it with "
                "train_one_shot or fit_iterative before adapting")
        cfg = self._pipe.cfg
        if not 0 <= label < cfg.n_classes:
            raise ValueError(f"label {label} not in [0, {cfg.n_classes})")
        self._online, self._class_hvs, applied = _session_adapt(
            self._online, self._class_hvs,
            jnp.asarray(self._last.frame_hv), jnp.asarray(self._last.scores),
            jnp.asarray(label, jnp.int32), jnp.asarray(margin, jnp.float32),
            cfg)
        return bool(applied)

    def snapshot(self, patient_id: Hashable = None) -> SessionSnapshot:
        """Capture this session's full streaming state as a
        ``SessionSnapshot`` (the session itself is untouched).  A session
        rebuilt from it — here or admitted into an ``ElasticFleet`` slot —
        continues the stream bit-exactly, including mid-window accumulator
        fill and adapted AM state."""
        cfg = self._pipe.cfg
        c = cfg.n_classes
        last = self._last
        return SessionSnapshot(
            patient_id=patient_id,
            counts=self._counts.astype(np.int32, copy=True),
            filled=int(self._filled),
            frame_index=int(self._frame_index),
            class_rows=np.asarray(self._class_hvs, np.uint32),
            am_counts=(np.asarray(self._online.counts, np.int32)
                       if self._online is not None else None),
            am_n=(np.asarray(self._online.n, np.int32)
                  if self._online is not None else None),
            last_frame=(np.asarray(last.frame_hv, np.uint32)
                        if last is not None
                        else np.zeros((cfg.words,), np.uint32)),
            last_scores=(np.asarray(last.scores, np.int32)
                         if last is not None
                         else np.zeros((c,), np.int32)),
            has_frame=int(last is not None))

    @classmethod
    def from_snapshot(cls, pipeline: HDCPipeline,
                      snap: SessionSnapshot) -> "SeizureSession":
        """Rebuild a session from a ``snapshot()`` against the SAME trained
        pipeline; the reconnect counterpart of ``snapshot``."""
        sess = cls(pipeline)
        sess._counts = np.asarray(snap.counts, np.int32).copy()
        sess._filled = int(snap.filled)
        sess._frame_index = int(snap.frame_index)
        sess._class_hvs = jnp.asarray(np.asarray(snap.class_rows, np.uint32))
        if snap.am_counts is not None:
            sess._online = online.OnlineAMState(
                counts=jnp.asarray(np.asarray(snap.am_counts, np.int32)),
                n=jnp.asarray(np.asarray(snap.am_n, np.int32)))
        if snap.has_frame:
            scores = np.asarray(snap.last_scores, np.int32)
            sess._last = FrameDecision(
                frame_index=int(snap.frame_index) - 1,
                scores=scores, prediction=int(np.argmax(scores)),
                frame_hv=np.asarray(snap.last_frame, np.uint32))
        return sess

    def push(self, codes: jax.Array) -> list[FrameDecision]:
        """Feed (t, channels) uint8 codes; returns decisions for every frame
        completed by this chunk (possibly empty).

        Codes are validated at the ingest boundary: a NaN-corrupted or
        mis-wired preprocessor that ships codes outside the item-memory
        alphabet fails HERE with a clear error instead of silently
        clamping into the wrong codebook rows."""
        cfg = self._pipe.cfg
        host = np.asarray(codes)
        if host.ndim != 2 or host.shape[1] != cfg.channels:
            raise ValueError(
                f"push needs a (t, {cfg.channels}) code chunk, got "
                f"{host.shape}")
        if not np.issubdtype(host.dtype, np.integer):
            raise ValueError(
                f"push needs integer LBP codes, got dtype {host.dtype} "
                "(run raw signal through data.ieeg.lbp_codes_np first; "
                "it rejects NaN/Inf and clamps ADC rails)")
        if host.size and (host.min() < 0 or host.max() >= cfg.codes):
            bad = host[(host < 0) | (host >= cfg.codes)][0]
            raise ValueError(
                f"code {int(bad)} outside the item-memory alphabet "
                f"[0, {cfg.codes}); corrupt ingest would silently clamp "
                "into the wrong codebook rows")
        codes = jnp.asarray(host.astype(np.uint8, copy=False))
        t = codes.shape[0]
        out: list[FrameDecision] = []
        if t == 0:
            return out
        bits = np.asarray(_chunk_spatial_bits(self._pipe.params, codes, cfg))
        pos = 0
        while pos < t:
            take = min(cfg.window - self._filled, t - pos)
            self._counts += bits[pos:pos + take].sum(axis=0, dtype=np.int32)
            self._filled += take
            pos += take
            if self._filled == cfg.window:
                out.append(self._emit_frame())
        return out
