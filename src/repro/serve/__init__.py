"""Multi-patient serving for the HDC seizure detector.

* ``engine.ServingEngine``   — batched request serving (one padded dispatch)
* ``engine.SeizureSession``  — single-patient streaming reference loop
* ``fleet.StreamingFleet``   — S concurrent streams, one jitted sharded step
* ``dispatch``               — shared owner-gathered vectorized datapath
"""

from repro.serve.engine import Decision, FrameDecision, SeizureSession, ServingEngine
from repro.serve.fleet import FleetOut, FleetState, StreamingFleet

__all__ = [
    "Decision",
    "FleetOut",
    "FleetState",
    "FrameDecision",
    "SeizureSession",
    "ServingEngine",
    "StreamingFleet",
]
