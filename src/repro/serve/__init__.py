# Batched multi-patient serving for the HDC seizure detector — see engine.py.
