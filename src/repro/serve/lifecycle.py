"""Elastic session lifecycle on the fleet's capacity tiles.

``StreamingFleet`` models production as a FIXED set of S sessions.  Real
implant telemetry is churn: streams connect, drop mid-window, reconnect
with their accumulated state, and sometimes arrive faster than the fleet
can grow.  ``ElasticFleet`` makes that lifecycle a first-class,
failure-tolerant subsystem on top of the existing tile machinery — without
giving up the property that makes the fleet fast: after ``warmup()``,
NOTHING on the admit/evict/push path compiles.

Free-slot maps over capacity tiles
    Provisioned capacity stays padded to whole tiles, so the tile-shaped
    step executables never change.  ``admit`` claims the lowest free slot
    and re-initializes it IN PLACE with one jitted ``_slot_write`` whose
    slot index is a TRACED operand (one executable serves every slot);
    ``evict`` just returns the slot to the free map — a dead slot always
    pushes a zero-length chunk, and since ``filled < window`` is a fleet
    invariant, ``n_emit = (filled + 0) // window = 0``: stale device state
    in a free slot is masked cycles, exactly the stale-staging-ring trick
    the ingest path already relies on.

Spill and compaction
    When every slot is taken the fleet SPILLS: it appends one more
    capacity tile (round-robined onto the local devices like the
    originals) up to ``max_tiles``.  ``warmup`` pre-compiles the step for
    every local device at the tile shape, so a spilled tile lands on warm
    executables — growth without recompiles.  ``compact()`` migrates the
    trailing tile's survivors into earlier free slots (snapshot out,
    slot-write in) and drops empty trailing tiles, shrinking the per-push
    working set after a churn wave recedes.

Reconnect-with-state
    ``evict(..., with_state=True)`` reads the slot's nine state rows into
    a compact host-side ``SessionSnapshot`` (serve/engine.py) — temporal
    accumulator, mid-window fill, adapted AM counter file, last emitted
    frame.  Re-admitting that snapshot (here, or into a plain
    ``SeizureSession.from_snapshot``) resumes the stream bit-exactly,
    including the next ``adapt`` against the pre-drop frame.

Overload backpressure
    ``offer`` is the admission front door: a full fleet that cannot spill
    QUEUES the arrival (bounded by ``queue_limit``) and beyond that SHEDS
    it explicitly — an "admitted" / "queued" / "shed" verdict instead of
    unbounded latency.  While arrivals are queued the fleet is overloaded
    and drops into a degraded decision-only mode: ``adapt`` becomes a
    no-op (counted in ``stats["adapt_shed"]``) so feedback processing
    never competes with decision latency under pressure.  Evictions drain
    the queue oldest-first.

Crash recovery
    ``save`` writes per-tile incremental checkpoints: tiles whose state
    did not change since the last checkpoint (``_dirty_t``, maintained by
    the step/adapt/slot-write paths) are HARD-LINKED from the previous
    step's files (``ckpt.save(..., link_from=...)``) instead of
    re-serialized, and the session table / queue / replay cursor ride in
    the manifest meta.  Every mutating call is also appended to a bounded
    in-memory replay ring; after a crash, ``restore`` + ``replay`` of the
    post-checkpoint events reproduces the uninterrupted fleet's decisions
    bit-exactly (tests/test_lifecycle.py and benchmarks/bench_churn.py
    both verify this end to end).

``benchmarks/bench_churn.py`` drives all of it at fleet scale with
Poisson arrivals/departures and reports p50/p99 decision latency and
sessions/s under churn; ``check_fleet_regression.py`` gates the ratios.
"""

from __future__ import annotations

import base64
import collections
import hashlib
import json
import os
import warnings
from typing import Hashable, Mapping, Sequence

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core.pipeline import HDCPipeline
from repro.runtime import aot as aot_mod
from repro.serve.engine import FrameDecision, SessionSnapshot
from repro.serve.fleet import (DEFAULT_BUCKETS, FleetRound, FleetState,
                               StreamingFleet, derive_tile)


class CapacityError(RuntimeError):
    """The fleet is full and cannot spill another tile (``max_tiles``)."""


def _slot_write(state: FleetState, slot, counts, filled, frame_index,
                class_rows, am_counts, am_n, last_frame, last_scores,
                has_frame) -> FleetState:
    """Overwrite ONE session slot's row in every state leaf.

    ``slot`` is a TRACED int32 scalar, so a single compiled program serves
    every slot of a tile, and the state is DONATED: re-initializing a slot
    rewrites the live tile buffers in place — no copy of the other
    ``tile_s - 1`` sessions, no recompile per slot."""
    return FleetState(
        counts=state.counts.at[slot].set(counts),
        filled=state.filled.at[slot].set(filled),
        frame_index=state.frame_index.at[slot].set(frame_index),
        class_rows=state.class_rows.at[slot].set(class_rows),
        am_counts=state.am_counts.at[slot].set(am_counts),
        am_n=state.am_n.at[slot].set(am_n),
        last_frame=state.last_frame.at[slot].set(last_frame),
        last_scores=state.last_scores.at[slot].set(last_scores),
        has_frame=state.has_frame.at[slot].set(has_frame),
    )


def _slot_read(state: FleetState, slot) -> tuple:
    """Gather ONE slot's row from every state leaf (the device half of an
    eviction snapshot); ``slot`` is traced like in ``_slot_write``."""
    return (state.counts[slot], state.filled[slot], state.frame_index[slot],
            state.class_rows[slot], state.am_counts[slot], state.am_n[slot],
            state.last_frame[slot], state.last_scores[slot],
            state.has_frame[slot])


class ElasticFleet(StreamingFleet):
    """A ``StreamingFleet`` whose sessions come and go at runtime.

    ``pipelines`` is the patient -> trained-pipeline bank (the set of
    per-patient configs sessions may connect with); capacity starts at ONE
    tile of ``tile`` slots and spills up to ``max_tiles`` tiles.  Sessions
    are addressed by the integer session id ``admit``/``offer`` return;
    ``push_sessions({sid: codes})`` advances whoever has traffic this
    round and returns ``{sid: [FrameDecision]}``.

    See the module docstring for the lifecycle semantics (free-slot maps,
    spill/compaction, reconnect snapshots, backpressure, replay recovery).
    Mesh sharding and fault campaigns stay on ``StreamingFleet`` — an
    elastic fleet is a per-device-tile construction.
    """

    def __init__(
        self,
        pipelines: Mapping[Hashable, HDCPipeline],
        *,
        tile: int | None = None,
        max_tiles: int = 4,
        queue_limit: int = 32,
        log_rounds: int = 64,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        backend: str | None = None,
        channel_masking: bool = False,
    ):
        if not pipelines:
            raise ValueError("ElasticFleet needs at least one pipeline")
        pids = list(pipelines)
        if tile is None:
            cfg = next(iter(pipelines.values())).cfg
            tile = derive_tile(cfg, max_bucket=max(buckets))
        if tile < len(pids):
            raise ValueError(
                f"tile={tile} < {len(pids)} patients: every patient needs "
                "at least one addressable slot in the owner cycle")
        if max_tiles < 1:
            raise ValueError(f"max_tiles={max_tiles} must be >= 1")
        # owners cycle the patient list so slot i < P starts as patient i:
        # the first P rows of the parent's per-owner arrays double as the
        # per-PATIENT init registers admissions are written from
        owners = [pids[i % len(pids)] for i in range(tile)]
        super().__init__(pipelines, owners, buckets=buckets,
                         backend=backend, tile=tile,
                         channel_masking=channel_masking)
        assert self._np == tile and len(self._tile_slices) == 1
        self._tile = int(tile)
        self._max_tiles = int(max_tiles)
        self._pid_of = {pid: i for i, pid in enumerate(pids)}
        p = len(pids)
        # host mirrors of the per-slot operand registers (device copies are
        # re-put per touched tile on admit/evict moves)
        self._thr_h = np.concatenate(
            [np.asarray(x) for x in self._thresholds_t])
        self._prow_h = np.concatenate(
            [np.asarray(x) for x in self._param_owner_t])
        self._dens_h = np.concatenate(
            [np.asarray(x) for x in self._density_t])
        # per-patient init registers (rows :P are patients in pid order)
        self._pat_thr = self._thr_h[:p].copy()
        self._pat_prow = self._prow_h[:p].copy()
        self._pat_dens = self._dens_h[:p].copy()
        self._pat_rows = self._class_rows0[:p].copy()
        if self._am_counts0 is not None:
            self._pat_am_counts = self._am_counts0[:p].copy()
            self._pat_am_n = self._am_n0[:p].copy()
        else:
            self._pat_am_counts = self._pat_am_n = None
        # lifecycle bookkeeping
        self._free: list[set[int]] = [set(range(tile))]
        self._sid_slot: dict[int, int] = {}
        self._slot_sid: dict[int, int] = {}
        self._sid_pid: dict[int, Hashable] = {}
        self._next_sid = 0
        self._queue: collections.deque = collections.deque()
        self._queue_limit = int(queue_limit)
        self._log: collections.deque = collections.deque(
            maxlen=int(log_rounds))
        self._op_id = 0
        self._stats = {"admitted": 0, "evicted": 0, "queued": 0, "shed": 0,
                       "adapt_shed": 0, "spills": 0, "compactions": 0}
        self._push_buf: np.ndarray | None = None
        # slot-surgery executables: jit fallbacks + per-(device, tile_s)
        # warmed executables, mirroring the step's _exec discipline
        self._slot_write_jit = jax.jit(_slot_write, donate_argnums=(0,))
        self._slot_read_jit = jax.jit(_slot_read)
        self._slot_exec: dict[tuple, jax.stages.Compiled] = {}
        self._read_exec: dict[tuple, jax.stages.Compiled] = {}

    # -- introspection ------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Provisioned slots (tiles x tile size); grows on spill, shrinks
        on compaction."""
        return self._np

    @property
    def n_tiles(self) -> int:
        return len(self._tile_slices)

    @property
    def sessions(self) -> dict[int, Hashable]:
        """``{session id: patient id}`` of every live session."""
        return dict(sorted(self._sid_pid.items()))

    @property
    def free_slots(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def overloaded(self) -> bool:
        """True while admissions are queued — the fleet sheds adapt work
        (decision-only degraded mode) until the queue drains."""
        return bool(self._queue)

    @property
    def stats(self) -> dict[str, int]:
        return dict(self._stats)

    @property
    def op_id(self) -> int:
        """Monotonic cursor of mutating operations; checkpoints record it
        and ``events_since``/``replay`` are keyed by it."""
        return self._op_id

    def slot_of(self, sid: int) -> int:
        return self._sid_slot[sid]

    # -- slot surgery (device side) -----------------------------------------

    def _slot_avals(self, dev) -> tuple:
        cfg = self._cfg
        c = cfg.n_classes
        sds = self._sds
        return (
            jax.tree.map(lambda x: sds(x, dev), self._state_t[0]),
            sds(np.int32(0), dev),
            sds(np.zeros((cfg.dim,), np.int32), dev),
            sds(np.int32(0), dev),
            sds(np.int32(0), dev),
            sds(np.zeros((c, cfg.words), np.uint32), dev),
            sds(np.zeros((c, cfg.dim), np.int32), dev),
            sds(np.zeros((c,), np.int32), dev),
            sds(np.zeros((cfg.words,), np.uint32), dev),
            sds(np.zeros((c,), np.int32), dev),
            sds(np.int32(0), dev),
        )

    def _fresh_rows(self, p: int) -> tuple:
        """A patient's pristine state row (fresh connection)."""
        cfg = self._cfg
        c = cfg.n_classes
        if self._pat_am_counts is not None:
            am_c, am_n = self._pat_am_counts[p], self._pat_am_n[p]
        else:
            am_c = np.zeros((c, cfg.dim), np.int32)
            am_n = np.zeros((c,), np.int32)
        return (np.zeros((cfg.dim,), np.int32), np.int32(0), np.int32(0),
                self._pat_rows[p], am_c, am_n,
                np.zeros((cfg.words,), np.uint32),
                np.zeros((c,), np.int32), np.int32(0))

    def _snap_rows(self, snap: SessionSnapshot) -> tuple:
        """A reconnecting session's state row, validated against this
        fleet's geometry."""
        cfg = self._cfg
        c = cfg.n_classes
        counts = np.asarray(snap.counts, np.int32)
        rows = np.asarray(snap.class_rows, np.uint32)
        lastf = np.asarray(snap.last_frame, np.uint32)
        lasts = np.asarray(snap.last_scores, np.int32)
        if (counts.shape != (cfg.dim,) or rows.shape != (c, cfg.words)
                or lastf.shape != (cfg.words,) or lasts.shape != (c,)):
            raise ValueError(
                f"snapshot geometry {counts.shape}/{rows.shape} does not "
                f"match this fleet (dim={cfg.dim}, classes={c}, "
                f"words={cfg.words})")
        if not 0 <= int(snap.filled) < cfg.window:
            raise ValueError(
                f"snapshot filled={snap.filled} outside [0, {cfg.window})")
        if snap.am_counts is not None:
            am_c = np.asarray(snap.am_counts, np.int32)
            am_n = np.asarray(snap.am_n, np.int32)
            if am_c.shape != (c, cfg.dim) or am_n.shape != (c,):
                raise ValueError(
                    f"snapshot AM geometry {am_c.shape} does not match "
                    f"this fleet ({c}, {cfg.dim})")
        else:
            am_c = np.zeros((c, cfg.dim), np.int32)
            am_n = np.zeros((c,), np.int32)
        return (counts, np.int32(snap.filled), np.int32(snap.frame_index),
                rows, am_c, am_n, lastf, lasts, np.int32(snap.has_frame))

    def _reput_registers(self, k: int) -> None:
        sl, d = self._tile_slices[k], self._tile_devs[k]
        self._thresholds_t[k] = self._put_tile(self._thr_h[sl],
                                               ("batch",), d)
        self._param_owner_t[k] = self._put_tile(self._prow_h[sl],
                                                ("batch",), d)
        self._density_t[k] = self._put_tile(self._dens_h[sl], ("batch",), d)
        if self._masked:
            self._cmask_t[k] = self._put_tile(self._cmask_h[sl],
                                              ("batch", None), d)

    def _write_slot(self, slot: int, pid: Hashable,
                    snapshot: SessionSnapshot | None) -> None:
        """Re-initialize one slot's device row (fresh or from a snapshot)
        and its host mirrors/operand registers.  Recompile-free after
        ``warmup``: the slot index is a traced operand."""
        k = slot // self._tile
        sl, d = self._tile_slices[k], self._tile_devs[k]
        p = self._pid_of[pid]
        rows = (self._fresh_rows(p) if snapshot is None
                else self._snap_rows(snapshot))
        args = (self._state_t[k], jax.device_put(np.int32(slot - sl.start), d)
                ) + tuple(jax.device_put(r, d) for r in rows)
        akey = (d, sl.stop - sl.start)
        fn = self._slot_exec.get(akey)
        if fn is not None:
            try:
                self._state_t[k] = fn(*args)
            except AssertionError:  # sanitizer verdicts must surface
                raise
            except Exception:
                self._slot_exec.pop(akey, None)
                self._state_t[k] = self._slot_write_jit(*args)
        else:
            self._state_t[k] = self._slot_write_jit(*args)
        self._dirty_t[k] = True
        self._filled_h[slot] = int(rows[1])
        self._fidx_h[slot] = int(rows[2])
        self._thr_h[slot] = self._pat_thr[p]
        self._prow_h[slot] = self._pat_prow[p]
        self._dens_h[slot] = self._pat_dens[p]
        if self._masked:
            # electrode quarantine follows the SESSION: a reconnecting
            # snapshot re-installs its mask, a fresh admit (or a snapshot
            # from an unmasked source) starts all-live
            ch = self._cfg.channels
            if snapshot is not None and snapshot.channel_mask is not None:
                cm = np.asarray(snapshot.channel_mask, np.uint8)
                if cm.shape != (ch,):
                    raise ValueError(
                        f"snapshot channel_mask must be ({ch},), got "
                        f"{cm.shape}")
                self._cmask_h[slot] = cm
            else:
                self._cmask_h[slot] = 1
        self._reput_registers(k)

    def _snapshot_slot(self, slot: int) -> SessionSnapshot:
        """Read one slot's state row into a host-side SessionSnapshot.
        This is control-plane code: the ``np.asarray`` syncs are explicit
        and intentional (an eviction must land its state on the host)."""
        k = slot // self._tile
        sl, d = self._tile_slices[k], self._tile_devs[k]
        args = (self._state_t[k],
                jax.device_put(np.int32(slot - sl.start), d))
        akey = (d, sl.stop - sl.start)
        fn = self._read_exec.get(akey)
        rows = None
        if fn is not None:
            try:
                rows = fn(*args)
            except AssertionError:  # sanitizer verdicts must surface
                raise
            except Exception:
                self._read_exec.pop(akey, None)
        if rows is None:
            rows = self._slot_read_jit(*args)
        counts, _, _, rows9, am_c, am_n, lastf, lasts, hasf = (
            np.asarray(r) for r in rows)
        has_am = self._am_counts0 is not None
        return SessionSnapshot(
            patient_id=self._sid_pid[self._slot_sid[slot]],
            counts=counts,
            filled=int(self._filled_h[slot]),
            frame_index=int(self._fidx_h[slot]),
            class_rows=rows9,
            am_counts=am_c if has_am else None,
            am_n=am_n if has_am else None,
            last_frame=lastf, last_scores=lasts, has_frame=int(hasf),
            channel_mask=(self._cmask_h[slot].copy()
                          if self._masked else None))

    # -- tile growth / shrink -----------------------------------------------

    def _spill_tile(self) -> int:
        """Append one more capacity tile (round-robined onto the local
        devices).  Recompile-free when ``warmup`` ran: every local device
        already holds the tile-shaped executables."""
        if len(self._tile_slices) >= self._max_tiles:
            raise CapacityError(
                f"fleet at max_tiles={self._max_tiles} "
                f"({self.capacity} slots)")
        k = len(self._tile_slices)
        t = self._tile
        start = self._np
        sl = slice(start, start + t)
        devs = jax.local_devices()
        d = devs[k % len(devs)]
        self._tile_slices.append(sl)
        self._tile_devs.append(d)
        # reuse an existing per-device table-bank copy when one lives on
        # this device already; first landing on a new device pays one put
        for i, dd in enumerate(self._tile_devs[:-1]):
            if dd == d:
                self._tables_t.append(self._tables_t[i])
                break
        else:
            self._tables_t.append(jax.device_put(self._tables_t[0], d))
        # grow the host-side per-slot arrays by one tile of placeholder
        # rows (first tile's pattern; admissions overwrite per slot)
        self._class_rows0 = np.concatenate(
            [self._class_rows0, self._class_rows0[:t]])
        if self._am_counts0 is not None:
            self._am_counts0 = np.concatenate(
                [self._am_counts0, self._am_counts0[:t]])
            self._am_n0 = np.concatenate([self._am_n0, self._am_n0[:t]])
        for name in ("_thr_h", "_prow_h", "_dens_h"):
            arr = getattr(self, name)
            setattr(self, name, np.concatenate([arr, arr[:t]]))
        self._filled_h = np.concatenate(
            [self._filled_h, np.zeros((t,), np.int64)])
        self._fidx_h = np.concatenate(
            [self._fidx_h, np.zeros((t,), np.int64)])
        if self._masked:
            self._cmask_h = np.concatenate(
                [self._cmask_h,
                 np.ones((t, self._cfg.channels), np.uint8)])
        self._np += t
        self._n = self._np
        for lst in (self._thresholds_t, self._param_owner_t,
                    self._density_t):
            lst.append(None)  # filled by _reput_registers just below
        if self._masked:
            self._cmask_t.append(None)  # likewise
        self._reput_registers(k)
        self._state_t.append(self._zero_state(sl, d))
        self._stage_t.append({})
        self._stage_busy.append({})
        self._dirty_t.append(True)
        self._free.append(set(range(start, start + t)))
        self._ragged_buf = None  # parent scatter buffer is capacity-shaped
        self._push_buf = None
        self._stats["spills"] += 1
        if self._exec:
            self._warm_tile(k)
        return k

    def _warm_tile(self, k: int) -> None:
        """Ensure tile ``k``'s device holds the step/adapt/slot
        executables; compiles only what ``warmup`` did not already cover
        (nothing, when warmup ran — spill stays recompile-free)."""
        d = self._tile_devs[k]
        t = self._tile
        for b in self._buckets:
            if (d, t, b) not in self._exec:
                self._exec[(d, t, b)] = self._step.lower(
                    *self._step_avals(k, b, dev=d)).compile()
        if self._am_counts0 is not None and (d, t) not in self._adapt_exec:
            self._adapt_exec[(d, t)] = self._adapt_step.lower(
                *self._adapt_avals(k, dev=d)).compile()
        if (d, t) not in self._slot_exec:
            avals = self._slot_avals(d)
            self._slot_exec[(d, t)] = self._slot_write_jit.lower(
                *avals).compile()
            self._read_exec[(d, t)] = self._slot_read_jit.lower(
                *avals[:2]).compile()

    def _drop_last_tile(self) -> None:
        k = len(self._tile_slices) - 1
        sl = self._tile_slices[k]
        if any(slot in self._slot_sid for slot in range(sl.start, sl.stop)):
            raise RuntimeError("dropping a tile with live sessions")
        for lst in (self._tile_slices, self._tile_devs, self._state_t,
                    self._tables_t, self._thresholds_t, self._param_owner_t,
                    self._density_t, self._stage_t, self._stage_busy,
                    self._dirty_t, self._free):
            lst.pop()
        if self._masked:
            self._cmask_t.pop()
        t = self._tile
        self._np -= t
        self._n = self._np
        for name in ("_filled_h", "_fidx_h", "_thr_h", "_prow_h",
                     "_dens_h"):
            setattr(self, name, getattr(self, name)[:self._np].copy())
        if self._masked:
            self._cmask_h = self._cmask_h[:self._np].copy()
        self._class_rows0 = self._class_rows0[:self._np].copy()
        if self._am_counts0 is not None:
            self._am_counts0 = self._am_counts0[:self._np].copy()
            self._am_n0 = self._am_n0[:self._np].copy()
        self._ragged_buf = None
        self._push_buf = None

    # -- admission / eviction -----------------------------------------------

    def _logged(self, kind: str, payload) -> None:
        self._log.append((self._op_id, kind, payload))
        self._op_id += 1

    def _take_slot(self) -> int:
        """Claim the lowest free slot, spilling a tile when none is free;
        raises CapacityError at max_tiles."""
        for free in self._free:
            if free:
                slot = min(free)
                free.discard(slot)
                return slot
        k = self._spill_tile()
        slot = min(self._free[k])
        self._free[k].discard(slot)
        return slot

    def _place(self, pid: Hashable,
               snapshot: SessionSnapshot | None) -> int:
        slot = self._take_slot()
        sid = self._next_sid
        self._next_sid += 1
        self._write_slot(slot, pid, snapshot)
        self._sid_slot[sid] = slot
        self._slot_sid[slot] = sid
        self._sid_pid[sid] = pid
        self._stats["admitted"] += 1
        return sid

    def _check_admission(self, pid: Hashable,
                         snapshot: SessionSnapshot | None) -> None:
        if pid not in self._pid_of:
            raise KeyError(f"unknown patient id {pid!r}")
        if snapshot is not None and snapshot.patient_id is not None \
                and snapshot.patient_id != pid:
            raise ValueError(
                f"snapshot belongs to patient {snapshot.patient_id!r}, "
                f"admission names {pid!r}")

    def admit(self, patient_id: Hashable, *,
              snapshot: SessionSnapshot | None = None) -> int:
        """Admit one session (fresh, or resuming from an eviction
        ``SessionSnapshot``) into the lowest free slot; returns its session
        id.  Spills a new tile when full; raises :class:`CapacityError` at
        ``max_tiles`` — use :meth:`offer` for queue/shed semantics."""
        self._check_admission(patient_id, snapshot)
        self._logged("admit", (patient_id, snapshot))
        return self._place(patient_id, snapshot)

    def offer(self, patient_id: Hashable, *,
              snapshot: SessionSnapshot | None = None
              ) -> tuple[str, int | None]:
        """Backpressured admission: ``("admitted", sid)`` when a slot (or a
        spill) is available, ``("queued", None)`` when full but the bounded
        queue has room (drained oldest-first by evictions), and
        ``("shed", None)`` beyond that — the explicit overload decision."""
        self._check_admission(patient_id, snapshot)
        if snapshot is not None and snapshot.patient_id is None:
            # queued snapshots must carry their patient for ckpt round-trips
            snapshot = SessionSnapshot(**{
                **snapshot.__dict__, "patient_id": patient_id})
        self._logged("offer", (patient_id, snapshot))
        if self._queue or self.free_slots == 0 and \
                len(self._tile_slices) >= self._max_tiles:
            if len(self._queue) >= self._queue_limit:
                self._stats["shed"] += 1
                return ("shed", None)
            self._queue.append((patient_id, snapshot))
            self._stats["queued"] += 1
            return ("queued", None)
        return ("admitted", self._place(patient_id, snapshot))

    def evict(self, session_ids: Sequence[int], *,
              with_state: bool = True
              ) -> dict[int, SessionSnapshot | None]:
        """Evict sessions, returning ``{sid: SessionSnapshot}`` (``None``
        values under ``with_state=False`` — a drop with no reconnect
        intent).  Slots return to the free map without touching device
        state (free slots are masked cycles) and queued admissions drain
        into them oldest-first."""
        sids = [int(s) for s in session_ids]
        for sid in sids:
            if sid not in self._sid_slot:
                raise KeyError(f"unknown session id {sid}")
        self._logged("evict", (tuple(sids), with_state))
        out: dict[int, SessionSnapshot | None] = {}
        for sid in sids:
            slot = self._sid_slot[sid]
            out[sid] = self._snapshot_slot(slot) if with_state else None
            self._free[slot // self._tile].add(slot)
            del self._sid_slot[sid]
            del self._slot_sid[slot]
            del self._sid_pid[sid]
            self._stats["evicted"] += 1
        self._drain_queue()
        return out

    def _drain_queue(self) -> None:
        while self._queue:
            try:
                slot = self._take_slot()
            except CapacityError:
                return
            pid, snap = self._queue.popleft()
            sid = self._next_sid
            self._next_sid += 1
            self._write_slot(slot, pid, snap)
            self._sid_slot[sid] = slot
            self._slot_sid[slot] = sid
            self._sid_pid[sid] = pid
            self._stats["admitted"] += 1

    def compact(self) -> int:
        """Defragment: migrate the trailing tile's sessions into earlier
        free slots (snapshot out, slot-write in) and drop trailing tiles
        that empty out, shrinking provisioned capacity.  Returns the
        number of tiles dropped.  A tile is only drained when the earlier
        tiles can absorb ALL its sessions."""
        self._logged("compact", ())
        dropped = 0
        while len(self._tile_slices) > 1:
            k = len(self._tile_slices) - 1
            sl = self._tile_slices[k]
            live = sorted(s for s in range(sl.start, sl.stop)
                          if s in self._slot_sid)
            if len(live) > sum(len(self._free[j]) for j in range(k)):
                break
            for slot in live:
                sid = self._slot_sid[slot]
                snap = self._snapshot_slot(slot)
                del self._slot_sid[slot]
                new_slot = self._take_slot()  # earlier tiles have room
                self._write_slot(new_slot, self._sid_pid[sid], snap)
                self._sid_slot[sid] = new_slot
                self._slot_sid[new_slot] = sid
            self._drop_last_tile()
            dropped += 1
            self._stats["compactions"] += 1
        return dropped

    # -- traffic ------------------------------------------------------------

    def push_sessions_raw(self, chunks: Mapping[int, np.ndarray]
                          ) -> tuple[list[FleetRound], dict[int, int]]:
        """Advance the sessions named in ``chunks`` (``{sid: (t, channels)
        uint8 codes}``, lengths may differ; everyone else idles this
        round).  Returns the raw device rounds plus the ``{sid: slot}``
        routing captured at push time; ``push_sessions`` is the
        materializing wrapper."""
        ch = self._cfg.channels
        lengths = np.zeros((self._np,), np.int64)
        arrs: dict[int, np.ndarray] = {}
        t_max = 0
        for sid, codes in chunks.items():
            slot = self._sid_slot.get(int(sid))
            if slot is None:
                raise KeyError(f"unknown session id {sid}")
            a = np.asarray(codes, np.uint8)
            if a.size == 0:
                a = a.reshape(0, ch)
            if a.ndim != 2 or a.shape[1] != ch:
                raise ValueError(
                    f"session {sid}: chunk must be (t, {ch}), "
                    f"got {a.shape}")
            arrs[slot] = a
            lengths[slot] = a.shape[0]
            t_max = max(t_max, a.shape[0])
        self._logged("push", {int(s): arrs[self._sid_slot[int(s)]].copy()
                              for s in chunks})
        mapping = {int(sid): self._sid_slot[int(sid)] for sid in chunks}
        if t_max == 0:
            return [], mapping
        if self._push_buf is None or self._push_buf.shape[0] < self._np \
                or self._push_buf.shape[1] < t_max:
            cap = max(t_max, self._buckets[-1],
                      0 if self._push_buf is None
                      else 2 * self._push_buf.shape[1])
            self._push_buf = np.zeros((self._np, cap, ch), np.uint8)
        big = self._push_buf
        for slot, a in arrs.items():
            big[slot, :a.shape[0]] = a  # stale bytes past t are masked
        return self._rounds(big, lengths), mapping

    def push_sessions(self, chunks: Mapping[int, np.ndarray]
                      ) -> dict[int, list[FrameDecision]]:
        """``push_sessions_raw`` + decision materialization: returns
        ``{sid: [FrameDecision]}`` for every pushed session (empty list
        when its chunk completed no frame)."""
        rounds, mapping = self.push_sessions_raw(chunks)
        decs = self.collect_decisions(rounds)
        return {sid: decs[slot] for sid, slot in mapping.items()}

    def adapt(self, labels: Mapping[int, int], *,  # type: ignore[override]
              margin: float = 0.0) -> dict[int, bool]:
        """Feedback for live sessions: ``{sid: true label of its last
        emitted frame}``.  Under overload (queued admissions) the fleet is
        in decision-only degraded mode and the whole call is SHED — every
        verdict False, counted in ``stats["adapt_shed"]`` — so adaptation
        never competes with decision latency while the queue drains."""
        labels = {int(s): int(v) for s, v in labels.items()}
        for sid in labels:
            if sid not in self._sid_slot:
                raise KeyError(f"unknown session id {sid}")
        self._logged("adapt", (dict(labels), float(margin)))
        if self._queue:
            self._stats["adapt_shed"] += 1
            return {sid: False for sid in labels}
        full = np.full((self._n,), -1, np.int64)
        for sid, lab in labels.items():
            full[self._sid_slot[sid]] = lab
        applied = super().adapt(full, margin=margin)
        return {sid: bool(applied[self._sid_slot[sid]]) for sid in labels}

    # -- warmup / AOT -------------------------------------------------------

    def warmup(self, *, aot: aot_mod.AOTArtifact | None = None,
               buckets: Sequence[int] | None = None) -> dict[str, int]:
        """Parent warmup plus the elastic extras: the step/adapt
        executables for EVERY local device at the tile shape (a spilled
        tile round-robins onto any of them and must land warm) and the
        slot-write/slot-read surgery programs.  After this, admit / evict
        / spill / compact / push are all recompile-free."""
        stats = super().warmup(aot=aot, buckets=buckets)
        t = self._tile
        for d in jax.local_devices():
            for b in buckets or self._buckets:
                if (d, t, b) in self._exec:
                    continue
                compiled = None
                if aot is not None and d == jax.local_devices()[0]:
                    compiled = aot.compile(self._aot_name("step", t, b),
                                           *self._step_avals(0, b, dev=None))
                if compiled is None:
                    compiled = self._step.lower(
                        *self._step_avals(0, b, dev=d)).compile()
                    stats["compiled"] += 1
                else:
                    stats["loaded"] += 1
                self._exec[(d, t, b)] = compiled
            if self._am_counts0 is not None and (d, t) not in \
                    self._adapt_exec:
                self._adapt_exec[(d, t)] = self._adapt_step.lower(
                    *self._adapt_avals(0, dev=d)).compile()
                stats["compiled"] += 1
            if (d, t) not in self._slot_exec:
                avals = self._slot_avals(d)
                self._slot_exec[(d, t)] = self._slot_write_jit.lower(
                    *avals).compile()
                self._read_exec[(d, t)] = self._slot_read_jit.lower(
                    *avals[:2]).compile()
                stats["compiled"] += 2
        return stats

    # -- replay recovery ----------------------------------------------------

    def events_since(self, op_id: int) -> list[tuple]:
        """The replay-ring suffix at or after ``op_id`` (a checkpoint's
        recorded cursor).  Raises when the bounded ring has already
        dropped events from that window — checkpoint more often or raise
        ``log_rounds``."""
        events = [e for e in self._log if e[0] >= op_id]
        if events and events[0][0] != op_id and \
                (not self._log or self._log[0][0] > op_id):
            raise ValueError(
                f"replay ring starts at op {self._log[0][0]}, checkpoint "
                f"cursor is {op_id}: events were dropped (log_rounds="
                f"{self._log.maxlen})")
        return events

    def replay(self, events: Sequence[tuple]) -> dict[int, object]:
        """Re-apply a contiguous event suffix (``events_since`` of the
        surviving fleet, or a mirrored ring) onto a just-restored fleet.
        Every mutating op re-executes through the public API — and
        re-logs, so the restored fleet's ring keeps covering future
        crashes.  Returns ``{op_id: result}`` (push decisions, admit sids,
        offer verdicts, adapt verdict maps); a restarted worker's push
        results are bit-exact with the uninterrupted run's."""
        results: dict[int, object] = {}
        for op, kind, payload in events:
            if op != self._op_id:
                raise ValueError(
                    f"replay gap: event {op} arrived while the fleet "
                    f"expects {self._op_id} (non-contiguous suffix)")
            if kind == "push":
                results[op] = self.push_sessions(payload)
            elif kind == "admit":
                pid, snap = payload
                results[op] = self.admit(pid, snapshot=snap)
            elif kind == "offer":
                pid, snap = payload
                results[op] = self.offer(pid, snapshot=snap)
            elif kind == "evict":
                sids, with_state = payload
                results[op] = self.evict(sids, with_state=with_state)
            elif kind == "adapt":
                labels, margin = payload
                results[op] = self.adapt(labels, margin=margin)
            elif kind == "compact":
                results[op] = self.compact()
            else:  # pragma: no cover - ring holds only the kinds above
                raise ValueError(f"unknown replay event kind {kind!r}")
        return results

    # -- durability ---------------------------------------------------------

    @staticmethod
    def _tile_key(k: int) -> str:
        return f"tile_{k:02d}"

    def _meta(self) -> dict:
        return {
            "kind": "elastic_fleet",
            "tile": self._tile,
            "dim": self._cfg.dim,
            "window": self._cfg.window,
            "n_classes": self._cfg.n_classes,
            "variant": self._cfg.variant,
            "bank": self._bank_fingerprint(),
        }

    def _bank_fingerprint(self) -> str:
        """PATIENT-level bank digest: unlike the parent's per-slot version
        this is invariant to which sessions currently occupy which slots,
        so checkpoints stay valid across admissions/evictions/spills as
        long as the trained per-patient bank is the same."""
        h = hashlib.sha256()
        operands = [self._tables_t[0], self._pat_prow, self._pat_thr,
                    self._pat_dens, self._pat_rows]
        if self._pat_am_counts is not None:
            operands += [self._pat_am_counts, self._pat_am_n]
        for a in operands:
            arr = np.ascontiguousarray(np.asarray(a))
            h.update(str((arr.dtype.str, arr.shape)).encode())
            h.update(arr.tobytes())
        return h.hexdigest()[:16]

    def _lifecycle_meta(self) -> dict:
        out = {
            "n_tiles": len(self._tile_slices),
            "sessions": [[sid, slot, json.dumps(self._sid_pid[sid])]
                         for sid, slot in sorted(self._sid_slot.items())],
            "next_sid": self._next_sid,
            "op_id": self._op_id,
            "queue": [[json.dumps(pid),
                       None if snap is None
                       else base64.b64encode(snap.to_bytes()).decode()]
                      for pid, snap in self._queue],
            "stats": dict(self._stats),
        }
        if self._masked:
            out["channel_mask"] = {
                "shape": [self._np, self._cfg.channels],
                "hex": self._cmask_h[:self._np].tobytes().hex(),
            }
        return out

    def save(self, root: str, step: int | None = None,
             aot_dir: str | None = None) -> str:
        """Incremental per-tile checkpoint: tiles untouched since the last
        ``save`` are hard-linked from the previous step's files instead of
        re-serialized (``ckpt.save(..., link_from=...)``); the session
        table, admission queue (snapshots and all) and the replay cursor
        ride in the manifest meta.  ``restore`` + ``replay`` of the
        post-cursor events is the crash-recovery contract."""
        if step is None:
            latest = ckpt.latest_step(root)
            step = 0 if latest is None else latest + 1
        aot_entry = None
        if aot_dir is not None:
            self.save_aot(aot_dir)
            aot_entry = {"path": aot_dir, "key": aot_mod.artifact_key()}
        tree = {self._tile_key(k): st
                for k, st in enumerate(self._state_t)}
        link_from: dict[str, str] = {}
        prev = ckpt.latest_step(root)
        if prev is not None and prev < step:
            try:
                prev_files = ckpt.leaf_files(root, prev)
            except (OSError, json.JSONDecodeError):
                prev_files = {}
            for k in range(len(self._state_t)):
                if self._dirty_t[k]:
                    continue
                prefix = self._tile_key(k) + "/"
                link_from.update({key: path
                                  for key, path in prev_files.items()
                                  if key.startswith(prefix)})
        meta = dict(self._meta())
        meta["lifecycle"] = self._lifecycle_meta()
        path = ckpt.save(root, step, tree, meta=meta, aot=aot_entry,
                         link_from=link_from)
        self._dirty_t = [False] * len(self._state_t)
        return path

    def restore(self, root: str, step: int | None = None) -> int:
        """Restore a ``save``d elastic fleet into THIS fleet (same patient
        bank and tile size; the tile COUNT adapts — the restoring fleet
        spills or drops tiles to match the checkpoint).  Live sessions,
        the queue and the replay cursor come back exactly; follow with
        ``replay(events)`` to reproduce post-checkpoint traffic."""
        if step is None:
            step = ckpt.latest_step(root)
            if step is None:
                raise FileNotFoundError(
                    f"no fleet checkpoint under {root!r}")
        with open(os.path.join(root, f"step_{step:08d}",
                               "manifest.json")) as f:
            meta = json.load(f).get("meta", {})
        want = self._meta()
        bad = {k: (meta.get(k), v) for k, v in want.items()
               if meta.get(k) != v}
        if bad:
            raise ValueError(
                f"checkpoint does not match this fleet: {bad} "
                "(saved, expected)")
        life = meta.get("lifecycle")
        if life is None:
            raise ValueError(
                "checkpoint lacks lifecycle meta (saved by a non-elastic "
                "fleet?)")  # unreachable after the kind check, belt+braces
        # adapt provisioned capacity to the checkpoint's tile count
        self._sid_slot.clear()
        self._slot_sid.clear()
        self._sid_pid.clear()
        self._queue.clear()
        self._log.clear()
        n_tiles = int(life["n_tiles"])
        while len(self._tile_slices) < n_tiles:
            self._spill_tile()
        while len(self._tile_slices) > n_tiles:
            self._drop_last_tile()
        like = {self._tile_key(k): self._state_t[k]
                for k in range(n_tiles)}
        shardings = {
            self._tile_key(k): jax.tree.map(
                lambda _, d=self._tile_devs[k]:
                    jax.sharding.SingleDeviceSharding(d),
                self._state_t[k])
            for k in range(n_tiles)}
        restored = ckpt.restore(root, step, like=like, shardings=shardings)
        for k in range(n_tiles):
            self._state_t[k] = restored[self._tile_key(k)]
        filled = np.concatenate(
            [np.asarray(restored[self._tile_key(k)].filled)
             for k in range(n_tiles)])
        fidx = np.concatenate(
            [np.asarray(restored[self._tile_key(k)].frame_index)
             for k in range(n_tiles)])
        self._filled_h = filled.astype(np.int64)
        self._fidx_h = fidx.astype(np.int64)
        # session table + per-slot operand registers
        self._free = [set(range(sl.start, sl.stop))
                      for sl in self._tile_slices]
        self._thr_h[:] = self._pat_thr[0]
        self._prow_h[:] = self._pat_prow[0]
        self._dens_h[:] = self._pat_dens[0]
        for sid, slot, pid_json in life["sessions"]:
            pid = json.loads(pid_json)
            if pid not in self._pid_of:
                raise ValueError(
                    f"checkpointed session {sid} belongs to unknown "
                    f"patient {pid!r}")
            sid, slot = int(sid), int(slot)
            self._free[slot // self._tile].discard(slot)
            self._sid_slot[sid] = slot
            self._slot_sid[slot] = sid
            self._sid_pid[sid] = pid
            p = self._pid_of[pid]
            self._thr_h[slot] = self._pat_thr[p]
            self._prow_h[slot] = self._pat_prow[p]
            self._dens_h[slot] = self._pat_dens[p]
        if self._masked:
            self._cmask_h[:] = 1
            cm = life.get("channel_mask")
            if cm is not None:
                n, c = (int(v) for v in cm["shape"])
                if (n, c) != (self._np, self._cfg.channels):
                    raise ValueError(
                        f"checkpoint channel_mask is ({n}, {c}); this "
                        f"fleet provisions ({self._np}, "
                        f"{self._cfg.channels})")
                self._cmask_h[:] = np.frombuffer(
                    bytes.fromhex(cm["hex"]), np.uint8).reshape(n, c)
        for k in range(n_tiles):
            self._reput_registers(k)
        for pid_json, b64snap in life["queue"]:
            snap = (None if b64snap is None
                    else SessionSnapshot.from_bytes(
                        base64.b64decode(b64snap)))
            self._queue.append((json.loads(pid_json), snap))
        self._next_sid = int(life["next_sid"])
        self._op_id = int(life["op_id"])
        self._stats.update({k: int(v)
                            for k, v in life.get("stats", {}).items()})
        self._dirty_t = [True] * n_tiles
        return step

    @classmethod
    def from_checkpoint(
        cls,
        pipelines: Mapping[Hashable, HDCPipeline],
        root: str,
        *,
        step: int | None = None,
        aot_dir: str | None = None,
        warm: bool = True,
        **fleet_kwargs,
    ) -> "ElasticFleet":
        """Worker-restart path: build an elastic fleet, warm it (from the
        checkpoint's recorded AOT artifact when valid), and restore the
        checkpointed lifecycle state.  The caller then ``replay``s the
        surviving event suffix to catch up to the crash point."""
        fleet = cls(pipelines, **fleet_kwargs)
        if step is None:
            step = ckpt.latest_step(root)
            if step is None:
                raise FileNotFoundError(
                    f"no fleet checkpoint under {root!r}")
        with open(os.path.join(root, f"step_{step:08d}",
                               "manifest.json")) as f:
            manifest = json.load(f)
        art = None
        path = aot_dir
        if path is None:
            entry = manifest.get("aot")
            if entry is not None:
                saved_key = entry.get("key")
                bad = aot_mod.stale_fields(saved_key or {},
                                           aot_mod.artifact_key())
                if bad:
                    warnings.warn(
                        f"checkpoint AOT artifact is stale ({bad}); "
                        "warming via JIT", stacklevel=2)
                else:
                    path = entry.get("path")
                    if path is not None and not os.path.isabs(path):
                        path = os.path.join(root, path)
        if path is not None:
            art = aot_mod.load_artifact(path)
        if warm:
            fleet.warmup(aot=art)
        fleet.restore(root, step)
        return fleet

    @classmethod
    def from_artifact(cls, *args, **kwargs):  # pragma: no cover - guard
        raise NotImplementedError(
            "ElasticFleet restores via from_checkpoint(pipelines, root) — "
            "its session set lives in the checkpoint, not a constructor "
            "owners list")
