"""Sharded streaming fleet: thousands of concurrent sessions, one jitted step.

``SeizureSession`` (serve/engine.py) is the single-patient streaming API: a
host-side Python object per stream, one jit dispatch + numpy accumulator
update per push.  That shape cannot serve a population — S streams cost S
Python loops per service interval.  ``StreamingFleet`` vectorizes S concurrent
sessions into ONE device-resident pytree:

* ``counts``       (S, D) int32 — the stacked temporal-accumulator register
                   files (the hardware's D x 8-bit counter bank, one per
                   implant),
* ``filled``       (S,)   int32 — cycles accumulated toward each next frame,
* ``frame_index``  (S,)   int32 — frames emitted so far per stream,

plus per-stream operands gathered once at construction: each session's class
HVs from the stacked (P, C, W) AM bank, its calibrated temporal threshold,
and its row into the stacked unique-params codebook bank.

One jitted ``step(state, chunk, lengths)`` advances ALL sessions, and the
whole step consumes RAW uint8 LBP codes end to end (the CODE domain —
1 byte per (cycle, channel) of host->device traffic): the spatial stage is
a fused gather+bind+bundle out of the pre-bound per-(channel, code)
codebook bank (``dispatch.owner_spatial_codes`` — binding folded into the
table build, the reduction fused into the gather consumer, the
(S, T, C, W) bound expansion never materialized), ``hv.time_pack`` flips
the per-cycle packed HVs into bit planes (one uint32 = 32 cycles of one
bit position), and per-frame-slot temporal counts fall out of popcount
prefix sums — no unpacked (S, block, D) float tensor, no f32 GEMM, no
per-cycle branching.  WHEN each session's window boundaries fall is a pure
function of ``(filled, lengths)``, so the emission schedule is computed
INSIDE the jitted step (at most K = ceil(t_pad / window) completed slots
plus a leftover tail per step); the host ships only the codes and the (S,)
chunk lengths and keeps O(S) mirrors for collection.  ONE
threshold/majority-pack + AM search scores all K frame slots of all
sessions together.  ``lengths`` masks the padding — sessions push chunks
of ANY length, including 0 — and chunk lengths are bucketed/padded to a
fixed set so steady streams compile once per bucket.  With
``backend="pallas"`` the table gather + spatial bundle + bit transpose +
masked-popcount accumulate run as ONE fused VMEM kernel with the CompIM
table bank resident in VMEM (codes in, per-slot counts out).

The step is memory-bound, so the fleet partitions sessions into TILES
(``derive_tile``: sized from the device's reported memory geometry, the
``REPRO_FLEET_TILE`` env var, or the cache-tuned ``DEFAULT_TILE=256`` CPU
fallback) that keep each step's gather/bit-plane temporaries
cache-resident — throughput now grows with S instead of plateauing — and
round-robins tiles over the local devices: per-tile steps dispatch
asynchronously, so multi-device hosts advance tiles concurrently with no
SPMD machinery.  All tiles share one jitted executable per chunk bucket.
Ingest is staged through per-tile pinned uint8 code rings: one vectorized
slice write + one device put per tile per round (``push_codes`` skips even
the ragged-list packing for pre-stacked steady streams).

Online adaptation (core.online): the fleet carries a stacked (S, C, D)
counter-file bank — each session's private, adaptable view of its patient's
AM — plus per-session class-HV rows refreshed from it.  ``adapt(labels)``
applies ONE jitted confidence-gated update across all S sessions (labels
``-1`` mask out sessions with no feedback), bit-exact with a per-session
``SeizureSession.adapt`` loop; the step itself tracks each session's last
emitted frame/scores so the adapt operands never round-trip the host.

Durability: ``save``/``restore`` round-trip the full ``FleetState``
(streaming accumulators + online AM banks) through ``ckpt.checkpoint`` —
atomic-rename directories, elastic re-placement under the current mesh — so
an interrupted fleet resumes mid-stream bit-exactly
(``launch/serve.py --hdc-fleet --ckpt-dir ... --resume``).

Sharding: pass ``mesh=`` to place the fleet on a device mesh — session-axis
state and operands shard along the ``batch`` logical axis (-> ``data`` mesh
axis per runtime/sharding.py), the codebook/AM banks replicate, and the step
stays a single SPMD program.

Decisions are bit-exact with per-patient ``SeizureSession`` loops for all
variants (tested in tests/test_fleet.py); benchmarks/bench_fleet.py measures
the sessions-per-second win over the looped baseline.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
import warnings
from dataclasses import dataclass, replace
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core import hv, online
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.kernels.hdc_fleet import ops as fleet_ops
from repro.reliability import ecc as rel_ecc
from repro.reliability import faults as rel_faults
from repro.reliability.faults import FaultConfig, FaultPlan
from repro.runtime import aot as aot_mod
from repro.runtime import sharding as shd
from repro.serve import dispatch
from repro.serve.engine import FrameDecision

DEFAULT_BUCKETS = (32, 64, 128, 256)
# sessions per device step: the step is memory-bound, and tiles this size
# keep its gather/bit-plane temporaries cache-resident (one 1024-session
# step measures ~1.7x slower than four 256-session steps on CPU).  Session
# capacity is provisioned in WHOLE tiles: a fleet pads up to a multiple of
# ``tile``, so every step runs the ONE tile-shaped executable per chunk
# bucket, a fleet grows within its provisioned capacity without
# recompiling, and step latency is predictable.  Fleets smaller than a
# quarter tile compile exact shapes instead (tile-padding down there
# would dominate their cost, and latency-sensitive few-stream users are
# better served by exact shapes or by SeizureSession directly).
# DEFAULT_TILE is the CPU-cache-tuned fallback; ``derive_tile`` sizes the
# tile from the device's memory geometry when it exposes one.
DEFAULT_TILE = 256


def derive_tile(cfg: HDCConfig, *, max_bucket: int = DEFAULT_BUCKETS[-1],
                device=None) -> int:
    """Sessions-per-tile default for this device and config geometry.

    Resolution order:

    1. ``REPRO_FLEET_TILE`` env var (explicit operator override);
    2. devices that report a memory size (``device.memory_stats()``:
       TPU/GPU ``bytes_limit``): the largest power-of-two tile whose
       per-session working set — streaming state, online AM bank, staged
       chunk codes and the step's bit-plane temporaries — fills at most
       ~1/16 of device memory, clamped to [64, 4096] (the banks, the other
       round-robin tiles and the executables share the rest);
    3. otherwise (CPU hosts expose no memory stats): ``DEFAULT_TILE``, the
       L2/L3-cache-tuned measurement from this repo's benchmark container.

    The ``StreamingFleet(tile=...)`` constructor argument bypasses all of
    this.
    """
    env = os.environ.get("REPRO_FLEET_TILE", "")
    if env:
        try:
            tile = int(env)
        except ValueError:
            raise ValueError(
                f"REPRO_FLEET_TILE={env!r} is not an integer; expected a "
                "power of two in [64, 4096]") from None
        if not (64 <= tile <= 4096 and tile & (tile - 1) == 0):
            raise ValueError(
                f"REPRO_FLEET_TILE={env!r} must be a power of two in "
                "[64, 4096] (the range derive_tile itself produces); use "
                "StreamingFleet(tile=...) for out-of-range experiments")
        return tile
    if device is None:
        device = jax.local_devices()[0]
    try:
        stats = device.memory_stats() or {}
    except Exception:  # backends without memory introspection
        stats = {}
    limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
    if not limit:
        return DEFAULT_TILE
    per_session = (
        cfg.dim * 4 * (1 + cfg.n_classes)          # counts + online AM bank
        + cfg.n_classes * cfg.words * 4            # class-HV rows
        + max_bucket * cfg.channels                # staged uint8 codes
        + 8 * max_bucket * cfg.words               # bit-plane temporaries
    )
    budget = int(limit) // 16
    tile = max(64, min(4096, budget // max(per_session, 1)))
    return 1 << (tile.bit_length() - 1)            # floor to a power of two


@dataclass(frozen=True)
class FleetState:
    """Device-resident state of all S sessions (a pytree of stacked leaves).

    The first block is the streaming state; the second is the online
    continual-learning state — per-session counter-file AM banks, the class
    rows re-thresholded from them, and the last emitted frame's operands
    (what ``adapt`` consumes).  Checkpointing the whole dataclass captures a
    fleet mid-stream."""

    counts: jax.Array  # (S, D) int32 temporal accumulators
    filled: jax.Array  # (S,) int32 cycles toward each next frame
    frame_index: jax.Array  # (S,) int32 frames emitted so far
    class_rows: jax.Array  # (S, C, W) uint32 per-session (adaptive) AM rows
    am_counts: jax.Array  # (S, C, D) int32 online counter-file bank
    am_n: jax.Array  # (S, C) int32 frames bundled per class
    last_frame: jax.Array  # (S, W) uint32 last emitted frame HV
    last_scores: jax.Array  # (S, C) int32 last emitted frame's AM scores
    has_frame: jax.Array  # (S,) int32 1 once a session has emitted


@dataclass(frozen=True)
class FleetOut:
    """Raw step outputs: one row per potential frame slot (K per step); the
    host-side schedule knows which (session, slot) pairs really emitted."""

    frames: jax.Array  # (S, K, W) uint32 packed frame HVs
    scores: jax.Array  # (S, K, C) int32 AM scores


@dataclass(frozen=True)
class FleetRound:
    """One step's raw results plus the host-side schedule needed to read
    them: ``tiles`` holds each session tile's ``FleetOut`` as DEVICE arrays
    (no forced sync), and ``(session, slot)`` pairs with ``slot <
    n_emit[session]`` are real emissions with frame index
    ``frame_base[session] + slot``."""

    tiles: tuple[FleetOut, ...]  # per-tile (tile_s, K, ...) device outputs
    n_emit: np.ndarray      # (S,) frames emitted this round
    frame_base: np.ndarray  # (S,) frame index of each session's slot 0


for _cls, _fields in (
    (FleetState, ["counts", "filled", "frame_index", "class_rows",
                  "am_counts", "am_n", "last_frame", "last_scores",
                  "has_frame"]),
    (FleetOut, ["frames", "scores"]),
):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields, meta_fields=[])
    # the same pytrees cross the jax.export boundary in the AOT deploy
    # artifacts (runtime/aot.py); no-op when export serialization is absent
    aot_mod.register_pytree_serialization(
        _cls, f"repro.serve.fleet.{_cls.__name__}")

# logical sharding axes per FleetState leaf: session state splits along the
# batch axis, everything trailing replicates (used by the step's constraints
# and by the elastic checkpoint restore)
_STATE_AXES = {
    "counts": ("batch", None),
    "filled": ("batch",),
    "frame_index": ("batch",),
    "class_rows": ("batch", None, None),
    "am_counts": ("batch", None, None),
    "am_n": ("batch", None),
    "last_frame": ("batch", None),
    "last_scores": ("batch", None),
    "has_frame": ("batch",),
}


def _fleet_step(
    state: FleetState,
    tables: jax.Array,
    owner: jax.Array,
    thresholds: jax.Array,
    chunk: jax.Array,
    lengths: jax.Array,
    fault_ber: jax.Array | None = None,
    fault_seed: jax.Array | None = None,
    chan_mask: jax.Array | None = None,
    *,
    cfg: HDCConfig,
    ctx: shd.ShardCtx,
    use_kernel: bool,
    faults: FaultPlan | None = None,
    masked: bool = False,
) -> tuple:
    """Advance all S sessions by one padded chunk batch.

    chunk: (S, t_pad, channels) uint8 RAW LBP codes — the only per-cycle
    payload the host ever ships; lengths: (S,) int32 valid cycles per
    session.  The emission schedule is computed HERE from
    ``(state.filled, lengths)`` — the host ships no masks — and the whole
    datapath stays in the code/packed/bit-plane domain (kernels/hdc_fleet):
    the spatial stage is a fused gather+bind+bundle out of the pre-bound
    codebook bank (``dispatch.owner_spatial_codes``, never materializing
    the (S, T, C, W) bound expansion), temporal counts are popcount prefix
    sums at frame-slot boundaries, or ONE fused VMEM kernel does all of it
    when ``use_kernel``.  Frames score against ``state.class_rows``
    (refreshed by ``adapt``), and the step records each emitting session's
    last frame HV + scores — the operands a later ``adapt`` call consumes,
    captured inside the same jitted program.

    Fault injection (repro.reliability): with a static ``faults`` plan the
    step additionally takes the traced ``fault_ber`` (3,) BER vector and
    scalar ``fault_seed``, derives per-component PRNG keys INSIDE the jit,
    and corrupts the memory READS of the enabled targets — the codebook
    bank (before the gather / via the fused kernel's ``tables_xor`` hook),
    the AM class rows (optionally through the ECC word codec, whose
    corrected rows then score), and the carried temporal accumulators (low
    counter bits only).  Storage is never mutated.  The step then returns
    a third output: the (S, 3) [corrected, detected, uncorrectable] ECC
    word counts of this read (zeros when no ECC scheme is configured).
    With ``faults=None`` (the default) none of this is traced and the step
    is the unmodified two-output program; with faults enabled but BER 0
    every mask is all-zero and the outputs are bit-exact with it.

    Channel masking (repro.reliability.channels): with the static
    ``masked`` flag the step additionally takes the traced ``chan_mask``
    (S, channels) uint8 operand — 1 = live, 0 = quarantined electrode —
    and the spatial stage drops masked channels from the bundle with
    renormalized count denominators (dispatch.owner_spatial_codes /
    the fused kernel's mask operand).  The mask is DATA: walking masks
    never recompiles, and an all-live mask is bit-exact with the
    unmasked step.  ``masked=False`` (the default) keeps the jaxpr
    byte-identical to the mask-free program.
    """
    s, t_pad, _ = chunk.shape
    counts_in = state.counts
    tables_xor = None
    if not masked:
        chan_mask = None
    if faults is not None:
        k_tab, k_am, k_cnt = rel_faults.component_keys(fault_seed)
        if faults.tables:
            tables_xor = rel_faults.xor_mask(tables, k_tab, fault_ber[0],
                                             mode=faults.mode)
        if faults.counts:
            counts_in = rel_faults.flip_counts(
                counts_in, k_cnt, fault_ber[2],
                bits=rel_faults.counter_bits(faults, cfg.window),
                mode=faults.mode)
    if use_kernel:
        # fused kernel: codes in, slot counts out — the table gather,
        # spatial bundle, bit transpose and masked popcount stay in VMEM
        seg = fleet_ops.fleet_counts_fused(tables, owner, chunk,
                                           state.filled, lengths, cfg,
                                           tables_xor=tables_xor,
                                           chan_mask=chan_mask)
    else:
        if tables_xor is not None:
            tables = tables ^ tables_xor
        words = dispatch.owner_spatial_codes(tables, owner, chunk, cfg,
                                             chan_mask)
        seg = fleet_ops.fleet_counts(words, state.filled, lengths, cfg)
    seg = shd.constrain(seg, ("batch", None, None), ctx)  # (S, K+1, D) int32

    n_emit = (state.filled + lengths) // cfg.window  # (S,)
    # the carried accumulator belongs to the FIRST completed frame when the
    # session emits, and to the tail otherwise
    emits = n_emit > 0
    frame_counts = seg[:, :-1].at[:, 0].add(
        jnp.where(emits[:, None], counts_in, 0)
    )
    if cfg.variant == "dense":
        frames = hv.majority_pack(frame_counts, cfg.window, cfg.dim)
    else:
        frames = hv.threshold_pack(frame_counts, thresholds[:, None, None])
    ecc_counts = None
    if faults is None:
        scores = dispatch.owner_am_scores(frames, state.class_rows[:, None],
                                          cfg)
    else:
        rows = state.class_rows
        check = (rel_ecc.encode(rows, faults.ecc)
                 if faults.ecc != "none" else None)
        if faults.am:
            k_am_d, k_am_c = jax.random.split(k_am)
            rows = rel_faults.flip_words(rows, k_am_d, fault_ber[1],
                                         mode=faults.mode)
            if check is not None:
                check = rel_faults.flip_words(
                    check, k_am_c, fault_ber[1],
                    bits=rel_ecc.n_check_bits(faults.ecc), mode=faults.mode)
        if check is not None:
            scores, ecc_counts = dispatch.owner_am_scores_protected(
                frames, rows, check, cfg, faults.ecc)
        else:
            scores = dispatch.owner_am_scores(frames, rows[:, None], cfg)
        if ecc_counts is None:
            ecc_counts = jnp.zeros((s, 3), jnp.int32)
        ecc_counts = shd.constrain(ecc_counts, ("batch", None), ctx)
    new_counts = seg[:, -1] + jnp.where(emits[:, None], 0, counts_in)
    # capture each emitting session's LAST completed frame for adapt
    sidx = jnp.arange(s, dtype=jnp.int32)
    last_slot = jnp.maximum(n_emit - 1, 0)
    new_state = replace(
        state,
        counts=shd.constrain(new_counts, _STATE_AXES["counts"], ctx),
        filled=shd.constrain(
            state.filled + lengths - n_emit * cfg.window,
            _STATE_AXES["filled"], ctx,
        ),
        frame_index=shd.constrain(
            state.frame_index + n_emit, _STATE_AXES["frame_index"], ctx
        ),
        last_frame=shd.constrain(
            jnp.where(emits[:, None], frames[sidx, last_slot],
                      state.last_frame),
            _STATE_AXES["last_frame"], ctx,
        ),
        last_scores=shd.constrain(
            # int32 pinned: the popcount scores promote to int64 under
            # JAX_ENABLE_X64, which would drift the carried state dtype
            # (and the jit cache key) after the first step
            jnp.where(emits[:, None], scores[sidx, last_slot],
                      state.last_scores).astype(jnp.int32),
            _STATE_AXES["last_scores"], ctx,
        ),
        has_frame=shd.constrain(
            state.has_frame | emits.astype(jnp.int32),
            _STATE_AXES["has_frame"], ctx,
        ),
    )
    out = FleetOut(frames=frames, scores=scores)
    if faults is None:
        return new_state, out
    return new_state, out, ecc_counts


def _fleet_adapt(
    state: FleetState,
    labels: jax.Array,
    margin: jax.Array,
    density: jax.Array,
    *,
    cfg: HDCConfig,
    ctx: shd.ShardCtx,
) -> tuple[FleetState, jax.Array]:
    """One gated online update for ALL S sessions (core.online).

    labels: (S,) int32 true class of each session's last emitted frame
    (-1 = no feedback); density: (S,) f32 per-patient ``class_density``.
    Sessions whose gate fires get their counter-file rows updated and their
    class rows re-thresholded; everyone else's state passes through
    bit-identically.  Returns (state, applied (S,) bool)."""
    bits = hv.unpack_bits(state.last_frame, cfg.dim)            # (S, D)
    am_state = online.OnlineAMState(counts=state.am_counts, n=state.am_n)
    new_am, applied = online.update(
        am_state, bits, labels, state.last_scores,
        margin=margin, valid=state.has_frame > 0)
    chvs = online.class_hvs_from_state(new_am, cfg, density=density[:, None])
    class_rows = jnp.where(applied[:, None, None], chvs, state.class_rows)
    new_state = replace(
        state,
        am_counts=shd.constrain(new_am.counts, _STATE_AXES["am_counts"], ctx),
        am_n=shd.constrain(new_am.n, _STATE_AXES["am_n"], ctx),
        class_rows=shd.constrain(class_rows, _STATE_AXES["class_rows"], ctx),
    )
    return new_state, applied


class StreamingFleet:
    """S concurrent streaming seizure sessions advanced by one jitted step.

    ``pipelines`` is the patient -> trained-pipeline bank (one shared
    datapath; per-patient calibrated thresholds and codebooks welcome, see
    ``dispatch.datapath_key``).  ``owners[i]`` names the patient session ``i``
    belongs to — any number of sessions per patient.

    ``push(chunks)`` feeds one (t_i, channels) chunk per session (lengths may
    differ; 0 is fine) and returns the completed ``FrameDecision`` lists,
    bit-exact with per-session ``SeizureSession`` loops.  Chunks are padded to
    the smallest configured bucket (longer chunks are split over multiple
    steps), so a steady stream compiles once per bucket — see
    ``compile_count``.  Steady-state serving should prefer ``push_raw``: it
    returns the device-resident ``FleetRound`` results WITHOUT materializing
    per-frame Python objects or forcing a device sync (``push`` is
    ``collect_decisions(push_raw(...))``).  Equal-length pre-stacked streams
    should use ``push_codes`` / ``push_codes_raw`` — the (S, t, channels)
    batch goes straight into the per-tile staging rings with no ragged-list
    packing at all.

    ``backend`` selects the device datapath ("jnp" = pure XLA code-domain
    gather + bit-plane path, "pallas" = fused VMEM kernel with the CompIM
    table bank resident on chip; both bit-exact); defaults to the bank's
    pipeline backend.

    ``adapt(labels)`` personalizes AMs in place: one jitted gated update for
    the whole fleet against each session's last emitted frame (labels of -1
    mask out sessions without feedback), bit-exact with per-session
    ``SeizureSession.adapt`` calls.  ``save``/``restore`` checkpoint the
    full fleet state (streaming + online AM banks) for mid-stream resume.

    ``faults`` (repro.reliability.faults.FaultConfig) turns the fleet into
    a degradation testbench: the jitted step corrupts the configured
    memory reads (codebook bank / AM rows / temporal counters) at the
    configured bit-error rates, optionally decoding AM reads through an
    ECC word codec (``ecc_stats`` accumulates per-session corrected /
    detected / uncorrectable counts).  BER values are traced operands —
    ``set_ber`` sweeps a grid with no recompiles — and ``faults=None``
    (the default) compiles the exact fault-free step, zero overhead.

    ``channel_masking=True`` threads a per-session (S, channels) electrode
    mask through the step as a TRACED operand: ``set_channel_mask``
    quarantines failing channels (the spatial bundle drops them with
    renormalized denominators — see serve/dispatch.py) and walks mask
    grids with zero recompiles; an all-live mask (the initial state) is
    bit-exact with an unmasked fleet.  ``channel_masking=False`` (the
    default) compiles the exact mask-free step, zero overhead.
    """

    def __init__(
        self,
        pipelines: Mapping[Hashable, HDCPipeline],
        owners: Sequence[Hashable],
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        mesh=None,
        backend: str | None = None,
        tile: int | None = None,
        faults: FaultConfig | None = None,
        channel_masking: bool = False,
    ):
        self._cfg = dispatch.validate_bank(pipelines)
        self._faults = faults
        self._plan = None if faults is None else faults.plan()
        self._masked = bool(channel_masking)
        if backend is None:
            backend = next(iter(pipelines.values())).cfg.backend
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self._backend = backend
        if not owners:
            raise ValueError("StreamingFleet needs at least one session")
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self._buckets = tuple(sorted(set(int(b) for b in buckets)))
        pids = list(pipelines)
        pid_index = {pid: i for i, pid in enumerate(pids)}
        for pid in owners:
            if pid not in pid_index:
                raise KeyError(f"unknown patient id {pid!r} in owners")
        pipes = [pipelines[pid] for pid in pids]
        tables, param_rows = dispatch.stack_bound_tables(pipes)
        bank = jnp.stack([p.class_hvs for p in pipes])  # (P, C, W)
        thresholds = np.asarray(
            [p.cfg.temporal_threshold for p in pipes], np.int32
        )
        owner_idx = np.asarray([pid_index[pid] for pid in owners], np.int32)

        self._ctx = shd.make_ctx(mesh)
        self._n = len(owner_idx)
        self._owners = list(owners)
        # session tiles: bound each device step's working set so the
        # memory-bound step stays cache-resident, and round-robin tiles over
        # the local devices (independent async dispatches, so multi-device
        # hosts advance tiles concurrently).  Capacity pads to whole tiles
        # (see DEFAULT_TILE); padded phantom sessions always push
        # zero-length chunks and never emit or adapt.  A mesh replaces
        # tiling with SPMD sharding: one (padded) tile spanning the mesh.
        if tile is None:
            tile = derive_tile(self._cfg, max_bucket=self._buckets[-1])
            if not os.environ.get("REPRO_FLEET_TILE", ""):
                # phantom-capacity guard: capacity pads to WHOLE tiles, so
                # a memory-derived tile (up to 4096 on big accelerators) is
                # also capped at the fleet's own size rounded up to a power
                # of two — provisioning headroom stays < n instead of up to
                # 4095 phantom rows stepped on every push.  Explicit
                # tile=/env overrides are the operator's choice, uncapped.
                tile = min(tile,
                           max(64, 1 << (max(self._n - 1, 1).bit_length())))
        if tile <= 0:
            raise ValueError(f"tile={tile} must be positive")
        if self._n < tile // 4:
            # tile-padding a tiny fleet would dominate its cost: compile an
            # exact shape instead
            self._np = self._n
        else:
            self._np = -(-self._n // tile) * tile
        if self._np > self._n:
            owner_idx = np.concatenate(
                [owner_idx, np.zeros(self._np - self._n, np.int32)])
        if self._ctx.mesh is not None:
            tile = self._np
        self._tile_slices = [slice(i, min(i + tile, self._np))
                             for i in range(0, self._np, tile)]
        if self._ctx.mesh is not None:
            devs: list = [None]
        else:
            devs = jax.local_devices()
        self._tile_devs = [devs[k % len(devs)]
                           for k in range(len(self._tile_slices))]
        # per-tile pinned uint8 code staging rings: each round writes one
        # vectorized slice per tile then ships it with ONE device put — no
        # per-push allocation and no np scatter on the steady path.  Stale
        # bytes past a session's round length are never re-zeroed: the step
        # masks dead cycles via ``lengths`` and the table gather clips.
        # One CONTIGUOUS buffer per (slot, bucket) — allocated lazily on a
        # bucket's first use, so every round's put is the zero-copy aliasing
        # case, never a strided-view copy — and DOUBLE-buffered: a slot is
        # rewritten only after the round that consumed it completed
        # (``_stage_busy``).  On the CPU backend ``jax.device_put`` of a
        # contiguous aligned numpy array is ZERO-COPY — the jitted step
        # reads the ring itself — so an unsynchronized rewrite would race
        # an in-flight async step.
        self._stage_t: list[dict] = [{} for _ in self._tile_slices]
        # per tile: {(slot, bucket): output of the last round that read it}
        self._stage_busy: list[dict] = [{} for _ in self._tile_slices]
        self._stage_phase = 0
        self._ragged_buf: np.ndarray | None = None
        # pre-bound codebook bank (P_unique, C, codes, W): replicated across
        # the mesh, or one copy per device used by the tiles
        if self._ctx.mesh is not None:
            shared = self._put(tables, (None,) * 4)
            self._tables_t = [shared]
        else:
            per_dev = {d: jax.device_put(tables, d) for d in set(devs)}
            self._tables_t = [per_dev[d] for d in self._tile_devs]
        # per-session operand registers, sliced per tile
        thr_all = thresholds[owner_idx]
        prow_all = np.asarray(param_rows)[owner_idx]
        dens_all = np.asarray(
            [p.cfg.class_density for p in pipes], np.float32)[owner_idx]
        self._thresholds_t = self._put_tiles(thr_all, ("batch",))
        self._param_owner_t = self._put_tiles(prow_all, ("batch",))
        self._density_t = self._put_tiles(dens_all, ("batch",))
        # online-adaptation operands: each session starts from its patient's
        # class rows + counter-file am_state (host copies: the jitted step
        # donates its state, so reset() must rebuild fresh device arrays)
        self._class_rows0 = np.asarray(bank)[owner_idx]  # (S, C, W)
        if all(p.am_state is not None for p in pipes):
            self._am_counts0 = np.stack(
                [np.asarray(pipes[i].am_state.counts) for i in owner_idx])
            self._am_n0 = np.stack(
                [np.asarray(pipes[i].am_state.n) for i in owner_idx])
        else:  # bank mixes in externally built pipelines: adapt unavailable
            self._am_counts0 = self._am_n0 = None
        self._state_t = self._zero_states()
        # fault-injection operands: the (3,) BER vector rides as a TRACED
        # per-tile operand (set_ber moves along a BER grid with no
        # recompile) and the per-tile (tile_s, 3) ECC word counters
        # accumulate device-side, OUTSIDE FleetState (checkpoints stay
        # compatible with fault-free fleets)
        if self._plan is not None:
            self._ber_t = [self._put_tile(faults.ber_vector(), (None,), d)
                           for d in self._tile_devs]
            self._ecc_t = self._zero_ecc()
        # channel-fault quarantine operand: a host-mirrored (S_prov, C)
        # uint8 mask (1 = live) with per-tile device copies, rides the step
        # as a TRACED operand like the BER vector (set_channel_mask walks
        # masks with no recompile).  Phantom capacity rows stay all-live.
        if self._masked:
            self._cmask_h = np.ones((self._np, self._cfg.channels), np.uint8)
            self._cmask_t = self._put_tiles(self._cmask_h, ("batch", None))
        # host mirrors of filled/frame_index: the emission schedule runs on
        # device, but the host needs O(S) mirrors to route raw results
        # (which (session, slot) pairs really emitted) without a round-trip
        self._filled_h = np.zeros((self._np,), np.int64)
        self._fidx_h = np.zeros((self._np,), np.int64)
        # per-tile "state changed since last checkpoint" flags: steps and
        # adapts set them, ckpt writers clear them — the incremental
        # checkpoint path (ckpt.save link_from=...) hard-links untouched
        # tiles from the previous step instead of re-serializing them
        self._dirty_t = [True] * len(self._tile_slices)
        self._shapes_seen: set[int] = set()  # buckets JIT-dispatched so far
        # AOT executables (runtime/aot.py): ``warmup`` fills these with
        # pre-compiled step/adapt executables — loaded from a serialized
        # deploy artifact or lowered+compiled here ahead of traffic — keyed
        # by (device, tile sessions, bucket); the hot loops prefer them and
        # fall back to the jitted callables on any signature mismatch
        self._exec: dict[tuple, jax.stages.Compiled] = {}
        self._adapt_exec: dict[tuple, jax.stages.Compiled] = {}
        # faults=None keeps the partial's jaxpr IDENTICAL to the fault-free
        # step — the fault path costs nothing unless a plan is configured
        # (and masked=False likewise keeps the mask-free jaxpr byte-exact)
        self._step = jax.jit(
            functools.partial(_fleet_step, cfg=self._cfg, ctx=self._ctx,
                              use_kernel=self._backend == "pallas",
                              faults=self._plan, masked=self._masked),
            donate_argnums=(0,),
        )
        # NOT donated: several state leaves pass through adapt untouched and
        # XLA cannot alias every same-shaped pair, which trips the
        # donation warning; adapt is rare relative to push, so the one
        # transient copy is the cheaper trade
        self._adapt_step = jax.jit(
            functools.partial(_fleet_adapt, cfg=self._cfg, ctx=self._ctx),
        )

    # -- state management ---------------------------------------------------

    def _put(self, x: jax.Array, axes: tuple) -> jax.Array:
        s = shd.sharding_for(axes, self._ctx, jnp.shape(x))
        return jax.device_put(x, s) if s is not None else jnp.asarray(x)

    def _put_tile(self, x, axes: tuple, dev) -> jax.Array:
        """Place one tile's operand: sharded under a mesh, pinned to the
        tile's device otherwise."""
        if self._ctx.mesh is not None:
            return self._put(jnp.asarray(x), axes)
        return jax.device_put(x, dev)

    def _put_tiles(self, x: np.ndarray, axes: tuple) -> list[jax.Array]:
        return [self._put_tile(x[sl], axes, d)
                for sl, d in zip(self._tile_slices, self._tile_devs)]

    def _zero_state(self, sl: slice, d) -> FleetState:
        """Fresh device state for ONE capacity tile (every session reset to
        its patient's trained bank) — also the template the elastic fleet
        uses to provision a spilled tile."""
        cfg = self._cfg
        c = self._class_rows0.shape[1]
        axes = _STATE_AXES
        s = sl.stop - sl.start
        if self._am_counts0 is not None:
            am_counts, am_n = self._am_counts0[sl], self._am_n0[sl]
        else:
            am_counts = np.zeros((s, c, cfg.dim), np.int32)
            am_n = np.zeros((s, c), np.int32)
        put = self._put_tile
        return FleetState(
            counts=put(np.zeros((s, cfg.dim), np.int32),
                       axes["counts"], d),
            filled=put(np.zeros((s,), np.int32), axes["filled"], d),
            frame_index=put(np.zeros((s,), np.int32),
                            axes["frame_index"], d),
            class_rows=put(self._class_rows0[sl], axes["class_rows"], d),
            am_counts=put(am_counts, axes["am_counts"], d),
            am_n=put(am_n, axes["am_n"], d),
            last_frame=put(np.zeros((s, cfg.words), np.uint32),
                           axes["last_frame"], d),
            last_scores=put(np.zeros((s, c), np.int32),
                            axes["last_scores"], d),
            has_frame=put(np.zeros((s,), np.int32), axes["has_frame"], d),
        )

    def _zero_states(self) -> list[FleetState]:
        return [self._zero_state(sl, d)
                for sl, d in zip(self._tile_slices, self._tile_devs)]

    def _split_state(self, full: FleetState) -> list[FleetState]:
        """Scatter a whole-fleet state (e.g. a restored checkpoint) back
        onto the session tiles and their devices."""
        if self._ctx.mesh is not None:
            return [full]
        return [
            jax.tree.map(lambda x, sl=sl, d=d: jax.device_put(x[sl], d), full)
            for sl, d in zip(self._tile_slices, self._tile_devs)
        ]

    def _zero_ecc(self) -> list[jax.Array]:
        return [self._put_tile(np.zeros((sl.stop - sl.start, 3), np.int32),
                               ("batch", None), d)
                for sl, d in zip(self._tile_slices, self._tile_devs)]

    def reset(self) -> None:
        """Zero all accumulators, fill levels, frame indices and ECC
        counters, and restore every session's AM to its patient's trained
        (pre-adaptation) state."""
        self._state_t = self._zero_states()
        self._filled_h[:] = 0
        self._fidx_h[:] = 0
        self._dirty_t = [True] * len(self._tile_slices)
        if self._plan is not None:
            self._ecc_t = self._zero_ecc()

    @property
    def n_sessions(self) -> int:
        return self._n

    @property
    def n_tiles(self) -> int:
        return len(self._tile_slices)

    @property
    def state(self) -> FleetState:
        """Whole-fleet state view (tiles concatenated; one gather when the
        fleet spans several tiles — cheap relative to how rarely callers
        need it: checkpointing and tests).  Leading dim is the PROVISIONED
        capacity (sessions padded to whole capacity tiles); rows past
        ``n_sessions`` are phantom slots that never emit or adapt."""
        if len(self._state_t) == 1:
            return self._state_t[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                            *self._state_t)

    @property
    def fill_levels(self) -> np.ndarray:
        """(S,) cycles accumulated toward each next (incomplete) frame."""
        return self._filled_h[:self._n].copy()

    @property
    def frame_indices(self) -> np.ndarray:
        """(S,) frames emitted so far per session."""
        return self._fidx_h[:self._n].copy()

    @property
    def fault_config(self) -> FaultConfig | None:
        """The active fault campaign (None = fault-free fleet)."""
        return self._faults

    def set_ber(self, ber: float) -> None:
        """Move every ENABLED fault target to one bit-error rate.

        BER rides as a traced operand of the jitted step, so sweeping a BER
        grid through one fleet never recompiles; which targets / mode / ECC
        scheme are enabled is static (build a new fleet to change those).
        """
        if self._faults is None:
            raise ValueError(
                "fleet was built without faults; pass "
                "StreamingFleet(..., faults=FaultConfig(...)) to enable "
                "fault injection")
        self._faults = self._faults.with_ber(ber)
        vec = self._faults.ber_vector()
        self._ber_t = [self._put_tile(vec, (None,), d)
                       for d in self._tile_devs]

    @property
    def channel_masking(self) -> bool:
        """True when the step carries the channel-mask operand."""
        return self._masked

    @property
    def channel_masks(self) -> np.ndarray:
        """(S, channels) uint8 live-channel masks (1 = live).  All ones —
        including for fleets built without ``channel_masking`` — until
        ``set_channel_mask`` quarantines something."""
        if not self._masked:
            return np.ones((self._n, self._cfg.channels), np.uint8)
        return self._cmask_h[:self._n].copy()

    def set_channel_mask(self, mask, sessions: Sequence[int] | None = None
                         ) -> None:
        """Quarantine / reinstate electrodes: install per-session live-
        channel masks (1 = live, 0 = masked out of the spatial bundle).

        ``mask`` is (S, channels) — or (channels,), broadcast to every
        session — of 0/1 values; ``sessions`` optionally restricts the
        update to those session indices (then ``mask`` is (len(sessions),
        channels) or (channels,)).  The mask rides the jitted step as a
        TRACED operand, so walking a mask grid (the channel-health
        monitor's quarantine/reinstate churn, the degradation benchmark's
        sweep) never recompiles.  Masks persist across ``reset`` — they
        describe electrode health, not stream state — and are carried by
        ``save``/``restore`` checkpoints.
        """
        if not self._masked:
            raise ValueError(
                "fleet was built without channel_masking; pass "
                "StreamingFleet(..., channel_masking=True) to enable "
                "electrode quarantine")
        c = self._cfg.channels
        m = np.asarray(mask)
        idx = (np.arange(self._n) if sessions is None
               else np.asarray(list(sessions), np.int64))
        if sessions is not None and (idx.size == 0 or idx.min() < 0
                                     or idx.max() >= self._n):
            raise ValueError(
                f"sessions must be indices in [0, {self._n})")
        if m.ndim == 1:
            m = np.broadcast_to(m, (idx.size, c))
        if m.shape != (idx.size, c):
            raise ValueError(
                f"mask must be ({idx.size}, {c}) or ({c},), got {m.shape}")
        if not np.isin(m, (0, 1)).all():
            raise ValueError("mask entries must be 0 or 1")
        self._cmask_h[idx] = m.astype(np.uint8)
        self._cmask_t = self._put_tiles(self._cmask_h, ("batch", None))

    @property
    def ecc_stats(self) -> np.ndarray:
        """(S, 3) cumulative per-session ECC word counts since the last
        ``reset``: [corrected, detected, uncorrectable] — ``detected``
        counts every faulty word observed (= corrected + uncorrectable for
        SECDED; parity only detects).  All zeros when no ECC scheme is
        configured (or no faults landed)."""
        if self._plan is None:
            return np.zeros((self._n, 3), np.int64)
        return np.concatenate(
            [np.asarray(x) for x in self._ecc_t]).astype(np.int64)[:self._n]

    @property
    def compile_count(self) -> int:
        """Step executables built or loaded so far (<= buckets x tiles).

        Counts BOTH the jit cache (preferring jit's real cache size, which
        catches accidental recompiles; falling back to the count of distinct
        JIT-dispatched bucket shapes if the private jax API ever disappears)
        AND the AOT executables installed by ``warmup`` — a warmed fleet
        whose pushes never touch the jit cache still reports its real
        executable count, so bucketed compile-count guards hold on the AOT
        path instead of passing vacuously at 0."""
        cache_size = getattr(self._step, "_cache_size", None)
        jit_n = (cache_size() if cache_size is not None
                 else len(self._shapes_seen))
        return jit_n + len(self._exec)

    @property
    def aot_count(self) -> int:
        """Step executables that came from ``warmup`` (artifact-loaded or
        pre-compiled) rather than first-push JIT."""
        return len(self._exec)

    # -- ahead-of-time compilation (runtime/aot.py) ---------------------------

    def _aot_sig(self) -> str:
        """Digest of everything that selects this fleet's step program
        beyond the argument shapes: datapath config, fault plan, channel
        masking, backend, the stacked table-bank geometry and the x64
        regime.  Rides in the artifact entry names so a lookup can never
        hand back an executable compiled for a different program."""
        h = hashlib.sha256()
        h.update(repr(self._cfg).encode())
        h.update(repr(self._plan).encode())
        h.update(str(self._masked).encode())
        h.update(self._backend.encode())
        h.update(str(tuple(jnp.shape(self._tables_t[0]))).encode())
        h.update(str(bool(jax.config.jax_enable_x64)).encode())
        return h.hexdigest()[:10]

    def _aot_name(self, kind: str, tile_s: int, t_pad: int | None = None) -> str:
        base = (f"fleet.{self._cfg.variant}.{self._backend}"
                f"{'.faulted' if self._plan is not None else ''}"
                f"{'.masked' if self._masked else ''}.s{tile_s}")
        mid = f".t{t_pad}" if kind == "step" else ""
        return f"{base}{mid}.{kind}.{self._aot_sig()}"

    def _sds(self, x, dev) -> jax.ShapeDtypeStruct:
        sharding = (None if dev is None
                    else jax.sharding.SingleDeviceSharding(dev))
        return jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype,
                                    sharding=sharding)

    def _step_avals(self, k: int, t_pad: int, dev) -> tuple:
        """Abstract args of tile ``k``'s step at bucket ``t_pad`` (pinned to
        ``dev``; dev=None = portable, for export blobs)."""
        sl = self._tile_slices[k]
        tile_s = sl.stop - sl.start
        avals = (
            jax.tree.map(lambda x: self._sds(x, dev), self._state_t[k]),
            self._sds(self._tables_t[k], dev),
            self._sds(self._param_owner_t[k], dev),
            self._sds(self._thresholds_t[k], dev),
            jax.ShapeDtypeStruct((tile_s, t_pad, self._cfg.channels),
                                 jnp.uint8,
                                 sharding=None if dev is None else
                                 jax.sharding.SingleDeviceSharding(dev)),
            self._sds(np.zeros((tile_s,), np.int32), dev),
        )
        if self._plan is not None:
            avals += (self._sds(np.zeros((3,), np.float32), dev),
                      self._sds(np.int32(0), dev))
        if self._masked:
            avals += (self._sds(
                np.ones((tile_s, self._cfg.channels), np.uint8), dev),)
        return avals

    def _adapt_avals(self, k: int, dev) -> tuple:
        sl = self._tile_slices[k]
        tile_s = sl.stop - sl.start
        return (
            jax.tree.map(lambda x: self._sds(x, dev), self._state_t[k]),
            self._sds(np.zeros((tile_s,), np.int32), dev),
            self._sds(np.float32(0), dev),
            self._sds(np.zeros((tile_s,), np.float32), dev),
        )

    def aot_entries(self, buckets: Sequence[int] | None = None
                    ) -> list[aot_mod.AOTEntry]:
        """The executable set of this fleet, as portable AOT entries: one
        step per (distinct tile shape) x (chunk bucket) — the faulted step
        when a fault plan is configured — plus the adapt step per tile
        shape.  ``aot_mod.save_artifact`` turns these into a serialized
        deploy artifact; ``warmup(aot=...)`` loads them back."""
        out: list[aot_mod.AOTEntry] = []
        seen: set[tuple] = set()
        # the pinned (cache_args) form is what a plain-JIT restart actually
        # compiles — its operands are committed to their tile device, which
        # hashes to a different persistent-cache key than the portable form
        dev = None if self._ctx.mesh is not None else jax.local_devices()[0]
        for k, sl in enumerate(self._tile_slices):
            tile_s = sl.stop - sl.start
            for b in buckets or self._buckets:
                if ("step", tile_s, b) in seen:
                    continue
                seen.add(("step", tile_s, b))
                out.append(aot_mod.AOTEntry(
                    name=self._aot_name("step", tile_s, b),
                    fn=self._step,
                    args=self._step_avals(k, b, dev=None),
                    cache_args=(self._step_avals(k, b, dev=dev)
                                if dev is not None else None)))
            if self._am_counts0 is not None and ("adapt", tile_s) not in seen:
                seen.add(("adapt", tile_s))
                out.append(aot_mod.AOTEntry(
                    name=self._aot_name("adapt", tile_s),
                    fn=self._adapt_step,
                    args=self._adapt_avals(k, dev=None),
                    cache_args=(self._adapt_avals(k, dev=dev)
                                if dev is not None else None)))
        return out

    def save_aot(self, path: str) -> dict:
        """Serialize + pre-compile this fleet's whole executable set into a
        versioned deploy artifact at ``path`` (see runtime/aot.py); returns
        the artifact manifest.  Run at deploy time — e.g. the
        ``launch/serve.py compile`` subcommand — so restarted workers load
        executables instead of compiling them."""
        return aot_mod.save_artifact(path, self.aot_entries())

    def warmup(self, *, aot: aot_mod.AOTArtifact | None = None,
               buckets: Sequence[int] | None = None) -> dict[str, int]:
        """Build every step (and adapt) executable BEFORE traffic arrives.

        With ``aot`` (a loaded deploy artifact), executables deserialize
        from it — no tracing, and no XLA compile when the entry ships its
        PjRt executable; entries the artifact lacks (or whose load fails)
        are pre-lowered and compiled here, which still beats paying the
        compile under the first push.  Installed executables serve the hot loops
        directly (the jit cache stays cold — ``compile_count`` counts them,
        see above).  Returns ``{"loaded", "compiled", "skipped"}`` counts.
        Under a mesh the step is a sharded SPMD program the artifact format
        does not carry; warmup is a no-op there (plain JIT, one warning).
        """
        stats = {"loaded": 0, "compiled": 0, "skipped": 0}
        if self._ctx.mesh is not None:
            warnings.warn("StreamingFleet.warmup: mesh-sharded fleets "
                          "fall back to JIT (no AOT path)", stacklevel=2)
            return stats
        default_dev = jax.local_devices()[0]
        for k, (sl, dev) in enumerate(zip(self._tile_slices,
                                          self._tile_devs)):
            tile_s = sl.stop - sl.start
            for b in buckets or self._buckets:
                key = (dev, tile_s, b)
                if key in self._exec:
                    stats["skipped"] += 1
                    continue
                compiled = None
                if aot is not None and dev == default_dev:
                    compiled = aot.compile(
                        self._aot_name("step", tile_s, b),
                        *self._step_avals(k, b, dev=None))
                    if compiled is not None:
                        stats["loaded"] += 1
                if compiled is None:
                    compiled = self._step.lower(
                        *self._step_avals(k, b, dev=dev)).compile()
                    stats["compiled"] += 1
                self._exec[key] = compiled
            akey = (dev, tile_s)
            if self._am_counts0 is not None and akey not in self._adapt_exec:
                compiled = None
                if aot is not None and dev == default_dev:
                    compiled = aot.compile(self._aot_name("adapt", tile_s),
                                           *self._adapt_avals(k, dev=None))
                if compiled is None:
                    compiled = self._adapt_step.lower(
                        *self._adapt_avals(k, dev=dev)).compile()
                self._adapt_exec[akey] = compiled
        return stats

    @classmethod
    def from_artifact(
        cls,
        pipelines: Mapping[Hashable, HDCPipeline],
        owners: Sequence[Hashable],
        root: str,
        *,
        step: int | None = None,
        aot_dir: str | None = None,
        warm: bool = True,
        **fleet_kwargs,
    ) -> "StreamingFleet":
        """Deploy-restore: build a fleet, warm its executables from the
        checkpoint's recorded AOT artifact, and restore the checkpointed
        state — the worker-restart path, first decision without a compile.

        The checkpoint manifest's ``aot`` entry (written by
        ``save(..., aot_dir=...)``) names the artifact directory and its
        validity key; ``aot_dir`` overrides the recorded path.  A stale or
        missing artifact (different jax version / device kind / kernel
        sources) degrades to plain-JIT warmup with a warning — decisions
        are identical either way, only the cold-start latency differs.
        """
        fleet = cls(pipelines, owners, **fleet_kwargs)
        if step is None:
            step = ckpt.latest_step(root)
            if step is None:
                raise FileNotFoundError(f"no fleet checkpoint under {root!r}")
        with open(os.path.join(root, f"step_{step:08d}",
                               "manifest.json")) as f:
            manifest = json.load(f)
        art = None
        path = aot_dir
        if path is None:
            entry = manifest.get("aot")
            if entry is not None:
                saved_key = entry.get("key")
                bad = (aot_mod.stale_fields(saved_key, aot_mod.artifact_key())
                       if saved_key is not None else {})
                if bad:
                    warnings.warn(
                        "checkpoint AOT entry is stale ("
                        + ", ".join(f"{k}: saved {s!r} != current {c!r}"
                                    for k, (s, c) in sorted(bad.items()))
                        + "); warming up via JIT", stacklevel=2)
                else:
                    path = entry.get("path")
                    if path is not None and not os.path.isabs(path):
                        path = os.path.join(root, path)
        if path is not None:
            art = aot_mod.load_artifact(path)  # None (+warning) when stale
        if warm:
            fleet.warmup(aot=art)
        fleet.restore(root, step)
        return fleet

    def _call_step(self, t_pad: int, sl: slice, dev, args: tuple):
        """One tile step through the warmed executable when one matches,
        else the jitted callable (also the safety net: an executable whose
        placement/signature no longer matches is dropped, not fatal)."""
        key = (dev, sl.stop - sl.start, t_pad)
        fn = self._exec.get(key)
        if fn is not None:
            try:
                return fn(*args)
            except AssertionError:
                # sanitizer verdicts (guards.GuardViolation is an
                # AssertionError) must surface, not silently demote the
                # warmed executable to a JIT recompile
                raise
            except Exception:
                self._exec.pop(key, None)
        self._shapes_seen.add(t_pad)
        return self._step(*args)

    # -- streaming ----------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        raise AssertionError("length exceeds max bucket")  # pragma: no cover

    def _stage_buf(self, k: int, slot: int, t_pad: int) -> np.ndarray:
        """Tile ``k``'s contiguous staging buffer for (slot, bucket), safe
        to rewrite: waits for the previous round that read this buffer (the
        CPU backend's device_put aliases it zero-copy) before returning."""
        key = (slot, t_pad)
        busy = self._stage_busy[k].pop(key, None)
        if busy is not None:
            jax.block_until_ready(busy)
        if key not in self._stage_t[k]:
            sl = self._tile_slices[k]
            self._stage_t[k][key] = np.zeros(
                (sl.stop - sl.start, t_pad, self._cfg.channels), np.uint8)
        return self._stage_t[k][key]

    def _validate(self, chunks: Sequence) -> tuple[list[np.ndarray], np.ndarray]:
        """Per-chunk dtype/shape validation; returns (arrays, lengths)."""
        ch = self._cfg.channels
        arrs = []
        for i, c in enumerate(chunks):
            a = np.asarray(c, dtype=np.uint8)
            if a.size == 0:
                a = a.reshape(0, ch)
            if a.ndim != 2 or a.shape[1] != ch:
                raise ValueError(
                    f"session {i}: chunk must be (t, {ch}), got {a.shape}"
                )
            arrs.append(a)
        return arrs, np.asarray([a.shape[0] for a in arrs], np.int64)

    def _pack(self, arrs: list[np.ndarray], lengths: np.ndarray) -> np.ndarray:
        """Ragged chunk list -> one (S, T_max, ch) code batch.

        Steady streams (all lengths equal — the service-interval shape) are
        one concatenate + reshape VIEW, no scatter.  Ragged pushes scatter
        once into a REUSED staging buffer (grown geometrically, never
        re-zeroed: rows past a session's length are dead cycles — the
        device step masks them via ``lengths`` and the code-domain gather
        clips, so stale bytes are harmless).
        """
        ch = self._cfg.channels
        total = int(lengths.max(initial=0))
        flat = np.concatenate(arrs, axis=0)                # (sum(t_i), ch)
        if (lengths == total).all():                       # steady streams
            return flat.reshape(self._n, total, ch)
        if (self._ragged_buf is None
                or self._ragged_buf.shape[1] < total):
            cap = max(total, 2 * (0 if self._ragged_buf is None
                                  else self._ragged_buf.shape[1]))
            self._ragged_buf = np.empty((self._n, cap, ch), np.uint8)
        big = self._ragged_buf
        rows = np.repeat(np.arange(self._n), lengths)
        starts = np.cumsum(lengths) - lengths
        cols = np.arange(int(lengths.sum())) - np.repeat(starts, lengths)
        big[rows, cols] = flat
        return big

    def _rounds(self, big: np.ndarray, lengths: np.ndarray) -> list[FleetRound]:
        """Advance the fleet over one packed (S, T, ch) code batch.

        The per-round device payload is staged through PER-TILE pinned uint8
        code buffers (one contiguous buffer per (slot, bucket), allocated on
        first use, reused round-robin): one vectorized slice write + ONE
        device put per tile per round, nothing else — codes are 1 byte per
        (cycle, channel), 128x less than the packed bound rows the spatial
        stage used to expand on device.  The CPU backend's ``device_put``
        zero-copy-aliases the staging buffer, so each buffer is rewritten
        only AFTER the round that read it finished (``_stage_buf``; double
        buffering keeps a pipeline depth of two before that wait can
        stall).  ``lengths`` must already be padded to provisioned capacity
        (phantom rows 0).
        """
        rounds: list[FleetRound] = []
        max_bucket = self._buckets[-1]
        pos = 0
        total = int(lengths.max(initial=0))
        while pos < total:
            round_len = np.clip(lengths - pos, 0, max_bucket)
            t_pad = self._bucket_for(int(round_len.max()))
            width = min(t_pad, total - pos)
            round_len32 = round_len.astype(np.int32)
            n_emit = (self._filled_h + round_len) // self._cfg.window
            phase = self._stage_phase
            slot = phase & 1
            self._stage_phase += 1
            fos = []
            # per-tile steps dispatch asynchronously: tiles on different
            # devices overlap, and nothing here waits on the results
            # (except a slot whose previous reader is still in flight)
            for k, (sl, d) in enumerate(
                    zip(self._tile_slices, self._tile_devs)):
                stage = self._stage_buf(k, slot, t_pad)
                hi = min(sl.stop, self._n)   # phantom rows: stale == masked
                if hi > sl.start:
                    stage[:hi - sl.start, :width] = big[sl.start:hi,
                                                        pos:pos + width]
                args = (
                    self._state_t[k],
                    self._tables_t[k],
                    self._param_owner_t[k],
                    self._thresholds_t[k],
                    self._put_tile(stage, ("batch", None, None), d),
                    self._put_tile(round_len32[sl], ("batch",), d),
                )
                if self._plan is not None:
                    seed = rel_faults.step_seed(
                        self._plan, tile=k, n_tiles=len(self._tile_slices),
                        phase=phase)
                    args += (self._ber_t[k],
                             self._put_tile(np.int32(seed), (), d))
                if self._masked:
                    args += (self._cmask_t[k],)
                res = self._call_step(t_pad, sl, d, args)
                if self._plan is None:
                    self._state_t[k], fo = res
                else:
                    self._state_t[k], fo, ecc_c = res
                    self._ecc_t[k] = self._ecc_t[k] + ecc_c
                if round_len[sl].any():  # all-masked rounds leave the tile
                    self._dirty_t[k] = True  # VALUE-identical (clean)
                # fo depends on the staged codes: once it is ready the
                # step has consumed the slot and it is safe to rewrite
                self._stage_busy[k][(slot, t_pad)] = fo
                fos.append(fo)
            # rounds expose REAL sessions only ((S,) arrays); phantom
            # capacity-padding rows never emit, so dropping them is lossless
            rounds.append(FleetRound(tiles=tuple(fos),
                                     n_emit=n_emit[:self._n],
                                     frame_base=self._fidx_h[:self._n].copy()))
            self._filled_h += round_len - n_emit * self._cfg.window
            self._fidx_h += n_emit
            pos += max_bucket
        return rounds

    def push_raw(self, chunks: Sequence) -> list[FleetRound]:
        """Feed one (t_i, channels) uint8 chunk per session; zero host-side
        schedule work beyond O(S) per round.

        Returns one ``FleetRound`` per bucketed device step (chunks longer
        than the largest bucket split over several).  ``frames``/``scores``
        stay on device — nothing here blocks on the step's results, so
        steady-state serving can overlap pushes with downstream reads; use
        ``collect_decisions`` (or ``push``) to materialize FrameDecisions.
        For pre-stacked equal-length streams prefer ``push_codes`` (skips
        the ragged-list handling entirely).
        """
        if len(chunks) != self._n:
            raise ValueError(
                f"push needs one chunk per session ({self._n}), got {len(chunks)}"
            )
        arrs, real_lengths = self._validate(chunks)
        lengths = np.zeros((self._np,), np.int64)  # phantom rows stay empty
        lengths[:self._n] = real_lengths
        if int(real_lengths.max(initial=0)) == 0:
            return []
        return self._rounds(self._pack(arrs, real_lengths), lengths)

    def push_codes_raw(self, batch, lengths: Sequence[int] | None = None
                       ) -> list[FleetRound]:
        """Zero-scatter ingest fast path: feed one pre-stacked (S, t, ch)
        uint8 code batch for the whole fleet.

        The batch goes straight to the per-tile staging buffers — no
        per-session list handling, no concatenate, no scatter; the host
        work per round is one vectorized tile-slice write and one device
        put per tile.  ``lengths`` optionally gives per-session valid
        cycles (default: all ``t``); bit-exact with ``push_raw`` on the
        equivalent chunk list.
        """
        batch = np.asarray(batch, np.uint8)
        ch = self._cfg.channels
        if batch.ndim != 3 or batch.shape[0] != self._n or batch.shape[2] != ch:
            raise ValueError(
                f"push_codes needs a ({self._n}, t, {ch}) batch, got "
                f"{batch.shape}")
        t = batch.shape[1]
        lens = np.zeros((self._np,), np.int64)
        if lengths is None:
            lens[:self._n] = t
        else:
            ll = np.asarray(lengths, np.int64)
            if ll.shape != (self._n,) or ll.min(initial=0) < 0 or \
                    ll.max(initial=0) > t:
                raise ValueError(
                    f"lengths must be ({self._n},) ints in [0, {t}]")
            lens[:self._n] = ll
        if t == 0 or int(lens.max(initial=0)) == 0:
            return []
        return self._rounds(batch, lens)

    def push_codes(self, batch, lengths: Sequence[int] | None = None
                   ) -> list[list[FrameDecision]]:
        """``push`` for a pre-stacked (S, t, ch) uint8 code batch: the
        zero-scatter steady-stream ingest path.  Bit-exact with
        ``push(list(batch))``."""
        return self.collect_decisions(self.push_codes_raw(batch, lengths))

    def collect_decisions(
        self, rounds: Sequence[FleetRound]
    ) -> list[list[FrameDecision]]:
        """Materialize per-session FrameDecision lists from raw rounds.

        This is the ONLY place the raw path syncs with the device; the
        argmax runs vectorized over all (session, slot) pairs and the Python
        loop touches only sessions that actually emitted."""
        out: list[list[FrameDecision]] = [[] for _ in range(self._n)]
        for r in rounds:
            if not r.n_emit.any():
                continue
            for sl, fo in zip(self._tile_slices, r.tiles):
                ne = r.n_emit[sl]
                if not ne.any():
                    continue
                frames = np.asarray(fo.frames)
                scores = np.asarray(fo.scores)
                preds = np.argmax(scores, axis=-1)         # (tile_s, K)
                for i in np.nonzero(ne)[0]:
                    g = sl.start + int(i)
                    base = int(r.frame_base[g])
                    out[g].extend(
                        FrameDecision(frame_index=base + k,
                                      scores=scores[i, k],
                                      prediction=int(preds[i, k]),
                                      frame_hv=frames[i, k])
                        for k in range(int(ne[i]))
                    )
        return out

    def push(self, chunks: Sequence) -> list[list[FrameDecision]]:
        """Feed one (t_i, channels) uint8 chunk per session.

        Chunk lengths may differ per session (0 included).  Returns, per
        session, the decisions for every frame completed by this push.
        """
        return self.collect_decisions(self.push_raw(chunks))

    # -- instrumentation ------------------------------------------------------

    def stage_probes(self, batch) -> dict[str, tuple]:
        """Per-stage sub-benchmarks of one steady push round, for the fleet
        benchmark's breakdown rows (bench_fleet.py) — the stages live HERE so
        the probe tracks the step implementation instead of reaching into
        fleet internals from the benchmark.

        ``batch`` is one (S, t, channels) uint8 code round (t <= max
        bucket).  Returns ``{stage: (fn, scale)}``: ``fn()`` runs that stage
        once on ONE session tile and blocks on the result; ``scale`` (the
        tile count, 1 for the host-side ``ingest``) multiplies the time to
        cover the whole fleet.  Each fn is pre-run once, so jit compilation
        never pollutes the first timed call.  Stages overlap/fuse inside
        the real jitted step, so their times need not sum to a push.
        """
        cfg = self._cfg
        if self._backend != "jnp":
            # the probes time the jnp reference stages; the pallas backend
            # fuses gather+bundle+transpose+counters into one kernel, so
            # per-stage shares measured here would describe a datapath the
            # fleet never runs
            raise ValueError(
                "stage_probes breaks the step into the jnp reference "
                f"stages; this fleet runs backend={self._backend!r} — "
                "benchmark a backend='jnp' fleet")
        batch = np.asarray(batch, np.uint8)
        t = batch.shape[1]
        if not 0 < t <= self._buckets[-1]:
            raise ValueError(
                f"stage_probes needs one round, 0 < t <= {self._buckets[-1]}")
        sl, dev = self._tile_slices[0], self._tile_devs[0]
        tile_s = sl.stop - sl.start
        tables, owner = self._tables_t[0], self._param_owner_t[0]
        thresholds = self._thresholds_t[0]
        # SNAPSHOT the class rows: the live state leaf is donated by the
        # next real push, which would delete the buffer under the probe
        # (callers interleave probe timings with reference pushes)
        class_rows = jnp.array(self._state_t[0].class_rows)
        tile_batch = np.zeros((tile_s, t, cfg.channels), np.uint8)
        tile_batch[:min(tile_s, self._n)] = batch[sl.start:
                                                  min(sl.stop, self._n)]
        chunk_d = self._put_tile(tile_batch, ("batch", None, None), dev)
        filled = self._put_tile(np.zeros(tile_s, np.int32), ("batch",), dev)
        lengths = self._put_tile(np.full(tile_s, t, np.int32),
                                 ("batch",), dev)

        # cfg rides in the closure (a static, like the step's partial) —
        # operands stay explicit jit arguments so nothing constant-folds
        if self._masked:
            f_spatial = jax.jit(
                lambda t_, o, c, m: dispatch.owner_spatial_codes(
                    t_, o, c, cfg, m))
            spatial_args = (tables, owner, chunk_d, self._cmask_t[0])
        else:
            f_spatial = jax.jit(
                lambda t_, o, c: dispatch.owner_spatial_codes(t_, o, c, cfg))
            spatial_args = (tables, owner, chunk_d)
        words = jax.block_until_ready(f_spatial(*spatial_args))
        f_temporal = jax.jit(
            lambda w, f, l: fleet_ops.fleet_counts(w, f, l, cfg))
        seg = jax.block_until_ready(f_temporal(words, filled, lengths))

        def _am(seg, thr, cls):
            if cfg.variant == "dense":
                frames = hv.majority_pack(seg[:, :-1], cfg.window, cfg.dim)
            else:
                frames = hv.threshold_pack(seg[:, :-1], thr[:, None, None])
            return dispatch.owner_am_scores(frames, cls[:, None], cfg)
        f_am = jax.jit(_am)
        jax.block_until_ready(f_am(seg, thresholds, class_rows))

        t_bucket = self._bucket_for(t)

        def run_ingest():  # host side of one round: ring writes + puts
            for k, (tsl, d) in enumerate(zip(self._tile_slices,
                                             self._tile_devs)):
                stage = self._stage_buf(k, 0, t_bucket)
                hi = min(tsl.stop, self._n)
                if hi > tsl.start:
                    stage[:hi - tsl.start, :t] = batch[tsl.start:hi]
                jax.block_until_ready(self._put_tile(
                    stage, ("batch", None, None), d))
        run_ingest()

        n_tiles = self.n_tiles
        return {
            "ingest": (run_ingest, 1),
            "spatial": (lambda: jax.block_until_ready(
                f_spatial(*spatial_args)), n_tiles),
            "temporal": (lambda: jax.block_until_ready(
                f_temporal(words, filled, lengths)), n_tiles),
            "am": (lambda: jax.block_until_ready(
                f_am(seg, thresholds, class_rows)), n_tiles),
        }

    # -- online adaptation ----------------------------------------------------

    @property
    def class_rows(self) -> np.ndarray:
        """(S, C, W) per-session (possibly adapted) class HV rows."""
        return np.asarray(self.state.class_rows)[:self._n]

    def adapt(self, labels: Sequence[int], *,
              margin: float = 0.0) -> np.ndarray:
        """Personalize all S sessions' AMs from one feedback label each.

        ``labels[i]`` is the true class of session ``i``'s LAST emitted
        frame; ``-1`` means no feedback (skip).  Sessions that have not
        emitted a frame yet are skipped too.  One jitted gated update
        (core.online) for the whole fleet: misclassified / low-margin
        sessions add the frame's bits to the true class's counters, subtract
        from the rival's, and get their class rows re-thresholded.
        Bit-exact with calling ``SeizureSession.adapt`` per stream.  Returns
        the (S,) bool mask of sessions whose update fired."""
        if self._am_counts0 is None:
            raise ValueError(
                "fleet bank has pipelines without am_state counter files; "
                "train them with train_one_shot/fit_iterative to enable "
                "adapt()")
        lab = np.asarray(labels, np.int64)
        if lab.shape != (self._n,):
            raise ValueError(
                f"adapt needs one label per session ({self._n}), got shape "
                f"{lab.shape}")
        if lab.max(initial=-1) >= self._cfg.n_classes:
            raise ValueError(
                f"labels must be < n_classes={self._cfg.n_classes} "
                "(-1 = no feedback)")
        lab32 = np.full((self._np,), -1, np.int32)  # phantoms: no feedback
        lab32[:self._n] = lab
        applied = []
        for k, (sl, d) in enumerate(zip(self._tile_slices, self._tile_devs)):
            args = (
                self._state_t[k],
                self._put_tile(lab32[sl], ("batch",), d),
                # committed per tile so the warmed (device-pinned) adapt
                # executables accept it directly
                self._put_tile(np.float32(margin), (), d),
                self._density_t[k],
            )
            akey = (d, sl.stop - sl.start)
            fn = self._adapt_exec.get(akey)
            if fn is not None:
                try:
                    self._state_t[k], app = fn(*args)
                    self._dirty_t[k] = True
                    applied.append(app)
                    continue
                except AssertionError:  # sanitizer verdicts must surface
                    raise
                except Exception:
                    self._adapt_exec.pop(akey, None)
            self._state_t[k], app = self._adapt_step(*args)
            self._dirty_t[k] = True
            applied.append(app)
        return np.concatenate([np.asarray(a) for a in applied])[:self._n]

    # -- durability -----------------------------------------------------------

    def _meta(self) -> dict:
        return {
            "kind": "hdc_fleet",
            "n_sessions": self._n,
            "dim": self._cfg.dim,
            "window": self._cfg.window,
            "n_classes": self._cfg.n_classes,
            "variant": self._cfg.variant,
            "bank": self._bank_fingerprint(),
        }

    def _bank_fingerprint(self) -> str:
        """Digest of everything a checkpointed state is only valid against:
        the per-session codebook tables, initial class rows / AM banks and
        the per-session operand registers.  A fleet built from DIFFERENT
        patient pipelines shares none of these, and restoring state across
        banks would silently score one bank's frames against another's class
        HVs."""
        h = hashlib.sha256()
        operands = [self._tables_t[0],
                    np.concatenate([np.asarray(x)
                                    for x in self._param_owner_t]),
                    np.concatenate([np.asarray(x)
                                    for x in self._thresholds_t]),
                    np.concatenate([np.asarray(x) for x in self._density_t]),
                    self._class_rows0]
        if self._am_counts0 is not None:
            operands += [self._am_counts0, self._am_n0]
        for a in operands:
            arr = np.ascontiguousarray(np.asarray(a))
            h.update(str((arr.dtype.str, arr.shape)).encode())
            h.update(arr.tobytes())
        return h.hexdigest()[:16]

    def _state_shardings(self) -> FleetState | None:
        if self._ctx.mesh is None:
            return None
        full = self.state
        return FleetState(**{
            f: shd.sharding_for(axes, self._ctx,
                                jnp.shape(getattr(full, f)))
            for f, axes in _STATE_AXES.items()
        })

    def save(self, root: str, step: int | None = None,
             aot_dir: str | None = None) -> str:
        """Checkpoint the full fleet state (streaming accumulators + online
        AM banks) under ``root`` via ckpt.checkpoint's atomic-rename
        contract; ``step`` defaults to one past the latest.  Returns the
        checkpoint directory.

        ``aot_dir`` additionally serializes this fleet's executable set
        there (``save_aot``) and records the artifact path + validity key in
        the checkpoint manifest, which is what lets ``from_artifact``
        restore a worker without recompiling.  Relative paths are resolved
        against ``root`` at restore time."""
        if step is None:
            latest = ckpt.latest_step(root)
            step = 0 if latest is None else latest + 1
        aot_entry = None
        if aot_dir is not None:
            self.save_aot(aot_dir)
            aot_entry = {"path": aot_dir, "key": aot_mod.artifact_key()}
        meta = self._meta()
        if self._masked:
            # electrode-health carriage: the quarantine masks ride the
            # manifest meta OUTSIDE the _meta() comparison dict, so
            # checkpoints stay loadable by mask-free fleets (extra keys
            # are ignored at restore)
            meta["channel_mask"] = {
                "shape": [self._n, self._cfg.channels],
                "hex": self._cmask_h[:self._n].tobytes().hex(),
            }
        return ckpt.save(root, step, self.state, meta=meta,
                         aot=aot_entry)

    def restore(self, root: str, step: int | None = None) -> int:
        """Restore a ``save``d fleet state into THIS fleet (same bank
        geometry and session count), elastic under the current mesh: leaves
        re-shard onto however many devices the restored fleet runs on.  The
        host-side emission schedule resumes from the restored fill levels,
        so pushes continue mid-stream bit-exactly.  Returns the step."""
        if step is None:
            step = ckpt.latest_step(root)
            if step is None:
                raise FileNotFoundError(f"no fleet checkpoint under {root!r}")
        with open(os.path.join(root, f"step_{step:08d}",
                               "manifest.json")) as f:
            meta = json.load(f).get("meta", {})
        want = self._meta()
        bad = {k: (meta.get(k), v) for k, v in want.items()
               if meta.get(k) != v}
        if bad:
            raise ValueError(
                f"checkpoint does not match this fleet: {bad} "
                "(saved, expected)")
        full = ckpt.restore(root, step, like=self.state,
                            shardings=self._state_shardings())
        self._state_t = self._split_state(full)
        self._filled_h = np.asarray(full.filled).astype(np.int64)
        self._fidx_h = np.asarray(full.frame_index).astype(np.int64)
        self._dirty_t = [True] * len(self._tile_slices)
        if self._masked:
            # re-establish the checkpoint's electrode quarantine (all-live
            # when the checkpoint came from a fleet without masking)
            cm = meta.get("channel_mask")
            self._cmask_h[:] = 1
            if cm is not None:
                n, c = cm["shape"]
                if (n, c) != (self._n, self._cfg.channels):
                    raise ValueError(
                        f"checkpoint channel_mask is ({n}, {c}); this "
                        f"fleet is ({self._n}, {self._cfg.channels})")
                self._cmask_h[:self._n] = np.frombuffer(
                    bytes.fromhex(cm["hex"]), np.uint8).reshape(n, c)
            self._cmask_t = self._put_tiles(self._cmask_h, ("batch", None))
        return step
