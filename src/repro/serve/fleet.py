"""Sharded streaming fleet: thousands of concurrent sessions, one jitted step.

``SeizureSession`` (serve/engine.py) is the single-patient streaming API: a
host-side Python object per stream, one jit dispatch + numpy accumulator
update per push.  That shape cannot serve a population — S streams cost S
Python loops per service interval.  ``StreamingFleet`` vectorizes S concurrent
sessions into ONE device-resident pytree:

* ``counts``       (S, D) int32 — the stacked temporal-accumulator register
                   files (the hardware's D x 8-bit counter bank, one per
                   implant),
* ``filled``       (S,)   int32 — cycles accumulated toward each next frame,
* ``frame_index``  (S,)   int32 — frames emitted so far per stream,

plus per-stream operands gathered once at construction: each session's class
HVs from the stacked (P, C, W) AM bank, its calibrated temporal threshold,
and its row into the stacked unique-params codebook bank.

One jitted ``step(state, chunk, lengths, masks)`` advances ALL sessions.  The
key structural trick: WHEN each session's window boundaries fall is a pure
function of the chunk lengths, so the host computes the emission schedule and
ships it as a dense (S, K+1, t_pad) cycle-mask — rows 0..K-1 select the
cycles that close each completed frame (at most K = ceil(t_pad / window) per
step), row K the leftover tail.  The device then never branches per cycle: a
``lax.scan`` over fixed-size time blocks accumulates the masked per-frame
counts as one batched GEMM per block (f32 is exact for counts <= window),
and ONE threshold/majority-pack + AM search scores all K frame slots of all
sessions together.  ``lengths`` masks the padding — sessions push chunks of
ANY length, including 0 — and chunk lengths are bucketed/padded to a fixed
set so steady streams compile once per bucket.

Sharding: pass ``mesh=`` to place the fleet on a device mesh — session-axis
state and operands shard along the ``batch`` logical axis (-> ``data`` mesh
axis per runtime/sharding.py), the codebook/AM banks replicate, and the step
stays a single SPMD program.

Decisions are bit-exact with per-patient ``SeizureSession`` loops for all
variants (tested in tests/test_fleet.py); benchmarks/bench_fleet.py measures
the sessions-per-second win over the looped baseline.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hv
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.runtime import sharding as shd
from repro.serve import dispatch
from repro.serve.engine import FrameDecision

DEFAULT_BUCKETS = (32, 64, 128, 256)


@dataclass(frozen=True)
class FleetState:
    """Device-resident state of all S sessions (a pytree of stacked leaves)."""

    counts: jax.Array  # (S, D) int32 temporal accumulators
    filled: jax.Array  # (S,) int32 cycles toward each next frame
    frame_index: jax.Array  # (S,) int32 frames emitted so far


@dataclass(frozen=True)
class FleetOut:
    """Raw step outputs: one row per potential frame slot (K per step); the
    host-side schedule knows which (session, slot) pairs really emitted."""

    frames: jax.Array  # (S, K, W) uint32 packed frame HVs
    scores: jax.Array  # (S, K, C) int32 AM scores


for _cls, _fields in (
    (FleetState, ["counts", "filled", "frame_index"]),
    (FleetOut, ["frames", "scores"]),
):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields, meta_fields=[])


def _block_len(t_pad: int, cfg: HDCConfig) -> int:
    """Largest divisor of t_pad <= min(cap, window): the scan's time-block.

    Blocks bound the per-iteration temporaries of the vectorized spatial
    encode (the bit-domain variants materialize a (S, block, channels, D)
    expansion, so they get a tighter cap than the position-domain default).
    """
    cap = min(8 if cfg.variant == "sparse_compim" else 4, cfg.window, t_pad)
    return max(b for b in range(1, cap + 1) if t_pad % b == 0)


def _fleet_step(
    state: FleetState,
    tables: jax.Array,
    owner: jax.Array,
    class_rows: jax.Array,
    thresholds: jax.Array,
    chunk: jax.Array,
    lengths: jax.Array,
    masks: jax.Array,
    *,
    cfg: HDCConfig,
    ctx: shd.ShardCtx,
) -> tuple[FleetState, FleetOut]:
    """Advance all S sessions by one padded chunk batch.

    chunk: (S, t_pad, channels) uint8; lengths: (S,) int32 valid cycles per
    session; masks: (S, K+1, t_pad) f32 host-built cycle masks (rows 0..K-1
    = cycles closing each completed frame, row K = leftover tail).
    """
    s, t_pad, _ = chunk.shape
    kp1 = masks.shape[1]
    block = _block_len(t_pad, cfg)
    nb = t_pad // block
    # (nb, S, block, ...): scan over time blocks, vectorize within
    blocks = chunk.reshape(s, nb, block, cfg.channels).transpose(1, 0, 2, 3)
    mask_blocks = masks.reshape(s, kp1, nb, block).transpose(2, 0, 1, 3)

    def body(acc, xs):
        codes_b, m_b = xs  # (S, block, channels), (S, K+1, block)
        spatial = dispatch.owner_spatial_encode(tables, owner, codes_b, cfg)
        bits = hv.unpack_bits(spatial, cfg.dim).astype(jnp.float32)  # (S, b, D)
        # one batched GEMM accumulates every frame-slot's counts; f32 is
        # exact for counts <= window << 2^24
        return acc + jnp.einsum("skb,sbd->skd", m_b, bits), None

    acc0 = shd.constrain(
        jnp.zeros((s, kp1, cfg.dim), jnp.float32), ("batch", None, None), ctx
    )
    seg, _ = jax.lax.scan(body, acc0, (blocks, mask_blocks))
    seg = seg.astype(jnp.int32)  # (S, K+1, D)

    n_emit = (state.filled + lengths) // cfg.window  # (S,)
    # the carried accumulator belongs to the FIRST completed frame when the
    # session emits, and to the tail otherwise
    emits = n_emit > 0
    frame_counts = seg[:, :-1].at[:, 0].add(
        jnp.where(emits[:, None], state.counts, 0)
    )
    if cfg.variant == "dense":
        frames = hv.majority_pack(frame_counts, cfg.window, cfg.dim)
    else:
        frames = hv.threshold_pack(frame_counts, thresholds[:, None, None])
    scores = dispatch.owner_am_scores(frames, class_rows[:, None], cfg)
    new_counts = seg[:, -1] + jnp.where(emits[:, None], 0, state.counts)
    new_state = FleetState(
        counts=shd.constrain(new_counts, ("batch", None), ctx),
        filled=shd.constrain(
            state.filled + lengths - n_emit * cfg.window, ("batch",), ctx
        ),
        frame_index=shd.constrain(state.frame_index + n_emit, ("batch",), ctx),
    )
    return new_state, FleetOut(frames=frames, scores=scores)


class StreamingFleet:
    """S concurrent streaming seizure sessions advanced by one jitted step.

    ``pipelines`` is the patient -> trained-pipeline bank (one shared
    datapath; per-patient calibrated thresholds and codebooks welcome, see
    ``dispatch.datapath_key``).  ``owners[i]`` names the patient session ``i``
    belongs to — any number of sessions per patient.

    ``push(chunks)`` feeds one (t_i, channels) chunk per session (lengths may
    differ; 0 is fine) and returns the completed ``FrameDecision`` lists,
    bit-exact with per-session ``SeizureSession`` loops.  Chunks are padded to
    the smallest configured bucket (longer chunks are split over multiple
    steps), so a steady stream compiles once per bucket — see
    ``compile_count``.
    """

    def __init__(
        self,
        pipelines: Mapping[Hashable, HDCPipeline],
        owners: Sequence[Hashable],
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        mesh=None,
    ):
        self._cfg = dispatch.validate_bank(pipelines)
        if not owners:
            raise ValueError("StreamingFleet needs at least one session")
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self._buckets = tuple(sorted(set(int(b) for b in buckets)))
        pids = list(pipelines)
        pid_index = {pid: i for i, pid in enumerate(pids)}
        for pid in owners:
            if pid not in pid_index:
                raise KeyError(f"unknown patient id {pid!r} in owners")
        pipes = [pipelines[pid] for pid in pids]
        tables, param_rows = dispatch.stack_bound_tables(pipes)
        bank = jnp.stack([p.class_hvs for p in pipes])  # (P, C, W)
        thresholds = np.asarray(
            [p.cfg.temporal_threshold for p in pipes], np.int32
        )
        owner_idx = np.asarray([pid_index[pid] for pid in owners], np.int32)

        self._ctx = shd.make_ctx(mesh)
        self._n = len(owner_idx)
        self._owners = list(owners)
        put = self._put
        # replicated pre-bound codebook bank (P_unique, C, codes, W)
        self._tables = put(tables, (None,) * 4)
        self._bank = put(bank, (None, None, None))  # replicated (P, C, W)
        self._class_rows = put(bank[owner_idx], ("batch", None, None))
        self._thresholds = put(jnp.asarray(thresholds[owner_idx]), ("batch",))
        self._param_owner = put(jnp.asarray(param_rows[owner_idx]), ("batch",))
        self._state = self._zero_state()
        # host mirrors of filled/frame_index: the emission schedule (and so
        # the step's cycle masks) is a pure function of the pushed lengths,
        # so the host tracks it without any device round-trip
        self._filled_h = np.zeros((self._n,), np.int64)
        self._fidx_h = np.zeros((self._n,), np.int64)
        self._shapes_seen: set[int] = set()  # buckets pushed so far
        self._step = jax.jit(
            functools.partial(_fleet_step, cfg=self._cfg, ctx=self._ctx),
            donate_argnums=(0,),
        )

    # -- state management ---------------------------------------------------

    def _put(self, x: jax.Array, axes: tuple) -> jax.Array:
        s = shd.sharding_for(axes, self._ctx, jnp.shape(x))
        return jax.device_put(x, s) if s is not None else jnp.asarray(x)

    def _zero_state(self) -> FleetState:
        return FleetState(
            counts=self._put(
                jnp.zeros((self._n, self._cfg.dim), jnp.int32), ("batch", None)
            ),
            filled=self._put(jnp.zeros((self._n,), jnp.int32), ("batch",)),
            frame_index=self._put(jnp.zeros((self._n,), jnp.int32), ("batch",)),
        )

    def reset(self) -> None:
        """Zero all accumulators, fill levels and frame indices."""
        self._state = self._zero_state()
        self._filled_h[:] = 0
        self._fidx_h[:] = 0

    @property
    def n_sessions(self) -> int:
        return self._n

    @property
    def state(self) -> FleetState:
        return self._state

    @property
    def fill_levels(self) -> np.ndarray:
        """(S,) cycles accumulated toward each next (incomplete) frame."""
        return np.asarray(self._state.filled)

    @property
    def frame_indices(self) -> np.ndarray:
        """(S,) frames emitted so far per session."""
        return np.asarray(self._state.frame_index)

    @property
    def compile_count(self) -> int:
        """Jitted-step executables built so far (<= number of buckets used).

        Prefers jit's real cache size (catches accidental recompiles); falls
        back to the count of distinct bucket shapes pushed if the private
        jax API ever disappears.
        """
        cache_size = getattr(self._step, "_cache_size", None)
        if cache_size is not None:
            return cache_size()
        return len(self._shapes_seen)

    # -- streaming ----------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        raise AssertionError("length exceeds max bucket")  # pragma: no cover

    def _round_masks(self, round_len: np.ndarray, t_pad: int) -> np.ndarray:
        """Host-built (S, K+1, t_pad) f32 cycle masks for one step.

        Cycle j of session s belongs to frame-slot ``(filled_s + j) //
        window`` — slots below the session's emission count are completed
        frames, everything else (and the padding) lands in the tail row.
        """
        window = self._cfg.window
        k_max = (t_pad - 1) // window + 1
        j = np.arange(t_pad)
        ordinal = (self._filled_h[:, None] + j[None, :]) // window  # (S, t)
        valid = j[None, :] < round_len[:, None]
        n_emit = (self._filled_h + round_len) // window  # (S,)
        rows = np.arange(k_max)
        frame_rows = (
            (ordinal[:, None, :] == rows[None, :, None])
            & (rows[None, :, None] < n_emit[:, None, None])
            & valid[:, None, :]
        )
        tail = (ordinal >= n_emit[:, None]) & valid
        return np.concatenate(
            [frame_rows, tail[:, None, :]], axis=1
        ).astype(np.float32)

    def push(self, chunks: Sequence) -> list[list[FrameDecision]]:
        """Feed one (t_i, channels) uint8 chunk per session.

        Chunk lengths may differ per session (0 included).  Returns, per
        session, the decisions for every frame completed by this push.
        """
        if len(chunks) != self._n:
            raise ValueError(
                f"push needs one chunk per session ({self._n}), got {len(chunks)}"
            )
        ch = self._cfg.channels
        arrs = []
        for i, c in enumerate(chunks):
            a = np.asarray(c, dtype=np.uint8)
            if a.size == 0:
                a = a.reshape(0, ch)
            if a.ndim != 2 or a.shape[1] != ch:
                raise ValueError(
                    f"session {i}: chunk must be (t, {ch}), got {a.shape}"
                )
            arrs.append(a)
        lengths = np.asarray([a.shape[0] for a in arrs], np.int64)
        out: list[list[FrameDecision]] = [[] for _ in range(self._n)]
        max_bucket = self._buckets[-1]
        pos = 0
        total = int(lengths.max(initial=0))
        while pos < total:
            round_len = np.clip(lengths - pos, 0, max_bucket)
            t_pad = self._bucket_for(int(round_len.max()))
            self._shapes_seen.add(t_pad)
            batch = np.zeros((self._n, t_pad, ch), np.uint8)
            for i, a in enumerate(arrs):
                n = int(round_len[i])
                if n:
                    batch[i, :n] = a[pos : pos + n]
            masks = self._round_masks(round_len, t_pad)
            n_emit = (self._filled_h + round_len) // self._cfg.window
            self._state, fo = self._step(
                self._state,
                self._tables,
                self._param_owner,
                self._class_rows,
                self._thresholds,
                jnp.asarray(batch),
                jnp.asarray(round_len, dtype=jnp.int32),
                jnp.asarray(masks),
            )
            self._collect(fo, n_emit, out)
            self._filled_h += round_len - n_emit * self._cfg.window
            self._fidx_h += n_emit
            pos += max_bucket
        return out

    def _collect(
        self, fo: FleetOut, n_emit: np.ndarray, out: list[list[FrameDecision]]
    ) -> None:
        if not n_emit.any():
            return
        frames = np.asarray(fo.frames)
        scores = np.asarray(fo.scores)
        for s in np.nonzero(n_emit)[0]:
            for k in range(int(n_emit[s])):
                sc = scores[s, k]
                out[s].append(
                    FrameDecision(
                        frame_index=int(self._fidx_h[s]) + k,
                        scores=sc,
                        prediction=int(np.argmax(sc)),
                        frame_hv=frames[s, k],
                    )
                )
