"""Sharded streaming fleet: thousands of concurrent sessions, one jitted step.

``SeizureSession`` (serve/engine.py) is the single-patient streaming API: a
host-side Python object per stream, one jit dispatch + numpy accumulator
update per push.  That shape cannot serve a population — S streams cost S
Python loops per service interval.  ``StreamingFleet`` vectorizes S concurrent
sessions into ONE device-resident pytree:

* ``counts``       (S, D) int32 — the stacked temporal-accumulator register
                   files (the hardware's D x 8-bit counter bank, one per
                   implant),
* ``filled``       (S,)   int32 — cycles accumulated toward each next frame,
* ``frame_index``  (S,)   int32 — frames emitted so far per stream,

plus per-stream operands gathered once at construction: each session's class
HVs from the stacked (P, C, W) AM bank, its calibrated temporal threshold,
and its row into the stacked unique-params codebook bank.

One jitted ``step(state, chunk, lengths, masks)`` advances ALL sessions.  The
key structural trick: WHEN each session's window boundaries fall is a pure
function of the chunk lengths, so the host computes the emission schedule and
ships it as a dense (S, K+1, t_pad) cycle-mask — rows 0..K-1 select the
cycles that close each completed frame (at most K = ceil(t_pad / window) per
step), row K the leftover tail.  The device then never branches per cycle: a
``lax.scan`` over fixed-size time blocks accumulates the masked per-frame
counts as one batched GEMM per block (f32 is exact for counts <= window),
and ONE threshold/majority-pack + AM search scores all K frame slots of all
sessions together.  ``lengths`` masks the padding — sessions push chunks of
ANY length, including 0 — and chunk lengths are bucketed/padded to a fixed
set so steady streams compile once per bucket.

Online adaptation (core.online): the fleet carries a stacked (S, C, D)
counter-file bank — each session's private, adaptable view of its patient's
AM — plus per-session class-HV rows refreshed from it.  ``adapt(labels)``
applies ONE jitted confidence-gated update across all S sessions (labels
``-1`` mask out sessions with no feedback), bit-exact with a per-session
``SeizureSession.adapt`` loop; the step itself tracks each session's last
emitted frame/scores so the adapt operands never round-trip the host.

Durability: ``save``/``restore`` round-trip the full ``FleetState``
(streaming accumulators + online AM banks) through ``ckpt.checkpoint`` —
atomic-rename directories, elastic re-placement under the current mesh — so
an interrupted fleet resumes mid-stream bit-exactly
(``launch/serve.py --hdc-fleet --ckpt-dir ... --resume``).

Sharding: pass ``mesh=`` to place the fleet on a device mesh — session-axis
state and operands shard along the ``batch`` logical axis (-> ``data`` mesh
axis per runtime/sharding.py), the codebook/AM banks replicate, and the step
stays a single SPMD program.

Decisions are bit-exact with per-patient ``SeizureSession`` loops for all
variants (tested in tests/test_fleet.py); benchmarks/bench_fleet.py measures
the sessions-per-second win over the looped baseline.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
from dataclasses import dataclass, replace
from typing import Hashable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core import hv, online
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.runtime import sharding as shd
from repro.serve import dispatch
from repro.serve.engine import FrameDecision

DEFAULT_BUCKETS = (32, 64, 128, 256)


@dataclass(frozen=True)
class FleetState:
    """Device-resident state of all S sessions (a pytree of stacked leaves).

    The first block is the streaming state; the second is the online
    continual-learning state — per-session counter-file AM banks, the class
    rows re-thresholded from them, and the last emitted frame's operands
    (what ``adapt`` consumes).  Checkpointing the whole dataclass captures a
    fleet mid-stream."""

    counts: jax.Array  # (S, D) int32 temporal accumulators
    filled: jax.Array  # (S,) int32 cycles toward each next frame
    frame_index: jax.Array  # (S,) int32 frames emitted so far
    class_rows: jax.Array  # (S, C, W) uint32 per-session (adaptive) AM rows
    am_counts: jax.Array  # (S, C, D) int32 online counter-file bank
    am_n: jax.Array  # (S, C) int32 frames bundled per class
    last_frame: jax.Array  # (S, W) uint32 last emitted frame HV
    last_scores: jax.Array  # (S, C) int32 last emitted frame's AM scores
    has_frame: jax.Array  # (S,) int32 1 once a session has emitted


@dataclass(frozen=True)
class FleetOut:
    """Raw step outputs: one row per potential frame slot (K per step); the
    host-side schedule knows which (session, slot) pairs really emitted."""

    frames: jax.Array  # (S, K, W) uint32 packed frame HVs
    scores: jax.Array  # (S, K, C) int32 AM scores


for _cls, _fields in (
    (FleetState, ["counts", "filled", "frame_index", "class_rows",
                  "am_counts", "am_n", "last_frame", "last_scores",
                  "has_frame"]),
    (FleetOut, ["frames", "scores"]),
):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields, meta_fields=[])

# logical sharding axes per FleetState leaf: session state splits along the
# batch axis, everything trailing replicates (used by the step's constraints
# and by the elastic checkpoint restore)
_STATE_AXES = {
    "counts": ("batch", None),
    "filled": ("batch",),
    "frame_index": ("batch",),
    "class_rows": ("batch", None, None),
    "am_counts": ("batch", None, None),
    "am_n": ("batch", None),
    "last_frame": ("batch", None),
    "last_scores": ("batch", None),
    "has_frame": ("batch",),
}


def _block_len(t_pad: int, cfg: HDCConfig) -> int:
    """Largest divisor of t_pad <= min(cap, window): the scan's time-block.

    Blocks bound the per-iteration temporaries of the vectorized spatial
    encode (the bit-domain variants materialize a (S, block, channels, D)
    expansion, so they get a tighter cap than the position-domain default).
    """
    cap = min(8 if cfg.variant == "sparse_compim" else 4, cfg.window, t_pad)
    return max(b for b in range(1, cap + 1) if t_pad % b == 0)


def _fleet_step(
    state: FleetState,
    tables: jax.Array,
    owner: jax.Array,
    thresholds: jax.Array,
    chunk: jax.Array,
    lengths: jax.Array,
    masks: jax.Array,
    *,
    cfg: HDCConfig,
    ctx: shd.ShardCtx,
) -> tuple[FleetState, FleetOut]:
    """Advance all S sessions by one padded chunk batch.

    chunk: (S, t_pad, channels) uint8; lengths: (S,) int32 valid cycles per
    session; masks: (S, K+1, t_pad) f32 host-built cycle masks (rows 0..K-1
    = cycles closing each completed frame, row K = leftover tail).  Frames
    score against ``state.class_rows`` (refreshed by ``adapt``), and the
    step records each emitting session's last frame HV + scores — the
    operands a later ``adapt`` call consumes, captured inside the same
    jitted program.
    """
    s, t_pad, _ = chunk.shape
    kp1 = masks.shape[1]
    block = _block_len(t_pad, cfg)
    nb = t_pad // block
    # (nb, S, block, ...): scan over time blocks, vectorize within
    blocks = chunk.reshape(s, nb, block, cfg.channels).transpose(1, 0, 2, 3)
    mask_blocks = masks.reshape(s, kp1, nb, block).transpose(2, 0, 1, 3)

    def body(acc, xs):
        codes_b, m_b = xs  # (S, block, channels), (S, K+1, block)
        spatial = dispatch.owner_spatial_encode(tables, owner, codes_b, cfg)
        bits = hv.unpack_bits(spatial, cfg.dim).astype(jnp.float32)  # (S, b, D)
        # one batched GEMM accumulates every frame-slot's counts; f32 is
        # exact for counts <= window << 2^24
        return acc + jnp.einsum("skb,sbd->skd", m_b, bits), None

    acc0 = shd.constrain(
        jnp.zeros((s, kp1, cfg.dim), jnp.float32), ("batch", None, None), ctx
    )
    seg, _ = jax.lax.scan(body, acc0, (blocks, mask_blocks))
    seg = seg.astype(jnp.int32)  # (S, K+1, D)

    n_emit = (state.filled + lengths) // cfg.window  # (S,)
    # the carried accumulator belongs to the FIRST completed frame when the
    # session emits, and to the tail otherwise
    emits = n_emit > 0
    frame_counts = seg[:, :-1].at[:, 0].add(
        jnp.where(emits[:, None], state.counts, 0)
    )
    if cfg.variant == "dense":
        frames = hv.majority_pack(frame_counts, cfg.window, cfg.dim)
    else:
        frames = hv.threshold_pack(frame_counts, thresholds[:, None, None])
    scores = dispatch.owner_am_scores(frames, state.class_rows[:, None], cfg)
    new_counts = seg[:, -1] + jnp.where(emits[:, None], 0, state.counts)
    # capture each emitting session's LAST completed frame for adapt
    sidx = jnp.arange(s)
    last_slot = jnp.maximum(n_emit - 1, 0)
    new_state = replace(
        state,
        counts=shd.constrain(new_counts, _STATE_AXES["counts"], ctx),
        filled=shd.constrain(
            state.filled + lengths - n_emit * cfg.window,
            _STATE_AXES["filled"], ctx,
        ),
        frame_index=shd.constrain(
            state.frame_index + n_emit, _STATE_AXES["frame_index"], ctx
        ),
        last_frame=shd.constrain(
            jnp.where(emits[:, None], frames[sidx, last_slot],
                      state.last_frame),
            _STATE_AXES["last_frame"], ctx,
        ),
        last_scores=shd.constrain(
            # int32 pinned: the popcount scores promote to int64 under
            # JAX_ENABLE_X64, which would drift the carried state dtype
            # (and the jit cache key) after the first step
            jnp.where(emits[:, None], scores[sidx, last_slot],
                      state.last_scores).astype(jnp.int32),
            _STATE_AXES["last_scores"], ctx,
        ),
        has_frame=shd.constrain(
            state.has_frame | emits.astype(jnp.int32),
            _STATE_AXES["has_frame"], ctx,
        ),
    )
    return new_state, FleetOut(frames=frames, scores=scores)


def _fleet_adapt(
    state: FleetState,
    labels: jax.Array,
    margin: jax.Array,
    density: jax.Array,
    *,
    cfg: HDCConfig,
    ctx: shd.ShardCtx,
) -> tuple[FleetState, jax.Array]:
    """One gated online update for ALL S sessions (core.online).

    labels: (S,) int32 true class of each session's last emitted frame
    (-1 = no feedback); density: (S,) f32 per-patient ``class_density``.
    Sessions whose gate fires get their counter-file rows updated and their
    class rows re-thresholded; everyone else's state passes through
    bit-identically.  Returns (state, applied (S,) bool)."""
    bits = hv.unpack_bits(state.last_frame, cfg.dim)            # (S, D)
    am_state = online.OnlineAMState(counts=state.am_counts, n=state.am_n)
    new_am, applied = online.update(
        am_state, bits, labels, state.last_scores,
        margin=margin, valid=state.has_frame > 0)
    chvs = online.class_hvs_from_state(new_am, cfg, density=density[:, None])
    class_rows = jnp.where(applied[:, None, None], chvs, state.class_rows)
    new_state = replace(
        state,
        am_counts=shd.constrain(new_am.counts, _STATE_AXES["am_counts"], ctx),
        am_n=shd.constrain(new_am.n, _STATE_AXES["am_n"], ctx),
        class_rows=shd.constrain(class_rows, _STATE_AXES["class_rows"], ctx),
    )
    return new_state, applied


class StreamingFleet:
    """S concurrent streaming seizure sessions advanced by one jitted step.

    ``pipelines`` is the patient -> trained-pipeline bank (one shared
    datapath; per-patient calibrated thresholds and codebooks welcome, see
    ``dispatch.datapath_key``).  ``owners[i]`` names the patient session ``i``
    belongs to — any number of sessions per patient.

    ``push(chunks)`` feeds one (t_i, channels) chunk per session (lengths may
    differ; 0 is fine) and returns the completed ``FrameDecision`` lists,
    bit-exact with per-session ``SeizureSession`` loops.  Chunks are padded to
    the smallest configured bucket (longer chunks are split over multiple
    steps), so a steady stream compiles once per bucket — see
    ``compile_count``.

    ``adapt(labels)`` personalizes AMs in place: one jitted gated update for
    the whole fleet against each session's last emitted frame (labels of -1
    mask out sessions without feedback), bit-exact with per-session
    ``SeizureSession.adapt`` calls.  ``save``/``restore`` checkpoint the
    full fleet state (streaming + online AM banks) for mid-stream resume.
    """

    def __init__(
        self,
        pipelines: Mapping[Hashable, HDCPipeline],
        owners: Sequence[Hashable],
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        mesh=None,
    ):
        self._cfg = dispatch.validate_bank(pipelines)
        if not owners:
            raise ValueError("StreamingFleet needs at least one session")
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self._buckets = tuple(sorted(set(int(b) for b in buckets)))
        pids = list(pipelines)
        pid_index = {pid: i for i, pid in enumerate(pids)}
        for pid in owners:
            if pid not in pid_index:
                raise KeyError(f"unknown patient id {pid!r} in owners")
        pipes = [pipelines[pid] for pid in pids]
        tables, param_rows = dispatch.stack_bound_tables(pipes)
        bank = jnp.stack([p.class_hvs for p in pipes])  # (P, C, W)
        thresholds = np.asarray(
            [p.cfg.temporal_threshold for p in pipes], np.int32
        )
        owner_idx = np.asarray([pid_index[pid] for pid in owners], np.int32)

        self._ctx = shd.make_ctx(mesh)
        self._n = len(owner_idx)
        self._owners = list(owners)
        put = self._put
        # replicated pre-bound codebook bank (P_unique, C, codes, W)
        self._tables = put(tables, (None,) * 4)
        self._bank = put(bank, (None, None, None))  # replicated (P, C, W)
        self._thresholds = put(jnp.asarray(thresholds[owner_idx]), ("batch",))
        self._param_owner = put(jnp.asarray(param_rows[owner_idx]), ("batch",))
        # online-adaptation operands: each session starts from its patient's
        # class rows + counter-file am_state (host copies: the jitted step
        # donates its state, so reset() must rebuild fresh device arrays)
        self._class_rows0 = np.asarray(bank)[owner_idx]  # (S, C, W)
        self._density = put(
            jnp.asarray(np.asarray(
                [p.cfg.class_density for p in pipes], np.float32)[owner_idx]),
            ("batch",))
        if all(p.am_state is not None for p in pipes):
            self._am_counts0 = np.stack(
                [np.asarray(pipes[i].am_state.counts) for i in owner_idx])
            self._am_n0 = np.stack(
                [np.asarray(pipes[i].am_state.n) for i in owner_idx])
        else:  # bank mixes in externally built pipelines: adapt unavailable
            self._am_counts0 = self._am_n0 = None
        self._state = self._zero_state()
        # host mirrors of filled/frame_index: the emission schedule (and so
        # the step's cycle masks) is a pure function of the pushed lengths,
        # so the host tracks it without any device round-trip
        self._filled_h = np.zeros((self._n,), np.int64)
        self._fidx_h = np.zeros((self._n,), np.int64)
        self._shapes_seen: set[int] = set()  # buckets pushed so far
        self._step = jax.jit(
            functools.partial(_fleet_step, cfg=self._cfg, ctx=self._ctx),
            donate_argnums=(0,),
        )
        # NOT donated: several state leaves pass through adapt untouched and
        # XLA cannot alias every same-shaped pair, which trips the
        # donation warning; adapt is rare relative to push, so the one
        # transient copy is the cheaper trade
        self._adapt_step = jax.jit(
            functools.partial(_fleet_adapt, cfg=self._cfg, ctx=self._ctx),
        )

    # -- state management ---------------------------------------------------

    def _put(self, x: jax.Array, axes: tuple) -> jax.Array:
        s = shd.sharding_for(axes, self._ctx, jnp.shape(x))
        return jax.device_put(x, s) if s is not None else jnp.asarray(x)

    def _zero_state(self) -> FleetState:
        s, cfg = self._n, self._cfg
        c = self._class_rows0.shape[1]
        if self._am_counts0 is not None:
            am_counts, am_n = self._am_counts0, self._am_n0
        else:
            am_counts = np.zeros((s, c, cfg.dim), np.int32)
            am_n = np.zeros((s, c), np.int32)
        axes = _STATE_AXES
        return FleetState(
            counts=self._put(
                jnp.zeros((s, cfg.dim), jnp.int32), axes["counts"]),
            filled=self._put(jnp.zeros((s,), jnp.int32), axes["filled"]),
            frame_index=self._put(
                jnp.zeros((s,), jnp.int32), axes["frame_index"]),
            class_rows=self._put(
                jnp.asarray(self._class_rows0), axes["class_rows"]),
            am_counts=self._put(jnp.asarray(am_counts), axes["am_counts"]),
            am_n=self._put(jnp.asarray(am_n), axes["am_n"]),
            last_frame=self._put(
                jnp.zeros((s, cfg.words), jnp.uint32), axes["last_frame"]),
            last_scores=self._put(
                jnp.zeros((s, c), jnp.int32), axes["last_scores"]),
            has_frame=self._put(jnp.zeros((s,), jnp.int32), axes["has_frame"]),
        )

    def reset(self) -> None:
        """Zero all accumulators, fill levels and frame indices, and restore
        every session's AM to its patient's trained (pre-adaptation) state."""
        self._state = self._zero_state()
        self._filled_h[:] = 0
        self._fidx_h[:] = 0

    @property
    def n_sessions(self) -> int:
        return self._n

    @property
    def state(self) -> FleetState:
        return self._state

    @property
    def fill_levels(self) -> np.ndarray:
        """(S,) cycles accumulated toward each next (incomplete) frame."""
        return np.asarray(self._state.filled)

    @property
    def frame_indices(self) -> np.ndarray:
        """(S,) frames emitted so far per session."""
        return np.asarray(self._state.frame_index)

    @property
    def compile_count(self) -> int:
        """Jitted-step executables built so far (<= number of buckets used).

        Prefers jit's real cache size (catches accidental recompiles); falls
        back to the count of distinct bucket shapes pushed if the private
        jax API ever disappears.
        """
        cache_size = getattr(self._step, "_cache_size", None)
        if cache_size is not None:
            return cache_size()
        return len(self._shapes_seen)

    # -- streaming ----------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        raise AssertionError("length exceeds max bucket")  # pragma: no cover

    def _round_masks(self, round_len: np.ndarray, t_pad: int) -> np.ndarray:
        """Host-built (S, K+1, t_pad) f32 cycle masks for one step.

        Cycle j of session s belongs to frame-slot ``(filled_s + j) //
        window`` — slots below the session's emission count are completed
        frames, everything else (and the padding) lands in the tail row.
        """
        window = self._cfg.window
        k_max = (t_pad - 1) // window + 1
        j = np.arange(t_pad)
        ordinal = (self._filled_h[:, None] + j[None, :]) // window  # (S, t)
        valid = j[None, :] < round_len[:, None]
        n_emit = (self._filled_h + round_len) // window  # (S,)
        rows = np.arange(k_max)
        frame_rows = (
            (ordinal[:, None, :] == rows[None, :, None])
            & (rows[None, :, None] < n_emit[:, None, None])
            & valid[:, None, :]
        )
        tail = (ordinal >= n_emit[:, None]) & valid
        return np.concatenate(
            [frame_rows, tail[:, None, :]], axis=1
        ).astype(np.float32)

    def push(self, chunks: Sequence) -> list[list[FrameDecision]]:
        """Feed one (t_i, channels) uint8 chunk per session.

        Chunk lengths may differ per session (0 included).  Returns, per
        session, the decisions for every frame completed by this push.
        """
        if len(chunks) != self._n:
            raise ValueError(
                f"push needs one chunk per session ({self._n}), got {len(chunks)}"
            )
        ch = self._cfg.channels
        arrs = []
        for i, c in enumerate(chunks):
            a = np.asarray(c, dtype=np.uint8)
            if a.size == 0:
                a = a.reshape(0, ch)
            if a.ndim != 2 or a.shape[1] != ch:
                raise ValueError(
                    f"session {i}: chunk must be (t, {ch}), got {a.shape}"
                )
            arrs.append(a)
        lengths = np.asarray([a.shape[0] for a in arrs], np.int64)
        out: list[list[FrameDecision]] = [[] for _ in range(self._n)]
        max_bucket = self._buckets[-1]
        pos = 0
        total = int(lengths.max(initial=0))
        while pos < total:
            round_len = np.clip(lengths - pos, 0, max_bucket)
            t_pad = self._bucket_for(int(round_len.max()))
            self._shapes_seen.add(t_pad)
            batch = np.zeros((self._n, t_pad, ch), np.uint8)
            for i, a in enumerate(arrs):
                n = int(round_len[i])
                if n:
                    batch[i, :n] = a[pos : pos + n]
            masks = self._round_masks(round_len, t_pad)
            n_emit = (self._filled_h + round_len) // self._cfg.window
            self._state, fo = self._step(
                self._state,
                self._tables,
                self._param_owner,
                self._thresholds,
                jnp.asarray(batch),
                jnp.asarray(round_len, dtype=jnp.int32),
                jnp.asarray(masks),
            )
            self._collect(fo, n_emit, out)
            self._filled_h += round_len - n_emit * self._cfg.window
            self._fidx_h += n_emit
            pos += max_bucket
        return out

    def _collect(
        self, fo: FleetOut, n_emit: np.ndarray, out: list[list[FrameDecision]]
    ) -> None:
        if not n_emit.any():
            return
        frames = np.asarray(fo.frames)
        scores = np.asarray(fo.scores)
        for s in np.nonzero(n_emit)[0]:
            for k in range(int(n_emit[s])):
                sc = scores[s, k]
                out[s].append(
                    FrameDecision(
                        frame_index=int(self._fidx_h[s]) + k,
                        scores=sc,
                        prediction=int(np.argmax(sc)),
                        frame_hv=frames[s, k],
                    )
                )

    # -- online adaptation ----------------------------------------------------

    @property
    def class_rows(self) -> np.ndarray:
        """(S, C, W) per-session (possibly adapted) class HV rows."""
        return np.asarray(self._state.class_rows)

    def adapt(self, labels: Sequence[int], *,
              margin: float = 0.0) -> np.ndarray:
        """Personalize all S sessions' AMs from one feedback label each.

        ``labels[i]`` is the true class of session ``i``'s LAST emitted
        frame; ``-1`` means no feedback (skip).  Sessions that have not
        emitted a frame yet are skipped too.  One jitted gated update
        (core.online) for the whole fleet: misclassified / low-margin
        sessions add the frame's bits to the true class's counters, subtract
        from the rival's, and get their class rows re-thresholded.
        Bit-exact with calling ``SeizureSession.adapt`` per stream.  Returns
        the (S,) bool mask of sessions whose update fired."""
        if self._am_counts0 is None:
            raise ValueError(
                "fleet bank has pipelines without am_state counter files; "
                "train them with train_one_shot/fit_iterative to enable "
                "adapt()")
        lab = np.asarray(labels, np.int64)
        if lab.shape != (self._n,):
            raise ValueError(
                f"adapt needs one label per session ({self._n}), got shape "
                f"{lab.shape}")
        if lab.max(initial=-1) >= self._cfg.n_classes:
            raise ValueError(
                f"labels must be < n_classes={self._cfg.n_classes} "
                "(-1 = no feedback)")
        self._state, applied = self._adapt_step(
            self._state,
            jnp.asarray(lab, dtype=jnp.int32),
            jnp.asarray(margin, jnp.float32),
            self._density,
        )
        return np.asarray(applied)

    # -- durability -----------------------------------------------------------

    def _meta(self) -> dict:
        return {
            "kind": "hdc_fleet",
            "n_sessions": self._n,
            "dim": self._cfg.dim,
            "window": self._cfg.window,
            "n_classes": self._cfg.n_classes,
            "variant": self._cfg.variant,
            "bank": self._bank_fingerprint(),
        }

    def _bank_fingerprint(self) -> str:
        """Digest of everything a checkpointed state is only valid against:
        the per-session codebook tables, initial class rows / AM banks and
        the per-session operand registers.  A fleet built from DIFFERENT
        patient pipelines shares none of these, and restoring state across
        banks would silently score one bank's frames against another's class
        HVs."""
        h = hashlib.sha256()
        operands = [self._tables, self._param_owner, self._thresholds,
                    self._density, self._class_rows0]
        if self._am_counts0 is not None:
            operands += [self._am_counts0, self._am_n0]
        for a in operands:
            arr = np.ascontiguousarray(np.asarray(a))
            h.update(str((arr.dtype.str, arr.shape)).encode())
            h.update(arr.tobytes())
        return h.hexdigest()[:16]

    def _state_shardings(self) -> FleetState | None:
        if self._ctx.mesh is None:
            return None
        return FleetState(**{
            f: shd.sharding_for(axes, self._ctx,
                                jnp.shape(getattr(self._state, f)))
            for f, axes in _STATE_AXES.items()
        })

    def save(self, root: str, step: int | None = None) -> str:
        """Checkpoint the full fleet state (streaming accumulators + online
        AM banks) under ``root`` via ckpt.checkpoint's atomic-rename
        contract; ``step`` defaults to one past the latest.  Returns the
        checkpoint directory."""
        if step is None:
            latest = ckpt.latest_step(root)
            step = 0 if latest is None else latest + 1
        return ckpt.save(root, step, self._state, meta=self._meta())

    def restore(self, root: str, step: int | None = None) -> int:
        """Restore a ``save``d fleet state into THIS fleet (same bank
        geometry and session count), elastic under the current mesh: leaves
        re-shard onto however many devices the restored fleet runs on.  The
        host-side emission schedule resumes from the restored fill levels,
        so pushes continue mid-stream bit-exactly.  Returns the step."""
        if step is None:
            step = ckpt.latest_step(root)
            if step is None:
                raise FileNotFoundError(f"no fleet checkpoint under {root!r}")
        with open(os.path.join(root, f"step_{step:08d}",
                               "manifest.json")) as f:
            meta = json.load(f).get("meta", {})
        want = self._meta()
        bad = {k: (meta.get(k), v) for k, v in want.items()
               if meta.get(k) != v}
        if bad:
            raise ValueError(
                f"checkpoint does not match this fleet: {bad} "
                "(saved, expected)")
        self._state = ckpt.restore(root, step, like=self._state,
                                   shardings=self._state_shardings())
        self._filled_h = np.asarray(self._state.filled).astype(np.int64)
        self._fidx_h = np.asarray(self._state.frame_index).astype(np.int64)
        return step
