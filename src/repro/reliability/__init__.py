"""Reliability subsystem: packed-domain fault injection, ECC word codecs
and fleet-scale degradation sweeps.

The implant case for sparse HDC rests on ultra-low-energy SRAM holding the
CompIM tables and the associative memory — exactly the memories that flip
bits at low voltage — and HDC's headline robustness claim is graceful
degradation under such faults (Karunaratne et al., arXiv:2106.11654).  This
package asks the implant-critical question the accuracy/energy benchmarks
cannot: how much detection accuracy / delay does each design variant lose
per unit bit-error rate, and when is ECC worth its read energy?

* ``faults``  — BER-parameterized fault injectors operating entirely in the
  packed uint32 domain (XOR with Bernoulli masks sampled from per-component
  PRNG keys INSIDE the jitted fleet step), targeting the CompIM/IM codebook
  bank, the packed AM class rows and the in-flight temporal accumulator
  counters independently, in transient or stuck-at mode.
* ``ecc``     — Hamming SECDED (and parity-detect) per packed 32-bit word,
  with corrected / detected / uncorrectable accounting and an op-count hook
  that maps through ``core.hwmodel`` constants to energy-per-read.
* ``sweep``   — fleet-scale degradation sweeps: synthetic-patient streams
  replayed through ``StreamingFleet`` across a BER grid x variant x density,
  reporting episode-level detection metrics (Pale et al., arXiv:2105.00934)
  plus the ECC energy overhead per point.
"""

from repro.reliability.ecc import SCHEMES, decode, encode, n_check_bits
from repro.reliability.faults import FaultConfig, FaultPlan

__all__ = ["FaultConfig", "FaultPlan", "SCHEMES", "decode", "encode",
           "n_check_bits"]
