"""Fleet-scale bit-error degradation sweeps (BER x variant x density).

The reliability question the subsystem answers: how fast does end-to-end
seizure-detection quality (accuracy, detection delay, false alarms) decay as
raw bit-error rate rises in each of the accelerator's memory classes, and
how much of that decay does word-level ECC on the associative memory buy
back, at what energy cost per read?

The sweep replays the SAME synthetic-patient test streams through a
``StreamingFleet`` for every grid point:

* one fleet per (variant, density, scheme) — the fault structure
  (``FaultPlan``) is a jit static, so the step compiles once;
* BER points ride the traced ``(3,)`` operand — ``set_ber`` + ``reset``
  walks the whole grid with zero recompiles;
* the BER = 0 point is checked BIT-EXACT (full per-frame score streams)
  against a fault-free fleet built from the identical pipelines — the
  degradation curves are anchored to the unmodified datapath, not to a
  parallel implementation.

Variant names follow ``core.hwmodel`` (dense / sparse_naive / sparse_compim
/ sparse_opt); ``HW_VARIANTS`` maps them onto ``HDCConfig`` fields.

Everything returns plain dicts so ``benchmarks/bench_reliability.py`` can
serialize points straight into ``BENCH_reliability.json``.
"""

from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.classifier import HDCConfig
from repro.core.pipeline import HDCPipeline
from repro.data import ieeg
from repro.reliability import ecc
from repro.reliability.faults import TARGETS, FaultConfig
from repro.serve.fleet import StreamingFleet

# hwmodel variant name -> HDCConfig overrides (mirrors hwmodel's mapping:
# "sparse_opt" is CompIM + OR-tree spatial bundling, "sparse_compim" the
# thinned CompIM design point, "sparse_naive" always thins).
HW_VARIANTS: dict[str, dict] = {
    "dense": {"variant": "dense", "spatial_thinning": False},
    "sparse_naive": {"variant": "sparse_naive", "spatial_thinning": True},
    "sparse_compim": {"variant": "sparse_compim", "spatial_thinning": True},
    "sparse_opt": {"variant": "sparse_compim", "spatial_thinning": False},
}


def variant_config(hw_variant: str, base: HDCConfig) -> HDCConfig:
    """Map a ``core.hwmodel`` variant name onto the pipeline config."""
    if hw_variant not in HW_VARIANTS:
        raise ValueError(f"variant {hw_variant!r} must be one of "
                         f"{sorted(HW_VARIANTS)}")
    return replace(base, **HW_VARIANTS[hw_variant])


# ---------------------------------------------------------------------------
# synthetic-patient session bank
# ---------------------------------------------------------------------------

def make_sessions(*, n_patients: int, n_test: int, channels: int,
                  record_kw: dict | None = None, seed: int = 0) -> dict:
    """Build the patient streams the whole sweep replays.

    Per patient: record 0 trains the one-shot AM, records 1..n_test are
    test streams.  Every (patient, test record) pair becomes one fleet
    session, so the batch stacks to (S, T, channels) with equal T by
    construction (fixed pre/ictal/post durations)."""
    record_kw = dict(record_kw or {})
    record_kw["channels"] = channels
    train, tests, owners, onsets = {}, [], [], []
    for pid in range(n_patients):
        rng = np.random.default_rng(7000 + seed + pid)
        recs = [ieeg.make_record(rng, **record_kw) for _ in range(1 + n_test)]
        train[f"p{pid}"] = recs[0]
        for rec in recs[1:]:
            tests.append(rec)
            owners.append(f"p{pid}")
            onsets.append(rec)
    batch = np.stack([r.codes for r in tests])  # (S, T, channels)
    return {"train": train, "tests": tests, "owners": owners, "batch": batch}


def train_pipelines(hw_variant: str, density: float, sessions: dict,
                    base_cfg: HDCConfig, *, seed: int = 0
                    ) -> tuple[dict[str, HDCPipeline], HDCConfig]:
    """One-shot pipelines per patient at this (variant, density) point.

    ``calibrate_density`` programs the temporal threshold BEFORE training
    (no-op for dense, which has no thinning stage)."""
    cfg = variant_config(hw_variant, base_cfg)
    pipes: dict[str, HDCPipeline] = {}
    for i, (name, rec) in enumerate(sessions["train"].items()):
        codes = jnp.asarray(rec.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
        pipe = HDCPipeline.init(jax.random.PRNGKey(seed + i), cfg)
        pipe = pipe.calibrate_density(codes, target=density)
        pipes[name] = pipe.train_one_shot(codes, labels)
    return pipes, cfg


# ---------------------------------------------------------------------------
# fleet replay
# ---------------------------------------------------------------------------

def replay(fleet: StreamingFleet, batch: np.ndarray
           ) -> tuple[np.ndarray, np.ndarray]:
    """Reset + stream the stacked test batch; returns per-session
    ``(preds (S, F) int32, scores (S, F, C) f32)``.  Records are
    equal-length, so every session emits the same frame count."""
    fleet.reset()
    decs = fleet.push_codes(batch)
    preds = np.asarray([[d.prediction for d in ds] for ds in decs], np.int32)
    scores = np.asarray([[d.scores for d in ds] for ds in decs], np.float32)
    return preds, scores


def detection_summary(preds: np.ndarray, sessions: dict, cfg: HDCConfig
                      ) -> dict:
    """k-of-m post-processed detection metrics over all fleet sessions."""
    res = [
        metrics.detection_metrics(
            preds[s], ieeg.onset_frame(rec, cfg.window),
            frame_seconds=cfg.window / ieeg.FS)
        for s, rec in enumerate(sessions["tests"])
    ]
    return metrics.aggregate(res)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def _fault_config(targets, mode: str, scheme: str, seed: int,
                  counts_bits: int | None = None) -> FaultConfig:
    bad = set(targets) - set(TARGETS)
    if bad:
        raise ValueError(f"unknown fault targets {sorted(bad)}; "
                         f"pick from {TARGETS}")
    kw = {t: (0.0 if t in targets else None) for t in TARGETS}
    return FaultConfig(mode=mode, seed=seed, ecc=scheme,
                       counts_bits=counts_bits, **kw)


def run_sweep(*, variants=("sparse_opt",), densities=(0.25,),
              bers=(0.0, 1e-3, 1e-2), schemes=("none",),
              targets=("tables", "am", "counts"), mode: str = "transient",
              base_cfg: HDCConfig, n_patients: int = 2, n_test: int = 2,
              record_kw: dict | None = None, seed: int = 0,
              counts_bits: int | None = None) -> list[dict]:
    """Degradation grid: variant x density x ECC scheme x BER.

    One fleet per (variant, density, scheme); BER moves via ``set_ber``
    (no recompiles).  Each point dict carries the detection metrics, the
    frame-level disagreement rate vs the clean run, cumulative ECC event
    counters, and the per-frame ECC read energy/overhead priced through
    ``core.hwmodel`` constants.  BER = 0 points additionally carry
    ``zero_ber_bitexact`` — full score-stream equality against a
    fault-free fleet (the acceptance gate; callers should treat False as
    an error).  ``counts_bits`` widens the faulted temporal-counter word
    to a physical register width (see ``faults.counter_bits``)."""
    sessions = make_sessions(n_patients=n_patients, n_test=n_test,
                             channels=base_cfg.channels,
                             record_kw=record_kw, seed=seed)
    batch, owners = sessions["batch"], sessions["owners"]
    points: list[dict] = []
    for hw in variants:
        for density in densities:
            pipes, cfg = train_pipelines(hw, density, sessions, base_cfg,
                                         seed=seed)
            buckets = (cfg.window,)
            clean = StreamingFleet(pipes, owners, buckets=buckets)
            clean_preds, clean_scores = replay(clean, batch)
            clean_agg = detection_summary(clean_preds, sessions, cfg)
            for scheme in schemes:
                fc = _fault_config(targets, mode, scheme, seed,
                                   counts_bits=counts_bits)
                fleet = StreamingFleet(pipes, owners, buckets=buckets,
                                       faults=fc)
                n_frames = clean_preds.size
                for ber in bers:
                    fleet.set_ber(float(ber))
                    preds, scores = replay(fleet, batch)
                    agg = detection_summary(preds, sessions, cfg)
                    st = fleet.ecc_stats.sum(axis=0)
                    point = {
                        "variant": hw, "density": float(density),
                        "scheme": scheme, "ber": float(ber), "mode": mode,
                        "targets": list(targets),
                        "sessions": len(owners), "frames": int(n_frames),
                        "detection_accuracy": agg["detection_accuracy"],
                        "mean_delay_s": agg["mean_delay_s"],
                        "false_alarm_rate": agg["false_alarm_rate"],
                        "clean_detection_accuracy":
                            clean_agg["detection_accuracy"],
                        "frame_disagreement":
                            float(np.mean(preds != clean_preds)),
                        "ecc_corrected": int(st[0]),
                        "ecc_detected": int(st[1]),
                        "ecc_uncorrectable": int(st[2]),
                        "ecc_read_energy_nj": ecc.read_energy_nj(
                            scheme, cfg.n_classes, cfg.words),
                        "ecc_read_overhead": ecc.read_overhead(
                            scheme, cfg.n_classes, cfg.words),
                    }
                    if ber == 0.0:
                        point["zero_ber_bitexact"] = bool(
                            np.array_equal(preds, clean_preds)
                            and np.array_equal(scores, clean_scores))
                    points.append(point)
    return points
