"""BER-parameterized packed-domain fault injection for the fleet datapath.

The three memory classes the implant's accuracy lives in — the CompIM/IM
codebook bank, the packed AM class rows, and the in-flight temporal
accumulator counters — are faulted INDEPENDENTLY, entirely in the packed
uint32 domain, and entirely INSIDE the jitted fleet step: each step derives
per-component PRNG keys from one scalar seed operand, samples Bernoulli
bit-flip masks (``core.hv.random_flip_mask``) and XORs them into the memory
READS.  No host work, no storage mutation, and the BER values ride as a
traced ``(3,)`` operand — one compiled executable serves a whole BER grid,
and BER = 0 is numerically bit-exact with the fault-free step.

Two fault modes:

* ``transient`` — a fresh mask per step (SEU-style upsets): the host folds
  the round counter into the seed, so every step sees independent flips.
* ``stuck``     — persistent cell faults: a FIXED per-tile seed selects a
  Bernoulli(ber) set of stuck cells once, each holding a fixed random
  value; every read of a stuck cell returns that value (textbook
  stuck-at-0/1, so the expected read-flip rate is ber/2).

The static/traced split: ``FaultPlan`` (which targets are compiled in, the
mode, base seed, ECC scheme) is hashable and rides as a jit static —
changing it recompiles; ``FaultConfig`` additionally carries the BER
VALUES, which ride as traced operands — ``StreamingFleet.set_ber`` moves
along the BER grid without recompiling.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hv
from repro.reliability import ecc

MODES = ("transient", "stuck")
TARGETS = ("tables", "am", "counts")  # index order of the traced BER vector


@dataclass(frozen=True)
class FaultPlan:
    """Static structure of a fault campaign (hashable; a jit static).

    ``tables`` / ``am`` / ``counts`` say which targets are compiled into
    the step at all — a disabled target costs literally nothing.  ``ecc``
    selects the AM word protection (``reliability.ecc.SCHEMES``).
    ``counts_bits`` overrides the faulted counter width (None = the
    VALUE width ceil(log2(window+1)); see ``counter_bits``)."""

    tables: bool = False
    am: bool = False
    counts: bool = False
    mode: str = "transient"
    seed: int = 0
    ecc: str = "none"
    counts_bits: int | None = None

    @property
    def any_target(self) -> bool:
        return self.tables or self.am or self.counts


@dataclass(frozen=True)
class FaultConfig:
    """A fault campaign: per-target BERs (None = target untouched and
    compiled out), fault mode, base PRNG seed and AM ECC scheme.

    ``ecc`` may be enabled with ``am=None`` (or BER 0) — protection is a
    hardware design choice, and its energy overhead is paid on every read
    whether or not faults land.

    ``counts_bits`` widens (or narrows) the faulted temporal-counter word:
    by default flips land only in the VALUE width ceil(log2(window+1)) —
    the bits a right-sized sparse counter bank would implement — but the
    paper's dense datapath carries a full physical D x 8-bit register file
    (core.bundling.temporal_counts), so ``counts_bits=8`` faults the dense
    counters at their real hardware width (the sparse-binary-vs-dense-
    counter degradation rows of bench_reliability.py)."""

    tables: float | None = None
    am: float | None = None
    counts: float | None = None
    mode: str = "transient"
    seed: int = 0
    ecc: str = "none"
    counts_bits: int | None = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode={self.mode!r} must be one of {MODES}")
        ecc.n_check_bits(self.ecc)  # validates the scheme name
        for name in TARGETS:
            ber = getattr(self, name)
            if ber is not None and not 0.0 <= float(ber) <= 1.0:
                raise ValueError(
                    f"{name} BER must be in [0, 1] or None, got {ber!r}")
        if self.counts_bits is not None and not 1 <= self.counts_bits <= 32:
            raise ValueError(
                f"counts_bits must be in [1, 32] or None, got "
                f"{self.counts_bits!r}")

    def plan(self) -> FaultPlan:
        return FaultPlan(tables=self.tables is not None,
                         am=self.am is not None,
                         counts=self.counts is not None,
                         mode=self.mode, seed=self.seed, ecc=self.ecc,
                         counts_bits=self.counts_bits)

    def ber_vector(self) -> np.ndarray:
        """(3,) float32 [tables, am, counts] BERs (0.0 for disabled targets)
        — the step's traced operand."""
        return np.asarray([float(getattr(self, t) or 0.0) for t in TARGETS],
                          np.float32)

    def with_ber(self, ber: float) -> "FaultConfig":
        """Every ENABLED target moved to one BER (grid sweeps); disabled
        targets stay compiled out."""
        if not 0.0 <= float(ber) <= 1.0:
            raise ValueError(f"ber={ber!r} must be in [0, 1]")
        return replace(self, **{
            t: (float(ber) if getattr(self, t) is not None else None)
            for t in TARGETS})


def counter_bits(plan: FaultPlan, window: int) -> int:
    """Faulted bit width of one temporal-accumulator counter.

    ``plan.counts_bits`` when set (e.g. 8 = the paper's full physical
    D x 8-bit dense register file, core.bundling.temporal_counts);
    otherwise the VALUE width ceil(log2(window+1)) — the minimum a
    right-sized counter bank implements, where every flip perturbs a bit
    the accumulation actually uses."""
    if plan.counts_bits is not None:
        return plan.counts_bits
    return max(1, int(np.ceil(np.log2(window + 1))))


# ---------------------------------------------------------------------------
# host-side seed schedule
# ---------------------------------------------------------------------------

def step_seed(plan: FaultPlan, *, tile: int, n_tiles: int, phase: int) -> int:
    """Scalar seed operand for one (tile, round): stuck faults reuse a fixed
    per-tile seed (the same masks every step = persistent cells); transient
    faults fold the round counter in (fresh masks every step).  The ranges
    never collide."""
    if plan.mode == "stuck":
        return plan.seed + tile
    return plan.seed + n_tiles * (1 + phase) + tile


def component_keys(seed) -> jax.Array:
    """(3, key) per-target PRNG keys (TARGETS order) from one scalar seed.

    ``seed`` may be traced — the whole derivation runs inside the jitted
    step, so the host ships one int32 and no mask bytes."""
    return jax.random.split(jax.random.PRNGKey(seed), len(TARGETS))


# ---------------------------------------------------------------------------
# read-fault transforms (pure jnp, traced ber)
# ---------------------------------------------------------------------------

def xor_mask(words: jax.Array, key: jax.Array, ber, *,
             bits: int = hv.WORD, mode: str = "transient") -> jax.Array:
    """Effective XOR mask such that ``words ^ mask`` is the faulty read.

    Transient: a fresh Bernoulli(ber) flip mask.  Stuck: a persistent
    Bernoulli(ber) cell-select mask with fixed random stuck values ``v`` —
    the read returns ``(w & ~sel) | (v & sel)``, i.e. the XOR mask is
    ``(w ^ v) & sel`` (depends on the stored data, as stuck-at does).
    ``ber == 0`` yields an all-zero mask either way."""
    if mode == "transient":
        return hv.random_flip_mask(key, words.shape, ber, bits)
    if mode != "stuck":
        raise ValueError(f"mode={mode!r} must be one of {MODES}")
    k_sel, k_val = jax.random.split(key)
    sel = hv.random_flip_mask(k_sel, words.shape, ber, bits)
    val = hv.random_flip_mask(k_val, words.shape, 0.5, bits)
    return (words ^ val) & sel


def flip_words(words: jax.Array, key: jax.Array, ber, *,
               bits: int = hv.WORD, mode: str = "transient") -> jax.Array:
    """Faulty read of packed uint32 words at bit-error-rate ``ber``."""
    return words ^ xor_mask(words, key, ber, bits=bits, mode=mode)


def flip_counts(counts: jax.Array, key: jax.Array, ber, *,
                bits: int, mode: str = "transient") -> jax.Array:
    """Faulty read of the int32 temporal accumulators: only the low ``bits``
    bits exist in hardware (the D x ceil(log2(window+1))-bit counter bank of
    core.hwmodel), so flips land there and the value stays in range."""
    u = counts.astype(jnp.uint32)
    return flip_words(u, key, ber, bits=bits, mode=mode).astype(jnp.int32)
