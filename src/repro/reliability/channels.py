"""Electrode-fault models + online channel-health quarantine.

Chronic iEEG's dominant real-world fault is a failing ELECTRODE, not a
flipped memory bit (reliability.faults' territory): over months of
implantation channels go flat, rail at the amplifier limits, pick up line
noise, drop out intermittently, or drift in gain.  HDC is structurally
robust to this failure class — the spatial bundle is a symmetric
OR/threshold over channel HVs, so a known-bad channel is a MASKABLE term,
not a retrain — and this module supplies the three pieces that turn the
fleet's channel-mask operand (``StreamingFleet(channel_masking=True)`` +
``set_channel_mask``) into an end-to-end robustness story:

* **fault models**, at two levels: raw-signal injection for
  ``data/ieeg.py`` records (all five ``CHANNEL_FAULT_TYPES``) and
  LBP-code-level injection for fleet-scale sweeps (``CODE_FAULT_TYPES`` —
  everything except ``gain_drift``: LBP's sign-of-difference coding is
  invariant to constant gain, and a slow drift perturbs only near-tie
  first differences, so the code statistics stay healthy — the built-in
  robustness the paper's preprocessing buys);
* an online **ChannelHealthMonitor** that flags dead/railed channels
  purely from per-channel LBP code statistics (entropy collapse and
  stuck-code runs — no raw signal needed, so it runs wherever codes flow)
  with hysteresis-based quarantine/reinstate and an event log;
* the **fleet wrapper** (``FleetChannelMonitor``) holding one monitor per
  session and emitting the (S, C) masks ``set_channel_mask`` consumes.

Mask semantics per variant live in serve/dispatch.py ("Channel masking");
the degradation benchmark is benchmarks/bench_channelfault.py.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data import ieeg

# signal-level kinds; CODE_FAULT_TYPES is the subset observable in the
# LBP code domain (gain drift is amplitude-only and LBP codes are
# amplitude-invariant, so it has no code-level model — by design)
CHANNEL_FAULT_TYPES = ("dead", "saturated", "line_noise", "dropout",
                       "gain_drift")
CODE_FAULT_TYPES = ("dead", "saturated", "line_noise", "dropout")

LINE_HZ = 50.0  # mains interference frequency of the line_noise model


# ---------------------------------------------------------------------------
# signal-level electrode fault models (raw (channels, T) float signal)
# ---------------------------------------------------------------------------

def inject_signal_fault(x: np.ndarray, channel: int, kind: str,
                        rng: np.random.Generator, *, fs: int = ieeg.FS,
                        start: int = 0) -> np.ndarray:
    """Return a copy of the (channels, T) raw signal with one electrode
    fault injected on ``channel`` from sample ``start`` on.

    ``dead``       — the contact detaches: the channel holds its last value
                     (flat line; LBP codes collapse to 0).
    ``saturated``  — the amplifier rails: hard clip at a rail well inside
                     the signal's dynamic range, so the waveform slams
                     between the rails (long stuck-code runs).
    ``line_noise`` — a failing reference couples in mains: a 50 Hz
                     sinusoid an order of magnitude above the signal
                     dominates the first differences (periodic codes).
    ``dropout``    — intermittent contact: random flat segments (geometric
                     lengths, ~half duty cycle) interleave with the true
                     signal.
    ``gain_drift`` — electrode impedance drifts: a slow multiplicative
                     gain ramp (2x over the fault span).  LBP coding is
                     invariant to constant gain and a slow ramp perturbs
                     only near-tie first differences, so the channel's
                     code statistics stay healthy — the model exists to
                     DEMONSTRATE that robustness (tests/test_channels.py).
    """
    if kind not in CHANNEL_FAULT_TYPES:
        raise ValueError(f"kind={kind!r} must be one of "
                         f"{CHANNEL_FAULT_TYPES}")
    x = np.array(x, dtype=np.float32, copy=True)
    ch = x[channel]
    t = ch.shape[0]
    if not 0 <= start < t:
        raise ValueError(f"start={start} outside [0, {t})")
    span = t - start
    if kind == "dead":
        ch[start:] = ch[start]
    elif kind == "saturated":
        rail = 0.25 * float(np.std(ch) or 1.0)
        ch[start:] = np.clip(ch[start:], -rail, rail)
    elif kind == "line_noise":
        amp = 10.0 * float(np.std(ch) or 1.0)
        tt = np.arange(start, t, dtype=np.float32) / fs
        ch[start:] = ch[start:] + amp * np.sin(
            2 * np.pi * LINE_HZ * tt, dtype=np.float32)
    elif kind == "dropout":
        pos, flat = start, False
        while pos < t:
            seg = int(rng.geometric(1.0 / 64.0))
            if flat:
                ch[pos:pos + seg] = ch[pos - 1] if pos else ch[0]
            pos += seg
            flat = not flat
    else:  # gain_drift
        ramp = 1.0 + np.arange(span, dtype=np.float32) / max(span - 1, 1)
        ch[start:] = ch[start:] * ramp
    x[channel] = ch
    return x


def signal_fault_transform(faults: list[tuple[int, str]], *,
                           fs: int = ieeg.FS, start: int = 0):
    """Build the ``ieeg.make_record(signal_transform=...)`` hook that
    injects ``[(channel, kind), ...]`` electrode faults into a record's
    raw signal just before LBP coding — per-channel, per-record fault
    injection through the exact production preprocessing."""
    for ch, kind in faults:
        if kind not in CHANNEL_FAULT_TYPES:
            raise ValueError(f"kind={kind!r} must be one of "
                             f"{CHANNEL_FAULT_TYPES}")

    def transform(x, rng):
        for ch, kind in faults:
            x = inject_signal_fault(x, ch, kind, rng, fs=fs, start=start)
        return x
    return transform


# ---------------------------------------------------------------------------
# code-level electrode fault models ((..., T, C) uint8 LBP codes)
# ---------------------------------------------------------------------------

def inject_code_fault(codes: np.ndarray, channel: int, kind: str,
                      rng: np.random.Generator, *, bits: int = 6,
                      fs: int = ieeg.FS, start: int = 0) -> np.ndarray:
    """Return a copy of the (..., T, C) uint8 LBP codes with ``channel``
    replaced by the code stream the corresponding SIGNAL fault produces —
    the fleet-scale injection point (no raw signal round-trip per sweep).

    ``dead`` is code 0 (a flat line has no positive first differences);
    ``saturated`` alternates geometric-length runs of 0 (parked at a rail)
    and ``2**bits - 1`` (slamming upward between rails); ``line_noise`` is
    the exact LBP coding of a dominant 50 Hz sinusoid (periodic over
    fs / 50 samples); ``dropout`` interleaves flat (code 0) segments with
    the channel's true codes.  ``gain_drift`` has no code-level model —
    gain barely moves the code statistics (see inject_signal_fault) —
    and raises.
    """
    if kind not in CODE_FAULT_TYPES:
        raise ValueError(
            f"kind={kind!r} must be one of {CODE_FAULT_TYPES} "
            "(gain_drift is signal-only: LBP codes are amplitude-"
            "invariant, see inject_signal_fault)")
    codes = np.array(codes, copy=True)
    t = codes.shape[-2]
    if not 0 <= start < t:
        raise ValueError(f"start={start} outside [0, {t})")
    span = t - start
    full = np.uint8((1 << bits) - 1)
    if kind == "dead":
        codes[..., start:, channel] = 0
    elif kind == "saturated":
        stream = np.zeros(span, np.uint8)
        pos, high = 0, False
        while pos < span:
            seg = int(rng.geometric(1.0 / 32.0))
            stream[pos:pos + seg] = full if high else 0
            pos += seg
            high = not high
        codes[..., start:, channel] = stream
    elif kind == "line_noise":
        tt = np.arange(start, t + bits, dtype=np.float32) / fs
        wave = np.sin(2 * np.pi * LINE_HZ * tt, dtype=np.float32)
        codes[..., start:, channel] = ieeg.lbp_codes_np(wave, bits)[:span]
    else:  # dropout
        pos, flat = start, False
        while pos < t:
            seg = int(rng.geometric(1.0 / 64.0))
            if flat:
                codes[..., pos:pos + seg, channel] = 0
            pos += seg
            flat = not flat
    return codes


def degrade_batch(batch: np.ndarray, n_failed: int, kind: str, *,
                  seed: int = 0, bits: int = 6
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Fleet-sweep helper: fail ``n_failed`` channels per session (chosen
    independently per session) in an (S, T, C) code batch.

    Returns ``(faulted_batch, mask)`` where ``mask`` is the (S, C) uint8
    LIVE mask (0 on the faulted channels) — exactly what
    ``StreamingFleet.set_channel_mask`` takes for the oracle-quarantine
    arm of the degradation sweep."""
    s, _, c = batch.shape
    if not 0 <= n_failed <= c:
        raise ValueError(f"n_failed={n_failed} outside [0, {c}]")
    rng = np.random.default_rng(seed)
    out = np.array(batch, copy=True)
    mask = np.ones((s, c), np.uint8)
    for i in range(s):
        for ch in rng.choice(c, size=n_failed, replace=False):
            out[i] = inject_code_fault(out[i], int(ch), kind, rng, bits=bits)
            mask[i, ch] = 0
    return out, mask


# ---------------------------------------------------------------------------
# online channel-health monitoring (code statistics only)
# ---------------------------------------------------------------------------

def channel_stats(codes: np.ndarray, *, n_codes: int = 64
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Per-channel health statistics of one (T, C) code block.

    Returns ``(entropy, stuck)``: the Shannon entropy (bits) of each
    channel's code histogram and its longest same-code run.  Healthy
    broadband iEEG spreads LBP codes over the alphabet (entropy well
    above 1 bit, short runs); a dead/railed electrode collapses to a
    handful of codes (entropy -> 0) and/or parks on one code for long
    runs.  Line noise keeps runs short but still collapses the histogram
    onto the few codes of its periodic pattern."""
    t, c = codes.shape
    ent = np.zeros(c, np.float64)
    stuck = np.zeros(c, np.int64)
    for ch in range(c):
        col = codes[:, ch]
        hist = np.bincount(col, minlength=n_codes).astype(np.float64)
        p = hist[hist > 0] / t
        ent[ch] = float(-(p * np.log2(p)).sum())
        changes = np.nonzero(np.diff(col))[0]
        edges = np.concatenate([[-1], changes, [t - 1]])
        stuck[ch] = int(np.diff(edges).max())
    return ent, stuck


@dataclass
class ChannelHealthMonitor:
    """Hysteresis quarantine of failing electrodes from LBP code blocks.

    Feed each service interval's (T, C) codes to ``observe``; a channel
    whose block statistics look dead/railed (entropy below
    ``min_entropy`` OR a same-code run longer than ``max_stuck``) earns an
    unhealthy strike, and ``quarantine_after`` CONSECUTIVE strikes
    quarantine it (mask 0).  A quarantined channel that produces
    ``reinstate_after`` consecutive healthy blocks is reinstated — the
    hysteresis (quarantine fast, reinstate slowly, never on a single
    block) keeps a flickering electrode from thrashing the mask.  Every
    transition lands in ``events`` (block index, channel, event, the
    triggering statistics) — the log ``launch/serve.py`` surfaces.

    ``mask`` is the current (C,) uint8 live mask, shaped for
    ``StreamingFleet.set_channel_mask``.
    """

    channels: int
    n_codes: int = 64
    min_entropy: float = 0.5
    max_stuck: int = 96
    quarantine_after: int = 2
    reinstate_after: int = 4
    mask: np.ndarray = field(init=False)
    events: list[dict] = field(init=False, default_factory=list)

    def __post_init__(self):
        self.mask = np.ones(self.channels, np.uint8)
        self._bad_streak = np.zeros(self.channels, np.int64)
        self._good_streak = np.zeros(self.channels, np.int64)
        self._block = 0

    def observe(self, codes: np.ndarray) -> np.ndarray:
        """Update health state from one (T, C) code block; returns the
        (C,) live mask AFTER this block."""
        codes = np.asarray(codes)
        if codes.ndim != 2 or codes.shape[1] != self.channels:
            raise ValueError(
                f"observe needs a (t, {self.channels}) code block, got "
                f"{codes.shape}")
        if codes.shape[0] == 0:
            return self.mask.copy()
        ent, stuck = channel_stats(codes, n_codes=self.n_codes)
        bad = (ent < self.min_entropy) | (stuck > self.max_stuck)
        self._bad_streak = np.where(bad, self._bad_streak + 1, 0)
        self._good_streak = np.where(bad, 0, self._good_streak + 1)
        for ch in range(self.channels):
            if self.mask[ch] and self._bad_streak[ch] >= \
                    self.quarantine_after:
                self.mask[ch] = 0
                self.events.append({
                    "block": self._block, "channel": ch,
                    "event": "quarantine", "entropy": float(ent[ch]),
                    "stuck_run": int(stuck[ch])})
            elif not self.mask[ch] and self._good_streak[ch] >= \
                    self.reinstate_after:
                self.mask[ch] = 1
                self.events.append({
                    "block": self._block, "channel": ch,
                    "event": "reinstate", "entropy": float(ent[ch]),
                    "stuck_run": int(stuck[ch])})
        self._block += 1
        return self.mask.copy()

    @property
    def n_quarantined(self) -> int:
        return int((self.mask == 0).sum())


class FleetChannelMonitor:
    """One ``ChannelHealthMonitor`` per fleet session.

    ``observe(batch)`` consumes the same (S, T, C) code batch the fleet's
    ``push_codes`` takes and returns the stacked (S, C) live mask —
    changed masks go straight to ``StreamingFleet.set_channel_mask`` (a
    traced-operand update, no recompiles).  ``events`` merges the
    per-session logs with a ``session`` key."""

    def __init__(self, n_sessions: int, channels: int, **monitor_kw):
        self._monitors = [ChannelHealthMonitor(channels, **monitor_kw)
                          for _ in range(n_sessions)]

    def observe(self, batch: np.ndarray) -> np.ndarray:
        batch = np.asarray(batch)
        if batch.ndim != 3 or batch.shape[0] != len(self._monitors):
            raise ValueError(
                f"observe needs a ({len(self._monitors)}, t, channels) "
                f"batch, got {batch.shape}")
        return np.stack([m.observe(batch[i])
                         for i, m in enumerate(self._monitors)])

    @property
    def masks(self) -> np.ndarray:
        return np.stack([m.mask for m in self._monitors])

    @property
    def events(self) -> list[dict]:
        out = []
        for i, m in enumerate(self._monitors):
            out.extend({**e, "session": i} for e in m.events)
        out.sort(key=lambda e: (e["block"], e["session"], e["channel"]))
        return out

    @property
    def n_quarantined(self) -> int:
        return sum(m.n_quarantined for m in self._monitors)
