"""ECC word codecs for packed-domain associative memories.

The AM stores one packed uint32 word per 32 HV bits.  This module protects
each stored word independently with one of three schemes:

* ``none``   — raw storage, no check bits (the paper's design).
* ``parity`` — one even-parity bit per word: detects any odd number of
  flips, corrects nothing.
* ``secded`` — Hamming SECDED over the 32-bit word: a (39, 32) code with 6
  Hamming check bits plus one overall parity bit.  Any single flipped bit
  of the 39-bit codeword is corrected; any double flip is detected as
  uncorrectable.  (Triple flips may miscorrect, as in real SECDED SRAM.)

All codecs are pure jnp, vectorized over arbitrary leading axes and
jit-compatible, so the fleet step decodes every session's AM rows in one
shot.  ``decode`` classifies each word as clean (0) / corrected (1) /
uncorrectable (2) — ``serve.fleet`` accumulates these into the per-session
corrected/detected/uncorrectable counters the degradation sweeps report.

The cost side: ``ops_per_word`` counts the XOR/AND gate evaluations of one
word's read-path decode (syndrome trees, compare, correction), and
``read_energy_nj`` maps one full AM read (``n_classes * cfg.words`` words)
through the ``core.hwmodel`` 16nm gate-energy constants — so raw and
ECC-protected AMs land on a single energy axis in the sweeps.

Codeword layout (SECDED): the standard Hamming positions 1..38 hold the 6
check bits at the power-of-two positions and the 32 data bits at the rest;
a flipped data bit at position p yields syndrome p, a flipped check bit i
yields syndrome 2**i.  The check word packs [c0..c5, overall] into the low
7 bits of a uint32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hv, hwmodel

SCHEMES = ("none", "parity", "secded")

# word-level decode status codes
CLEAN, CORRECTED, UNCORRECTABLE = 0, 1, 2


def _secded_tables() -> tuple[np.ndarray, np.ndarray]:
    """(parity_masks (6,) uint32, synd_flip (64,) uint32) for SECDED(39,32).

    ``parity_masks[i]`` selects the data bits covered by Hamming check bit
    ``i`` (data bit j lives at the j-th non-power-of-two codeword position);
    ``synd_flip[s]`` is the data-word XOR mask that corrects syndrome ``s``
    (0 when s points at a check bit, the overall bit, or no position).
    """
    data_pos = [p for p in range(1, 39) if p & (p - 1)]  # 32 of them
    assert len(data_pos) == hv.WORD
    masks = np.zeros(6, np.uint32)
    flip = np.zeros(64, np.uint32)
    for j, p in enumerate(data_pos):
        flip[p] = np.uint32(1) << j
        for i in range(6):
            if (p >> i) & 1:
                masks[i] |= np.uint32(1) << j
    return masks, flip


_PARITY_MASKS, _SYND_FLIP = _secded_tables()

_CHECK_BITS = {"none": 0, "parity": 1, "secded": 7}


def n_check_bits(scheme: str) -> int:
    """Stored check bits per protected 32-bit word."""
    if scheme not in SCHEMES:
        raise ValueError(f"unknown ECC scheme {scheme!r}; pick from {SCHEMES}")
    return _CHECK_BITS[scheme]


def encode(words: jax.Array, scheme: str = "secded") -> jax.Array:
    """Check bits for packed uint32 ``words`` (same shape, low-bit packed).

    This is what the AM write path stores alongside each data word; the
    fleet recomputes it from the clean stored rows inside the jitted step,
    which is bit-identical to carrying stored check bits (storage is never
    mutated by the read-fault model)."""
    n_check_bits(scheme)  # validate
    if scheme == "none":
        return jnp.zeros_like(words)
    if scheme == "parity":
        return hv.word_parity(words)
    check = jnp.zeros_like(words)
    for i, m in enumerate(_PARITY_MASKS):
        check = check | (hv.word_parity(words & jnp.uint32(m)) << i)
    overall = hv.word_parity(words) ^ hv.word_parity(check)
    return check | (overall << 6)


def decode(words: jax.Array, check: jax.Array, scheme: str = "secded"
           ) -> tuple[jax.Array, jax.Array]:
    """Decode possibly-corrupted (word, check) pairs.

    Returns ``(corrected_words, status)`` with status int32 per word:
    ``CLEAN`` (0), ``CORRECTED`` (1, data repaired — or the fault was in a
    check/parity bit and the data was already clean), ``UNCORRECTABLE``
    (2, detected but not repairable: SECDED double flips, or any odd-count
    parity mismatch, which corrects nothing)."""
    n_check_bits(scheme)  # validate
    if scheme == "none":
        return words, jnp.zeros(words.shape, jnp.int32)
    if scheme == "parity":
        mismatch = hv.word_parity(words) ^ (check & jnp.uint32(1))
        return words, (mismatch * UNCORRECTABLE).astype(jnp.int32)
    syn = jnp.zeros_like(words)
    for i, m in enumerate(_PARITY_MASKS):
        rx = (check >> i) & jnp.uint32(1)
        syn = syn | ((hv.word_parity(words & jnp.uint32(m)) ^ rx) << i)
    # parity over all 39 received bits: odd -> an odd number of flips
    overall = hv.word_parity(words) ^ hv.word_parity(check & jnp.uint32(0x7F))
    single = overall == 1
    flip = jnp.asarray(_SYND_FLIP)[syn.astype(jnp.int32)]
    corrected = jnp.where(single, words ^ flip, words)
    status = jnp.where(single, CORRECTED,
                       jnp.where(syn != 0, UNCORRECTABLE, CLEAN))
    return corrected, status.astype(jnp.int32)


# ---------------------------------------------------------------------------
# cost model: gate ops per read -> energy through core.hwmodel constants
# ---------------------------------------------------------------------------

def ops_per_word(scheme: str) -> dict[str, int]:
    """Gate evaluations of one word's read-path decode, by gate kind.

    ``parity``: one 33-input XOR tree (data + stored parity bit).
    ``secded``: six syndrome parity trees over the covered data bits, six
    check-bit compares, the 39-input overall-parity tree, the 6->38
    syndrome one-hot decode (two AND2 levels per line), the 32 correction
    XORs and their single-error gating ANDs.  Keys map 1:1 onto
    ``hwmodel.gate_energy_fj``."""
    n_check_bits(scheme)  # validate
    if scheme == "none":
        return {"xor2": 0, "and2": 0}
    if scheme == "parity":
        return {"xor2": 32, "and2": 0}
    tree_xor = int(sum(int(m).bit_count() - 1 for m in _PARITY_MASKS))
    return {
        "xor2": tree_xor + 6 + 38 + 32,  # trees + compare + overall + fix
        "and2": 2 * 38 + 32,             # syndrome decode + correction gate
    }


def read_ops(scheme: str, n_classes: int, words: int) -> dict[str, int]:
    """Gate evaluations of one full AM read (all class rows decoded)."""
    per = ops_per_word(scheme)
    n = n_classes * words
    return {k: v * n for k, v in per.items()}


def raw_am_read_ops(n_classes: int, words: int) -> dict[str, int]:
    """Baseline ops of the UNPROTECTED AM similarity read, for the overhead
    ratio: per word one 32-bit AND plus its share of the popcount adder tree
    (D-1 full adders over the whole row)."""
    return {"and2": n_classes * words * hv.WORD,
            "fa": n_classes * (words * hv.WORD - 1)}


def read_energy_nj(scheme: str, n_classes: int, words: int,
                   c: hwmodel.HWConstants = hwmodel.C16) -> float:
    """Energy (nJ) of one AM read's ECC decode, via hwmodel gate constants."""
    return hwmodel.gate_energy_fj(read_ops(scheme, n_classes, words), c) * 1e-6


def read_overhead(scheme: str, n_classes: int, words: int,
                  c: hwmodel.HWConstants = hwmodel.C16) -> float:
    """ECC decode energy as a fraction of the raw AM similarity read."""
    base = hwmodel.gate_energy_fj(raw_am_read_ops(n_classes, words), c)
    return hwmodel.gate_energy_fj(read_ops(scheme, n_classes, words), c) / base
