import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on 512 placeholder host devices and extract the roofline terms.

MUST be the first import in the process (XLA_FLAGS above precedes any jax
import — jax locks the device count on first init).

Per cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with:
  memory_analysis   (bytes per device: args / outputs / temps / peak)
  cost_analysis     (per-device HLO flops & bytes accessed)
  collectives       (per-op-kind byte totals parsed from the partitioned HLO)
  roofline          (compute / memory / collective seconds + dominant term)

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --hdc                # the paper's HDC system
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
from repro.data import lm as lmdata
from repro.launch.mesh import make_production_mesh
from repro.models import params as pmod
from repro.models.config import param_count
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.runtime.hlo_cost import analyze_hlo
from repro.runtime.roofline import memory_analysis_dict, roofline_terms

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def abstract_opt_state(spec, opt: adamw.OptConfig):
    sdt = jnp.dtype(opt.state_dtype)
    mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, sdt), spec,
                      is_leaf=lambda s: isinstance(s, pmod.ParamSpec))
    return {"m": mv, "v": mv, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def lower_cell(arch_id: str, shape_name: str, mesh_kind: str,
               overrides: dict | None = None):
    import dataclasses
    cfg = get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = lmdata.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    opt = adamw.OptConfig(
        state_dtype="bfloat16" if "398b" in arch_id else "float32")
    dtype = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        specs = lmdata.input_specs(cfg, shape)
        jitted, ctx, spec = steps_mod.jit_train_step(cfg, opt, mesh, specs)
        params_abs = pmod.abstract(spec, dtype)
        lowered = jitted.lower(params_abs, abstract_opt_state(spec, opt), specs)
    elif shape.kind == "prefill":
        specs = lmdata.input_specs(cfg, shape)
        jitted, ctx, spec = steps_mod.jit_prefill(
            cfg, mesh, specs, cache_seq=shape.seq_len)
        params_abs = pmod.abstract(spec, dtype)
        lowered = jitted.lower(params_abs, specs)
    else:  # decode
        specs = lmdata.input_specs(cfg, shape)
        seq_sharded = shape.global_batch < 16   # long_500k: SP over the cache
        jitted, ctx, spec = steps_mod.jit_decode_step(
            cfg, mesh, specs, seq_sharded_kv=seq_sharded)
        params_abs = pmod.abstract(spec, dtype)
        lowered = jitted.lower(params_abs, specs["tokens"], specs["caches"],
                               specs["pos"])
    return cfg, shape, mesh, lowered


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(
        out_dir, f"{arch_id}__{shape_name}__{mesh_kind}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch_id)
    shape = lmdata.SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    record = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
              "kind": shape.kind, "tag": tag, "overrides": overrides or {}}
    if not ok:
        record |= {"status": "skipped", "reason": reason}
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    t0 = time.time()
    try:
        cfg, shape, mesh, lowered = lower_cell(arch_id, shape_name, mesh_kind,
                                               overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = memory_analysis_dict(compiled.memory_analysis())
        print(f"[{arch_id} {shape_name} {mesh_kind}] memory_analysis:", mem)
        xla_cost = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
                    if isinstance(v, (int, float))}
        # XLA's cost_analysis counts while bodies once (useless under scan):
        # use our call-graph analyzer with trip-count multiplication instead
        hlo = analyze_hlo(compiled.as_text())
        cost = {"flops": hlo["flops"], "bytes accessed": hlo["bytes"]}
        colls = hlo["collectives"]
        print(f"[{arch_id} {shape_name} {mesh_kind}] hlo_cost: "
              f"flops={hlo['flops']:.3e} bytes={hlo['bytes']:.3e} "
              f"colls={ {k: f'{v:.2e}' for k, v in colls.items()} }")
        n_total, n_active = param_count(cfg)
        terms = roofline_terms(cost, colls, cfg, shape, mesh,
                               n_total=n_total, n_active=n_active)
        record |= {"status": "ok", "lower_s": round(t_lower, 1),
                   "compile_s": round(t_compile, 1), "memory": mem,
                   "cost": cost, "xla_cost_analysis": xla_cost,
                   "collectives": colls, "roofline": terms,
                   "params_total": n_total, "params_active": n_active}
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record |= {"status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        print(f"[{arch_id} {shape_name} {mesh_kind}] FAILED: {record['error']}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def run_hdc(out_dir: str, mesh_kind: str = "single", force: bool = False):
    """Dry-run the paper's sparse-HDC inference pipeline as a serving cell:
    batched streams sharded over (pod,)data; AM classes replicated."""
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"hdc-ieeg__serve__{mesh_kind}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.classifier import HDCConfig
    from repro.core import classifier as clf
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = HDCConfig()
    dp = ("pod", "data") if mesh_kind == "multi" else ("data",)
    batch, t = 8192, 2048     # 8192 concurrent streams, 8 frames each
    specs = {
        "item_pos": jax.ShapeDtypeStruct((cfg.channels, 64, cfg.segments), jnp.uint8),
        "elec_pos": jax.ShapeDtypeStruct((cfg.channels, cfg.segments), jnp.uint8),
        "codes": jax.ShapeDtypeStruct((batch, t, cfg.channels), jnp.uint8),
        "classes": jax.ShapeDtypeStruct((2, cfg.words), jnp.uint32),
    }
    from repro.core.im import IMParams
    from repro.core import am

    def serve(item_pos, elec_pos, codes, classes):
        params = IMParams(item_pos=item_pos, elec_pos=elec_pos,
                          dim=cfg.dim, segments=cfg.segments)
        frames = clf.encode_frames(params, codes, cfg)
        scores = am.am_scores_sparse(frames, classes)
        return am.am_predict(scores)

    shard = NamedSharding(mesh, P(dp))
    rep = NamedSharding(mesh, P())
    jitted = jax.jit(serve, in_shardings=(rep, rep, shard, rep),
                     out_shardings=shard)
    record = {"arch": "hdc-ieeg", "shape": "serve", "mesh": mesh_kind,
              "kind": "serve"}
    t0 = time.time()
    try:
        lowered = jitted.lower(specs["item_pos"], specs["elec_pos"],
                               specs["codes"], specs["classes"])
        compiled = lowered.compile()
        mem = memory_analysis_dict(compiled.memory_analysis())
        hlo = analyze_hlo(compiled.as_text())
        cost = {"flops": hlo["flops"], "bytes accessed": hlo["bytes"]}
        colls = hlo["collectives"]
        preds = batch * (t // cfg.window)
        record |= {"status": "ok", "compile_s": round(time.time() - t0, 1),
                   "memory": mem, "cost": cost, "collectives": colls,
                   "predictions_per_call": preds}
        print(f"[hdc {mesh_kind}] mem={mem} flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}")
    except Exception as e:  # noqa: BLE001
        record |= {"status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        print(f"[hdc {mesh_kind}] FAILED: {record['error']}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(lmdata.SHAPES) + [None])
    ap.add_argument("--mesh", default=None, choices=["single", "multi", None])
    ap.add_argument("--hdc", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for overrides")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. moe_dispatch=local_index")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v

    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    if args.hdc:
        for mk in meshes:
            run_hdc(args.out, mk, force=args.force)
        return
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(lmdata.SHAPES)
    n_ok = n_skip = n_err = 0
    for mk in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mk, args.out, force=args.force,
                               overrides=overrides, tag=args.tag)
                status = rec.get("status")
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                print(f"== {a} {s} {mk}: {status}")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
