"""Serving launcher: prefill + decode loop for any zoo architecture.

Container-scale usage (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --prompt-len 32 --gen 16

On a fleet the same entry point runs the full config on the production mesh
(--mesh 16x16), with the KV cache sharded per runtime/sharding.py (batch-DP
for wide batches, sequence-parallel for long-context single streams).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data import lm as lmdata
from repro.launch.train import parse_mesh
from repro.models import model as M
from repro.models import params as P
from repro.models import serve as S
from repro.runtime import steps as steps_mod
from repro.runtime.sharding import make_ctx, tree_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--seq-sharded-kv", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh)
    cache_seq = args.prompt_len + args.gen
    shape = lmdata.ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    batch = lmdata.synth_batch(jax.random.PRNGKey(0), cfg, shape)
    specs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)

    prefill_fn, ctx, spec = steps_mod.jit_prefill(
        cfg, mesh, specs, cache_seq, seq_sharded_kv=args.seq_sharded_kv)
    params = P.initialize(jax.random.PRNGKey(1), spec, jnp.dtype(cfg.dtype))
    if mesh is not None:
        shardings = tree_shardings(spec, ctx)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            params, shardings)

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{t_prefill * 1e3:.1f} ms")

    def decode(p, t, c, q):
        return S.decode_step(p, t, c, q, cfg,
                             make_ctx(mesh, seq_sharded_kv=args.seq_sharded_kv))

    decode_fn = jax.jit(decode)
    n_media = cfg.num_media_tokens if cfg.family == "vlm" else 0
    pos0 = batch["tokens"].shape[1] + n_media
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode_fn(params, tok, caches,
                                   jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decode: {args.gen - 1} steps in {t_dec * 1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / max(t_dec, 1e-9):.1f} tok/s)")
    print("generated token ids (greedy):")
    for b in range(min(args.batch, 4)):
        print(f"  [{b}] {gen[b].tolist()}")


if __name__ == "__main__":
    main()
