"""Serving launcher: LM prefill+decode loop, or the HDC streaming fleet.

LM zoo (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --prompt-len 32 --gen 16

HDC streaming fleet (population-scale seizure detection):
  PYTHONPATH=src python -m repro.launch.serve --hdc-fleet \
      --sessions 256 --patients 8 --rounds 4

Deploy flow — compile once, serve many (runtime/aot.py): `compile` writes a
versioned artifact of serialized pre-compiled step executables; every later
`serve --aot-dir` warms the fleet from it and the first decision costs
milliseconds of deserialization instead of seconds of trace+compile (a
stale artifact — different jax version / device kind / kernel sources —
falls back to JIT with a warning):
  PYTHONPATH=src python -m repro.launch.serve compile --aot-dir /tmp/aot \
      --sessions 256 --patients 8
  PYTHONPATH=src python -m repro.launch.serve --hdc-fleet --aot-dir /tmp/aot \
      --sessions 256 --patients 8 --rounds 4

Durable adaptive fleet: --adapt-every N personalizes every session's AM via
one jitted fleet-wide online update each N rounds; --ckpt-dir saves the full
fleet state (streaming accumulators + online AM banks) after the run and
--resume restores the latest checkpoint to continue mid-stream bit-exactly:

  PYTHONPATH=src python -m repro.launch.serve --hdc-fleet \
      --sessions 256 --patients 8 --rounds 8 --adapt-every 2 \
      --ckpt-dir /tmp/fleet-ckpt --resume

Channel-fault tolerance: --channel-health builds the fleet with per-session
channel masking and runs the online electrode-health monitor
(reliability/channels.py) over every round's LBP codes — channels whose code
statistics collapse (dead/railed/line-noise electrodes) are quarantined out
of the spatial encoder via a traced-operand mask update (zero recompiles)
and reinstated with hysteresis if they recover; the quarantine event log is
printed at the end of the run.  --inject-fault CH:KIND demos it by faulting
a channel of every session's stream:

  PYTHONPATH=src python -m repro.launch.serve --hdc-fleet \
      --sessions 64 --patients 4 --rounds 8 --channel-health \
      --inject-fault 3:dead --inject-fault 7:line_noise

On a fleet the same entry points run on the production mesh (--mesh 16x16):
the LM path shards the KV cache per runtime/sharding.py, the HDC path shards
the per-session accumulator state along the data axis (serve/fleet.py) while
the codebook/AM banks replicate.
"""

from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp

from repro.launch.train import parse_mesh


class _GracefulStop:
    """SIGTERM/SIGINT -> finish the in-flight round, write one final atomic
    checkpoint, exit 0.  The flag is only *read* at round boundaries, so a
    kill mid-push never tears the fleet state — the checkpoint the next
    worker resumes from is always a complete round (ckpt saves are already
    atomic: tmp dir + rename)."""

    def __init__(self):
        self.signum: int | None = None
        self._old: dict[int, object] = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._old[sig] = signal.signal(sig, self._handle)
        return self

    def __exit__(self, *exc):
        for sig, old in self._old.items():
            signal.signal(sig, old)
        return False

    def _handle(self, signum, frame):
        if self.signum is not None:  # second signal: give up immediately
            raise KeyboardInterrupt
        self.signum = signum

    @property
    def requested(self) -> bool:
        return self.signum is not None

    @property
    def name(self) -> str:
        return signal.Signals(self.signum).name if self.signum else ""


def _build_hdc_fleet(args):
    """Train a small synthetic per-patient bank and assemble the fleet."""
    import numpy as np

    from repro.core.pipeline import HDCConfig, HDCPipeline
    from repro.serve.fleet import StreamingFleet

    mesh = parse_mesh(args.mesh)
    cfg = HDCConfig(variant=args.variant)
    rng = np.random.default_rng(0)

    def trained(seed: int) -> HDCPipeline:
        codes = jnp.asarray(
            rng.integers(0, cfg.codes, (1, 4 * cfg.window, cfg.channels), np.uint8))
        labels = np.asarray(rng.integers(0, 2, (1, 4), np.int32))
        labels[0, :2] = (0, 1)  # every class needs >= 1 example (empty-class guard)
        pipe = HDCPipeline.init(jax.random.PRNGKey(seed), cfg)
        # per-patient calibrated operating point (the programmed register)
        pipe = pipe.calibrate_density(codes, target=0.2 + 0.05 * (seed % 4))
        return pipe.train_one_shot(codes, jnp.asarray(labels))

    t0 = time.perf_counter()
    bank = {f"patient{p}": trained(p) for p in range(args.patients)}
    owners = [f"patient{i % args.patients}" for i in range(args.sessions)]
    fleet = StreamingFleet(bank, owners, mesh=mesh,
                           channel_masking=args.channel_health)
    print(f"fleet: {args.sessions} sessions over {args.patients} patients "
          f"({'mesh ' + 'x'.join(map(str, mesh.devices.shape)) if mesh else 'single device'}), "
          f"built in {time.perf_counter() - t0:.1f} s")
    return fleet, cfg, rng, mesh


def run_hdc_compile(args) -> None:
    """``compile`` subcommand: serialize + pre-compile the fleet's whole
    executable set into the --aot-dir deploy artifact (runtime/aot.py), so
    ``serve --aot-dir <dir>`` workers start without paying trace+compile."""
    if not args.aot_dir:
        raise SystemExit("compile mode needs --aot-dir <artifact directory>")
    fleet, _, _, mesh = _build_hdc_fleet(args)
    if mesh is not None:
        raise SystemExit("compile mode serializes single-device executables; "
                         "drop --mesh")
    t0 = time.perf_counter()
    manifest = fleet.save_aot(args.aot_dir)
    dt = time.perf_counter() - t0
    print(f"AOT artifact -> {args.aot_dir}: {len(manifest['entries'])} "
          f"executables in {dt:.1f} s (key: {manifest['key']})")
    for e in manifest["entries"]:
        print(f"  {e['name']}  exported={e['exported']} "
              f"compile={e['compile_s']:.2f}s")


def run_hdc_fleet(args) -> None:
    """Stream a (possibly sharded) fleet; --aot-dir warms it from a deploy
    artifact first."""
    import numpy as np

    fleet, cfg, rng, _ = _build_hdc_fleet(args)

    t0 = time.perf_counter()
    if args.aot_dir:
        from repro.runtime import aot as aot_mod

        art = aot_mod.load_artifact(args.aot_dir)  # None (+warning) if stale
        stats = fleet.warmup(aot=art)
        print(f"warmup from {args.aot_dir}: {stats['loaded']} loaded, "
              f"{stats['compiled']} compiled in "
              f"{time.perf_counter() - t0:.2f} s"
              + ("" if art is not None else "  [stale artifact: JIT]"))

    chunk_len = args.chunk or cfg.window
    chunks = [rng.integers(0, cfg.codes, (chunk_len, cfg.channels), np.uint8)
              for _ in range(args.sessions)]
    if args.inject_fault:
        from repro.reliability import channels as chan_mod

        frng = np.random.default_rng(1)
        for spec in args.inject_fault:
            ch_s, _, kind = spec.partition(":")
            try:
                ch = int(ch_s)
            except ValueError:
                raise SystemExit(f"--inject-fault {spec!r}: want CH:KIND")
            if kind not in chan_mod.CODE_FAULT_TYPES:
                raise SystemExit(
                    f"--inject-fault kind {kind!r} must be one of "
                    f"{chan_mod.CODE_FAULT_TYPES}")
            if not 0 <= ch < cfg.channels:
                raise SystemExit(
                    f"--inject-fault channel {ch} outside "
                    f"[0, {cfg.channels})")
            chunks = [chan_mod.inject_code_fault(c, ch, kind, frng)
                      for c in chunks]
            print(f"injected {kind} fault on channel {ch} "
                  f"(all {args.sessions} sessions)")
    monitor = None
    if args.channel_health:
        from repro.reliability.channels import FleetChannelMonitor

        monitor = FleetChannelMonitor(args.sessions, cfg.channels)
    fleet.push(chunks)  # warmup / compile (no-op compile when AOT-warmed)

    # restore AFTER the warmup push: restore overwrites the fleet state, so
    # the warmup round never leaks into the resumed stream (which would
    # silently advance it by one chunk per resume)
    if args.resume and args.ckpt_dir:
        from repro.ckpt import checkpoint as ckpt
        if ckpt.latest_step(args.ckpt_dir) is not None:
            step = fleet.restore(args.ckpt_dir)
            print(f"resumed fleet from {args.ckpt_dir} step {step} "
                  f"(frames so far: {int(fleet.frame_indices.sum())})")
        else:
            print(f"--resume: no checkpoint under {args.ckpt_dir}, cold start")
    decisions = 0
    adapted = 0
    rounds_done = 0
    t0 = time.perf_counter()
    with _GracefulStop() as stopper:
        for r in range(args.rounds):
            if stopper.requested:
                break
            out = fleet.push(chunks)
            decisions += sum(len(o) for o in out)
            rounds_done = r + 1
            if monitor is not None:
                masks = monitor.observe(np.stack(chunks))
                if not np.array_equal(masks, fleet.channel_masks):
                    fleet.set_channel_mask(masks)
            if args.adapt_every and (r + 1) % args.adapt_every == 0:
                # synthetic feedback: label each session's last frame at random
                labels = np.where([len(o) > 0 for o in out],
                                  rng.integers(0, cfg.n_classes, args.sessions),
                                  -1)
                adapted += int(fleet.adapt(labels).sum())
            if (args.ckpt_dir and args.ckpt_every
                    and (r + 1) % args.ckpt_every == 0):
                fleet.save(args.ckpt_dir)
    dt = time.perf_counter() - t0
    rate = args.sessions * rounds_done / max(dt, 1e-9)
    print(f"stream: {rounds_done} rounds x {chunk_len} cycles in {dt * 1e3:.1f} ms "
          f"({rate:.0f} session-chunks/s, {decisions} decisions, "
          f"{dt * 1e6 / max(decisions, 1):.1f} us/decision)")
    if args.adapt_every:
        print(f"online adaptation: {adapted} gated AM updates across the fleet")
    if monitor is not None:
        ev = monitor.events
        print(f"channel health: {monitor.n_quarantined} channel(s) "
              f"quarantined across the fleet ({len(ev)} events)")
        for e in ev[:20]:
            print(f"  round {e['block']} session {e['session']} "
                  f"ch {e['channel']}: {e['event']} "
                  f"(entropy {e['entropy']:.2f} bits, "
                  f"run {e['stuck_run']})")
        if len(ev) > 20:
            print(f"  ... {len(ev) - 20} more event(s)")
    print(f"compiled step executables: {fleet.compile_count} "
          f"(buckets: {fleet._buckets})")
    if args.ckpt_dir:
        path = fleet.save(args.ckpt_dir)
        print(f"saved fleet checkpoint -> {path}")
    if stopper.requested:
        # the final atomic checkpoint above IS the shutdown contract; exit
        # clean so supervisors treat this as a graceful drain, not a crash
        print(f"caught {stopper.name}: checkpointed after round "
              f"{rounds_done}, exiting 0")
        raise SystemExit(0)


def run_lm(args) -> None:
    from repro.configs.registry import get_config
    from repro.data import lm as lmdata
    from repro.models import params as P
    from repro.models import serve as S
    from repro.runtime import steps as steps_mod
    from repro.runtime.sharding import make_ctx, tree_shardings

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh)
    cache_seq = args.prompt_len + args.gen
    shape = lmdata.ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    batch = lmdata.synth_batch(jax.random.PRNGKey(0), cfg, shape)
    specs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)

    prefill_fn, ctx, spec = steps_mod.jit_prefill(
        cfg, mesh, specs, cache_seq, seq_sharded_kv=args.seq_sharded_kv)
    params = P.initialize(jax.random.PRNGKey(1), spec, jnp.dtype(cfg.dtype))
    if mesh is not None:
        shardings = tree_shardings(spec, ctx)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            params, shardings)

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{t_prefill * 1e3:.1f} ms")

    def decode(p, t, c, q):
        return S.decode_step(p, t, c, q, cfg,
                             make_ctx(mesh, seq_sharded_kv=args.seq_sharded_kv))

    decode_fn = jax.jit(decode)
    n_media = cfg.num_media_tokens if cfg.family == "vlm" else 0
    pos0 = batch["tokens"].shape[1] + n_media
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode_fn(params, tok, caches,
                                   jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decode: {args.gen - 1} steps in {t_dec * 1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / max(t_dec, 1e-9):.1f} tok/s)")
    print("generated token ids (greedy):")
    for b in range(min(args.batch, 4)):
        print(f"  [{b}] {gen[b].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("command", nargs="?", default="serve",
                    choices=["serve", "compile"],
                    help="serve (default) or compile: build the --aot-dir "
                         "deploy artifact for the HDC fleet and exit")
    ap.add_argument("--arch", default=None, help="LM zoo architecture to serve")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--seq-sharded-kv", action="store_true")
    # HDC streaming-fleet mode
    ap.add_argument("--hdc-fleet", action="store_true",
                    help="serve the HDC seizure-detection streaming fleet")
    ap.add_argument("--sessions", type=int, default=64)
    ap.add_argument("--patients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=None,
                    help="cycles per session per round (default: one window)")
    ap.add_argument("--variant", default="sparse_compim",
                    choices=["sparse_naive", "sparse_compim", "dense"])
    ap.add_argument("--channel-health", action="store_true",
                    help="build the fleet with channel masking and run the "
                         "online electrode-health monitor: channels whose "
                         "LBP code statistics collapse are quarantined out "
                         "of the spatial encoder (traced mask update, no "
                         "recompiles) and reinstated with hysteresis")
    ap.add_argument("--inject-fault", action="append", default=[],
                    metavar="CH:KIND",
                    help="inject a code-level electrode fault into channel "
                         "CH of every session's stream (KIND: dead, "
                         "saturated, line_noise, dropout); repeatable")
    ap.add_argument("--adapt-every", type=int, default=0,
                    help="run one fleet-wide online AM update every N rounds")
    ap.add_argument("--ckpt-dir", default=None,
                    help="save the fleet state here after the run")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="with --ckpt-dir: also checkpoint every N rounds "
                         "(periodic crash-recovery saves, not just the "
                         "final one)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from --ckpt-dir "
                         "before streaming")
    ap.add_argument("--aot-dir", default=None,
                    help="deploy-artifact directory of serialized executables"
                         " (runtime/aot.py): `compile` writes it, `serve` "
                         "warms the fleet from it")
    args = ap.parse_args()
    if args.command == "compile":
        run_hdc_compile(args)
        return
    if args.hdc_fleet:
        run_hdc_fleet(args)
        return
    if not args.arch:
        ap.error("--arch is required (or pass --hdc-fleet)")
    run_lm(args)


if __name__ == "__main__":
    main()
