"""Training launcher with fault tolerance.

Design for a real fleet (documented here, exercised at container scale):

* **Checkpoint/restart**: async sharded checkpoints every `ckpt_every`
  steps; on (re)start the launcher restores the latest complete checkpoint
  and resumes at the recorded step.  The data pipeline is a pure function of
  the step index (data/lm.py), so resume is bitwise reproducible.
* **Watchdog**: the runner supervises the step loop; a step exceeding
  `step_timeout_s` (straggler / hung collective) aborts the attempt and
  restarts from the last checkpoint.  `max_restarts` bounds crash loops.
* **Elastic scaling**: `--mesh` accepts e.g. ``2x2`` (tests) up to
  ``16x16``/``2x16x16``; restore re-shards checkpoints onto whatever mesh
  the surviving fleet provides (ckpt/checkpoint.py saves unsharded arrays).
* **Inter-pod gradient compression**: --grad-compress enables int8
  error-feedback quantization ahead of the cross-pod reduction.

Usage (container-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --reduced --steps 20 --mesh 1x2 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.data import lm as lmdata
from repro.models import params as pmod
from repro.optim import adamw, compress
from repro.runtime import steps as steps_mod
from repro.runtime.sharding import tree_shardings


def parse_mesh(s: str | None):
    if not s or s == "none":
        return None
    dims = tuple(int(x) for x in s.split("x"))
    axes = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def train_loop(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh)
    shape = lmdata.ShapeSpec("train", args.seq, args.batch, "train")
    opt = adamw.OptConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                          accum_steps=args.accum, state_dtype=args.opt_dtype)
    batch0 = lmdata.batch_for_step(cfg, shape, 0)
    jitted, ctx, spec = steps_mod.jit_train_step(
        cfg, opt, mesh, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                                     batch0), grad_compress=args.grad_compress)

    p_shard = tree_shardings(spec, ctx)
    params = pmod.initialize(jax.random.PRNGKey(args.seed), spec,
                             jnp.dtype(cfg.dtype))
    if mesh is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            params, p_shard)
    opt_state = adamw.init_state(params, opt)
    residual = compress.init_residual(params) if args.grad_compress else None

    start_step = 0
    ckptr = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckptr and not args.fresh:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state_like = {"params": params, "m": opt_state["m"],
                          "v": opt_state["v"], "step": opt_state["step"]}
            restored = ckpt.restore(args.ckpt_dir, latest, state_like,
                                    {"params": p_shard, "m": p_shard,
                                     "v": p_shard, "step": None})
            params = restored["params"]
            opt_state = {"m": restored["m"], "v": restored["v"],
                         "step": restored["step"]}
            start_step = latest
            print(f"[resume] restored step {latest} from {args.ckpt_dir}")

    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = lmdata.batch_for_step(cfg, shape, step)
        if args.fail_at is not None and step == args.fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        if args.grad_compress:
            params, opt_state, residual, loss, metrics = jitted(
                params, opt_state, batch, residual)
        else:
            params, opt_state, loss, metrics = jitted(params, opt_state, batch)
        loss = float(loss)
        dt = time.time() - t0
        if dt > args.step_timeout_s:
            raise TimeoutError(f"step {step} took {dt:.1f}s > {args.step_timeout_s}s "
                               "(straggler watchdog)")
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} gnorm "
                  f"{float(metrics['grad_norm']):.3f} ({dt*1e3:.0f} ms)")
        if ckptr and (step + 1) % args.ckpt_every == 0:
            ckptr.save_async(step + 1, {"params": params, "m": opt_state["m"],
                                        "v": opt_state["v"],
                                        "step": opt_state["step"]})
    if ckptr:
        ckptr.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "steps": args.steps - start_step,
            "wall_s": time.time() - t_start}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 / 16x16 / 2x16x16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--step-timeout-s", type=float, default=3600.0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (fault-tolerance tests)")
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    # supervisor: restart from the latest checkpoint on failure
    for attempt in range(args.max_restarts + 1):
        try:
            out = train_loop(args)
            print(f"done: final_loss={out['final_loss']:.4f} "
                  f"wall={out['wall_s']:.1f}s")
            return
        except (RuntimeError, TimeoutError) as e:
            print(f"[watchdog] attempt {attempt} failed: {e}")
            if attempt == args.max_restarts or not args.ckpt_dir:
                raise
            args.fail_at = None   # injected failures fire once
            print("[watchdog] restarting from latest checkpoint...")


if __name__ == "__main__":
    main()
