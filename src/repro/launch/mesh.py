"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.

Production topology (TPU v5e pods):
  single-pod  (data=16, model=16)            = 256 chips
  multi-pod   (pod=2, data=16, model=16)     = 512 chips
The `pod` axis is the slow (DCI) axis: only data-parallel gradient
reduction crosses it (optionally int8-compressed, optim/compress.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic restarts on smaller fleets."""
    return jax.make_mesh(shape, axes)


def host_device_count_or_die(n: int):
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but jax sees {have}; the dry-run must "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"BEFORE importing jax (see launch/dryrun.py)")
