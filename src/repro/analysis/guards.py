"""Runtime jit-hygiene sanitizers for steady-state serving loops.

``no_recompiles()`` asserts that a code region triggers zero XLA
compilations -- the steady-state property the fleet's bucketed padding is
designed to guarantee.  It listens to :func:`jax.log_compiles` output
instead of poking jit-internal cache sizes, so it sees *every* compile
(jit cache hits, AOT misses, nested jits) regardless of which executable
tier served the call.

``no_transfers()`` asserts that a region performs no implicit
device-to-host synchronisation.  ``jax.transfer_guard("disallow")`` covers
real accelerators, but on the CPU backend committed arrays are zero-copy
host views and produce **no transfer event** for ``.item()`` /
``np.asarray`` -- exactly the syncs that stall a TPU pipeline.  So the
context additionally instruments the concrete Array type's host-sync
surface (``__array__``, ``item``, ``tolist``, ``__float__``, ...) to raise
inside the region, keeping the check meaningful in CI.

Both are exposed as pytest fixtures from ``tests/conftest.py``.
"""

from __future__ import annotations

import contextlib
import logging
import re

import jax


class GuardViolation(AssertionError):
    """A sanitized region broke a jit-hygiene invariant."""


_COMPILE_RE = re.compile(r"^Compiling (\S+)")
# loggers that announce XLA compilation under jax.log_compiles()
_COMPILE_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class _CompileRecorder(logging.Handler):
    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.compiled: list[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.match(record.getMessage())
        if m:
            self.compiled.append(m.group(1))


@contextlib.contextmanager
def no_recompiles(allow: int = 0):
    """Fail with :class:`GuardViolation` if the region compiles more than
    *allow* XLA programs.  Yields the recorder; ``recorder.compiled`` lists
    the names of programs compiled so far (useful for warmup accounting).
    """
    recorder = _CompileRecorder()
    loggers = [logging.getLogger(name) for name in _COMPILE_LOGGERS]
    levels = [lg.level for lg in loggers]
    for lg in loggers:
        lg.addHandler(recorder)
        if lg.level > logging.WARNING or lg.level == logging.NOTSET:
            lg.setLevel(logging.WARNING)
    try:
        with jax.log_compiles(True):
            yield recorder
        if len(recorder.compiled) > allow:
            names = ", ".join(recorder.compiled)
            raise GuardViolation(
                f"region compiled {len(recorder.compiled)} XLA program(s) "
                f"(allowed {allow}): {names}")
    finally:
        for lg, level in zip(loggers, levels):
            lg.removeHandler(recorder)
            lg.setLevel(level)


# --- host-sync instrumentation (CPU-backend complement to transfer_guard) --

_SYNC_METHODS = ("__array__", "item", "tolist", "__float__", "__int__",
                 "__bool__", "__index__", "__complex__")


def _array_impl_type():
    # the concrete jax Array class whose methods perform host syncs
    import jax.numpy as jnp
    return type(jnp.zeros((), jnp.int32))


@contextlib.contextmanager
def no_transfers():
    """Fail with :class:`GuardViolation` on any implicit device->host sync
    inside the region.

    Combines ``jax.transfer_guard_device_to_host("disallow")``
    (authoritative on accelerator backends; the device->host direction is
    the one that stalls a pipeline, and guarding host->device too would
    reject the weak scalar literals every jnp op uploads) with
    method-level instrumentation of the concrete Array type so that
    zero-copy CPU "transfers" -- invisible to the transfer guard -- are
    caught too.  Explicit ``jax.device_put`` / ``jax.device_get`` escapes
    are intentionally NOT patched: steady-state code that wants to sync
    must say so.

    DONATED buffers are exempt: reading an array whose buffer was donated
    (``is_deleted()``) cannot transfer anything — there is no buffer — so
    the guard steps aside and lets jax raise its "Array has been deleted"
    RuntimeError.  Before this carve-out the guard reported a phantom
    host sync on donated-buffer reuse, hiding the actual use-after-donate
    bug behind a misleading verdict.
    """
    import numpy as np

    cls = _array_impl_type()
    saved: dict[str, object] = {}

    def _deleted(a) -> bool:
        # donated-buffer reuse: a donated (deleted) array has NO live device
        # buffer, so touching it cannot possibly transfer — fall through to
        # the original method, which raises jax's informative "Array has
        # been deleted" RuntimeError instead of a false host-sync verdict
        # that would mask the real use-after-donate bug
        try:
            return bool(a.is_deleted())
        except Exception:  # pragma: no cover - exotic array impls
            return False

    def _blocked(name, orig):
        def method(self, *args, **kwargs):
            if _deleted(self) and orig is not None:
                return orig(self, *args, **kwargs)
            raise GuardViolation(
                f"implicit host sync via Array.{name} inside a "
                f"no_transfers() region")
        return method

    for name in _SYNC_METHODS:
        if hasattr(cls, name):
            saved[name] = cls.__dict__.get(name)
            try:
                setattr(cls, name, _blocked(name, saved[name]))
            except TypeError:  # pragma: no cover - immutable type
                saved.pop(name, None)

    # numpy >= 2 reads jax arrays through the C buffer protocol, never
    # calling __array__ -- so the conversion entry points themselves must
    # be guarded for np.asarray(device_array) to be caught on CPU
    def _np_guard(orig, name):
        def wrapper(*args, **kwargs):
            if args and isinstance(args[0], cls) and not _deleted(args[0]):
                raise GuardViolation(
                    f"implicit host sync via np.{name}(device array) "
                    f"inside a no_transfers() region")
            return orig(*args, **kwargs)
        return wrapper

    np_saved = {name: getattr(np, name)
                for name in ("asarray", "array", "ascontiguousarray")}
    try:
        for name, orig in np_saved.items():
            setattr(np, name, _np_guard(orig, name))
        with jax.transfer_guard_device_to_host("disallow"):
            yield
    finally:
        for name, orig in np_saved.items():
            setattr(np, name, orig)
        for name, orig in saved.items():
            if orig is None:
                with contextlib.suppress(AttributeError):
                    delattr(cls, name)
            else:
                setattr(cls, name, orig)
