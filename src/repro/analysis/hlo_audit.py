"""Lower + compile the real fleet/engine programs and audit the HLO.

Static lint (``analysis/lint.py``) checks what the *source* says; this
module checks what XLA actually *got*.  It reuses the serving stack's own
AOT entry enumeration (``StreamingFleet.aot_entries()`` /
``ServingEngine.aot_entries()``) so the audited programs are byte-for-byte
the ones a deploy artifact would ship, then asserts three invariants per
entry:

1. **Donation aliasing** -- the donated fleet step must show every
   ``FleetState`` leaf aliased input->output (``tf.aliasing_output`` in the
   StableHLO, ``input_output_alias`` in the compiled executable).  PR 7
   found jaxlib corrupting the heap *around* this aliasing; this audit
   pins that the aliasing itself exists and covers the state.
2. **No host escapes** -- the steady-state step must contain no
   host-callback/infeed/outfeed ``custom_call`` ops; any custom call
   outside an explicit allowlist fails the audit.
3. **Dtype-width histogram** -- every ``tensor<...>`` element type in the
   lowering is counted; 64-bit types (``i64``/``ui64``/``f64``) in the
   packed path fail the audit.  Run under ``JAX_ENABLE_X64=1`` this is the
   machine-checked version of the PR 2 bug class.  Single-element 64-bit
   tensors are reported in the histogram but do not fail: they are jax's
   weak-typed lowering of Python scalar literals (``x // 32``,
   ``jnp.where(m, x, 0)``), are converted in place, and cannot widen any
   buffer -- a real promotion always shows up as a multi-element 64-bit
   tensor.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# custom-call targets that are pure device code, not host escapes
DEFAULT_CUSTOM_CALL_ALLOWLIST = ("Sharding", "tpu_custom_call")

# custom-call targets / op names that reach back to the host
_HOST_ESCAPE_RE = re.compile(
    r"callback|infeed|outfeed|xla_python|host_compute", re.IGNORECASE)

_WIDE_TYPES = ("i64", "ui64", "si64", "f64", "c128")

_TENSOR_RE = re.compile(r"tensor<([^>]*)>")
_ELEM_RE = re.compile(r"^[su]?[iuf]\d+$|^i1$|^bf16$|^c\d+$")
_ALIAS_RE = re.compile(r"tf\.aliasing_output")
_STABLEHLO_CC_RE = re.compile(r"stablehlo\.custom_call\s+@(\w+)")
_COMPILED_CC_RE = re.compile(r'custom_call_target="([^"]+)"')
# one "{out_idx}: (param_idx, {...}, kind)" per aliased buffer; the nested
# braces rule out a single [^}]* capture of the whole map
# the output tuple index is empty ("{}") for single-output programs
_IO_ALIAS_PAIR_RE = re.compile(
    r"\{\d*(?:,\s*\d+)*\}:\s*\(\d+,\s*\{[^}]*\},\s*(?:may|must)-alias\)")


@dataclass
class EntryAudit:
    """Audit result for one AOT entry's program."""

    name: str
    expected_donated: int | None    # state leaves that must alias, or None
    aliased: int = 0                # tf.aliasing_output count (StableHLO)
    alias_pairs: int = 0            # pairs in compiled input_output_alias
    custom_calls: list = field(default_factory=list)
    host_escapes: list = field(default_factory=list)
    dtype_histogram: dict = field(default_factory=dict)
    compiled: bool = False
    errors: list = field(default_factory=list)

    wide_buffers: dict = field(default_factory=dict)

    @property
    def wide_types(self) -> dict:
        """64-bit element types seen on multi-element tensors (scalar
        weak-literal constants excluded -- see module docstring)."""
        return self.wide_buffers

    @property
    def ok(self) -> bool:
        return not self.problems

    @property
    def problems(self) -> list:
        out = list(self.errors)
        if self.expected_donated is not None:
            if self.aliased < self.expected_donated:
                out.append(
                    f"donation not reflected in lowering: "
                    f"{self.aliased}/{self.expected_donated} state leaves "
                    f"carry tf.aliasing_output")
            if self.compiled and self.alias_pairs < self.expected_donated:
                out.append(
                    f"executable aliased only {self.alias_pairs}/"
                    f"{self.expected_donated} donated buffers")
        if self.host_escapes:
            out.append("host escapes in steady-state program: "
                       + ", ".join(sorted(set(self.host_escapes))))
        if self.custom_calls:
            out.append("unexpected custom_call targets: "
                       + ", ".join(sorted(set(self.custom_calls))))
        if self.wide_types:
            hist = ", ".join(f"{t}x{n}"
                             for t, n in sorted(self.wide_types.items()))
            out.append(f"64-bit types leaked into the packed path: {hist}")
        return out

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "ok": self.ok,
            "expected_donated": self.expected_donated,
            "aliased": self.aliased,
            "alias_pairs": self.alias_pairs,
            "custom_calls": sorted(set(self.custom_calls)),
            "host_escapes": sorted(set(self.host_escapes)),
            "dtype_histogram": dict(sorted(self.dtype_histogram.items())),
            "wide_types": dict(sorted(self.wide_types.items())),
            "compiled": self.compiled,
            "problems": self.problems,
        }


@dataclass
class AuditReport:
    entries: list
    x64: bool

    @property
    def ok(self) -> bool:
        return all(e.ok for e in self.entries)

    def to_dict(self) -> dict:
        return {"ok": self.ok, "x64": self.x64,
                "entries": [e.to_dict() for e in self.entries]}


def dtype_histogram(stablehlo_text: str) -> dict:
    """Count element dtypes over every ``tensor<...>`` in a StableHLO
    module (shape dims stripped)."""
    hist: dict[str, int] = {}
    for m in _TENSOR_RE.finditer(stablehlo_text):
        elem = m.group(1).split(",")[0].strip().split("x")[-1]
        if _ELEM_RE.match(elem):
            hist[elem] = hist.get(elem, 0) + 1
    return hist


def wide_buffer_histogram(stablehlo_text: str) -> dict:
    """Count 64-bit element types over *multi-element* tensors only --
    the shape of a real dtype-width leak (weak scalar literals lower as
    single-element 64-bit constants and are excluded)."""
    hist: dict[str, int] = {}
    for m in _TENSOR_RE.finditer(stablehlo_text):
        spec = m.group(1).split(",")[0].strip()
        dims, elem = spec.split("x")[:-1], spec.split("x")[-1]
        if elem not in _WIDE_TYPES or not _ELEM_RE.match(elem):
            continue
        try:
            numel = 1
            for d in dims:
                numel *= int(d)
        except ValueError:  # dynamic dim: treat as wide
            numel = 2
        if numel > 1:
            hist[elem] = hist.get(elem, 0) + 1
    return hist


def audit_entry(entry, *, expected_donated: int | None = None,
                allow_custom_calls=DEFAULT_CUSTOM_CALL_ALLOWLIST,
                compile: bool = True) -> EntryAudit:
    """Audit one ``runtime.aot.AOTEntry``'s lowering (and, when *compile*
    is true, its executable text)."""
    audit = EntryAudit(name=entry.name, expected_donated=expected_donated)
    try:
        lowered = entry.fn.lower(*entry.args, *entry.static)
        text = lowered.as_text()
    except Exception as exc:  # pragma: no cover - lowering must not fail
        audit.errors.append(f"lowering failed: {exc!r}")
        return audit

    audit.aliased = len(_ALIAS_RE.findall(text))
    audit.dtype_histogram = dtype_histogram(text)
    audit.wide_buffers = wide_buffer_histogram(text)
    for target in _STABLEHLO_CC_RE.findall(text):
        if _HOST_ESCAPE_RE.search(target):
            audit.host_escapes.append(target)
        elif target not in allow_custom_calls:
            audit.custom_calls.append(target)

    if compile:
        try:
            ctext = lowered.compile().as_text() or ""
        except Exception as exc:  # pragma: no cover
            audit.errors.append(f"compile failed: {exc!r}")
            return audit
        audit.compiled = True
        audit.alias_pairs = len(_IO_ALIAS_PAIR_RE.findall(ctext))
        for target in _COMPILED_CC_RE.findall(ctext):
            if _HOST_ESCAPE_RE.search(target):
                audit.host_escapes.append(target)
            elif target not in allow_custom_calls:
                audit.custom_calls.append(target)
    return audit


# ---------------------------------------------------------------------------
# default program set: a tiny-but-real fleet + engine
# ---------------------------------------------------------------------------

def _tiny_programs(backend: str = "jnp"):
    """Build a small trained pipeline and return ``(entry,
    expected_donated)`` pairs covering the fleet step, fleet adapt, and the
    engine dispatch.  Geometry is tiny -- dtype discipline, donation and
    host-escape structure do not depend on array sizes."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.pipeline import HDCConfig, HDCPipeline
    from repro.serve.engine import ServingEngine
    from repro.serve.fleet import StreamingFleet

    dim, segments, channels, window = 256, 8, 8, 32
    cfg = HDCConfig(dim=dim, segments=segments, channels=channels,
                    window=window, variant="sparse_compim",
                    spatial_threshold=1, temporal_threshold=4)
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 64, (2, 4 * window, channels),
                                     np.uint8))
    labels = np.asarray(rng.integers(0, 2, (2, 4), np.int32))
    labels[0, :2] = (0, 1)
    pipe = HDCPipeline.init(jax.random.PRNGKey(0), cfg)
    pipe = pipe.train_one_shot(codes, jnp.asarray(labels))

    fleet = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=(window,),
                           backend=backend)
    pairs = []
    for entry in fleet.aot_entries():
        if ".step." in entry.name:
            # the step donates its whole FleetState (arg 0): every leaf
            # must come back aliased
            expected = len(jax.tree_util.tree_leaves(entry.args[0]))
        else:
            expected = None  # adapt is deliberately not donated
        pairs.append((entry, expected))

    engine = ServingEngine({"p": pipe})
    for entry in engine.aot_entries([1, 2], window):
        pairs.append((entry, None))
    return pairs


def run_audit(*, backend: str = "jnp", compile: bool = True,
              allow_custom_calls=DEFAULT_CUSTOM_CALL_ALLOWLIST
              ) -> AuditReport:
    """Audit the default fleet + engine program set under the current
    ``jax_enable_x64`` setting."""
    import jax

    entries = [audit_entry(entry, expected_donated=expected,
                           allow_custom_calls=allow_custom_calls,
                           compile=compile)
               for entry, expected in _tiny_programs(backend=backend)]
    return AuditReport(entries=entries,
                       x64=bool(jax.config.jax_enable_x64))
