"""AST linter for the repo's jit-hygiene invariants (rule codes RPR0xx).

Generic linters cannot know that this codebase's packed uint32 domain must
never pick up 64-bit accumulators under ``JAX_ENABLE_X64``, or that the
functions reachable from the jitted fleet step must not synchronise with the
host.  These rules encode exactly that:

========  =============================================================
RPR001    unpinned dtype on a width-sensitive ``jnp`` call in a
          packed-domain module (``core``/``kernels``/``serve``/
          ``reliability``): reductions need ``dtype=`` (an outer
          ``.astype`` still materialises 64-bit intermediates under
          X64), factories need an explicit dtype argument.
RPR002    host-sync call (``.item()``/``.tolist()``/``np.asarray``/
          ``jax.device_get``/``float(arg)`` on a traced operand) inside
          a function reachable from a jit/pallas/scan entry point.  The
          call graph spans module-level functions AND methods of
          top-level classes (``jax.jit(self._step)`` roots,
          ``self.foo()``/``cls.foo()`` edges); methods inherited from a
          base class in another module are a known blind spot.
RPR003    nondeterminism source in ``src/``: legacy ``np.random.*``
          global-state API, seedless ``np.random.default_rng()``, or
          the stdlib ``random`` module.
RPR004    unhashable jit-static hazard: mutable default argument
          (list/dict/set literal or constructor, array constructor).
RPR005    Python side effect or host call inside a Pallas kernel body
          (``print``/``open``/``global``/``nonlocal``/host-sync/
          ``np.random``).
========  =============================================================

Waive an intentional finding with a trailing (or immediately preceding)
comment::

    x = jnp.arange(n)  # repro-lint: disable=RPR001  -- host-only index

Findings carry the waiver state rather than being dropped, so tooling can
report waived counts; ``lint_paths`` returns every finding and the CLI
fails only on unwaived ones.
"""

from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import asdict, dataclass, field

RULES = {
    "RPR001": "unpinned dtype on width-sensitive jnp call in packed-domain "
              "module (X64 drift)",
    "RPR002": "host-sync call inside jit-traced code",
    "RPR003": "nondeterministic RNG source in library code",
    "RPR004": "unhashable jit-static hazard (mutable default argument)",
    "RPR005": "Python side effect or host call inside a Pallas kernel body",
}

# modules whose arrays live in the packed uint32 / int32 domain
PACKED_DOMAIN = ("core", "kernels", "serve", "reliability")

# jnp calls whose accumulation dtype promotes to 64-bit under X64 unless
# pinned via the dtype= kwarg (.astype afterwards is NOT sufficient)
_REDUCTIONS = {"sum", "prod", "cumsum", "cumprod", "count_nonzero"}
# jnp factories whose default dtype follows the X64 flag
_FACTORIES = {"arange", "zeros", "ones", "full", "empty", "linspace"}

_HOST_SYNC_METHODS = {"item", "tolist"}
_NP_SYNC_FUNCS = {"asarray", "array", "ascontiguousarray"}
_SCALAR_CASTS = {"float", "int", "bool", "complex"}

# jax transforms whose first function-typed arguments are traced bodies
_TRACED_WRAPPERS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map", "jax.lax.associative_scan",
}

_WAIVER_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*|all)")


@dataclass
class Finding:
    """One lint hit, JSON-able via :meth:`to_dict`."""

    path: str
    line: int
    col: int
    code: str
    message: str
    waived: bool = False

    def to_dict(self) -> dict:
        return asdict(self)

    def __str__(self) -> str:  # pragma: no cover - display only
        tag = " (waived)" if self.waived else ""
        return f"{self.path}:{self.line}:{self.col}: {self.code}{tag} " \
               f"{self.message}"


@dataclass
class _Module:
    """Per-file facts gathered in pass 1 of the cross-module call graph."""

    path: str
    modname: str | None          # dotted repro.* name, None outside src/
    tree: ast.Module
    waivers: dict[int, set[str]] = field(default_factory=dict)
    aliases: dict[str, str] = field(default_factory=dict)
    # module-level functions by name PLUS methods of top-level classes by
    # qualified "ClassName.method" name — the call graph walks through both
    functions: dict[str, ast.FunctionDef] = field(default_factory=dict)
    jit_roots: set[str] = field(default_factory=set)
    pallas_kernels: set[str] = field(default_factory=set)
    # calls made from each function/method: ("local", qname) or
    # ("ext", module, name); self.foo()/cls.foo() resolve to the OWNING
    # class's "ClassName.foo" (inherited methods defined elsewhere are a
    # documented blind spot)
    calls: dict[str, set[tuple]] = field(default_factory=dict)

    @property
    def packed_domain(self) -> bool:
        parts = self.modname.split(".") if self.modname else []
        return len(parts) >= 2 and parts[0] == "repro" and \
            parts[1] in PACKED_DOMAIN

    @property
    def is_src(self) -> bool:
        return self.modname is not None


def _module_name(path: str) -> str | None:
    parts = os.path.normpath(path).split(os.sep)
    if "repro" not in parts:
        return None
    i = parts.index("repro")
    if i == 0 or parts[i - 1] != "src":
        return None
    dotted = parts[i:]
    dotted[-1] = dotted[-1][:-3] if dotted[-1].endswith(".py") else dotted[-1]
    if dotted[-1] == "__init__":
        dotted = dotted[:-1]
    return ".".join(dotted)


def _collect_waivers(source: str) -> dict[int, set[str]]:
    waivers: dict[int, set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _WAIVER_RE.search(tok.string)
            if not m:
                continue
            codes = {c.strip() for c in m.group(1).split(",")}
            waivers.setdefault(tok.start[0], set()).update(codes)
    except tokenize.TokenError:  # pragma: no cover - defensive
        pass
    return waivers


def _dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Resolve ``np.random.rand`` -> ``numpy.random.rand`` through imports."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    head = aliases.get(node.id, node.id)
    parts.append(head)
    return ".".join(reversed(parts))


def _fn_target(node: ast.AST, aliases: dict[str, str]):
    """Resolve a function-valued expression to a bare Name node or a
    ``self.x`` / ``cls.x`` Attribute node, unwrapping
    ``functools.partial(fn, ...)``."""
    if isinstance(node, ast.Call):
        dotted = _dotted(node.func, aliases)
        if dotted in ("functools.partial", "partial") and node.args:
            return _fn_target(node.args[0], aliases)
        return None
    if isinstance(node, ast.Name):
        return node
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and \
            node.value.id in ("self", "cls"):
        return node
    return None


def _target_qname(node: ast.AST, aliases: dict[str, str],
                  cls_name: str | None,
                  functions: dict[str, ast.FunctionDef]) -> str | None:
    """Resolve a function-valued expression to a key of *functions*:
    a module-level name, or — inside class *cls_name* — the qualified
    ``ClassName.method`` of a ``self.x``/``cls.x`` reference."""
    target = _fn_target(node, aliases)
    if isinstance(target, ast.Name) and target.id in functions:
        return target.id
    if isinstance(target, ast.Attribute) and cls_name is not None:
        qname = f"{cls_name}.{target.attr}"
        if qname in functions:
            return qname
    return None


def _walk_with_class(tree: ast.Module):
    """Yield ``(enclosing top-level class name | None, node)`` pairs."""
    for top in tree.body:
        cls = top.name if isinstance(top, ast.ClassDef) else None
        for node in ast.walk(top):
            yield cls, node


def _parse_module(path: str, source: str) -> _Module | None:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError:
        return None
    mod = _Module(path=path, modname=_module_name(path), tree=tree,
                  waivers=_collect_waivers(source))

    # imports (module-level and nested -- aliases are file-scoped here)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod.aliases[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module:
            base = node.module
            if node.level:  # relative import: resolve against the package
                if not mod.modname:
                    continue
                pkg = mod.modname.split(".")[:-node.level]
                base = ".".join(pkg + [node.module])
            for a in node.names:
                mod.aliases[a.asname or a.name] = f"{base}.{a.name}"

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            mod.functions[node.name] = node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    mod.functions[f"{node.name}.{sub.name}"] = sub

    _find_jit_roots(mod)
    _collect_calls(mod)
    return mod


def _is_jit_expr(node: ast.AST, aliases: dict[str, str]) -> bool:
    dotted = _dotted(node, aliases)
    return dotted in ("jax.jit", "jit")


def _find_jit_roots(mod: _Module) -> None:
    aliases = mod.aliases
    # decorators (module-level functions and class methods alike)
    for qname, fn in mod.functions.items():
        for dec in fn.decorator_list:
            if _is_jit_expr(dec, aliases):
                mod.jit_roots.add(qname)
            elif isinstance(dec, ast.Call):
                dotted = _dotted(dec.func, aliases)
                if _is_jit_expr(dec.func, aliases):
                    mod.jit_roots.add(qname)
                elif dotted in ("functools.partial", "partial") and \
                        dec.args and _is_jit_expr(dec.args[0], aliases):
                    mod.jit_roots.add(qname)
    # call sites: jax.jit(f), lax.scan(f, ...), pallas_call(f, ...) — with
    # f a module-level name or a self./cls. method of the enclosing class
    for cls_name, node in _walk_with_class(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func, aliases)
        if dotted is None:
            continue
        if _is_jit_expr(node.func, aliases) or dotted in _TRACED_WRAPPERS:
            for arg in node.args:
                qname = _target_qname(arg, aliases, cls_name, mod.functions)
                if qname is not None:
                    mod.jit_roots.add(qname)
        if dotted.endswith("pallas_call") and node.args:
            qname = _target_qname(node.args[0], aliases, cls_name,
                                  mod.functions)
            if qname is not None:
                mod.jit_roots.add(qname)
                mod.pallas_kernels.add(qname)


def _collect_calls(mod: _Module) -> None:
    for qname, fn in mod.functions.items():
        cls_name = qname.rsplit(".", 1)[0] if "." in qname else None
        targets: set[tuple] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name):
                nid = node.func.id
                if nid in mod.functions:
                    targets.add(("local", nid))
                elif nid in mod.aliases:
                    dotted = mod.aliases[nid]
                    if dotted.startswith("repro."):
                        module, _, func = dotted.rpartition(".")
                        targets.add(("ext", module, func))
            elif isinstance(node.func, ast.Attribute):
                v = node.func.value
                if isinstance(v, ast.Name) and v.id in ("self", "cls"):
                    # method call through the instance: resolve against the
                    # owning class (methods inherited from another module's
                    # base class are a documented blind spot)
                    mname = f"{cls_name}.{node.func.attr}" if cls_name \
                        else None
                    if mname and mname in mod.functions:
                        targets.add(("local", mname))
                    continue
                dotted = _dotted(node.func, mod.aliases)
                if dotted and dotted.startswith("repro."):
                    module, _, func = dotted.rpartition(".")
                    targets.add(("ext", module, func))
        mod.calls[qname] = targets


def _traced_fixpoint(modules: dict[str, _Module]) -> set[tuple]:
    """Propagate "reachable from a jit root" across the module graph."""
    by_name = {m.modname: m for m in modules.values() if m.modname}
    traced: set[tuple] = set()
    work = [(m.path, fn) for m in modules.values() for fn in m.jit_roots]
    while work:
        key = work.pop()
        if key in traced:
            continue
        traced.add(key)
        mod = modules[key[0]]
        for target in mod.calls.get(key[1], ()):
            if target[0] == "local":
                nxt = (mod.path, target[1])
            else:
                callee = by_name.get(target[1])
                if callee is None or target[2] not in callee.functions:
                    continue
                nxt = (callee.path, target[2])
            if nxt not in traced:
                work.append(nxt)
    return traced


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

def _looks_like_dtype(node: ast.AST, aliases: dict[str, str]) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    dotted = _dotted(node, aliases)
    if dotted is None:
        return False
    head = dotted.split(".")[0]
    return head in ("numpy", "jax") or dotted in ("int", "float", "bool")


def _rule_rpr001(mod: _Module, out: list[Finding]) -> None:
    if not mod.packed_domain:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func, mod.aliases)
        if dotted is None or not dotted.startswith("jax.numpy."):
            continue
        name = dotted.rsplit(".", 1)[1]
        kwargs = {k.arg for k in node.keywords}
        if name in _REDUCTIONS:
            if "dtype" not in kwargs:
                out.append(Finding(
                    mod.path, node.lineno, node.col_offset, "RPR001",
                    f"jnp.{name} without dtype=: accumulation promotes to "
                    f"64-bit under JAX_ENABLE_X64 (pin dtype inside the "
                    f"reduction; .astype after is too late)"))
        elif name in _FACTORIES:
            has_dtype = "dtype" in kwargs or any(
                _looks_like_dtype(a, mod.aliases) for a in node.args[1:])
            if name == "arange":
                has_dtype = "dtype" in kwargs or any(
                    _looks_like_dtype(a, mod.aliases) for a in node.args)
            if not has_dtype:
                out.append(Finding(
                    mod.path, node.lineno, node.col_offset, "RPR001",
                    f"jnp.{name} without an explicit dtype: default dtype "
                    f"follows JAX_ENABLE_X64 and widens the packed domain"))


def _rule_rpr002(mod: _Module, traced: set[tuple],
                 out: list[Finding]) -> None:
    for fname, fn in mod.functions.items():
        if (mod.path, fname) not in traced:
            continue
        params = {a.arg for a in fn.args.posonlyargs + fn.args.args} - \
            {"self", "cls"}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _HOST_SYNC_METHODS:
                out.append(Finding(
                    mod.path, node.lineno, node.col_offset, "RPR002",
                    f".{node.func.attr}() forces a host sync inside "
                    f"jit-traced '{fname}'"))
                continue
            dotted = _dotted(node.func, mod.aliases)
            if dotted == "jax.device_get" or (
                    dotted and dotted.startswith("numpy.") and
                    dotted.rsplit(".", 1)[1] in _NP_SYNC_FUNCS):
                out.append(Finding(
                    mod.path, node.lineno, node.col_offset, "RPR002",
                    f"{dotted} materialises a host array inside jit-traced "
                    f"'{fname}'"))
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in _SCALAR_CASTS and \
                    len(node.args) == 1 and not node.keywords and \
                    isinstance(node.args[0], ast.Name) and \
                    node.args[0].id in params:
                out.append(Finding(
                    mod.path, node.lineno, node.col_offset, "RPR002",
                    f"{node.func.id}({node.args[0].id}) on a traced operand "
                    f"of '{fname}' forces a host sync"))


def _rule_rpr003(mod: _Module, out: list[Finding]) -> None:
    if not mod.is_src:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func, mod.aliases)
        if dotted is None:
            continue
        if dotted.startswith("numpy.random."):
            fn = dotted.split(".")[-1]
            if fn == "default_rng" and (node.args or node.keywords):
                continue  # explicitly seeded generator: deterministic
            out.append(Finding(
                mod.path, node.lineno, node.col_offset, "RPR003",
                f"{dotted}: global-state / seedless RNG in library code "
                f"(use a seeded np.random.default_rng or jax.random)"))
        elif dotted.startswith("random.") and \
                mod.aliases.get("random", None) in (None, "random") and \
                "random" not in mod.functions:
            out.append(Finding(
                mod.path, node.lineno, node.col_offset, "RPR003",
                f"stdlib {dotted}: process-global RNG in library code"))


_MUTABLE_CTORS = {"dict", "list", "set", "bytearray"}
_ARRAY_CTORS = {"zeros", "ones", "empty", "full", "array", "asarray",
                "arange"}


def _rule_rpr004(mod: _Module, out: list[Finding]) -> None:
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        defaults = list(node.args.defaults) + \
            [d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            bad = None
            if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
                bad = "mutable literal"
            elif isinstance(d, ast.Call):
                dotted = _dotted(d.func, mod.aliases) or ""
                tail = dotted.rsplit(".", 1)[-1]
                if dotted in _MUTABLE_CTORS:
                    bad = f"{dotted}() constructor"
                elif dotted.split(".")[0] in ("numpy", "jax") and \
                        tail in _ARRAY_CTORS:
                    bad = f"{dotted}() array"
            if bad is not None:
                name = getattr(node, "name", "<lambda>")
                out.append(Finding(
                    mod.path, d.lineno, d.col_offset, "RPR004",
                    f"{bad} as default of '{name}': shared mutable state, "
                    f"and unhashable if passed as a jit static"))


_KERNEL_BANNED_CALLS = {"print", "open", "input", "breakpoint"}


def _rule_rpr005(mod: _Module, out: list[Finding]) -> None:
    for kname in mod.pallas_kernels:
        fn = mod.functions.get(kname)
        if fn is None:
            continue
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                out.append(Finding(
                    mod.path, node.lineno, node.col_offset, "RPR005",
                    f"{type(node).__name__.lower()} statement inside Pallas "
                    f"kernel '{kname}': kernels must be pure"))
            elif isinstance(node, ast.Call):
                dotted = _dotted(node.func, mod.aliases)
                banned = (
                    (isinstance(node.func, ast.Name) and
                     node.func.id in _KERNEL_BANNED_CALLS) or
                    (isinstance(node.func, ast.Attribute) and
                     node.func.attr in _HOST_SYNC_METHODS) or
                    (dotted and dotted.startswith("numpy.random.")) or
                    (dotted and dotted.startswith("numpy.") and
                     dotted.rsplit(".", 1)[1] in _NP_SYNC_FUNCS))
                if banned:
                    what = dotted or ast.unparse(node.func)
                    out.append(Finding(
                        mod.path, node.lineno, node.col_offset, "RPR005",
                        f"{what} inside Pallas kernel '{kname}': host call "
                        f"in a device kernel body"))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def iter_py_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
    return sorted(set(files))


def lint_paths(paths: list[str]) -> list[Finding]:
    """Lint every ``.py`` under *paths*; returns all findings (waived ones
    are marked, not dropped)."""
    modules: dict[str, _Module] = {}
    for f in iter_py_files(paths):
        try:
            with open(f, encoding="utf-8") as fh:
                source = fh.read()
        except OSError:  # pragma: no cover - unreadable file
            continue
        mod = _parse_module(f, source)
        if mod is not None:
            modules[f] = mod

    traced = _traced_fixpoint(modules)

    findings: list[Finding] = []
    for mod in modules.values():
        out: list[Finding] = []
        _rule_rpr001(mod, out)
        _rule_rpr002(mod, traced, out)
        _rule_rpr003(mod, out)
        _rule_rpr004(mod, out)
        _rule_rpr005(mod, out)
        for f in out:
            codes = mod.waivers.get(f.line, set()) | \
                mod.waivers.get(f.line - 1, set())
            f.waived = "all" in codes or f.code in codes
        findings.extend(out)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings
