"""CLI: ``python -m repro.analysis [paths...] [--audit] [--json OUT]``.

Runs the RPR0xx linter over *paths* (default: ``src``), optionally runs
the HLO jit-hygiene audit of the real fleet/engine programs, and exits
non-zero on any unwaived finding or failed audit.  ``--json`` writes a
machine-readable report (CI uploads it as an artifact).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint import RULES, lint_paths


def _print_rules() -> None:
    for code, desc in sorted(RULES.items()):
        print(f"{code}  {desc}")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific lint + jit-hygiene audit")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/directories to lint (default: src)")
    parser.add_argument("--audit", action="store_true",
                        help="also lower/compile the fleet + engine step "
                             "programs and audit donation, host escapes "
                             "and dtype widths")
    parser.add_argument("--x64", action="store_true",
                        help="run the audit under jax_enable_x64 (the "
                             "strict regime for dtype-width leaks)")
    parser.add_argument("--backend", default="jnp",
                        choices=("jnp", "pallas"),
                        help="fleet backend for the audited programs")
    parser.add_argument("--no-compile", action="store_true",
                        help="audit lowerings only (skip XLA compile)")
    parser.add_argument("--json", metavar="OUT", default=None,
                        help="write a machine-readable JSON report")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the RPR0xx rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        _print_rules()
        return 0

    report: dict = {}
    findings = lint_paths(args.paths or ["src"])
    unwaived = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]
    for f in unwaived:
        print(f, file=sys.stderr)
    report["lint"] = {
        "findings": [f.to_dict() for f in findings],
        "unwaived": len(unwaived),
        "waived": len(waived),
    }
    print(f"lint: {len(unwaived)} unwaived finding(s), "
          f"{len(waived)} waived")

    failed = bool(unwaived)
    if args.audit:
        if args.x64:
            import jax
            jax.config.update("jax_enable_x64", True)
        from repro.analysis.hlo_audit import run_audit
        audit = run_audit(backend=args.backend,
                          compile=not args.no_compile)
        report["audit"] = audit.to_dict()
        for entry in audit.entries:
            status = "ok" if entry.ok else "FAIL"
            hist = " ".join(f"{t}x{n}" for t, n in
                            sorted(entry.dtype_histogram.items()))
            print(f"audit: [{status}] {entry.name}  "
                  f"aliased={entry.aliased}"
                  f"/{entry.expected_donated if entry.expected_donated is not None else '-'}"
                  f"  dtypes: {hist}")
            for problem in entry.problems:
                print(f"  - {problem}", file=sys.stderr)
        failed = failed or not audit.ok

    if args.json:
        report["ok"] = not failed
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
        print(f"report written to {args.json}")

    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
