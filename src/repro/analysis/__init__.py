"""Repo-specific static analysis + jit-hygiene auditing.

Three layers, all runnable via ``python -m repro.analysis``:

* :mod:`repro.analysis.lint` -- AST linter with RPR0xx rule codes and
  inline ``# repro-lint: disable=...`` waivers.  Encodes the invariants
  generic tools cannot know: packed-domain dtype pinning, host-sync
  freedom of the traced datapath, determinism of library code, jit-static
  hashability, and Pallas kernel-body purity.
* :mod:`repro.analysis.hlo_audit` -- lowers/compiles the *real* fleet and
  engine step programs (via their ``aot_entries()``) and audits the
  StableHLO/executable text: donation aliasing, host-escape custom calls,
  and a per-op dtype-width histogram that fails on 64-bit leakage.
* :mod:`repro.analysis.guards` -- runtime sanitizer contexts
  (``no_recompiles()``, ``no_transfers()``) used as pytest fixtures around
  steady-state serving loops.
"""

from repro.analysis.lint import Finding, RULES, lint_paths  # noqa: F401
from repro.analysis.guards import (  # noqa: F401
    GuardViolation,
    no_recompiles,
    no_transfers,
)

__all__ = [
    "Finding",
    "RULES",
    "lint_paths",
    "GuardViolation",
    "no_recompiles",
    "no_transfers",
]
