"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling) and
are validated on CPU with ``interpret=True`` (the kernel body executes in
Python with identical semantics).  ``use_interpret()`` selects the mode from
the local backend so the same ``ops.py`` entry points work everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def use_interpret() -> bool:
    """True when no TPU is available (CI / CPU container): run kernels in
    Pallas interpret mode.  On a real TPU fleet this returns False and the
    Mosaic-compiled kernel runs."""
    return jax.default_backend() != "tpu"


def pack_words_in_kernel(bits: jax.Array) -> jax.Array:
    """(D,) {0,1} -> (D//32,) uint32 inside a kernel body (iota + shift, no
    gather/scatter so it vectorizes on the VPU)."""
    d = bits.shape[-1]
    w = d // 32
    b = bits.reshape(w, 32).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (w, 32), 1)
    return jnp.sum(b << shifts, axis=1, dtype=jnp.uint32)


def unpack_words_in_kernel(words: jax.Array, dim: int) -> jax.Array:
    """(..., W) uint32 -> (..., W*32) {0,1} uint8 inside a kernel body."""
    w = words.shape[-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (*words.shape, 32), words.ndim)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], w * 32)[..., :dim].astype(jnp.uint8)
