"""Fused code-domain fleet-step kernel: gather + bind + bundle + counters.

One grid cell is (session, 32-cycle time group).  The kernel consumes RAW
uint8 LBP codes — the only per-cycle input that ever crosses HBM — and keeps
the session's pre-bound CompIM table bank (binding folded into the table
build, serve/dispatch.py) resident in VMEM, selected per session by a
scalar-prefetched owner index (the table BlockSpec's index map reads
``owner[i]``, so patients sharing a codebook share one VMEM block and no
per-session table copy is ever materialized):

    codes (32, C) uint8
        --VMEM table gather-->  (32, C, W) bound rows
           (rows[j, c] = table[c, codes[j, c]]; the CompIM insight one
           stage further: binding IS the lookup)
        --spatial bundle-->     (32, W) per-cycle packed HVs
           (OR tree / adder tree + thinning / majority, per variant)
        --bit transpose-->      (32, W) time-packed bit planes
           (one uint32 = 32 cycles of one bit position)
        --masked popcount-->    (K+1, 32, W) int32 counter bank
           accumulated across time groups, like hdc_encoder's counter bank

HBM traffic per group is 32*C bytes of codes in and (on the last group) one
(K+1, D) count bank out — the bound rows, the per-cycle HVs, the bit planes
and the temporal counters never leave VMEM, and no float math or unpacked
expansion exists anywhere (the TPU analogue of the paper's binary-domain
argument; see README.md "Kernel & datapath design").  The old bound-rows
kernel shipped (32, C, W) uint32 per group from HBM — 128 bytes per
(cycle, channel) where this kernel ships ONE.

VMEM per grid step (defaults window=256, C=64, K=64 codes, D=1024, K+1=2):
  table bank    64*64*32*4 B = 1 MiB  (resident; re-fetched only when the
                                       session's owner row changes)
  codes block      32*64 B   =   2 KiB
  spatial/planes  32*32*4 B  =   4 KiB
  counter bank  2*32*32*4 B  =   8 KiB

The emission schedule arrives as time-packed per-slot cycle masks
(ref.emission_masks) computed on device from (filled, lengths): bit j of
mask word g selects cycle 32 g + j into a slot, so the masked popcount IS
the temporal bundling of that slot.  Bit-exact with the pure-jnp code-domain
path (dispatch.owner_spatial_codes + ref.fleet_counts_ref); validated in
interpret mode (tests/test_kernels.py) — Mosaic lowering of the in-kernel
gather is untested on real TPUs, like the SWAR transpose (ROADMAP).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hv


def _spatial_bundle(bound: jax.Array, *, mode: str, channels: int, dim: int,
                    threshold: int, live=None) -> jax.Array:
    """(32, C, W) bound rows -> (32, W) per-cycle packed spatial HVs.

    Mirrors dispatch.owner_spatial_codes: ``or`` = OR tree (optimized
    sparse), ``thin`` = adder tree + threshold (naive sparse), ``majority``
    = adder tree + majority (dense).

    ``live`` (traced int32 scalar) is the channel-masked path's live
    channel count: the caller has already zeroed quarantined rows (OR
    identity / zero addend), so this only renormalizes the count-variant
    denominators — thinning threshold via the ceil rule of
    dispatch.effective_spatial_threshold, majority over the live count.
    """
    if mode == "or":
        x = bound
        n = x.shape[1]
        while n > 1:  # pairwise OR tree, fully packed
            half = n // 2
            merged = x[:, :half] | x[:, half:2 * half]
            if n % 2:
                merged = jnp.concatenate([merged, x[:, 2 * half:]], axis=1)
            x = merged
            n = x.shape[1]
        return x[:, 0]
    # count variants need per-bit channel sums
    w = dim // 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, channels, w, 32), 3)
    bits = (bound[..., None] >> shifts) & jnp.uint32(1)       # (32, C, w, 32)
    counts = jnp.sum(bits.astype(jnp.int32), axis=1, dtype=jnp.int32)
    if mode == "thin":
        if live is None:
            keep = counts >= threshold
        else:
            thr = jnp.maximum(1, (threshold * live + channels - 1) // channels)
            keep = counts >= thr
    else:  # majority (ties broken low, matches hv.majority_pack)
        keep = counts * 2 > (channels if live is None else live)
    pack_shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, w, 32), 2)
    return jnp.sum(keep.astype(jnp.uint32) << pack_shifts, axis=2,
                   dtype=jnp.uint32)


def _fleet_kernel(owner_ref, tab_ref, codes_ref, tm_ref, *refs,
                  mode: str, channels: int, n_codes: int, dim: int,
                  threshold: int, masked: bool):
    del owner_ref  # consumed by the BlockSpec index maps (scalar prefetch)
    cm_ref, out_ref = (refs if masked else (None,) + refs)
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    tab = tab_ref[0]                                       # (C, K, W)
    # out-of-alphabet codes clamp within their channel's rows, like the
    # jnp path (dispatch.owner_spatial_codes) and the reference indexing
    cb = jnp.minimum(codes_ref[0].astype(jnp.int32), n_codes - 1)  # (32, C)
    # per-cycle gather out of the VMEM-resident bank: row (c, codes[j, c])
    flat = tab.reshape(channels * n_codes, tab.shape[-1])
    cbase = jax.lax.broadcasted_iota(jnp.int32, (32, channels), 1) * n_codes
    bound = jnp.take(flat, cbase + cb, axis=0)             # (32, C, W)
    live = None
    if masked:
        cm = cm_ref[0]                                     # (C,) uint32
        bound = bound * cm[None, :, None]  # quarantined rows contribute 0
        live = jnp.sum(cm.astype(jnp.int32), dtype=jnp.int32)
    words = _spatial_bundle(bound, mode=mode, channels=channels, dim=dim,
                            threshold=threshold, live=live)  # (32, W)
    planes = hv.bit_transpose32(words)                     # (32b, W)
    tm = tm_ref[0, :, 0]                                   # (K+1,) uint32
    # masked popcount: one AND + popcount bundles 32 cycles into each slot
    contrib = hv.lax_popcount(planes[None] & tm[:, None, None])
    out_ref[0] += contrib.astype(jnp.int32)                # (1, K+1, 32, W)


def fleet_counts_pallas(tables: jax.Array, owner: jax.Array,
                        codes: jax.Array, tm: jax.Array, *, mode: str,
                        dim: int, threshold: int = 1,
                        chan_mask: jax.Array | None = None,
                        interpret: bool = True) -> jax.Array:
    """tables: (P, C, K, W) uint32 stacked pre-bound codebook bank;
    owner: (S,) int32 each session's table row (scalar-prefetched so the
    BlockSpec can gather the right bank into VMEM);
    codes: (S, T32, C) uint8 raw LBP codes (T32 a multiple of 32; padded
    cycles are masked off by ``tm``);
    tm: (S, K+1, T32 // 32) uint32 time-packed slot masks
    (ref.emission_masks);
    chan_mask: optional (S, C) uint32 per-session channel mask (1 = live)
    — a fourth VMEM operand, one (1, C) row per session: quarantined
    channels drop out of the spatial bundle and the count-variant
    denominators renormalize to the live count (see _spatial_bundle).
    Returns (S, K+1, D) int32 slot counts."""
    p, c, k, w = tables.shape
    s, t32, c2 = codes.shape
    assert c2 == c and t32 % 32 == 0 and w * 32 == dim
    groups = t32 // 32
    kp1 = tm.shape[1]
    masked = chan_mask is not None
    kernel = functools.partial(_fleet_kernel, mode=mode, channels=c,
                               n_codes=k, dim=dim, threshold=threshold,
                               masked=masked)
    in_specs = [
        pl.BlockSpec((1, c, k, w), lambda i, g, owner_ref: (owner_ref[i], 0, 0, 0)),
        pl.BlockSpec((1, 32, c), lambda i, g, owner_ref: (i, g, 0)),
        pl.BlockSpec((1, kp1, 1), lambda i, g, owner_ref: (i, 0, g)),
    ]
    inputs = [owner.astype(jnp.int32), tables, codes, tm]
    if masked:
        in_specs.append(pl.BlockSpec((1, c), lambda i, g, owner_ref: (i, 0)))
        inputs.append(chan_mask.astype(jnp.uint32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, groups),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kp1, 32, w),
                               lambda i, g, owner_ref: (i, 0, 0, 0)),
    )
    counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, kp1, 32, w), jnp.int32),
        interpret=interpret,
    )(*inputs)
    # time_pack's (bit, word) layout -> standard d = word * 32 + bit order
    return counts.transpose(0, 1, 3, 2).reshape(s, kp1, dim)
