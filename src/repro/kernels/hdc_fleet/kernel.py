"""Fused fleet-step kernel: spatial bundling + bit-plane temporal counts.

One grid cell is (session, 32-cycle time group).  The kernel consumes
owner-gathered PRE-BOUND packed codebook rows (binding folded into the table
build, serve/dispatch.py) and keeps the whole per-group pipeline in VMEM:

    bound rows (32, C, W) uint32
        --spatial bundle-->  (32, W) per-cycle packed HVs
           (OR tree / adder tree + thinning / majority, per variant)
        --bit transpose-->   (32, W) time-packed bit planes
           (one uint32 = 32 cycles of one bit position)
        --masked popcount--> (K+1, 32, W) int32 counter bank
           accumulated across time groups, like hdc_encoder's counter bank

HBM traffic per group is the bound rows in and (on the last group) one
(K+1, D) count bank out — the per-cycle HVs, the bit planes and the
temporal counters never leave VMEM, and no float math or 32x unpacked
expansion exists anywhere (the TPU analogue of the paper's binary-domain
argument; see README.md "Kernel & datapath design").

VMEM per grid step (defaults window=256, C=64, D=1024, K=1):
  bound block   32*64*32*4 B = 256 KiB
  spatial/planes  32*32*4 B  =   4 KiB
  counter bank  2*32*32*4 B  =   8 KiB

The emission schedule arrives as time-packed per-slot cycle masks
(ref.emission_masks) computed on device from (filled, lengths): bit j of
mask word g selects cycle 32 g + j into a slot, so the masked popcount IS
the temporal bundling of that slot.  Bit-exact with ref.fleet_counts_ref
(tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hv


def _spatial_bundle(bound: jax.Array, *, mode: str, channels: int, dim: int,
                    threshold: int) -> jax.Array:
    """(32, C, W) bound rows -> (32, W) per-cycle packed spatial HVs.

    Mirrors dispatch.owner_spatial_encode: ``or`` = OR tree (optimized
    sparse), ``thin`` = adder tree + threshold (naive sparse), ``majority``
    = adder tree + majority (dense).
    """
    if mode == "or":
        x = bound
        n = x.shape[1]
        while n > 1:  # pairwise OR tree, fully packed
            half = n // 2
            merged = x[:, :half] | x[:, half:2 * half]
            if n % 2:
                merged = jnp.concatenate([merged, x[:, 2 * half:]], axis=1)
            x = merged
            n = x.shape[1]
        return x[:, 0]
    # count variants need per-bit channel sums
    w = dim // 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, channels, w, 32), 3)
    bits = (bound[..., None] >> shifts) & jnp.uint32(1)       # (32, C, w, 32)
    counts = jnp.sum(bits.astype(jnp.int32), axis=1, dtype=jnp.int32)
    if mode == "thin":
        keep = counts >= threshold
    else:  # majority (ties broken low, matches hv.majority_pack)
        keep = counts * 2 > channels
    pack_shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, w, 32), 2)
    return jnp.sum(keep.astype(jnp.uint32) << pack_shifts, axis=2,
                   dtype=jnp.uint32)


def _fleet_kernel(bound_ref, tm_ref, out_ref, *, mode: str, channels: int,
                  dim: int, threshold: int):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    bound = bound_ref[0]                                   # (32, C, W)
    words = _spatial_bundle(bound, mode=mode, channels=channels, dim=dim,
                           threshold=threshold)            # (32, W)
    planes = hv.bit_transpose32(words)                     # (32b, W)
    tm = tm_ref[0, :, 0]                                   # (K+1,) uint32
    # masked popcount: one AND + popcount bundles 32 cycles into each slot
    contrib = hv.lax_popcount(planes[None] & tm[:, None, None])
    out_ref[0] += contrib.astype(jnp.int32)                # (1, K+1, 32, W)


def fleet_counts_pallas(bound: jax.Array, tm: jax.Array, *, mode: str,
                        dim: int, threshold: int = 1,
                        interpret: bool = True) -> jax.Array:
    """bound: (S, T32, C, W) uint32 owner-gathered pre-bound rows (T32 a
    multiple of 32; padded cycles are masked off by ``tm``);
    tm: (S, K+1, T32 // 32) uint32 time-packed slot masks
    (ref.emission_masks).  Returns (S, K+1, D) int32 slot counts."""
    s, t32, c, w = bound.shape
    assert t32 % 32 == 0 and w * 32 == dim
    groups = t32 // 32
    kp1 = tm.shape[1]
    kernel = functools.partial(_fleet_kernel, mode=mode, channels=c, dim=dim,
                               threshold=threshold)
    counts = pl.pallas_call(
        kernel,
        grid=(s, groups),
        in_specs=[
            pl.BlockSpec((1, 32, c, w), lambda i, g: (i, g, 0, 0)),
            pl.BlockSpec((1, kp1, 1), lambda i, g: (i, 0, g)),
        ],
        out_specs=pl.BlockSpec((1, kp1, 32, w), lambda i, g: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, kp1, 32, w), jnp.int32),
        interpret=interpret,
    )(bound, tm)
    # time_pack's (bit, word) layout -> standard d = word * 32 + bit order
    return counts.transpose(0, 1, 3, 2).reshape(s, kp1, dim)
