"""Pure-jnp bit-plane reference for the fleet's masked temporal bundling.

The fleet step must split each session's chunk cycles across K completed
frame slots plus a leftover tail, and accumulate per-bit temporal counts for
every slot.  The old implementation unpacked every cycle's packed HV to a
(S, block, D) float32 tensor and pushed it through an f32 einsum against
dense host-built cycle masks — a 32x memory blowup plus FP math for what is
logically a masked popcount.

This path stays in the packed domain end to end:

* ``hv.time_pack`` flips the cycle axis into bit planes: one uint32 then
  holds 32 CYCLES of one bit position, so popcount(plane) is 32 cycles of
  temporal bundling at once.
* Frame-slot membership is CONTIGUOUS in time (cycle j belongs to slot
  ``(filled + j) // window``), so no per-slot masks exist at all: slot
  counts are differences of prefix counts ``C(x)`` evaluated at the K + 2
  slot boundaries — group-popcount cumulative sums plus one edge-masked
  popcount per boundary.

Bit-exact with the einsum formulation for every (filled, lengths) schedule
(integer counts, no rounding anywhere); tested against it and against
per-session ``SeizureSession`` loops in tests/test_kernels.py and
tests/test_fleet.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hv


def fleet_counts_ref(words: jax.Array, filled: jax.Array, lengths: jax.Array,
                     *, window: int, dim: int) -> jax.Array:
    """Per-frame-slot temporal counts from packed per-cycle spatial HVs.

    words: (S, T, W) uint32 — cycle-major spatial HVs (entries at cycle
    index >= ``lengths[s]`` are never counted, whatever they contain);
    filled: (S,) int32 cycles already accumulated toward each next frame;
    lengths: (S,) int32 valid cycles this step.

    Returns (S, K + 1, D) int32 with K = (T - 1) // window + 1: rows
    0..K-1 are the counts closing each completed frame slot (zero rows for
    slots this session does not reach), row K the leftover tail.
    """
    s, t, w = words.shape
    k_max = (t - 1) // window + 1
    t32 = -(-t // 32) * 32
    if t32 != t:
        words = jnp.pad(words, ((0, 0), (0, t32 - t), (0, 0)))
    groups = t32 // 32
    tb = hv.time_pack(words)                               # (S, G, 32, W)
    gpop = hv.lax_popcount(tb).astype(jnp.int32)
    # inclusive prefix over the (static, small) group axis; unrolled slice
    # adds lower leaner than jnp.cumsum's generic window-reduce on CPU
    acc = gpop[:, 0]
    prefixes = [acc]
    for g in range(1, groups):
        acc = acc + gpop[:, g]
        prefixes.append(acc)
    csum = jnp.stack(prefixes, axis=1)                     # (S, G, 32, W)

    filled = filled.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    n_emit = (filled + lengths) // window                  # (S,)
    # slot k spans cycles [k*window - filled, (k+1)*window - filled), clipped
    # to the valid range; slots past n_emit collapse to empty (their cycles
    # belong to the tail), which the min(k, n_emit) clamp encodes.
    k = jnp.arange(k_max + 2, dtype=jnp.int32)
    bx = jnp.clip(jnp.minimum(k[None, :], n_emit[:, None]) * window
                  - filled[:, None], 0, lengths[:, None])  # (S, K+2)
    bx = bx.at[:, -1].set(lengths)                         # tail ends at len
    xg = bx // 32
    xr = (bx - xg * 32).astype(jnp.uint32)
    # prefix count C(x) = full groups below x + popcount of the edge group's
    # first (x mod 32) cycles ((1 << r) - 1 keeps exactly bits 0..r-1, the
    # LSB-first cycle order of time_pack)
    idx = jnp.minimum(xg, groups - 1)[..., None, None]
    part = hv.take_along_axis32(tb, idx, axis=1)           # (S, K+2, 32, W)
    edge = (jnp.uint32(1) << xr)[..., None, None] - jnp.uint32(1)
    pref = jnp.where((xg > 0)[..., None, None],
                     hv.take_along_axis32(
                         csum, jnp.maximum(xg - 1, 0)[..., None, None],
                         axis=1),
                     0)
    cx = pref + hv.lax_popcount(part & edge).astype(jnp.int32)
    seg = cx[:, 1:] - cx[:, :-1]                           # (S, K+1, 32, W)
    # time_pack's (bit, word) layout -> standard d = word * 32 + bit order
    return seg.transpose(0, 1, 3, 2).reshape(s, k_max + 1, dim)


def emission_masks(filled: jax.Array, lengths: jax.Array, *, t_pad: int,
                   window: int) -> jax.Array:
    """Device-side emission schedule: time-packed per-slot cycle masks.

    Returns (S, K + 1, ceil(t_pad / 32)) uint32; bit j of word g in row k is
    set iff cycle 32 g + j of this step belongs to frame slot k (row K: the
    leftover tail).  Pure function of ``(filled, lengths)`` — the host ships
    only the (S,) lengths, not a dense (S, K+1, t_pad) mask.  Used by the
    fused Pallas kernel; the jnp reference path needs no masks at all
    (prefix counts at slot boundaries, see ``fleet_counts_ref``).
    """
    t32 = -(-t_pad // 32) * 32
    k_max = (t_pad - 1) // window + 1
    filled = filled.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    j = jnp.arange(t32, dtype=jnp.int32)
    ordinal = (filled[:, None] + j[None, :]) // window     # (S, t32)
    valid = j[None, :] < lengths[:, None]
    n_emit = (filled + lengths) // window
    rows = jnp.arange(k_max, dtype=jnp.int32)
    frame = ((ordinal[:, None, :] == rows[None, :, None])
             & (rows[None, :, None] < n_emit[:, None, None])
             & valid[:, None, :])
    tail = (ordinal >= n_emit[:, None]) & valid
    dense = jnp.concatenate([frame, tail[:, None, :]], axis=1)
    return hv.pack_bits(dense.astype(jnp.uint8))           # (S, K+1, t32//32)
