"""Public entry points for the fleet's packed-domain temporal bundling.

Two paths, both bit-exact with the per-session reference datapaths:

* ``fleet_counts`` — pure-jnp bit-plane path (ref.py): takes the per-cycle
  packed spatial HVs and needs NO masks (slot membership is contiguous, so
  counts are prefix-count differences at slot boundaries).
* ``fleet_counts_fused`` — the Pallas kernel (kernel.py): takes
  owner-gathered pre-bound codebook rows and fuses spatial bundling + bit
  transpose + masked-popcount temporal accumulation in VMEM, driven by
  device-computed time-packed emission masks (ref.emission_masks).

``spatial_mode`` maps an HDCConfig onto the kernel's spatial-bundle variant
exactly as serve/dispatch.owner_spatial_encode routes it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.classifier import HDCConfig
from repro.kernels.common import use_interpret
from repro.kernels.hdc_fleet.kernel import fleet_counts_pallas
from repro.kernels.hdc_fleet.ref import emission_masks, fleet_counts_ref


def spatial_mode(cfg: HDCConfig) -> tuple[str, int]:
    """(mode, threshold) for the fused kernel's spatial bundling stage."""
    if cfg.variant == "dense":
        return "majority", 0
    if cfg.variant == "sparse_naive" or cfg.spatial_thinning:
        return "thin", cfg.spatial_threshold
    return "or", 0


def fleet_counts(words: jax.Array, filled: jax.Array, lengths: jax.Array,
                 cfg: HDCConfig) -> jax.Array:
    """(S, T, W) spatial HVs -> (S, K+1, D) int32 frame-slot counts."""
    return fleet_counts_ref(words, filled, lengths, window=cfg.window,
                            dim=cfg.dim)


def fleet_counts_fused(bound: jax.Array, filled: jax.Array,
                       lengths: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(S, T, C, W) owner-gathered pre-bound rows -> (S, K+1, D) counts.

    Pads the cycle axis to a 32 multiple (padded cycles are masked off by
    the emission schedule) and runs the fused kernel; interpret mode off-TPU.
    """
    s, t, c, w = bound.shape
    t32 = -(-t // 32) * 32
    if t32 != t:
        bound = jnp.pad(bound, ((0, 0), (0, t32 - t), (0, 0), (0, 0)))
    tm = emission_masks(filled, lengths, t_pad=t, window=cfg.window)
    mode, threshold = spatial_mode(cfg)
    return fleet_counts_pallas(bound, tm, mode=mode, dim=cfg.dim,
                               threshold=threshold, interpret=use_interpret())
