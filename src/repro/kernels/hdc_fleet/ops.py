"""Public entry points for the fleet's packed-domain temporal bundling.

Two paths, both bit-exact with the per-session reference datapaths:

* ``fleet_counts`` — pure-jnp bit-plane path (ref.py): takes the per-cycle
  packed spatial HVs and needs NO masks (slot membership is contiguous, so
  counts are prefix-count differences at slot boundaries).
* ``fleet_counts_fused`` — the Pallas kernel (kernel.py): takes RAW uint8
  codes plus the stacked pre-bound codebook bank and fuses the table gather
  (bind), spatial bundling, bit transpose and masked-popcount temporal
  accumulation in VMEM, driven by device-computed time-packed emission
  masks (ref.emission_masks).  Nothing per-cycle wider than the codes
  themselves ever crosses HBM.

``spatial_mode`` maps an HDCConfig onto the kernel's spatial-bundle variant
exactly as serve/dispatch.owner_spatial_codes routes it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.classifier import HDCConfig
from repro.kernels.common import use_interpret
from repro.kernels.hdc_fleet.kernel import fleet_counts_pallas
from repro.kernels.hdc_fleet.ref import emission_masks, fleet_counts_ref


def spatial_mode(cfg: HDCConfig) -> tuple[str, int]:
    """(mode, threshold) for the fused kernel's spatial bundling stage."""
    if cfg.variant == "dense":
        return "majority", 0
    if cfg.variant == "sparse_naive" or cfg.spatial_thinning:
        return "thin", cfg.spatial_threshold
    return "or", 0


def fleet_counts(words: jax.Array, filled: jax.Array, lengths: jax.Array,
                 cfg: HDCConfig) -> jax.Array:
    """(S, T, W) spatial HVs -> (S, K+1, D) int32 frame-slot counts."""
    return fleet_counts_ref(words, filled, lengths, window=cfg.window,
                            dim=cfg.dim)


def fleet_counts_fused(tables: jax.Array, owner: jax.Array,
                       codes: jax.Array, filled: jax.Array,
                       lengths: jax.Array, cfg: HDCConfig,
                       tables_xor: jax.Array | None = None,
                       chan_mask: jax.Array | None = None) -> jax.Array:
    """(S, T, C) raw uint8 codes -> (S, K+1, D) counts, one fused pass.

    ``tables`` is the stacked (P, C, K, W) pre-bound codebook bank and
    ``owner`` each session's row into it (scalar-prefetched by the kernel's
    table BlockSpec).  Pads the cycle axis to a 32 multiple (padded cycles
    gather row 0 but are masked off by the emission schedule) and runs the
    fused kernel; interpret mode off-TPU.

    ``tables_xor`` (same shape as ``tables``) is the reliability
    subsystem's fault-injection hook (repro.reliability.faults): an
    effective bit-flip mask XORed into the codebook bank HERE, adjacent to
    the kernel launch, so the VMEM-resident table BlockSpec prefetches the
    FAULTED bank — the corruption rides the same operand path as the clean
    bank and the kernel body is untouched.  ``None`` (the default) skips
    the XOR entirely.

    ``chan_mask`` (S, C) uint8/uint32, the channel-fault tolerance hook
    (repro.reliability.channels): quarantined channels drop out of the
    in-kernel spatial bundle with renormalized count denominators, exactly
    like dispatch.owner_spatial_codes' masked path.  ``None`` (the
    default) keeps the kernel's operand list and body untouched.
    """
    s, t, c = codes.shape
    if tables_xor is not None:
        tables = tables ^ tables_xor
    t32 = -(-t // 32) * 32
    if t32 != t:
        codes = jnp.pad(codes, ((0, 0), (0, t32 - t), (0, 0)))
    tm = emission_masks(filled, lengths, t_pad=t, window=cfg.window)
    mode, threshold = spatial_mode(cfg)
    return fleet_counts_pallas(tables, owner, codes, tm, mode=mode,
                               dim=cfg.dim, threshold=threshold,
                               chan_mask=chan_mask,
                               interpret=use_interpret())
