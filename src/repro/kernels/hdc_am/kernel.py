"""Associative-memory similarity-search kernel.

Scores a batch of packed query HVs against the class HVs:

  * mode="overlap" (sparse HDC):  score = popcount(q AND c)
  * mode="hamming" (dense  HDC):  score = D - popcount(q XOR c)

This is a binary "matmul" (B, W) x (C, W) -> (B, C) executed on the VPU with
population_count; queries stream through VMEM in blocks of ``block_b`` while
the class HVs stay resident (a few KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _am_kernel(q_ref, c_ref, out_ref, *, mode: str, dim: int):
    q = q_ref[...]                                 # (TB, W) uint32
    cls = c_ref[...]                               # (C, W) uint32
    # sum dtypes pinned: under JAX_ENABLE_X64 jnp.sum would promote to int64
    # and mismatch the int32 output ref
    if mode == "overlap":
        combined = jnp.bitwise_and(q[:, None, :], cls[None, :, :])
        score = jnp.sum(jax.lax.population_count(combined).astype(jnp.int32),
                        axis=-1, dtype=jnp.int32)
    elif mode == "hamming":
        combined = jnp.bitwise_xor(q[:, None, :], cls[None, :, :])
        score = dim - jnp.sum(jax.lax.population_count(combined).astype(jnp.int32),
                              axis=-1, dtype=jnp.int32)
    else:
        raise ValueError(mode)
    out_ref[...] = score


def am_search_pallas(queries: jax.Array, classes: jax.Array, *, mode: str,
                     dim: int, block_b: int = DEFAULT_BLOCK_B,
                     interpret: bool = True) -> jax.Array:
    """queries: (B, W) uint32; classes: (C, W) uint32 -> (B, C) int32."""
    b, w = queries.shape
    c, _ = classes.shape
    block_b = min(block_b, b)
    if b % block_b:  # pad batch to a block multiple
        pad = block_b - b % block_b
        queries = jnp.pad(queries, ((0, pad), (0, 0)))
    bp = queries.shape[0]
    kernel = functools.partial(_am_kernel, mode=mode, dim=dim)
    out = pl.pallas_call(
        kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((c, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, c), jnp.int32),
        interpret=interpret,
    )(queries, classes)
    return out[:b]
