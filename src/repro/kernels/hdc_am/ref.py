"""Pure-jnp oracle for the AM similarity-search kernel."""

from __future__ import annotations

import jax

from repro.core import am


def am_search_ref(queries: jax.Array, classes: jax.Array, *, mode: str,
                  dim: int) -> jax.Array:
    if mode == "overlap":
        return am.am_scores_sparse(queries, classes)
    if mode == "hamming":
        return am.am_scores_dense(queries, classes, dim)
    raise ValueError(mode)
