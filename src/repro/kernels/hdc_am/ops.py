"""Jit'd public entry point for AM similarity search."""

from __future__ import annotations

import functools

import jax

from repro.kernels.common import use_interpret
from repro.kernels.hdc_am.kernel import am_search_pallas
from repro.kernels.hdc_am.ref import am_search_ref


@functools.partial(jax.jit, static_argnames=("mode", "dim", "use_kernel"))
def am_search(queries: jax.Array, classes: jax.Array, *, mode: str = "overlap",
              dim: int = 1024, use_kernel: bool = True) -> jax.Array:
    """(B, W) x (C, W) -> (B, C) similarity scores.

    Leading query dims beyond 2 are flattened and restored."""
    lead = queries.shape[:-1]
    q2 = queries.reshape(-1, queries.shape[-1])
    if use_kernel:
        out = am_search_pallas(q2, classes, mode=mode, dim=dim,
                               interpret=use_interpret())
    else:
        out = am_search_ref(q2, classes, mode=mode, dim=dim)
    return out.reshape(*lead, classes.shape[0])
