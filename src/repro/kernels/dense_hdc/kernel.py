"""Fused dense-HDC encoder kernel (the paper's comparison baseline [1]).

XOR binding + spatial majority (over channels) + temporal majority (over the
window), all in VMEM; one grid step emits one packed time-frame HV.  This is
the bit-packed TPU analogue of the dense accelerator whose switching energy
the paper beats by 7.5x — and our §Perf baseline for the sparse/dense
byte-traffic comparison.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 16


def _unpack(words: jax.Array, dim: int) -> jax.Array:
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (*words.shape, 32), words.ndim)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * 32)[..., :dim]


def _dense_kernel(item_ref, elec_ref, out_ref, *, window: int, channels: int,
                  dim: int):
    elec = elec_ref[...]                                         # (C, W)
    n_chunks = window // CHUNK

    def chunk_body(k, tcounts):
        hvs = item_ref[0, 0, pl.dslice(k * CHUNK, CHUNK)]         # (CHUNK, C, W)
        bound = jnp.bitwise_xor(hvs, elec[None])
        bits = _unpack(bound, dim).astype(jnp.int32)              # (CHUNK, C, D)
        # dtype pinned: under JAX_ENABLE_X64 jnp.sum would promote the
        # fori_loop carry to int64 and break the carry-type invariant
        scounts = jnp.sum(bits, axis=1, dtype=jnp.int32)          # (CHUNK, D)
        spat = (scounts * 2 > channels).astype(jnp.int32)         # majority
        return tcounts + jnp.sum(spat, axis=0, dtype=jnp.int32)

    tcounts = jax.lax.fori_loop(
        0, n_chunks, chunk_body, jnp.zeros((dim,), jnp.int32))
    bits = (tcounts * 2 > window).reshape(dim // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
    out_ref[0, 0, :] = jnp.sum(bits.astype(jnp.uint32) << shifts, axis=1,
                               dtype=jnp.uint32)


def dense_encoder_pallas(item_hvs: jax.Array, elec: jax.Array, *, window: int,
                         dim: int, interpret: bool = True) -> jax.Array:
    """item_hvs: (B, F, window, C, W) uint32 looked-up item HVs
    elec: (C, W) uint32 -> (B, F, W) uint32 packed frame HVs."""
    b, f, w, c, words = item_hvs.shape
    kernel = functools.partial(_dense_kernel, window=window, channels=c, dim=dim)
    return pl.pallas_call(
        kernel,
        grid=(b, f),
        in_specs=[
            pl.BlockSpec((1, 1, window, c, words), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((c, words), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, words), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, words), jnp.uint32),
        interpret=interpret,
    )(item_hvs, elec)
