"""Pure-jnp oracle for the fused dense-HDC encoder kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hv


def dense_encoder_ref(item_hvs: jax.Array, elec: jax.Array, *, window: int,
                      dim: int) -> jax.Array:
    """(B, F, window, C, W) x (C, W) -> (B, F, W) via the unfused core path."""
    bound = jnp.bitwise_xor(item_hvs, elec)
    channels = item_hvs.shape[-2]
    scounts = hv.unpacked_counts(bound, axis=-2, dim=dim)      # (B,F,win,D)
    spat = hv.pack_bits((scounts * 2 > channels).astype(jnp.uint8))
    tcounts = hv.unpacked_counts(spat, axis=-2, dim=dim)       # (B,F,D)
    return hv.pack_bits((tcounts * 2 > window).astype(jnp.uint8))
