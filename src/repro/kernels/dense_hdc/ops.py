"""Jit'd public entry point for the fused dense-HDC encoder."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.classifier import frame_view
from repro.core.im import DenseIMParams
from repro.kernels.common import use_interpret
from repro.kernels.dense_hdc.kernel import dense_encoder_pallas
from repro.kernels.dense_hdc.ref import dense_encoder_ref


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def dense_encode_frames_fused(params: DenseIMParams, codes: jax.Array,
                              cfg, use_kernel: bool = True) -> jax.Array:
    """Fused dense-HDC encoder (the `variant="dense", backend="pallas"` path
    of repro.core.pipeline).  `cfg` is any config with `window`, `channels`,
    `dim` — i.e. the unified HDCConfig.
    codes: (B, T, C) uint8 -> (B, F, W) uint32."""
    codes = frame_view(codes, cfg.window)
    ch = jnp.arange(cfg.channels, dtype=jnp.int32)
    item = params.item_packed[ch, codes.astype(jnp.int32)]   # (B,F,win,C,W)
    if use_kernel:
        return dense_encoder_pallas(item, params.elec_packed, window=cfg.window,
                                    dim=cfg.dim, interpret=use_interpret())
    return dense_encoder_ref(item, params.elec_packed, window=cfg.window,
                             dim=cfg.dim)
