"""Jit'd public entry point for the fused dense-HDC encoder."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dense import DenseHDCConfig, DenseIMParams
from repro.kernels.common import use_interpret
from repro.kernels.dense_hdc.kernel import dense_encoder_pallas
from repro.kernels.dense_hdc.ref import dense_encoder_ref


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def dense_encode_frames_fused(params: DenseIMParams, codes: jax.Array,
                              cfg: DenseHDCConfig,
                              use_kernel: bool = True) -> jax.Array:
    """Drop-in fused replacement for core.dense.encode_frames.
    codes: (B, T, C) uint8 -> (B, F, W) uint32."""
    b, t, c = codes.shape
    frames = t // cfg.window
    codes = codes[:, : frames * cfg.window].reshape(b, frames, cfg.window, c)
    ch = jnp.arange(cfg.channels)
    item = params.item_packed[ch, codes.astype(jnp.int32)]   # (B,F,win,C,W)
    if use_kernel:
        return dense_encoder_pallas(item, params.elec_packed, window=cfg.window,
                                    dim=cfg.dim, interpret=use_interpret())
    return dense_encoder_ref(item, params.elec_packed, window=cfg.window,
                             dim=cfg.dim)
