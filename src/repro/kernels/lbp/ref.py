"""Pure-jnp oracle for the LBP preprocessing kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lbp_ref(x: jax.Array, *, bits: int = 6) -> jax.Array:
    """x: (B, T, C) -> (B, T - bits, C) uint8, mirroring data.ieeg.lbp_codes_np
    (which operates channel-major; this is the time-major jnp twin)."""
    d = (x[:, 1:] > x[:, :-1]).astype(jnp.uint32)
    t_out = d.shape[1] - bits + 1
    code = jnp.zeros((x.shape[0], t_out, x.shape[2]), jnp.uint32)
    for i in range(bits):
        code = code | (d[:, bits - 1 - i : bits - 1 - i + t_out] << i)
    return code.astype(jnp.uint8)
