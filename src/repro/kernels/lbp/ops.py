"""Jit'd public entry point for LBP preprocessing with time chunking."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.lbp.kernel import lbp_pallas
from repro.kernels.lbp.ref import lbp_ref

# keep one (chunk+bits, C) f32 tile ~<= 4 MiB for C = 64
MAX_CHUNK_T = 16384


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def lbp_codes(x: jax.Array, *, bits: int = 6, use_kernel: bool = True) -> jax.Array:
    """x: (B, T, C) raw signal -> (B, T - bits, C) uint8 LBP codes.

    Long time axes are processed in overlapping chunks (halo = `bits`
    samples) outside the kernel, so each pallas_call sees a bounded tile."""
    if not use_kernel:
        return lbp_ref(x, bits=bits)
    b, t, c = x.shape
    t_out = t - bits
    if t_out <= MAX_CHUNK_T:
        return lbp_pallas(x, bits=bits, interpret=use_interpret())
    chunks = []
    for start in range(0, t_out, MAX_CHUNK_T):
        size = min(MAX_CHUNK_T, t_out - start)
        xin = jax.lax.dynamic_slice_in_dim(x, start, size + bits, axis=1)
        chunks.append(lbp_pallas(xin, bits=bits, interpret=use_interpret()))
    return jnp.concatenate(chunks, axis=1)
