"""Local-binary-pattern preprocessing kernel.

Converts raw iEEG samples to 6-bit LBP codes:
  code[t] = sum_i 2^i * [x[t - i] > x[t - i - 1]],  i = 0..bits-1

The comparison + weighted-sum is pure VPU work.  One grid step processes one
batch row; the `bits`-sample halo between time chunks is handled by the ops.py
wrapper (overlapped chunking outside the kernel), keeping the BlockSpec plain
Blocked indexing.  VMEM bound: one (T, C) f32 tile — the wrapper chunks T to
keep this ≤ ~4 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lbp_kernel(x_ref, out_ref, *, bits: int, t_out: int):
    x = x_ref[0]                                     # (t_out + bits, C)
    d = (x[1:] > x[:-1]).astype(jnp.uint32)          # (t_out + bits - 1, C)
    code = jnp.zeros((t_out, x.shape[1]), jnp.uint32)
    for i in range(bits):
        # bit i encodes sign(x[t - i] - x[t - i - 1]); t spans the output rows
        code |= d[bits - 1 - i : bits - 1 - i + t_out] << i
    out_ref[0] = code.astype(jnp.uint8)


def lbp_pallas(x: jax.Array, *, bits: int = 6,
               interpret: bool = True) -> jax.Array:
    """x: (B, T, C) float raw signal -> (B, T - bits, C) uint8 LBP codes."""
    b, t, c = x.shape
    t_out = t - bits
    kernel = functools.partial(_lbp_kernel, bits=bits, t_out=t_out)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, t, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, t_out, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t_out, c), jnp.uint8),
        interpret=interpret,
    )(x)
