"""Fused sparse-HDC encoder kernel (the paper's main datapath, CompIM domain).

One grid step produces ONE time-frame HV for one (batch, frame) cell:

    bound positions (window, C, S)  --bind-->  (pos + elec) mod L
        --demux-->  per-cycle spatial one-hot  --OR/thin-->  (S, L) indicator
        --temporal accumulate-->  (S, L) int32 counts
        --threshold + pack-->  (D // 32,) uint32 frame HV

Fusing the whole encoder keeps the per-cycle 1024-bit spatial HVs and the
8-bit temporal counters in VMEM: HBM traffic is just 56-bit positions in and
one packed HV out per frame (the TPU analogue of the CompIM energy win; see
README.md "Kernel & datapath design").

VMEM budget per grid step (defaults window=256, C=64, S=8, L=128):
  positions block  256*64*8  B   = 128 KiB
  chunk one-hot    32*64*8*128 B =   2 MiB (int8, transient)
  counters         8*128*4   B   =   4 KiB
comfortably under the ~16 MiB/core VMEM of TPU v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 32  # cycles expanded to one-hot at a time (VMEM working-set control)


def _encoder_kernel(pos_ref, elec_ref, out_ref, *, window: int, segments: int,
                    seg_len: int, temporal_threshold: int,
                    spatial_thinning: bool, spatial_threshold: int):
    c = elec_ref.shape[0]
    elec = elec_ref[...].astype(jnp.int32)                       # (C, S)
    n_chunks = window // CHUNK

    def chunk_body(k, counts):
        p = pos_ref[0, 0, pl.dslice(k * CHUNK, CHUNK)]            # (CHUNK, C, S)
        bound = (p.astype(jnp.int32) + elec[None]) % seg_len      # (CHUNK, C, S)
        iota = jax.lax.broadcasted_iota(
            jnp.int32, (CHUNK, c, segments, seg_len), 3)
        onehot = (bound[..., None] == iota)                       # (CHUNK, C, S, L)
        if spatial_thinning:
            spat = (jnp.sum(onehot.astype(jnp.int32), axis=1, dtype=jnp.int32)
                    >= spatial_threshold)
        else:
            spat = jnp.any(onehot, axis=1)                        # (CHUNK, S, L)
        # dtype pinned: under JAX_ENABLE_X64 jnp.sum would promote the
        # fori_loop carry to int64 and break the carry-type invariant
        return counts + jnp.sum(spat.astype(jnp.int32), axis=0, dtype=jnp.int32)

    counts = jax.lax.fori_loop(
        0, n_chunks, chunk_body, jnp.zeros((segments, seg_len), jnp.int32))
    bits = (counts >= temporal_threshold).reshape(segments * seg_len // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
    words = jnp.sum(bits.astype(jnp.uint32) << shifts, axis=1, dtype=jnp.uint32)
    out_ref[0, 0, :] = words


def encoder_pallas(positions: jax.Array, elec: jax.Array, *, window: int,
                   segments: int, seg_len: int, temporal_threshold: int,
                   spatial_thinning: bool = False, spatial_threshold: int = 1,
                   interpret: bool = True) -> jax.Array:
    """positions: (B, F, window, C, S) uint8 bound-input item positions
    elec: (C, S) uint8 electrode positions
    returns: (B, F, D // 32) uint32 packed frame HVs."""
    b, f, w, c, s = positions.shape
    assert w == window and s == segments
    dim = segments * seg_len
    kernel = functools.partial(
        _encoder_kernel, window=window, segments=segments, seg_len=seg_len,
        temporal_threshold=temporal_threshold,
        spatial_thinning=spatial_thinning, spatial_threshold=spatial_threshold)
    return pl.pallas_call(
        kernel,
        grid=(b, f),
        in_specs=[
            pl.BlockSpec((1, 1, window, c, s), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((c, s), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dim // 32), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, dim // 32), jnp.uint32),
        interpret=interpret,
    )(positions, elec)
