"""Pure-jnp oracle for the fused sparse-HDC encoder kernel."""

from __future__ import annotations

import jax

from repro.core import binding, bundling


def encoder_ref(positions: jax.Array, elec: jax.Array, *, window: int,
                segments: int, seg_len: int, temporal_threshold: int,
                spatial_thinning: bool = False,
                spatial_threshold: int = 1) -> jax.Array:
    """Mirrors kernel.encoder_pallas via the core (unfused) pipeline."""
    dim = segments * seg_len
    bound = binding.bind_positions(positions, elec, seg_len)   # (B,F,win,C,S)
    if spatial_thinning:
        spat = bundling.spatial_bundle_thinned_positions(
            bound, dim, segments, spatial_threshold)
    else:
        spat = bundling.spatial_bundle_or_positions(bound, dim, segments)
    return bundling.temporal_bundle(spat, dim, temporal_threshold)
