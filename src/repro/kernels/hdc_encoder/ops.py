"""Jit'd public entry point for the fused sparse-HDC encoder."""

from __future__ import annotations

import functools

import jax

from repro.core.classifier import HDCConfig, frame_view
from repro.core.im import IMParams, im_lookup_positions
from repro.kernels.common import use_interpret
from repro.kernels.hdc_encoder.kernel import encoder_pallas
from repro.kernels.hdc_encoder.ref import encoder_ref


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def encode_frames_fused(params: IMParams, codes: jax.Array, cfg: HDCConfig,
                        use_kernel: bool = True) -> jax.Array:
    """Fused sparse encoder (the `backend="pallas"` path of
    repro.core.pipeline).  Computes the position-domain datapath; the
    pipeline also routes `sparse_naive` here by forcing spatial thinning on
    (bit-identical by the binding-domain equivalence, paper Sec. III-A).
    codes: (B, T, C) uint8 -> (B, F, W) uint32."""
    codes = frame_view(codes, cfg.window)
    pos = im_lookup_positions(params, codes)      # XLA gather: (B,F,win,C,S)
    kw = dict(window=cfg.window, segments=cfg.segments, seg_len=cfg.seg_len,
              temporal_threshold=cfg.temporal_threshold,
              spatial_thinning=cfg.spatial_thinning,
              spatial_threshold=cfg.spatial_threshold)
    if use_kernel:
        return encoder_pallas(pos, params.elec_pos, interpret=use_interpret(), **kw)
    return encoder_ref(pos, params.elec_pos, **kw)
