"""Synthetic iEEG data + local-binary-pattern (LBP) preprocessing.

The SWEC-ETHZ one-shot iEEG dataset of [1] is not redistributable offline, so
we generate synthetic patients whose *LBP-code statistics* differ between
interictal background and ictal discharge the way real iEEG does:

* interictal: smooth AR(2) background (low-frequency dominated) + noise
* ictal: superimposed rhythmic 8–20 Hz discharge with per-channel gain and a
  recruitment profile (a subset of channels participates, as in focal onsets)

LBP (Burrello et al. [1]): the 6-bit code at time t encodes the signs of the
six consecutive first differences x[t-5..t] — exactly what the HDC item
memory consumes.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

FS = 512  # Hz, matches the short-term SWEC-ETHZ recordings


# ---------------------------------------------------------------------------
# LBP preprocessing
# ---------------------------------------------------------------------------

def validate_signal(x: np.ndarray, *, adc_limit: float | None = None
                    ) -> np.ndarray:
    """Ingest guard for raw iEEG: reject non-finite samples, clamp rails.

    NaN/Inf samples raise — a NaN propagates through ``np.diff`` into
    ``False`` comparisons and silently corrupts every LBP code in its
    6-sample neighborhood, which is far worse than failing loudly at the
    boundary.  With ``adc_limit`` the signal is clamped to the converter
    rails ``[-adc_limit, +adc_limit]`` (what a real front-end does in
    hardware: out-of-range samples saturate, they don't wrap)."""
    x = np.asarray(x)
    bad = ~np.isfinite(x)
    if bad.any():
        idx = np.argwhere(bad)[0]
        raise ValueError(
            f"signal contains {int(bad.sum())} non-finite sample(s) "
            f"(first at index {tuple(int(i) for i in idx)}); NaN/Inf "
            "silently corrupts LBP codes — sanitize the recording before "
            "ingest")
    if adc_limit is not None:
        if adc_limit <= 0:
            raise ValueError(f"adc_limit={adc_limit!r} must be positive")
        x = np.clip(x, -adc_limit, adc_limit)
    return x


def lbp_codes_np(x: np.ndarray, bits: int = 6,
                 adc_limit: float | None = None) -> np.ndarray:
    """x: (..., T) raw signal -> (..., T - bits) uint8 LBP codes.

    code[t] = sum_i 2^i * [ x[t - i] > x[t - i - 1] ],  i = 0..bits-1

    Rejects NaN/Inf input and (with ``adc_limit``) clamps out-of-range
    samples to the ADC rails first — see ``validate_signal``.
    """
    x = validate_signal(x, adc_limit=adc_limit)
    d = (np.diff(x, axis=-1) > 0).astype(np.uint8)           # (..., T-1)
    t_out = d.shape[-1] - bits + 1
    code = np.zeros((*d.shape[:-1], t_out), dtype=np.uint8)
    for i in range(bits):
        code |= d[..., bits - 1 - i : bits - 1 - i + t_out] << i
    return code


# ---------------------------------------------------------------------------
# synthetic patients
# ---------------------------------------------------------------------------

@dataclass
class SeizureRecord:
    codes: np.ndarray        # (T, channels) uint8 LBP codes
    onset_sample: int        # sample index of expert-marked onset
    label: np.ndarray        # (T,) 0 interictal / 1 ictal per sample


@dataclass
class Patient:
    pid: int
    records: list[SeizureRecord] = field(default_factory=list)
    channels: int = 64


def _ar2_background(rng: np.random.Generator, t: int, channels: int) -> np.ndarray:
    """Broadband AR(2) background, per-channel independent.

    Real interictal iEEG is broadband (first differences alternate sign
    often), so LBP codes spread over the code alphabet; the ictal discharge
    concentrates them.  Mild poles keep some 1/f character without the
    pathological low-pass that would concentrate background codes too.
    """
    a1, a2 = 0.9, -0.25
    e = rng.standard_normal((channels, t + 64)).astype(np.float32)
    x = np.zeros_like(e)
    for i in range(2, t + 64):
        x[:, i] = a1 * x[:, i - 1] + a2 * x[:, i - 2] + e[:, i]
    return x[:, 64:]


def _ictal_discharge(rng: np.random.Generator, t: int, channels: int,
                     fs: int, seed_freq: float, participation: np.ndarray) -> np.ndarray:
    """Rhythmic discharge with slow frequency drift and channel recruitment."""
    tt = np.arange(t) / fs
    freq = seed_freq * (1.0 + 0.15 * np.sin(2 * np.pi * 0.05 * tt))
    phase = 2 * np.pi * np.cumsum(freq) / fs
    # rhythmic discharge whose per-sample derivative dominates the background
    # first differences -> LBP code statistics shift strongly during ictal
    wave = np.sin(phase) * (1.0 + 0.3 * np.sin(2 * np.pi * 2.7 * tt))
    gains = participation[:, None] * rng.uniform(6.0, 12.0, (channels, 1)).astype(np.float32)
    jitter = rng.standard_normal((channels, t)).astype(np.float32) * 0.2
    return gains * (wave[None, :].astype(np.float32) + jitter)


def make_record(rng: np.random.Generator, *, channels: int = 64,
                pre_s: float = 30.0, ictal_s: float = 40.0, post_s: float = 10.0,
                fs: int = FS, seed_freq: float | None = None,
                participation_frac: float = 0.6,
                signal_transform=None) -> SeizureRecord:
    """``signal_transform`` (optional ``f(x, rng) -> x`` over the raw
    (channels, T) float signal, applied just before LBP coding) is the
    electrode-fault injection hook: ``reliability.channels`` builds
    transforms that kill/saturate/noise individual channels, so faulted
    records flow through the exact production preprocessing."""
    if seed_freq is None:
        seed_freq = float(rng.uniform(18.0, 40.0))
    t_pre, t_ict, t_post = int(pre_s * fs), int(ictal_s * fs), int(post_s * fs)
    t = t_pre + t_ict + t_post
    x = _ar2_background(rng, t, channels)
    sf = seed_freq
    part = (rng.random(channels) < participation_frac).astype(np.float32)
    if part.sum() == 0:
        part[rng.integers(channels)] = 1.0
    # ramp the discharge in over 2 s (seizures recruit gradually)
    ramp = np.clip(np.arange(t_ict) / (2.0 * fs), 0.0, 1.0).astype(np.float32)
    x[:, t_pre:t_pre + t_ict] += _ictal_discharge(rng, t_ict, channels, fs, sf, part) * ramp
    if signal_transform is not None:
        x = np.asarray(signal_transform(x, rng), np.float32)
        if x.shape != (channels, t):
            raise ValueError(
                f"signal_transform must preserve the ({channels}, {t}) "
                f"signal shape, got {x.shape}")
    codes = lbp_codes_np(x)                       # (channels, T-6)
    label = np.zeros(t, dtype=np.int32)
    label[t_pre:t_pre + t_ict] = 1
    return SeizureRecord(codes=codes.T.copy(), onset_sample=t_pre, label=label[: codes.shape[-1]])


def make_patient(pid: int, *, n_seizures: int = 4, channels: int = 64,
                 seed: int | None = None) -> Patient:
    """Patient = a fixed seizure 'fingerprint' (freq band, focus) + n records."""
    rng = np.random.default_rng(seed if seed is not None else 1000 + pid)
    base_freq = float(rng.uniform(18.0, 40.0))
    part_frac = float(rng.uniform(0.4, 0.8))
    recs = [
        make_record(rng, channels=channels,
                    seed_freq=base_freq * float(rng.uniform(0.9, 1.1)),
                    participation_frac=part_frac)
        for _ in range(n_seizures)
    ]
    return Patient(pid=pid, records=recs, channels=channels)


def frame_labels(record: SeizureRecord, window: int) -> np.ndarray:
    """Per-frame labels: frame is ictal if >= half its samples are ictal."""
    f = record.label.shape[0] // window
    lab = record.label[: f * window].reshape(f, window)
    return (lab.mean(axis=1) >= 0.5).astype(np.int32)


def onset_frame(record: SeizureRecord, window: int) -> int:
    return int(np.ceil(record.onset_sample / window))
