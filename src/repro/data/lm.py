"""LM data: ShapeDtypeStruct input specs (dry-run) + synthetic batches.

The four assigned input shapes map to step kinds:

  train_4k     seq 4,096   gb 256   -> train_step
  prefill_32k  seq 32,768  gb 32    -> prefill
  decode_32k   seq 32,768  gb 128   -> decode_step (cache = seq)
  long_500k    seq 524,288 gb 1     -> decode_step (cache = seq; SSM/hybrid only)

Modality conventions (per the assignment the frontends are stubs fed with
precomputed embeddings):

  vlm    `media` (B, M, d_model) patch embeddings; text length = seq - M so
         the backbone sees exactly `seq` positions.
  audio  `frames` (B, seq, d_model) to the encoder; decoder text length =
         seq // 8 for train/prefill (an ASR-ish 8:1 frame-to-token ratio).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import serve as serve_mod

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def text_len(cfg: ArchConfig, seq: int, kind: str) -> int:
    if cfg.family == "vlm":
        return seq - cfg.num_media_tokens
    if cfg.family in ("encdec", "audio") and kind != "decode":
        return max(seq // 8, 16)
    return seq


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, seq = shape.global_batch, shape.seq_len
    d = cfg.d_model
    tl = text_len(cfg, seq, shape.kind)
    tok = jnp.int32
    emb = jnp.float32
    if shape.kind == "train":
        spec = {"tokens": SDS((b, tl), tok), "labels": SDS((b, tl), tok)}
        if cfg.family == "vlm":
            spec["media"] = SDS((b, cfg.num_media_tokens, d), emb)
        if cfg.family in ("encdec", "audio"):
            spec["frames"] = SDS((b, seq, d), emb)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": SDS((b, tl), tok)}
        if cfg.family == "vlm":
            spec["media"] = SDS((b, cfg.num_media_tokens, d), emb)
        if cfg.family in ("encdec", "audio"):
            spec["frames"] = SDS((b, seq, d), emb)
        return spec
    # decode: one token + caches of length seq.  eval_shape — NEVER allocate
    # the caches here (a 32k-ctx command-r cache is ~0.5 TB on the host)
    dtype = jnp.dtype(cfg.dtype)
    enc_len = max(shape.seq_len // 8, 16)
    cache_specs = jax.eval_shape(
        lambda: serve_mod.init_caches(cfg, b, seq, dtype, enc_len=enc_len))
    return {"tokens": SDS((b, 1), tok), "caches": cache_specs,
            "pos": SDS((), jnp.int32)}


def synth_batch(key: jax.Array, cfg: ArchConfig, shape: ShapeSpec,
                batch_override: int | None = None) -> dict:
    """Concrete random batch (smoke tests, examples)."""
    b = batch_override or shape.global_batch
    seq = shape.seq_len
    tl = text_len(cfg, seq, shape.kind)
    k1, k2, k3 = jax.random.split(key, 3)
    out: dict = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = jax.random.randint(k1, (b, tl), 0, cfg.vocab, dtype=jnp.int32)
        if shape.kind == "train":
            out["labels"] = jax.random.randint(k2, (b, tl), 0, cfg.vocab, dtype=jnp.int32)
        if cfg.family == "vlm":
            out["media"] = jax.random.normal(k3, (b, cfg.num_media_tokens, cfg.d_model))
        if cfg.family in ("encdec", "audio"):
            out["frames"] = jax.random.normal(k3, (b, seq, cfg.d_model))
        return out
    out["tokens"] = jax.random.randint(k1, (b, 1), 0, cfg.vocab, dtype=jnp.int32)
    out["pos"] = jnp.asarray(seq // 2, jnp.int32)
    out["caches"] = serve_mod.init_caches(cfg, b, seq, jnp.dtype(cfg.dtype),
                                          enc_len=max(seq // 8, 16))
    return out


# ---------------------------------------------------------------------------
# deterministic host-side training pipeline (stateless-resumable)
# ---------------------------------------------------------------------------

def batch_for_step(cfg: ArchConfig, shape: ShapeSpec, step: int,
                   batch_override: int | None = None) -> dict:
    """Pure function of (config, step) — restart at step k reproduces the
    exact stream, which is what makes checkpoint-resume bitwise reproducible
    without persisting pipeline state."""
    return synth_batch(jax.random.PRNGKey(step), cfg, shape, batch_override)
