"""Host-side data pipeline: sharded, prefetching, stateless-resumable.

Production contract:

* **Stateless resume** — a batch is a pure function of (config, step), so a
  restart at step k regenerates the identical stream with no persisted
  iterator state (see data/lm.py:batch_for_step; exercised by the
  fault-tolerance tests).
* **Host sharding** — in a multi-process fleet each host materializes only
  its `jax.process_index()` slice of the global batch and hands
  per-host shards to `jax.make_array_from_process_local_data`.  In this
  single-process container that path degenerates to a device_put.
* **Prefetch** — a background thread keeps `depth` batches ahead of the
  training loop so host data generation overlaps device compute.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


def host_slice(global_batch: dict, *, process_index: int | None = None,
               process_count: int | None = None) -> dict:
    """The slice of a global batch this host is responsible for."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count

    def one(x):
        n = x.shape[0]
        per = n // pc
        return x[pi * per: (pi + 1) * per]

    return jax.tree.map(one, global_batch)


def shard_to_devices(batch: dict, shardings: Any | None) -> dict:
    """Place a (host-local) batch onto devices with the step's shardings."""
    if shardings is None:
        return batch
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        batch, shardings)


class Prefetcher:
    """Run `make_batch(step)` for steps [start, stop) on a background thread,
    `depth` batches ahead."""

    def __init__(self, make_batch: Callable[[int], dict], start: int,
                 stop: int, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop_evt = threading.Event()

        def worker():
            for step in range(start, stop):
                if self._stop_evt.is_set():
                    return
                self._q.put((step, make_batch(step)))
            self._q.put(None)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

    def close(self):
        self._stop_evt.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
