"""Dependency-free sharded checkpointing with async save and elastic restore.

Layout (one directory per step, atomically renamed on completion):

    <root>/step_000100.tmp/...      (in-flight)
    <root>/step_000100/
        manifest.json               {"step", "leaves": [{"key", "file",
                                     "shape", "dtype"}, ...], "meta": {...}}
        arr_00000.npy ...

Fault-tolerance contract (see runtime/launcher.py):
  * a checkpoint is valid iff the final rename happened -> a crash mid-save
    never corrupts the latest checkpoint;
  * `latest_step` scans for the highest complete step directory;
  * restore is **elastic**: arrays are saved unsharded (gathered) and
    re-placed with `jax.device_put` under the *current* mesh's shardings, so
    a run that lost a pod restarts on the surviving (smaller) mesh, and a
    grown fleet re-shards the other way.

Async: `save_async` snapshots to host memory (device_get) synchronously —
cheap relative to a training step — and writes to disk on a background
thread; `wait()` joins before the next save or at exit.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

_KEY_SEP = "/"


def _path_entry(p) -> str:
    # DictKey/FlattenedIndexKey -> .key, SequenceKey -> .idx,
    # GetAttrKey (registered dataclasses, e.g. serve.fleet.FleetState) -> .name
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        key = _KEY_SEP.join(_path_entry(p) for p in path)
        out.append((key, leaf))
    return out


def _link_or_copy(src: str, dst: str) -> None:
    try:
        os.link(src, dst)  # hard link: refcounted, safe across _gc removals
    except OSError:  # cross-device root or a filesystem without links
        shutil.copy2(src, dst)


def save(root: str, step: int, tree: Any, meta: dict | None = None,
         aot: dict | None = None,
         link_from: dict[str, str] | None = None) -> str:
    """Synchronous atomic save. Returns the final directory.

    ``aot`` (optional): ``{"path": <artifact dir>, "key": runtime/aot.py's
    ``artifact_key()``}`` — a validity pointer from this checkpoint to the
    serialized-executable deploy artifact its producer compiled against.
    Consumers (``StreamingFleet.from_artifact``) compare the key with the
    running environment and fall back to JIT warmup when it is stale.

    ``link_from`` (optional): ``{leaf key: existing .npy path}`` for leaves
    the caller knows are UNCHANGED since a previous step — the incremental
    path.  Those leaves skip ``device_get`` + serialization entirely and are
    hard-linked (copied when links are unsupported) from the given file, so
    a periodic checkpoint of a mostly-idle fleet costs I/O only for the
    tiles that actually advanced.  Every step directory stays fully
    self-contained: hard links are per-file refcounts, so ``_gc`` deleting
    the source step never invalidates a newer one.  The shape/dtype
    recorded in the manifest is read from the linked file's npy header (a
    mismatch with the live leaf raises, catching stale-dirty-flag bugs)."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "meta": meta or {}}
    if aot is not None:
        manifest["aot"] = aot
    link_from = link_from or {}
    for i, (key, leaf) in enumerate(_flatten(tree)):
        fname = f"arr_{i:05d}.npy"
        src = link_from.get(key)
        if src is not None:
            header = np.load(src, mmap_mode="r")  # header only, no read
            if (tuple(header.shape) != tuple(np.shape(leaf))
                    or np.dtype(header.dtype) != np.dtype(leaf.dtype)):
                raise ValueError(
                    f"link_from[{key!r}]: {src} holds "
                    f"{header.dtype}{tuple(header.shape)}, live leaf is "
                    f"{np.dtype(leaf.dtype)}{tuple(np.shape(leaf))}")
            shape, dtype = list(header.shape), str(header.dtype)
            del header
            _link_or_copy(src, os.path.join(tmp, fname))
        else:
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, fname), arr)
            shape, dtype = list(arr.shape), str(arr.dtype)
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": shape, "dtype": dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def leaf_files(root: str, step: int) -> dict[str, str]:
    """``{leaf key: absolute .npy path}`` for one saved step — the source
    map an incremental ``save(..., link_from=...)`` draws clean leaves
    from."""
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    return {leaf["key"]: os.path.join(d, leaf["file"])
            for leaf in manifest["leaves"]}


class AsyncCheckpointer:
    """Snapshot on the caller thread, write on a background thread."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, meta: dict | None = None,
                   aot: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(self.root, step, host_tree, meta, aot=aot)
            _gc(self.root, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()


def _gc(root: str, keep: int):
    steps = sorted(list_steps(root))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


def list_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        m = re.fullmatch(r"step_(\d{8})", name)
        if m and os.path.exists(os.path.join(root, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore(root: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: matching tree of (Named)Shardings or
    None -> elastic re-shard onto the current mesh."""
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {leaf["key"]: leaf for leaf in manifest["leaves"]}

    flat_like = _flatten(like)
    flat_shardings = (_flatten(shardings) if shardings is not None
                      else [(k, None) for k, _ in flat_like])
    shard_by_key = dict(flat_shardings)

    restored = []
    for key, leaf in flat_like:
        entry = by_key[key]
        arr = np.load(os.path.join(d, entry["file"]))
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs {expect}")
        sh = shard_by_key.get(key)
        restored.append(jax.device_put(arr, sh) if sh is not None
                        else jax.device_put(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(root: str, like: Any, shardings: Any = None):
    step = latest_step(root)
    if step is None:
        return None, None
    return step, restore(root, step, like, shardings)
