"""Item memory (IM) and compressed item memory (CompIM).

The IM maps each channel's LBP code to a sparse segmented HV.  The paper keeps
one LUT per channel (all 64 channels look up in parallel each cycle).

* baseline IM   : LUT of packed 1024-bit HVs  -> (channels, codes, D//32) uint32
* CompIM        : LUT of segment positions    -> (channels, codes, S) uint8
                  (8 segments x 7 bits = 56 bits per entry vs 1024)

The electrode (channel-identity) HVs are a second design-time random codebook,
stored position-domain for the CompIM datapath and packed for the baseline.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import hv


@dataclass(frozen=True)
class IMParams:
    """Design-time random codebooks for the sparse HDC classifier.

    The packed (bit-domain) tables are derived from the positions; `make_im`
    precomputes them once so the `sparse_naive` datapath does not re-expand
    the full (channels, codes, W) table on every eager lookup.  They are
    optional pytree leaves: an IMParams built without them (e.g. from an old
    checkpoint) falls back to deriving them on access.
    """
    item_pos: jax.Array       # (channels, codes, S) uint8 — CompIM contents
    elec_pos: jax.Array       # (channels, S) uint8 — electrode HV positions
    dim: int
    segments: int
    item_packed_cache: jax.Array | None = None   # (channels, codes, W) uint32
    elec_packed_cache: jax.Array | None = None   # (channels, W) uint32

    @property
    def seg_len(self) -> int:
        return self.dim // self.segments

    @property
    def item_packed(self) -> jax.Array:
        """(channels, codes, W) — the baseline (uncompressed) IM contents."""
        if self.item_packed_cache is not None:
            return self.item_packed_cache
        return hv.positions_to_packed(self.item_pos, self.dim, self.segments)

    @property
    def elec_packed(self) -> jax.Array:
        if self.elec_packed_cache is not None:
            return self.elec_packed_cache
        return hv.positions_to_packed(self.elec_pos, self.dim, self.segments)


jax.tree_util.register_dataclass(
    IMParams,
    data_fields=["item_pos", "elec_pos", "item_packed_cache", "elec_packed_cache"],
    meta_fields=["dim", "segments"])


def make_im(key: jax.Array, *, channels: int, codes: int, dim: int,
            segments: int, precompute_packed: bool = True) -> IMParams:
    """``precompute_packed=False`` skips the bit-domain caches — the CompIM
    datapath never reads them, and carrying the full (channels, codes, W)
    table would reintroduce exactly the working set CompIM avoids."""
    k1, k2 = jax.random.split(key)
    seg_len = dim // segments
    item_pos = hv.random_sparse_positions(k1, (channels, codes), segments, seg_len)
    elec_pos = hv.random_sparse_positions(k2, (channels,), segments, seg_len)
    return IMParams(
        item_pos=item_pos,
        elec_pos=elec_pos,
        dim=dim,
        segments=segments,
        item_packed_cache=(hv.positions_to_packed(item_pos, dim, segments)
                           if precompute_packed else None),
        elec_packed_cache=(hv.positions_to_packed(elec_pos, dim, segments)
                           if precompute_packed else None),
    )


# ---------------------------------------------------------------------------
# dense item memory (the dense-HDC comparison system's codebooks)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DenseIMParams:
    """Random p=50% packed codebooks for the dense-HDC baseline datapath."""
    item_packed: jax.Array   # (channels, codes, W)
    elec_packed: jax.Array   # (channels, W)
    dim: int


jax.tree_util.register_dataclass(
    DenseIMParams, data_fields=["item_packed", "elec_packed"], meta_fields=["dim"])


def make_dense_im(key: jax.Array, *, channels: int, codes: int, dim: int) -> DenseIMParams:
    k1, k2 = jax.random.split(key)
    return DenseIMParams(
        item_packed=hv.random_dense_packed(k1, (channels, codes), dim),
        elec_packed=hv.random_dense_packed(k2, (channels,), dim),
        dim=dim,
    )


def im_lookup_packed(im: IMParams, codes: jax.Array) -> jax.Array:
    """Baseline IM: (..., channels) codes -> (..., channels, W) packed HVs."""
    table = im.item_packed  # (C, codes, W)
    ch = jnp.arange(table.shape[0], dtype=jnp.int32)
    return table[ch, codes.astype(jnp.int32)]


def im_lookup_positions(im: IMParams, codes: jax.Array) -> jax.Array:
    """CompIM: (..., channels) codes -> (..., channels, S) uint8 positions."""
    ch = jnp.arange(im.item_pos.shape[0], dtype=jnp.int32)
    return im.item_pos[ch, codes.astype(jnp.int32)]
