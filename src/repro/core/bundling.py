"""Bundling (superposition) with and without thinning.

Spatial bundling combines the 64 bound channel HVs of one cycle; temporal
bundling combines 256 consecutive spatial outputs into one time-frame HV.

Baseline (paper Fig. 3a): per-element adder tree over the N inputs, then a
threshold ("thinning") back to binary.  Optimized spatial bundling (paper
Sec. III-B): the threshold is removed and the adder tree collapses to an OR
tree — valid because 64 x 0.78% <= 50% density, the HV cannot saturate.

Position-domain spatial bundling (CompIM datapath): the bound HVs exist only
as (channels, S) positions; bundling-without-thinning is a scatter-OR of
positions into the packed accumulator; bundling-with-thinning needs the
multiplicity of each position (segment bincount).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hv


# ---------------------------------------------------------------------------
# bit-domain (baseline datapath)
# ---------------------------------------------------------------------------

def spatial_counts_packed(bound: jax.Array, dim: int) -> jax.Array:
    """Adder tree: (..., N, W) packed -> (..., D) int32 counts."""
    return hv.unpacked_counts(bound, axis=-2, dim=dim)


def spatial_bundle_thinned(bound: jax.Array, dim: int, threshold: int) -> jax.Array:
    """Baseline spatial bundling: adder tree + thinning threshold -> packed."""
    counts = spatial_counts_packed(bound, dim)
    return hv.threshold_pack(counts, threshold)


def spatial_bundle_or(bound: jax.Array) -> jax.Array:
    """Optimized spatial bundling: OR tree over channels -> packed."""
    return hv.or_reduce(bound, axis=-2)


# ---------------------------------------------------------------------------
# position-domain (CompIM datapath)
# ---------------------------------------------------------------------------

def spatial_bundle_or_positions(pos: jax.Array, dim: int, segments: int) -> jax.Array:
    """(..., N, S) positions -> packed (..., W) via scatter-free OR.

    Builds each channel's packed HV from positions and ORs across channels —
    in XLA this fuses into a compare/select + OR-reduce with no 1024-wide
    one-hot materialized per channel in HBM.
    """
    packed = hv.positions_to_packed(pos, dim, segments)  # (..., N, W)
    return hv.or_reduce(packed, axis=-2)


def spatial_counts_positions(pos: jax.Array, dim: int, segments: int) -> jax.Array:
    """(..., N, S) positions -> (..., D) int32 counts (segment bincount).

    Goes through the packed representation and the scan-based adder so the
    peak temporary is one channel slice, not a (..., N, S, L) one-hot.
    """
    packed = hv.positions_to_packed(pos, dim, segments)  # (..., N, W)
    return hv.unpacked_counts(packed, axis=-2, dim=dim)


def spatial_bundle_thinned_positions(pos: jax.Array, dim: int, segments: int,
                                     threshold: int) -> jax.Array:
    counts = spatial_counts_positions(pos, dim, segments)
    return hv.threshold_pack(counts, threshold)


# ---------------------------------------------------------------------------
# temporal bundling (both datapaths share it: input is a packed HV stream)
# ---------------------------------------------------------------------------

def temporal_counts(frames: jax.Array, dim: int) -> jax.Array:
    """8-bit-counter accumulator: (..., T, W) packed -> (..., D) int32.

    Hardware: a D x 8-bit register file (8192 bits for D=1024) accumulating
    for T = 256 cycles.  Counts are <= T so 8 bits suffice (paper Sec. II-C).
    For T a multiple of 32 this runs as a bit-plane popcount adder
    (hv.bitplane_counts) — same integers, no unpacked (..., T, D) expansion.
    """
    return hv.unpacked_counts(frames, axis=-2, dim=dim)


def temporal_bundle(frames: jax.Array, dim: int, threshold) -> jax.Array:
    """Temporal bundling with thinning -> packed time-frame HV."""
    counts = temporal_counts(frames, dim)
    return hv.threshold_pack(counts, threshold)


def threshold_for_density(counts: jax.Array, target_density: float) -> jax.Array:
    """Calibrate a thinning threshold achieving <= target density.

    The paper treats "maximum HV density after thinning" as the tuned
    hyperparameter (Fig. 4); in hardware the threshold register is programmed
    per patient.  Given representative counts (..., D) we pick the smallest
    integer threshold whose density <= target (quantile of the count
    distribution over the last axis, averaged over leading axes).
    """
    q = jnp.quantile(counts.astype(jnp.float32), 1.0 - target_density, axis=-1)
    thr = jnp.ceil(jnp.mean(q)) + 1.0
    return jnp.maximum(thr, 1.0).astype(jnp.int32)
