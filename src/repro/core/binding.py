"""Binding operations for sparse (and dense) HDC.

Segmented-shift binding (paper Fig. 2a): split the D-bit HV into S segments of
L = D/S bits; circularly shift each segment of HV_a by the position of the
1-bit in the corresponding segment of HV_b.

Two implementations:

* ``bind_segmented_packed`` — the **naive baseline** (paper Fig. 3a): takes the
  packed data HV, runs the one-hot->binary decoder (packed_to_positions), then
  barrel-shifts the electrode HV segments.  Kept bit-exact with hardware
  semantics: this is the datapath whose switching activity the cost model
  meters.
* ``bind_positions`` — the **CompIM datapath** (paper Fig. 3b): both operands
  are already in position domain; binding is a 7-bit modular add per segment.

For one-bit-per-segment HVs the two are equivalent:
``shift(onehot(p_a), p_b) == onehot((p_a + p_b) mod L)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hv


def roll_segments_bits(bits: jax.Array, shifts: jax.Array, segments: int) -> jax.Array:
    """Circularly shift each L-bit segment of (..., D) bits by (..., S) shifts."""
    d = bits.shape[-1]
    seg_len = d // segments
    seg = bits.reshape(*bits.shape[:-1], segments, seg_len)
    idx = jnp.arange(seg_len, dtype=jnp.int32)
    # out[j] = in[(j - shift) mod L]  == circular left-roll by `shift`
    src = (idx[None, :] - shifts[..., :, None].astype(jnp.int32)) % seg_len
    out = hv.take_along_axis32(seg, src, axis=-1)
    return out.reshape(*bits.shape[:-1], d)


def bind_segmented_packed(data_packed: jax.Array, elec_packed: jax.Array,
                          dim: int, segments: int) -> jax.Array:
    """Naive baseline binding (one-hot decoder + barrel shifter), packed I/O.

    data_packed: (..., W) the IM output HV (one 1-bit per segment)
    elec_packed: (..., W) the electrode HV (broadcastable against data)
    """
    shifts = hv.packed_to_positions(data_packed, dim, segments)  # decoder
    elec_bits = hv.unpack_bits(elec_packed, dim)
    bound = roll_segments_bits(
        jnp.broadcast_to(elec_bits, jnp.broadcast_shapes(
            elec_bits.shape, shifts.shape[:-1] + (dim,))),
        shifts, segments)
    return hv.pack_bits(bound)


def bind_positions(data_pos: jax.Array, elec_pos: jax.Array, seg_len: int) -> jax.Array:
    """CompIM binding: (..., S) + (..., S) -> (..., S), mod seg_len adds."""
    return ((data_pos.astype(jnp.int32) + elec_pos.astype(jnp.int32)) % seg_len).astype(jnp.uint8)


def unbind_positions(bound_pos: jax.Array, elec_pos: jax.Array, seg_len: int) -> jax.Array:
    """Inverse binding in position domain (release)."""
    return ((bound_pos.astype(jnp.int32) - elec_pos.astype(jnp.int32)) % seg_len).astype(jnp.uint8)


def bind_xor(a_packed: jax.Array, b_packed: jax.Array) -> jax.Array:
    """Dense-HDC binding: bitwise XOR on packed words."""
    return jnp.bitwise_xor(a_packed, b_packed)
