"""Online continual learning for the HDC associative memory.

The paper trains class HVs one-shot and stops; the related work (Pale et
al., arXiv:2201.09759 and arXiv:2105.00934) shows iterative/online HD
learning substantially improves per-patient seizure detection.  This module
holds the learning state and update rules shared by every surface:

* ``HDCPipeline.fit_iterative`` — batch-iterative retraining: epochs over a
  labeled record, updating on the misclassified / low-margin frames,
* ``SeizureSession.adapt``      — one streaming feedback label at a time,
* ``StreamingFleet.adapt``      — the same update vectorized over S sessions.

``OnlineAMState`` mirrors the hardware's counter-file view of the AM: a
per-class integer accumulator ``counts`` (C, D) plus the number of frames
bundled per class ``n`` — exactly the intermediate that one-shot training
already computes before thresholding.  The iterative rule (classic HD
retraining): a gated frame ADDS its bits to the true class and SUBTRACTS
them from the rival (the best-scoring wrong class), after which the class
HVs are re-thresholded from the counts — sparse variants thin each class row
back to ``class_density`` (the paper's Sec. II-D training rule re-applied to
the live counters), dense takes the per-element majority.

All functions are pure jnp, jit-compatible, and broadcast over leading batch
dims (the fleet stacks S independent states into an (S, C, D) bank); the
gate's argmax tie-breaking matches ``am.am_predict`` (ties -> lower class).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import hv
from repro.core.classifier import HDCConfig


@dataclass(frozen=True)
class OnlineAMState:
    """Counter-file view of the AM; leading batch dims stack sessions."""

    counts: jax.Array  # (..., C, D) int32 per-class accumulated frame bits
    n: jax.Array       # (..., C) int32 frames currently bundled per class


jax.tree_util.register_dataclass(
    OnlineAMState, data_fields=["counts", "n"], meta_fields=[])


def state_from_frames(frame_bits: jax.Array, labels: jax.Array,
                      n_classes: int) -> OnlineAMState:
    """One-shot accumulation: (N, D) {0,1} bits + (N,) labels -> state.

    These are exactly the pre-threshold counts ``train_one_shot`` computes,
    so iterative training with zero epochs reproduces one-shot bit-exactly.
    """
    onehot = jax.nn.one_hot(labels, n_classes, dtype=jnp.int32)
    counts = jnp.einsum("nc,nd->cd", onehot, frame_bits.astype(jnp.int32))
    # dtype pinned: under JAX_ENABLE_X64 a bare sum promotes to int64 and
    # the fleet's state dtypes (and jit cache keys) would drift
    return OnlineAMState(counts=counts,
                         n=jnp.sum(onehot, axis=0, dtype=jnp.int32))


def _density_threshold(counts: jax.Array, density) -> jax.Array:
    """Smallest thinning threshold with post-thinning density <= ``density``.

    counts: (..., D) int; density broadcastable to ``counts.shape[:-1]``.
    Same linear-interpolated-quantile rule as
    ``bundling.threshold_for_density`` on a single row, implemented
    elementwise-broadcastable (explicit f32) so the single-state and the
    S-stacked fleet paths lower to identical arithmetic — that is what makes
    fleet ``adapt`` bit-exact with per-session loops.
    """
    d = counts.shape[-1]
    srt = jnp.sort(counts.astype(jnp.float32), axis=-1)
    density = jnp.asarray(density, jnp.float32)
    pos = jnp.broadcast_to((1.0 - density) * (d - 1), counts.shape[:-1])
    lo = jnp.floor(pos).astype(jnp.int32)
    hi = jnp.ceil(pos).astype(jnp.int32)
    vlo = hv.take_along_axis32(srt, lo[..., None], axis=-1)[..., 0]
    vhi = hv.take_along_axis32(srt, hi[..., None], axis=-1)[..., 0]
    q = vlo + (pos - lo.astype(jnp.float32)) * (vhi - vlo)
    return jnp.maximum(jnp.ceil(q) + 1.0, 1.0).astype(jnp.int32)


def class_hvs_from_state(state: OnlineAMState, cfg: HDCConfig,
                         density=None) -> jax.Array:
    """Re-threshold the counter file: (..., C, D) counts -> (..., C, W) HVs.

    Sparse: thin each class row to ``density`` (default
    ``cfg.class_density``); dense: per-element majority over the ``n`` frames
    currently bundled per class.  ``density`` may be a per-session array
    broadcastable to ``counts.shape[:-1]`` (the fleet gathers each patient's
    configured value).
    """
    counts = jnp.maximum(state.counts, 0)
    if cfg.variant == "dense":
        n = jnp.maximum(state.n, 1)[..., None]
        return hv.majority_pack(counts, n, cfg.dim)
    if density is None:
        density = cfg.class_density
    thr = _density_threshold(counts, density)
    return hv.threshold_pack(counts, thr[..., None])


def _gated_delta(labels: jax.Array, scores: jax.Array, margin,
                 valid: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Shared gating rule: (..., C) int32 class deltas + (...) bool gate.

    Gate fires when the prediction is wrong OR the true-vs-rival score
    margin is below ``margin`` (the confidence gate); the rival is the
    best-scoring class other than the true one.  ``labels < 0`` (no
    feedback) and ``valid == False`` (no frame) disable the update.
    """
    c = scores.shape[-1]
    lab = jnp.maximum(labels, 0)
    pred = hv.argmax32(scores, axis=-1)  # ties -> low, matches am.am_predict
    one_true = jax.nn.one_hot(lab, c, dtype=jnp.int32)
    s = scores.astype(jnp.float32)
    s_true = hv.take_along_axis32(s, lab[..., None], axis=-1)[..., 0]
    masked = jnp.where(one_true == 1, -jnp.inf, s)
    rival = hv.argmax32(masked, axis=-1)
    s_rival = jnp.max(masked, axis=-1)
    gate = (pred != lab) | (s_true - s_rival < jnp.asarray(margin, jnp.float32))
    gate = gate & (labels >= 0)
    if valid is not None:
        gate = gate & valid
    one_rival = jax.nn.one_hot(rival, c, dtype=jnp.int32)
    delta = jnp.where(gate[..., None], one_true - one_rival, 0)
    return delta, gate


def update(state: OnlineAMState, frame_bits: jax.Array, labels: jax.Array,
           scores: jax.Array, *, margin=0.0,
           valid: jax.Array | None = None) -> tuple[OnlineAMState, jax.Array]:
    """Confidence-gated iterative update: one frame per state.

    frame_bits: (..., D) {0,1}; labels: (...,) int; scores: (..., C).  The
    leading dims of ``state`` and the frame operands must agree (the fleet
    passes S of each).  Gated frames add their bits to the true class and
    subtract them from the rival; counts and n clamp at zero (the hardware
    counters cannot go negative).  Returns ``(new_state, applied)``.
    """
    delta, gate = _gated_delta(labels, scores, margin, valid)
    bits = frame_bits.astype(jnp.int32)[..., None, :]          # (..., 1, D)
    counts = state.counts + delta[..., None] * bits
    return OnlineAMState(counts=jnp.maximum(counts, 0),
                         n=jnp.maximum(state.n + delta, 0)), gate


def batch_update(state: OnlineAMState, frame_bits: jax.Array,
                 labels: jax.Array, scores: jax.Array, *,
                 margin=0.0) -> tuple[OnlineAMState, jax.Array]:
    """One epoch of batch-iterative retraining against a single shared state.

    frame_bits: (N, D); labels: (N,); scores: (N, C) — all N gated frames
    apply at once (one einsum), the standard iterative-retraining epoch.
    Returns ``(new_state, gate)`` with gate (N,) bool.
    """
    delta, gate = _gated_delta(labels, scores, margin, None)   # (N, C)
    counts = state.counts + jnp.einsum(
        "nc,nd->cd", delta, frame_bits.astype(jnp.int32))
    n = state.n + delta.sum(axis=0, dtype=jnp.int32)
    return OnlineAMState(counts=jnp.maximum(counts, 0),
                         n=jnp.maximum(n, 0)), gate
