"""End-to-end sparse HDC classifier pipelines (paper Fig. 1b).

Three selectable datapaths, bit-exact with their hardware counterparts:

* ``sparse_naive``  — baseline: packed IM, one-hot decoder + barrel-shift
                      binding, adder-tree spatial bundling WITH thinning.
* ``sparse_compim`` — paper-optimized: CompIM position-domain binding; spatial
                      bundling with thinning (adder tree) or without (OR tree),
                      per ``spatial_thinning``.
* ``dense``         — dense-HDC baseline of [1]: XOR binding, majority
                      bundling, Hamming AM (routed by ``core.pipeline``).

Input is a stream of LBP codes (batch, time, channels) uint8; every
``window`` cycles the temporal bundler emits one time-frame HV which the AM
scores against the class HVs.

This module holds the sparse reference datapaths and the unified ``HDCConfig``.
Prefer the variant-dispatched ``repro.core.pipeline.HDCPipeline`` surface,
which routes all three variants (including ``dense``) and both the pure-jnp
and fused-Pallas backends behind one API.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro.core import am, binding, bundling, im


@dataclass(frozen=True)
class HDCConfig:
    dim: int = 1024
    segments: int = 8
    channels: int = 64
    lbp_bits: int = 6
    window: int = 256           # temporal bundling length (one time frame)
    variant: str = "sparse_compim"   # sparse_naive | sparse_compim | dense
    backend: str = "jnp"             # jnp (pure-XLA) | pallas (fused kernels)
    spatial_thinning: bool = False   # paper-optimized: False (OR tree)
    spatial_threshold: int = 2       # used when spatial_thinning
    temporal_threshold: int = 130    # paper Sec. IV-B operating point
    n_classes: int = 2
    # training-time thinning target for class HVs (paper: 50%)
    class_density: float = 0.5

    def __post_init__(self):
        """Geometry validation: every derived quantity (``words``,
        ``seg_len``, the uint8 position domain, the uint8 code alphabet)
        must be exact — silent truncation/wraparound corrupts HVs with no
        error (e.g. dim=4096, segments=8 wraps seg_len=512 past uint8)."""
        if self.dim <= 0 or self.dim % 32:
            raise ValueError(
                f"dim={self.dim} must be a positive multiple of 32 "
                "(HVs pack into uint32 words)")
        if self.window <= 0:
            raise ValueError(f"window={self.window} must be positive")
        if not 1 <= self.lbp_bits <= 8:
            raise ValueError(
                f"lbp_bits={self.lbp_bits} must be in [1, 8] "
                "(LBP codes are uint8)")
        if self.n_classes < 1:
            raise ValueError(f"n_classes={self.n_classes} must be >= 1")
        if not 0.0 < self.class_density <= 1.0:
            raise ValueError(
                f"class_density={self.class_density} must be in (0, 1] "
                "(an out-of-range density silently thins class HVs to zero)")
        if self.variant == "dense":
            return  # the dense datapath has no segment structure
        if self.segments <= 0 or self.dim % self.segments:
            raise ValueError(
                f"dim={self.dim} must divide evenly into "
                f"segments={self.segments} (seg_len would truncate)")
        if self.dim // self.segments > 256:
            raise ValueError(
                f"seg_len={self.dim // self.segments} exceeds the uint8 "
                "position domain (max 256); increase segments for "
                f"dim={self.dim}")

    @property
    def codes(self) -> int:
        return 1 << self.lbp_bits

    @property
    def seg_len(self) -> int:
        return self.dim // self.segments

    @property
    def words(self) -> int:
        return self.dim // 32


def init_params(key: jax.Array, cfg: HDCConfig) -> im.IMParams:
    # only the naive bit-domain datapath reads the packed IM tables
    return im.make_im(key, channels=cfg.channels, codes=cfg.codes,
                      dim=cfg.dim, segments=cfg.segments,
                      precompute_packed=cfg.variant == "sparse_naive")


# ---------------------------------------------------------------------------
# spatial encoder: codes for one cycle -> one bundled HV
# ---------------------------------------------------------------------------

def spatial_encode(params: im.IMParams, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(..., channels) LBP codes -> (..., W) packed bundled HV."""
    if cfg.variant == "sparse_naive":
        data = im.im_lookup_packed(params, codes)                  # (..., C, W)
        bound = binding.bind_segmented_packed(data, params.elec_packed,
                                              cfg.dim, cfg.segments)
        return bundling.spatial_bundle_thinned(bound, cfg.dim, cfg.spatial_threshold)
    if cfg.variant == "sparse_compim":
        pos = im.im_lookup_positions(params, codes)                # (..., C, S)
        bound = binding.bind_positions(pos, params.elec_pos, cfg.seg_len)
        if cfg.spatial_thinning:
            return bundling.spatial_bundle_thinned_positions(
                bound, cfg.dim, cfg.segments, cfg.spatial_threshold)
        return bundling.spatial_bundle_or_positions(bound, cfg.dim, cfg.segments)
    if cfg.variant == "dense":
        raise ValueError("variant='dense' is routed by repro.core.pipeline."
                         "HDCPipeline (this module holds the sparse datapaths)")
    raise ValueError(f"unknown sparse variant {cfg.variant!r}")


# ---------------------------------------------------------------------------
# full encoder: code stream -> time-frame HVs
# ---------------------------------------------------------------------------

def frame_view(codes: jax.Array, window: int) -> jax.Array:
    """(B, T, C) code stream -> (B, F, window, C), truncating the ragged
    tail.  The single home of the framing rule (all encoders share it)."""
    b, t, c = codes.shape
    frames = t // window
    return codes[:, : frames * window].reshape(b, frames, window, c)


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode_frames(params: im.IMParams, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(B, T, channels) uint8 codes -> (B, T // window, W) packed frame HVs."""
    framed = frame_view(codes, cfg.window)
    spatial = spatial_encode(params, framed, cfg)      # (B, F, window, W)
    return bundling.temporal_bundle(spatial, cfg.dim, cfg.temporal_threshold)


@functools.partial(jax.jit, static_argnames=("cfg",))
def frame_counts(params: im.IMParams, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    """Temporal accumulator counts per frame (B, F, D) — used to calibrate the
    temporal threshold for a target maximum density (paper Fig. 4 sweep)."""
    framed = frame_view(codes, cfg.window)
    spatial = spatial_encode(params, framed, cfg)
    return bundling.temporal_counts(spatial, cfg.dim)


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def infer(params: im.IMParams, class_hvs: jax.Array, codes: jax.Array,
          cfg: HDCConfig) -> tuple[jax.Array, jax.Array]:
    """Returns (scores (B, F, n_classes), predictions (B, F))."""
    q = encode_frames(params, codes, cfg)
    scores = am.am_scores_sparse(q, class_hvs)
    return scores, am.am_predict(scores)


def with_density_target(params: im.IMParams, codes: jax.Array, cfg: HDCConfig,
                        target: float) -> HDCConfig:
    """Return cfg with temporal_threshold calibrated so the post-thinning
    density stays <= `target` on the given calibration stream."""
    counts = frame_counts(params, codes, cfg)
    thr = int(bundling.threshold_for_density(counts, target))
    return replace(cfg, temporal_threshold=thr)
