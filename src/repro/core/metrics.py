"""Detection metrics: detection delay and seizure detection accuracy.

Paper Sec. IV-A: delay is measured from the expert-marked seizure onset to the
first ictal-classified time frame; accuracy is the fraction of test seizures
detected.  Like the Burrello system we smooth single-frame flickers with a
k-of-m post-processing vote before declaring a detection.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DetectionResult:
    detected: bool
    delay_frames: float          # frames after onset (nan if undetected)
    false_alarm: bool            # any detection before onset
    delay_seconds: float = float("nan")


def postprocess(preds: np.ndarray, k: int = 2, m: int = 3) -> np.ndarray:
    """k-of-m smoothing: frame f fires iff it is ictal AND >= k of the last m
    predictions are ictal.  The stream start pads with interictal frames, so
    the FULL k votes are always required — frames 0..k-2 can never fire.
    (The old ``min(k, f - lo + 1)`` relaxation degenerated to 1-of-1 at
    frame 0: a single ictal flicker fired the detector, inflating both
    detection accuracy and the false-alarm rate at record boundaries.)
    """
    if not 1 <= k <= m:
        raise ValueError(f"need 1 <= k <= m, got k={k}, m={m}")
    preds = np.asarray(preds).astype(np.int32)
    out = np.zeros_like(preds)
    for f in range(len(preds)):
        lo = max(0, f - m + 1)
        out[f] = int(preds[f] == 1 and preds[lo:f + 1].sum() >= k)
    return out


def detection_metrics(preds: np.ndarray, onset_frame: int, *, k: int = 2,
                      m: int = 3, frame_seconds: float = 0.5,
                      horizon_frames: int | None = None) -> DetectionResult:
    """preds: (F,) 0/1 per-frame classifications of one test seizure record."""
    fired = postprocess(preds, k=k, m=m)
    post = np.nonzero(fired[onset_frame:])[0]
    pre = np.nonzero(fired[:onset_frame])[0]
    detected = len(post) > 0
    if horizon_frames is not None and detected:
        detected = post[0] <= horizon_frames
    delay = float(post[0]) if detected else float("nan")
    return DetectionResult(
        detected=bool(detected),
        delay_frames=delay,
        false_alarm=len(pre) > 0,
        delay_seconds=delay * frame_seconds if detected else float("nan"),
    )


def aggregate(results: list[DetectionResult]) -> dict:
    """Average delay over detected seizures + detection accuracy (paper Fig. 4)."""
    delays = [r.delay_seconds for r in results if r.detected]
    return {
        "detection_accuracy": float(np.mean([r.detected for r in results])) if results else 0.0,
        "mean_delay_s": float(np.mean(delays)) if delays else float("nan"),
        "false_alarm_rate": float(np.mean([r.false_alarm for r in results])) if results else 0.0,
        "n": len(results),
    }
