"""Dense HDC baseline (Burrello et al. [1]) — the paper's comparison system.

Dense ops: random p=50% item/electrode HVs; binding = XOR; spatial bundling =
per-element majority over the 64 channels; temporal bundling = majority over
the 256-cycle window; AM similarity = D - Hamming.  Same D=1024 as the sparse
system for the apples-to-apples hardware comparison (paper Fig. 5 / Table I).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import am, hv


@dataclass(frozen=True)
class DenseHDCConfig:
    dim: int = 1024
    channels: int = 64
    lbp_bits: int = 6
    window: int = 256
    n_classes: int = 2

    @property
    def codes(self) -> int:
        return 1 << self.lbp_bits

    @property
    def words(self) -> int:
        return self.dim // 32


@dataclass(frozen=True)
class DenseIMParams:
    item_packed: jax.Array   # (channels, codes, W)
    elec_packed: jax.Array   # (channels, W)
    dim: int


jax.tree_util.register_dataclass(
    DenseIMParams, data_fields=["item_packed", "elec_packed"], meta_fields=["dim"])


def init_params(key: jax.Array, cfg: DenseHDCConfig) -> DenseIMParams:
    k1, k2 = jax.random.split(key)
    return DenseIMParams(
        item_packed=hv.random_dense_packed(k1, (cfg.channels, cfg.codes), cfg.dim),
        elec_packed=hv.random_dense_packed(k2, (cfg.channels,), cfg.dim),
        dim=cfg.dim,
    )


def spatial_encode(params: DenseIMParams, codes: jax.Array, cfg: DenseHDCConfig) -> jax.Array:
    """(..., channels) codes -> (..., W) majority-bundled HV."""
    ch = jnp.arange(cfg.channels)
    data = params.item_packed[ch, codes.astype(jnp.int32)]       # (..., C, W)
    bound = jnp.bitwise_xor(data, params.elec_packed)            # XOR binding
    counts = hv.unpacked_counts(bound, axis=-2, dim=cfg.dim)     # (..., D)
    return hv.pack_bits((counts * 2 > cfg.channels).astype(jnp.uint8))


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode_frames(params: DenseIMParams, codes: jax.Array, cfg: DenseHDCConfig) -> jax.Array:
    """(B, T, channels) codes -> (B, F, W) majority time-frame HVs."""
    b, t, c = codes.shape
    frames = t // cfg.window
    codes = codes[:, : frames * cfg.window].reshape(b, frames, cfg.window, c)
    spatial = spatial_encode(params, codes, cfg)                 # (B, F, win, W)
    counts = hv.unpacked_counts(spatial, axis=-2, dim=cfg.dim)   # (B, F, D)
    return hv.pack_bits((counts * 2 > cfg.window).astype(jnp.uint8))


@functools.partial(jax.jit, static_argnames=("cfg",))
def infer(params: DenseIMParams, class_hvs: jax.Array, codes: jax.Array,
          cfg: DenseHDCConfig) -> tuple[jax.Array, jax.Array]:
    q = encode_frames(params, codes, cfg)
    scores = am.am_scores_dense(q, class_hvs, cfg.dim)
    return scores, am.am_predict(scores)


def train_one_shot(params: DenseIMParams, codes: jax.Array, labels: jax.Array,
                   cfg: DenseHDCConfig) -> jax.Array:
    """One-shot class HVs: majority-bundle the frame HVs of each class.

    codes: (B, T, channels); labels: (B, F) int32 per-frame class ids.
    Returns (n_classes, W) packed class HVs.
    """
    q = encode_frames(params, codes, cfg)                        # (B, F, W)
    bits = hv.unpack_bits(q, cfg.dim).astype(jnp.int32)          # (B, F, D)
    flat_bits = bits.reshape(-1, cfg.dim)
    flat_labels = labels.reshape(-1)
    onehot = jax.nn.one_hot(flat_labels, cfg.n_classes, dtype=jnp.int32)
    counts = jnp.einsum("nc,nd->cd", onehot, flat_bits)
    n_per_class = jnp.sum(onehot, axis=0)[:, None]
    return hv.pack_bits((counts * 2 > n_per_class).astype(jnp.uint8))
