"""DEPRECATED shim — the dense-HDC baseline now lives behind the unified
``repro.core.pipeline.HDCPipeline`` surface (``HDCConfig(variant="dense")``).

This module keeps the old entry points importable for one PR:

* ``DenseHDCConfig(...)``  -> unified ``HDCConfig`` with ``variant="dense"``
* ``DenseIMParams``        -> re-export of ``repro.core.im.DenseIMParams``
* ``init_params`` / ``encode_frames`` / ``infer`` / ``train_one_shot``
                           -> thin delegates to the pipeline dispatch

New code should use::

    from repro.core.pipeline import HDCConfig, HDCPipeline
    pipe = HDCPipeline.init(key, HDCConfig(variant="dense"))
"""

from __future__ import annotations

import warnings

import jax

from repro.core import im as _im
from repro.core import pipeline as _pipeline
from repro.core.im import DenseIMParams  # noqa: F401  (legacy import path)
from repro.core.pipeline import HDCConfig

warnings.warn("repro.core.dense is deprecated; use repro.core.pipeline."
              "HDCPipeline with HDCConfig(variant='dense')",
              DeprecationWarning, stacklevel=2)


def DenseHDCConfig(dim: int = 1024, channels: int = 64, lbp_bits: int = 6,
                   window: int = 256, n_classes: int = 2) -> HDCConfig:
    """Legacy constructor: returns the merged unified config.  Accepts the
    old dataclass's field order positionally; it is a factory function now,
    so isinstance/dataclasses.fields uses must migrate to HDCConfig."""
    return HDCConfig(variant="dense", dim=dim, channels=channels,
                     lbp_bits=lbp_bits, window=window, n_classes=n_classes)


def _coerce(cfg) -> HDCConfig:
    import dataclasses
    if isinstance(cfg, HDCConfig):
        return cfg if cfg.variant == "dense" else dataclasses.replace(cfg, variant="dense")
    # duck-typed legacy config object
    return DenseHDCConfig(dim=cfg.dim, channels=cfg.channels,
                          lbp_bits=cfg.lbp_bits, window=cfg.window,
                          n_classes=cfg.n_classes)


def init_params(key: jax.Array, cfg) -> DenseIMParams:
    cfg = _coerce(cfg)
    return _im.make_dense_im(key, channels=cfg.channels, codes=cfg.codes,
                             dim=cfg.dim)


def spatial_encode(params: DenseIMParams, codes: jax.Array, cfg) -> jax.Array:
    return _pipeline.spatial_encode(params, codes, _coerce(cfg))


def encode_frames(params: DenseIMParams, codes: jax.Array, cfg) -> jax.Array:
    return _pipeline._encode_frames(params, codes, _coerce(cfg))


def infer(params: DenseIMParams, class_hvs: jax.Array, codes: jax.Array,
          cfg) -> tuple[jax.Array, jax.Array]:
    pipe = _pipeline.HDCPipeline(params=params, cfg=_coerce(cfg),
                                 class_hvs=class_hvs)
    return pipe.infer(codes)


def train_one_shot(params: DenseIMParams, codes: jax.Array, labels: jax.Array,
                   cfg) -> jax.Array:
    return _pipeline._train_one_shot(params, codes, labels, _coerce(cfg))
