"""First-order 16nm energy/area model with simulated switching activity.

This module reproduces the paper's evaluation *methodology* without Synopsys:

* **Area**: an explicit gate/storage inventory per module per design variant
  (naive sparse / CompIM / CompIM+no-thinning / dense), multiplied by 16nm
  FinFET cell-area proxies.
* **Energy**: the functional datapath is simulated cycle-by-cycle on real
  (synthetic-patient) LBP streams; per-module output-signal **bit toggles**
  are counted (exactly what PrimeTime-PX switching annotation measures) and
  multiplied by per-wire-class toggle energies + per-op active energies.

Constants are order-of-magnitude 16nm proxies at 0.75 V / 10 MHz; the model is
validated by *structure* (which module dominates) and *ratios* (sparse-opt vs
sparse-naive vs dense), not absolute nJ — see EXPERIMENTS.md §HW.

Design variants:
  dense          — dense HDC baseline [1]: XOR bind, majority bundling
  sparse_naive   — paper baseline Fig. 3a: 1024-bit IM, one-hot->binary
                   decoder, barrel shifter, adder trees + thinning
  sparse_compim  — + CompIM (56-bit IM, 7-bit adder binding, 7->128 demux);
                   spatial bundling still adder trees + thinning
  sparse_opt     — + spatial bundling without thinning (OR trees): the paper's
                   full proposal
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binding, bundling, hv, im
from repro.core.classifier import HDCConfig
from repro.core.im import DenseIMParams

VARIANTS = ("dense", "sparse_naive", "sparse_compim", "sparse_opt")


# ---------------------------------------------------------------------------
# constants (16nm FinFET proxies, 0.75 V)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HWConstants:
    # area, um^2 per cell
    a_ff: float = 1.20          # flip-flop
    a_fa: float = 1.00          # full adder
    a_ha: float = 0.55          # half adder
    a_or2: float = 0.25
    a_and2: float = 0.25
    a_xor2: float = 0.50
    a_mux2: float = 0.45        # per mux bit
    a_rom_bit: float = 0.05     # synthesized random-logic LUT bit
    a_cmp_bit: float = 0.50     # comparator per bit
    # energy, fJ
    e_toggle: float = 1.5       # per toggled net (avg gate-input cap)
    e_ff_clk: float = 0.08      # clock load per FF per cycle
    e_ff_toggle: float = 4.0    # per FF data toggle (incl. local clk gating)
    e_rom_bit_read: float = 0.12   # per LUT output bit evaluated
    e_fa_op: float = 3.0        # per active full-add
    e_mux_bit: float = 1.2      # per mux bit whose output toggles
    e_mux_sel: float = 0.25     # per mux bit re-steered by a select toggle
    e_gate_op: float = 0.6      # OR/AND evaluation with toggling input
    e_cmp_bit: float = 1.0


C16 = HWConstants()


def gate_energy_fj(ops: dict[str, float], c: HWConstants = C16) -> float:
    """Energy (fJ) of a bag of gate evaluations, by gate kind.

    The op-count hook the reliability subsystem's ECC cost model maps
    through (reliability.ecc.read_energy_nj): callers count XOR/AND/adder/
    FF/compare evaluations and this prices them with the same 16nm
    constants the variant reports use, so ECC overheads land on the same
    energy axis.  An XOR2 is priced as two gate-equivalents (its standard
    ~2x gate cost over NAND/NOR at iso-drive)."""
    per_op = {
        "xor2": 2.0 * c.e_gate_op,
        "and2": c.e_gate_op,
        "or2": c.e_gate_op,
        "fa": c.e_fa_op,
        "ff": c.e_ff_toggle,
        "cmp_bit": c.e_cmp_bit,
    }
    unknown = set(ops) - set(per_op)
    if unknown:
        raise ValueError(f"unknown gate kinds {sorted(unknown)}; "
                         f"pick from {sorted(per_op)}")
    return float(sum(n * per_op[k] for k, n in ops.items()))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _toggles_packed(sig: jax.Array) -> jax.Array:
    """sig: (T, ...) packed uint32 -> mean toggled bits per cycle."""
    x = jnp.bitwise_xor(sig[1:], sig[:-1])
    return jnp.sum(jax.lax.population_count(x),
                   dtype=jnp.float32) / (sig.shape[0] - 1)


def _toggles_uint(sig: jax.Array, bits: int) -> jax.Array:
    """sig: (T, ...) small-int values -> mean toggled bits/cycle (low `bits`)."""
    a = sig.astype(jnp.uint32)
    x = jnp.bitwise_xor(a[1:], a[:-1]) & jnp.uint32((1 << bits) - 1)
    return jnp.sum(jax.lax.population_count(x),
                   dtype=jnp.float32) / (sig.shape[0] - 1)


# ---------------------------------------------------------------------------
# area inventories (um^2 per module)
# ---------------------------------------------------------------------------

def area_inventory(variant: str, cfg: HDCConfig, c: HWConstants = C16) -> dict[str, float]:
    D, S, C_ch = cfg.dim, cfg.segments, cfg.channels
    L = cfg.seg_len                      # 128
    pos_bits = int(np.ceil(np.log2(L)))  # 7
    codes = cfg.codes                    # 64
    cnt_bits = int(np.ceil(np.log2(C_ch + 1)))   # 7-bit spatial counts
    tmp_bits = int(np.ceil(np.log2(cfg.window + 1)))  # 8-bit temporal counters

    # adder-tree size with bit-width growth: summing N 1-bit leaves costs
    # sum_l (N/2^l)*l full adders ~= 2N FA-equivalents (vs N-1 for 1-bit OR)
    fa_tree = 1.2 * C_ch   # 3:2-compressor trees, synthesis-efficient

    a: dict[str, float] = {}
    if variant == "dense":
        a["im"] = C_ch * codes * D * c.a_rom_bit * 1.2   # dense random contents compress poorly
        a["binding"] = C_ch * D * c.a_xor2
        a["spatial_bundling"] = D * (fa_tree * c.a_fa + cnt_bits * c.a_cmp_bit) + D * c.a_ff
        a["decoder"] = 0.0
    elif variant == "sparse_naive":
        # sparse one-hot contents optimize well -> lower effective bit area
        a["im"] = C_ch * codes * D * c.a_rom_bit * 0.35
        # one-hot -> binary encoder: per segment, pos_bits OR-trees over L/2 inputs
        a["decoder"] = C_ch * S * pos_bits * (L / 2) * c.a_or2
        # barrel shifter with a CONSTANT one-hot input (the electrode HV):
        # synthesis collapses it to offset-add + 7->128 decode per segment
        a["binding"] = C_ch * S * (pos_bits * c.a_ha + 2 * L * c.a_and2)
        a["spatial_bundling"] = D * (fa_tree * c.a_fa + cnt_bits * c.a_cmp_bit) + D * c.a_ff
    else:  # sparse_compim / sparse_opt
        a["im"] = C_ch * codes * S * pos_bits * c.a_rom_bit  # 56-bit entries
        a["decoder"] = 0.0                                    # fused into CompIM
        # 7-bit adder (mod-128 = natural 7-bit wrap) + 7->128 demux per segment
        a["binding"] = C_ch * S * (pos_bits * c.a_fa + L * 2 * c.a_and2)
        if variant == "sparse_compim":
            a["spatial_bundling"] = D * (fa_tree * c.a_fa + cnt_bits * c.a_cmp_bit) + D * c.a_ff
        else:  # sparse_opt: OR trees, no threshold
            a["spatial_bundling"] = D * (C_ch - 1) * c.a_or2 + D * c.a_ff

    # temporal bundling and AM are shared across variants
    a["temporal_bundling"] = D * (tmp_bits * c.a_ff + tmp_bits * c.a_ha
                                  + tmp_bits * c.a_cmp_bit)
    gate = c.a_xor2 if variant == "dense" else c.a_and2
    a["am"] = (cfg.n_classes * D * c.a_ff          # class HV storage
               + D * gate                          # AND / XNOR similarity
               + (D - 1) * c.a_fa                  # popcount tree
               + 16 * c.a_cmp_bit + 64 * c.a_ff)   # score compare + regs
    a["control"] = 0.05 * sum(a.values())
    return a


# ---------------------------------------------------------------------------
# switching-activity simulation -> energy per prediction
# ---------------------------------------------------------------------------

def _sparse_signals(params: im.IMParams, codes: jax.Array, cfg: HDCConfig,
                    variant: str) -> dict[str, jax.Array]:
    """Per-cycle signal traces for one stream. codes: (T, channels)."""
    t = codes.shape[0]
    sig: dict[str, jax.Array] = {}
    if variant == "sparse_naive":
        im_out = im.im_lookup_packed(params, codes)                   # (T, C, W)
        dec = hv.packed_to_positions(im_out, cfg.dim, cfg.segments)   # (T, C, S)
        bound = binding.bind_segmented_packed(im_out, params.elec_packed,
                                              cfg.dim, cfg.segments)  # (T, C, W)
        counts = bundling.spatial_counts_packed(bound, cfg.dim)       # (T, D)
        spat = hv.threshold_pack(counts, cfg.spatial_threshold)       # (T, W)
        sig |= dict(im_out=im_out, dec=dec, bound_pos=None, bound=bound,
                    counts=counts, spat=spat)
    else:
        pos = im.im_lookup_positions(params, codes)                   # (T, C, S)
        bpos = binding.bind_positions(pos, params.elec_pos, cfg.seg_len)
        bound = hv.positions_to_packed(bpos, cfg.dim, cfg.segments)   # demux out
        if variant == "sparse_compim":
            counts = bundling.spatial_counts_positions(bpos, cfg.dim, cfg.segments)
            spat = hv.threshold_pack(counts, cfg.spatial_threshold)
        else:
            counts = None
            spat = hv.or_reduce(bound, axis=-2)
        sig |= dict(im_out=pos, dec=None, bound_pos=bpos, bound=bound,
                    counts=counts, spat=spat)
    # temporal counters: running within-frame prefix sums of unpacked spat bits
    frames = t // cfg.window
    spat_f = sig["spat"][: frames * cfg.window].reshape(frames, cfg.window, -1)
    bits = hv.unpack_bits(spat_f, cfg.dim).astype(jnp.int32)
    tcnt = jnp.cumsum(bits, axis=1,
                      dtype=jnp.int32).reshape(frames * cfg.window, cfg.dim)
    sig["tcnt"] = tcnt
    frame_hv = hv.threshold_pack(tcnt[cfg.window - 1 :: cfg.window], cfg.temporal_threshold)
    sig["frame_hv"] = frame_hv
    return sig


def _dense_signals(params: DenseIMParams, codes: jax.Array,
                   cfg: HDCConfig) -> dict[str, jax.Array]:
    t = codes.shape[0]
    ch = jnp.arange(cfg.channels, dtype=jnp.int32)
    im_out = params.item_packed[ch, codes.astype(jnp.int32)]          # (T, C, W)
    bound = jnp.bitwise_xor(im_out, params.elec_packed)
    counts = hv.unpacked_counts(bound, axis=-2, dim=cfg.dim)          # (T, D)
    spat = hv.pack_bits((counts * 2 > cfg.channels).astype(jnp.uint8))
    frames = t // cfg.window
    spat_f = spat[: frames * cfg.window].reshape(frames, cfg.window, -1)
    bits = hv.unpack_bits(spat_f, cfg.dim).astype(jnp.int32)
    tcnt = jnp.cumsum(bits, axis=1,
                      dtype=jnp.int32).reshape(frames * cfg.window, cfg.dim)
    frame_hv = hv.pack_bits(
        ((tcnt[cfg.window - 1 :: cfg.window]) * 2 > cfg.window).astype(jnp.uint8))
    return dict(im_out=im_out, dec=None, bound_pos=None, bound=bound,
                counts=counts, spat=spat, tcnt=tcnt, frame_hv=frame_hv)


def energy_per_prediction(variant: str, params, codes: jax.Array, cfg: HDCConfig,
                          c: HWConstants = C16) -> dict[str, float]:
    """Energy (nJ) per prediction (= one `window`-cycle time frame), by module.

    codes: (T, channels) uint8 with T a multiple of cfg.window.
    """
    D, S, C_ch, L = cfg.dim, cfg.segments, cfg.channels, cfg.seg_len
    pos_bits = int(np.ceil(np.log2(L)))
    cnt_bits = int(np.ceil(np.log2(C_ch + 1)))
    tmp_bits = int(np.ceil(np.log2(cfg.window + 1)))
    W = cfg.window

    if variant == "dense":
        sig = _dense_signals(params, codes, cfg)
    else:
        sig = _sparse_signals(params, codes, cfg, variant)

    e: dict[str, float] = {}
    fJ = 1.0  # accumulate in fJ/cycle then convert

    if variant == "dense":
        rom_bits_read = C_ch * D
        im_togg = float(_toggles_packed(sig["im_out"]))
        e["im"] = rom_bits_read * c.e_rom_bit_read + im_togg * c.e_toggle
        e["decoder"] = 0.0
        e["binding"] = float(_toggles_packed(sig["bound"])) * (c.e_gate_op + c.e_toggle)
        cnt_togg = float(_toggles_uint(sig["counts"], cnt_bits))
        e["spatial_bundling"] = (float(_toggles_packed(sig["bound"])) * 1.0 * c.e_fa_op
                                 + cnt_togg * c.e_toggle
                                 + float(_toggles_packed(sig["spat"]))
                                 * (c.e_cmp_bit + c.e_ff_toggle))
    elif variant == "sparse_naive":
        rom_bits_read = C_ch * D
        im_togg = float(_toggles_packed(sig["im_out"]))
        e["im"] = rom_bits_read * c.e_rom_bit_read + im_togg * c.e_toggle
        # encoder: toggled one-hot inputs propagate through log2(L)-deep OR
        # trees; each toggled input disturbs ~pos_bits internal nets
        dec_togg = float(_toggles_uint(sig["dec"], pos_bits))
        e["decoder"] = im_togg * c.e_gate_op * pos_bits + dec_togg * c.e_toggle
        # constant-input barrel shifter == offset-add + 7->128 decode
        bnd_togg = float(_toggles_packed(sig["bound"]))
        e["binding"] = (dec_togg * c.e_fa_op
                        + bnd_togg * 2.0 * c.e_gate_op
                        + dec_togg * c.e_toggle)
        cnt_togg = float(_toggles_uint(sig["counts"], cnt_bits))
        e["spatial_bundling"] = (bnd_togg * 1.0 * c.e_fa_op
                                 + cnt_togg * c.e_toggle
                                 + float(_toggles_packed(sig["spat"]))
                                 * (c.e_cmp_bit + c.e_ff_toggle))
    else:  # CompIM datapaths
        rom_bits_read = C_ch * S * pos_bits       # 56 bits per channel
        pos_togg = float(_toggles_uint(sig["im_out"], pos_bits))
        e["im"] = rom_bits_read * c.e_rom_bit_read + pos_togg * c.e_toggle
        e["decoder"] = 0.0
        bpos_togg = float(_toggles_uint(sig["bound_pos"], pos_bits))
        demux_togg = float(_toggles_packed(sig["bound"]))   # one-hot outputs
        e["binding"] = (bpos_togg * c.e_fa_op                     # 7-bit adds
                        + demux_togg * 2.0 * c.e_gate_op          # 7->128 demux
                        + bpos_togg * c.e_toggle)
        if variant == "sparse_compim":
            cnt_togg = float(_toggles_uint(sig["counts"], cnt_bits))
            e["spatial_bundling"] = (demux_togg * 1.0 * c.e_fa_op
                                     + cnt_togg * c.e_toggle
                                     + float(_toggles_packed(sig["spat"]))
                                     * (c.e_cmp_bit + c.e_ff_toggle))
        else:  # OR trees, no threshold
            e["spatial_bundling"] = (demux_togg * 2.0 * c.e_gate_op
                                     + float(_toggles_packed(sig["spat"])) * c.e_ff_toggle)

    # temporal bundling: counter FF toggles + incrementer activity (shared)
    tcnt_togg = float(_toggles_uint(sig["tcnt"], tmp_bits))
    spat_ones = float(jnp.mean(hv.popcount(sig["spat"]).astype(jnp.float32)))
    e["temporal_bundling"] = (tcnt_togg * c.e_ff_toggle
                              + spat_ones * c.e_fa_op * 1.5       # ripple increment
                              + D * tmp_bits * c.e_ff_clk)        # clock tree
    # AM: evaluated once per frame (2 sequential class compares) -> amortize
    fh = sig["frame_hv"]
    fh_togg = float(_toggles_packed(fh)) if fh.shape[0] > 1 else float(D) * 0.25
    gate_e = c.e_gate_op if variant != "dense" else c.e_gate_op * 2.0
    mean_q_ones = float(jnp.mean(hv.popcount(fh).astype(jnp.float32)))
    am_per_frame = (cfg.n_classes * (D * gate_e * 0.5 + mean_q_ones * c.e_fa_op * 2.0)
                    + fh_togg * c.e_ff_toggle + 64 * c.e_cmp_bit)
    e["am"] = am_per_frame / W                                    # per cycle

    e["control"] = 0.05 * sum(e.values())
    # fJ/cycle -> nJ per prediction (= window cycles)
    return {k: v * W * 1e-6 for k, v in e.items()}


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

def report(variant: str, params, codes, cfg: HDCConfig,
           c: HWConstants = C16, e_scale: float = 1.0, a_scale: float = 1.0) -> dict:
    area = {k: v * a_scale for k, v in area_inventory(variant, cfg, c).items()}
    energy = {k: v * e_scale
              for k, v in energy_per_prediction(variant, params, codes, cfg, c).items()}
    total_a, total_e = sum(area.values()), sum(energy.values())
    cycles = cfg.window + cfg.n_classes
    return {
        "variant": variant,
        "area_um2": area,
        "area_total_mm2": total_a / 1e6,
        "energy_nj": energy,
        "energy_total_nj": total_e,
        "energy_breakdown": {k: v / total_e for k, v in energy.items()},
        "area_breakdown": {k: v / total_a for k, v in area.items()},
        "latency_us_at_10mhz": cycles / 10.0,
        "energy_per_channel_nj": total_e / cfg.channels,
    }


def calibration_factors(params_sparse, codes, cfg: HDCConfig, c: HWConstants = C16,
                        target_e_nj: float = 12.5,
                        target_a_mm2: float = 0.059) -> tuple[float, float]:
    """Anchor the model's absolute scale to the paper's published numbers for
    the OPTIMIZED design (12.5 nJ/prediction, 0.059 mm² in 16nm @ 0.75 V).

    Only the global scale is calibrated — per-module structure and the
    cross-variant ratios remain fully model-driven, which is what we validate
    against the paper's Fig. 1c / Fig. 5 (see EXPERIMENTS.md §HW).
    """
    r = report("sparse_opt", params_sparse, codes, cfg, c)
    return target_e_nj / r["energy_total_nj"], target_a_mm2 / r["area_total_mm2"]
