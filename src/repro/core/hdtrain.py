"""One-shot training for the sparse HDC classifier (paper Sec. II-D).

Class HVs are computed through the SAME encoder as inference, on labeled data
from one seizure: all time-frame HVs of a class are bundled with thinning to
50% density (paper: "an additional bundling when training with thinning to
50% density").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import classifier, hv
from repro.core.classifier import HDCConfig
from repro.core.bundling import threshold_for_density
from repro.core.im import IMParams


def train_one_shot(params: IMParams, codes: jax.Array, labels: jax.Array,
                   cfg: HDCConfig) -> jax.Array:
    """codes: (B, T, channels) uint8; labels: (B, F) int per-frame class ids.

    Returns (n_classes, W) packed class HVs thinned to ~cfg.class_density.
    """
    frames = classifier.encode_frames(params, codes, cfg)        # (B, F, W)
    bits = hv.unpack_bits(frames, cfg.dim).astype(jnp.int32)     # (B, F, D)
    flat_bits = bits.reshape(-1, cfg.dim)
    flat_labels = labels.reshape(-1)
    onehot = jax.nn.one_hot(flat_labels, cfg.n_classes, dtype=jnp.int32)
    counts = jnp.einsum("nc,nd->cd", onehot, flat_bits)          # (n_cls, D)

    # per-class thinning threshold targeting class_density (>= 1)
    def thin(cls_counts):
        thr = threshold_for_density(cls_counts[None, :], cfg.class_density)
        return hv.threshold_pack(cls_counts[None, :], thr)[0]

    return jax.vmap(thin)(counts)
