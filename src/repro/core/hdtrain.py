"""DEPRECATED shim — one-shot training now lives on the unified pipeline:
``HDCPipeline.train_one_shot`` (repro.core.pipeline) dispatches the sparse
thinned-bundling rule (paper Sec. II-D) and the dense majority rule behind
one surface.  This module keeps the old sparse entry point for one PR.
"""

from __future__ import annotations

import warnings

import jax

from repro.core import pipeline as _pipeline
from repro.core.classifier import HDCConfig
from repro.core.im import IMParams

warnings.warn("repro.core.hdtrain is deprecated; use repro.core.pipeline."
              "HDCPipeline.train_one_shot", DeprecationWarning, stacklevel=2)


def train_one_shot(params: IMParams, codes: jax.Array, labels: jax.Array,
                   cfg: HDCConfig) -> jax.Array:
    """codes: (B, T, channels) uint8; labels: (B, F) int per-frame class ids.

    Returns (n_classes, W) packed class HVs thinned to ~cfg.class_density.
    """
    return _pipeline._train_one_shot(params, codes, labels, cfg)
