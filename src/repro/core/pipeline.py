"""Unified HDC pipeline: one variant-dispatched encode/train/infer surface.

The paper compares three datapaths — ``sparse_naive`` (packed IM, one-hot
decoder + barrel-shift binding, adder-tree bundling), ``sparse_compim``
(position-domain CompIM binding, OR-tree bundling) and ``dense`` (XOR binding,
majority bundling, Hamming AM).  ``HDCPipeline`` routes all three behind one
API, selected by ``HDCConfig.variant``, and additionally dispatches each stage
across two execution backends selected by ``HDCConfig.backend``:

* ``"jnp"``    — the pure-XLA reference datapaths (bit-exact with hardware).
* ``"pallas"`` — the fused TPU kernels (``kernels/hdc_encoder``,
  ``kernels/dense_hdc``, ``kernels/hdc_am``); interpret mode on CPU.

The two backends are bit-exact for every variant (tested in
``tests/test_unified_pipeline.py``), so the backend is a deployment choice,
not a modeling choice.

Quickstart::

    cfg = HDCConfig(variant="sparse_compim", backend="pallas")
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), cfg)
    pipe = pipe.calibrate_density(train_codes, target=0.25)
    pipe = pipe.train_one_shot(train_codes, train_labels)
    scores, preds = pipe.infer(test_codes)

``HDCPipeline`` is a frozen pytree: params and class HVs are leaves, the
config is static metadata, so pipelines pass through jit/vmap unchanged.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import am, binding, bundling, classifier, hv, online
from repro.core import im as im_mod
from repro.core.classifier import HDCConfig
from repro.core.im import DenseIMParams, IMParams
from repro.core.online import OnlineAMState
from repro.kernels.dense_hdc.ops import dense_encode_frames_fused
from repro.kernels.hdc_am.ops import am_search
from repro.kernels.hdc_encoder.ops import encode_frames_fused

VARIANTS = ("sparse_naive", "sparse_compim", "dense")
BACKENDS = ("jnp", "pallas")

# Re-exported so downstream code can `from repro.core.pipeline import
# HDCConfig` as its single entry point (DenseHDCConfig merged into it:
# construct with variant="dense").
__all__ = ["HDCConfig", "HDCPipeline", "VARIANTS", "BACKENDS", "spatial_encode"]


def _check_cfg(cfg: HDCConfig) -> None:
    if cfg.variant not in VARIANTS:
        raise ValueError(f"unknown variant {cfg.variant!r}; expected one of {VARIANTS}")
    if cfg.backend not in BACKENDS:
        raise ValueError(f"unknown backend {cfg.backend!r}; expected one of {BACKENDS}")


# ---------------------------------------------------------------------------
# variant-dispatched stages (module-level so jit caches are shared across
# pipeline instances with the same static cfg)
# ---------------------------------------------------------------------------

def spatial_encode(params, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(..., channels) LBP codes -> (..., W) packed bundled HV, any variant.

    Unlike ``classifier.spatial_encode`` this also routes ``dense``
    (XOR binding + per-element channel majority)."""
    if cfg.variant == "dense":
        ch = jnp.arange(cfg.channels, dtype=jnp.int32)
        data = params.item_packed[ch, codes.astype(jnp.int32)]   # (..., C, W)
        bound = binding.bind_xor(data, params.elec_packed)
        counts = hv.unpacked_counts(bound, axis=-2, dim=cfg.dim)
        return hv.majority_pack(counts, cfg.channels, cfg.dim)
    return classifier.spatial_encode(params, codes, cfg)


def _encode_frames_jnp(params, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    if cfg.variant != "dense":
        # delegate to the sparse reference datapath (single source of truth)
        return classifier.encode_frames(params, codes, cfg)
    framed = classifier.frame_view(codes, cfg.window)
    spatial = spatial_encode(params, framed, cfg)               # (B, F, win, W)
    # window-length reduction -> bit-plane popcount adder (hv.bitplane_counts)
    counts = hv.unpacked_counts(spatial, axis=-2, dim=cfg.dim)
    return hv.majority_pack(counts, cfg.window, cfg.dim)


def _fused_sparse_cfg(cfg: HDCConfig) -> HDCConfig:
    """The fused encoder kernel computes the position-domain datapath; the
    naive bit-domain variant is bit-identical to it with spatial thinning
    forced on at the naive threshold (binding-domain equivalence, paper
    Sec. III-A)."""
    if cfg.variant == "sparse_naive":
        return replace(cfg, spatial_thinning=True)
    return cfg


@functools.partial(jax.jit, static_argnames=("cfg",))
def _encode_frames(params, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(B, T, channels) uint8 codes -> (B, F, W) packed frame HVs."""
    if cfg.backend == "pallas":
        if cfg.variant == "dense":
            return dense_encode_frames_fused(params, codes, cfg)
        return encode_frames_fused(params, codes, _fused_sparse_cfg(cfg))
    return _encode_frames_jnp(params, codes, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _frame_counts(params, codes: jax.Array, cfg: HDCConfig) -> jax.Array:
    """Temporal accumulator counts per frame (B, F, D) int32 — the traced
    pre-threshold state used for density calibration and streaming."""
    if cfg.variant != "dense":
        return classifier.frame_counts(params, codes, cfg)
    framed = classifier.frame_view(codes, cfg.window)
    spatial = spatial_encode(params, framed, cfg)
    return bundling.temporal_counts(spatial, cfg.dim)


def _am_scores(frames: jax.Array, class_hvs: jax.Array, cfg: HDCConfig) -> jax.Array:
    """(..., W) frame HVs vs (C, W) class HVs -> (..., C) similarity."""
    mode = "hamming" if cfg.variant == "dense" else "overlap"
    if cfg.backend == "pallas":
        return am_search(frames, class_hvs, mode=mode, dim=cfg.dim)
    if cfg.variant == "dense":
        return am.am_scores_dense(frames, class_hvs, cfg.dim)
    return am.am_scores_sparse(frames, class_hvs)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _scores(frames: jax.Array, class_hvs: jax.Array, cfg: HDCConfig) -> jax.Array:
    return _am_scores(frames, class_hvs, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _infer(params, class_hvs: jax.Array, codes: jax.Array,
           cfg: HDCConfig) -> tuple[jax.Array, jax.Array]:
    """End-to-end jitted datapath: encode + AM search + argmax."""
    s = _am_scores(_encode_frames(params, codes, cfg), class_hvs, cfg)
    return s, am.am_predict(s)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _train_one_shot(params, codes: jax.Array, labels: jax.Array,
                    cfg: HDCConfig) -> tuple[jax.Array, OnlineAMState]:
    """One-shot class HVs through the SAME encoder as inference.

    Sparse: bundle each class's frame HVs with thinning to ``class_density``
    (paper Sec. II-D).  Dense: per-element majority over the class's frames.
    Returns ((n_classes, W) packed class HVs, the pre-threshold counter-file
    state) — the state seeds online continual learning (core.online)."""
    frames = _encode_frames(params, codes, cfg)                  # (B, F, W)
    bits = hv.unpack_bits(frames, cfg.dim).reshape(-1, cfg.dim)
    state = online.state_from_frames(bits, labels.reshape(-1), cfg.n_classes)
    return online.class_hvs_from_state(state, cfg), state


@functools.partial(jax.jit, static_argnames=("cfg", "epochs"))
def _fit_iterative(params, codes: jax.Array, labels: jax.Array,
                   margin: jax.Array, cfg: HDCConfig,
                   epochs: int) -> tuple[jax.Array, OnlineAMState, jax.Array]:
    """One-shot init + ``epochs`` batch-iterative retraining passes.

    Each epoch re-thresholds the counter file to class HVs, scores every
    frame through the backend-dispatched AM search, and applies the gated
    add-to-true / subtract-from-rival update (core.online) to all
    misclassified / low-margin frames at once.  ``epochs=0`` reproduces
    ``_train_one_shot`` bit-exactly.  Returns (class HVs, state, per-epoch
    gated-update counts)."""
    frames = _encode_frames(params, codes, cfg)                  # (B, F, W)
    flat = frames.reshape(-1, frames.shape[-1])
    bits = hv.unpack_bits(flat, cfg.dim)
    lab = labels.reshape(-1)
    state0 = online.state_from_frames(bits, lab, cfg.n_classes)

    def epoch(state, _):
        chvs = online.class_hvs_from_state(state, cfg)
        scores = _am_scores(flat, chvs, cfg)
        state, gate = online.batch_update(state, bits, lab, scores,
                                          margin=margin)
        return state, jnp.sum(gate, dtype=jnp.int32)

    state, n_upd = jax.lax.scan(epoch, state0, None, length=epochs)
    return online.class_hvs_from_state(state, cfg), state, n_upd


# ---------------------------------------------------------------------------
# the pipeline object
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HDCPipeline:
    """One variant's full datapath: IM params + (optional) trained class HVs.

    Frozen pytree: ``params`` / ``class_hvs`` / ``am_state`` are leaves,
    ``cfg`` is static metadata.  All methods are pure — training and
    calibration return new pipelines."""
    params: IMParams | DenseIMParams
    cfg: HDCConfig
    class_hvs: jax.Array | None = None           # (n_classes, W) packed
    # counter-file view of the AM (core.online): set by train_one_shot /
    # fit_iterative; seeds SeizureSession.adapt and StreamingFleet.adapt
    am_state: OnlineAMState | None = None

    @classmethod
    def init(cls, key: jax.Array, cfg: HDCConfig) -> "HDCPipeline":
        """Draw the design-time random codebooks for ``cfg.variant``."""
        _check_cfg(cfg)
        if cfg.variant == "dense":
            params = im_mod.make_dense_im(key, channels=cfg.channels,
                                          codes=cfg.codes, dim=cfg.dim)
        else:
            # only the naive bit-domain datapath reads the packed IM tables
            params = im_mod.make_im(
                key, channels=cfg.channels, codes=cfg.codes, dim=cfg.dim,
                segments=cfg.segments,
                precompute_packed=cfg.variant == "sparse_naive")
        return cls(params=params, cfg=cfg)

    # -- config rewrites ----------------------------------------------------

    # class HVs are trained "through the SAME encoder as inference"; changing
    # any of these on a trained pipeline would silently mismatch the class
    # prototypes against the query encoder, so with_cfg drops class_hvs then
    _ENCODER_FIELDS = ("variant", "spatial_thinning", "spatial_threshold",
                       "temporal_threshold", "class_density")

    def with_cfg(self, **overrides) -> "HDCPipeline":
        """Rebuild with config overrides that do not invalidate the params
        (variant/backend/thresholds — not geometry fields like
        dim/segments/channels/window/n_classes).  Changing an
        encoder-affecting field on a trained pipeline drops the class HVs
        (retrain with the new operating point); ``backend`` changes keep
        them (the backends are bit-exact)."""
        new = replace(self.cfg, **overrides)
        _check_cfg(new)
        # n_classes/window are pinned too: class_hvs rows and the calibrated
        # temporal_threshold would silently go stale
        for field in ("dim", "segments", "channels", "lbp_bits", "n_classes",
                      "window"):
            if getattr(new, field) != getattr(self.cfg, field):
                raise ValueError(f"cannot change {field} without re-init")
        if new.variant != self.cfg.variant and (new.variant == "dense") != (
                self.cfg.variant == "dense"):
            raise ValueError("cannot cross the sparse/dense params boundary; "
                             "HDCPipeline.init a new pipeline instead")
        chvs, state = self.class_hvs, self.am_state
        if chvs is not None and any(getattr(new, f) != getattr(self.cfg, f)
                                    for f in self._ENCODER_FIELDS):
            chvs = state = None
        params = self.params
        if (new.variant == "sparse_naive"
                and getattr(params, "item_packed_cache", True) is None):
            # entering the naive bit-domain datapath: precompute the packed
            # tables its eager lookups read (init skips them for CompIM)
            params = replace(params,
                             item_packed_cache=hv.positions_to_packed(
                                 params.item_pos, new.dim, new.segments),
                             elec_packed_cache=hv.positions_to_packed(
                                 params.elec_pos, new.dim, new.segments))
        elif (new.variant == "sparse_compim"
              and getattr(params, "item_packed_cache", None) is not None):
            # leaving it: drop the caches so CompIM pipelines do not haul
            # the full packed tables as pytree leaves
            params = replace(params, item_packed_cache=None,
                             elec_packed_cache=None)
        return replace(self, cfg=new, class_hvs=chvs, am_state=state,
                       params=params)

    def with_backend(self, backend: str) -> "HDCPipeline":
        return self.with_cfg(backend=backend)

    # -- encode / calibrate / train / infer ---------------------------------

    def encode_frames(self, codes: jax.Array) -> jax.Array:
        """(B, T, channels) uint8 codes -> (B, F, W) packed frame HVs."""
        return _encode_frames(self.params, codes, self.cfg)

    def frame_counts(self, codes: jax.Array) -> jax.Array:
        """Pre-threshold temporal accumulator counts (B, F, D)."""
        return _frame_counts(self.params, codes, self.cfg)

    def calibrate_density(self, codes: jax.Array, target: float) -> "HDCPipeline":
        """Program the temporal-thinning threshold register so post-thinning
        frame density stays <= ``target`` on the calibration stream (paper
        Fig. 4 sweep).  No-op for the dense variant (majority, no thinning).
        Calibrate BEFORE training: changing the threshold on a trained
        pipeline drops the class HVs (they were bundled at the old operating
        point)."""
        if self.cfg.variant == "dense":
            return self
        # single source of truth for the calibration rule
        new_cfg = classifier.with_density_target(self.params, codes,
                                                 self.cfg, target)
        return self.with_cfg(temporal_threshold=new_cfg.temporal_threshold)

    def _check_labels(self, labels: jax.Array) -> None:
        """Reject training batches that would silently corrupt class HVs.

        A class with zero examples yields an all-zero class HV (dense:
        majority of nothing; sparse: thinning all-zero counts) which then
        scores plausibly in the AM — raise instead.  Skipped under tracing
        (labels are concrete on every user-facing path)."""
        if isinstance(labels, jax.core.Tracer):
            return
        lab = np.asarray(labels)
        if lab.size and (lab.min() < 0 or lab.max() >= self.cfg.n_classes):
            raise ValueError(
                f"labels must be in [0, {self.cfg.n_classes}), got range "
                f"[{lab.min()}, {lab.max()}]")
        missing = sorted(set(range(self.cfg.n_classes)) - set(np.unique(lab)))
        if missing:
            raise ValueError(
                f"classes {missing} have no examples in the training batch; "
                "their class HVs would be all-zero yet still score in the "
                "AM — provide at least one frame per class")

    def train_one_shot(self, codes: jax.Array, labels: jax.Array) -> "HDCPipeline":
        """One-shot training: returns a pipeline carrying the class HVs and
        the counter-file ``am_state`` that seeds online adaptation.

        codes: (B, T, channels) uint8; labels: (B, F) int per-frame class ids.
        """
        self._check_labels(labels)
        chvs, state = _train_one_shot(self.params, codes, labels, self.cfg)
        return replace(self, class_hvs=chvs, am_state=state)

    def fit_iterative(self, codes: jax.Array, labels: jax.Array, *,
                      epochs: int = 5, margin: float = 0.0) -> "HDCPipeline":
        """Iterative retraining (Pale et al.): one-shot init, then ``epochs``
        passes that re-score every frame and apply the gated
        add-to-true / subtract-from-rival update to the counter file.

        ``margin > 0`` also updates on correct-but-low-confidence frames
        (score lead over the rival class below ``margin``).  ``epochs=0`` is
        bit-exact with ``train_one_shot``.  Returns a pipeline carrying the
        retrained class HVs + ``am_state``."""
        if epochs < 0:
            raise ValueError(f"epochs={epochs} must be >= 0")
        self._check_labels(labels)
        chvs, state, _ = _fit_iterative(
            self.params, codes, labels, jnp.asarray(margin, jnp.float32),
            self.cfg, epochs)
        return replace(self, class_hvs=chvs, am_state=state)

    def scores(self, frames: jax.Array) -> jax.Array:
        """(..., W) frame HVs -> (..., n_classes) AM similarity scores."""
        if self.class_hvs is None:
            raise ValueError("pipeline has no class HVs; call train_one_shot first")
        return _scores(frames, self.class_hvs, self.cfg)

    def infer(self, codes: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Full datapath (end-to-end jitted): (B, T, channels) codes ->
        (scores (B, F, n_classes), predictions (B, F))."""
        if self.class_hvs is None:
            raise ValueError("pipeline has no class HVs; call train_one_shot first")
        return _infer(self.params, self.class_hvs, codes, self.cfg)


jax.tree_util.register_dataclass(
    HDCPipeline, data_fields=["params", "class_hvs", "am_state"],
    meta_fields=["cfg"])
