"""Hypervector primitives: bit-packed and position-domain representations.

Two representations of a sparse segmented hypervector (HV) with dimension D,
S segments of L = D // S bits, and exactly one 1-bit per segment:

* **bit domain**  — packed ``uint32[D // 32]`` words (LSB-first within a word).
  This is the "naive" datapath the paper's baseline accelerator uses (1024
  wires per HV), and the only representation dense HDC has.
* **position domain** — ``uint8[S]`` (paper: 8 segments x 7-bit positions =
  56 bits).  This is the CompIM representation: all information of a sparse
  segmented HV lives in the positions of its 1-bits.

All functions are pure jnp and jit-compatible; batch dimensions lead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32  # packing word width


# ---------------------------------------------------------------------------
# packing / unpacking
# ---------------------------------------------------------------------------

def n_words(dim: int) -> int:
    if dim % WORD:
        raise ValueError(f"D={dim} must be a multiple of {WORD}")
    return dim // WORD


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a (..., D) array of {0,1} into (..., D//32) uint32, LSB-first."""
    d = bits.shape[-1]
    w = n_words(d)
    b = bits.reshape(*bits.shape[:-1], w, WORD).astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, dim: int | None = None) -> jax.Array:
    """Unpack (..., W) uint32 into (..., W*32) of {0,1} uint8, LSB-first."""
    w = words.shape[-1]
    dim = dim if dim is not None else w * WORD
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], w * WORD)[..., :dim].astype(jnp.uint8)


def popcount(words: jax.Array, axis=-1) -> jax.Array:
    """Total number of set bits along `axis` of a packed uint32 array."""
    return jnp.sum(lax_popcount(words), axis=axis, dtype=jnp.int32)


def argmax32(x: jax.Array, axis: int = -1) -> jax.Array:
    """``jnp.argmax`` with int32 result AND 32-bit index arithmetic.

    ``jnp.argmax`` builds its index iota in the default int dtype, so under
    ``JAX_ENABLE_X64`` the reduction runs over int64 buffers even when the
    result is cast back; ``lax.argmax`` takes the index dtype explicitly.
    Tie-breaking (lowest index wins) is identical.
    """
    return jax.lax.argmax(x, axis % x.ndim, jnp.int32)


def take_along_axis32(a: jax.Array, idx: jax.Array, axis: int = -1
                      ) -> jax.Array:
    """``jnp.take_along_axis`` with int32 gather indices.

    ``jnp.take_along_axis`` builds its index arithmetic in the default int
    dtype, so under ``JAX_ENABLE_X64`` it plants multi-element int64 index
    buffers in otherwise 32-bit programs (RPR001's runtime cousin; the HLO
    audit fails on them).  Open-grid advanced indexing with explicit int32
    iotas lowers to the same gather with 32-bit indices.  Broadcasting of
    ``idx`` against ``a`` on non-``axis`` dims matches numpy semantics.
    """
    axis = axis % a.ndim
    batch = jnp.broadcast_shapes(a.shape[:axis] + (1,) + a.shape[axis + 1:],
                                 idx.shape[:axis] + (1,) + idx.shape[axis + 1:])
    a_shape = batch[:axis] + (a.shape[axis],) + batch[axis + 1:]
    out_shape = batch[:axis] + (idx.shape[axis],) + batch[axis + 1:]
    a_b = jnp.broadcast_to(a, a_shape)
    idx_b = jnp.broadcast_to(idx, out_shape).astype(jnp.int32)
    grid = tuple(
        idx_b if d == axis else
        jnp.arange(n, dtype=jnp.int32).reshape(
            (-1,) + (1,) * (len(out_shape) - d - 1))
        for d, n in enumerate(out_shape))
    return a_b[grid]


def lax_popcount(words: jax.Array) -> jax.Array:
    return jax.lax.population_count(words)


# ---------------------------------------------------------------------------
# position <-> bit domain
# ---------------------------------------------------------------------------

def positions_to_bits(pos: jax.Array, dim: int, segments: int) -> jax.Array:
    """(..., S) segment positions -> (..., D) one-hot-per-segment bits (uint8).

    ``pos[..., s]`` is in [0, L) with L = dim // segments; the set bit of
    segment s lives at global index s * L + pos.
    """
    seg_len = dim // segments
    iota = jnp.arange(seg_len, dtype=pos.dtype)
    onehot = (pos[..., None] == iota).astype(jnp.uint8)  # (..., S, L)
    return onehot.reshape(*pos.shape[:-1], dim)


def positions_to_packed(pos: jax.Array, dim: int, segments: int) -> jax.Array:
    """(..., S) positions -> (..., D//32) packed uint32 (scatter-free)."""
    seg_len = dim // segments
    words_per_seg = seg_len // WORD
    if seg_len % WORD:
        return pack_bits(positions_to_bits(pos, dim, segments))
    word_idx = (pos // WORD).astype(jnp.int32)  # (..., S) in [0, words_per_seg)
    bit = jnp.uint32(1) << (pos % WORD).astype(jnp.uint32)
    iota = jnp.arange(words_per_seg, dtype=jnp.int32)
    seg_words = jnp.where(word_idx[..., None] == iota, bit[..., None], 0)
    return seg_words.reshape(*pos.shape[:-1], segments * words_per_seg).astype(jnp.uint32)


def packed_to_positions(words: jax.Array, dim: int, segments: int) -> jax.Array:
    """Inverse of positions_to_packed for HVs with exactly one bit/segment.

    This is the "one-hot to binary decoder" of the paper's baseline binding
    (Fig. 3a).  Returns (..., S) uint8 positions.
    """
    bits = unpack_bits(words, dim)  # (..., D)
    seg_len = dim // segments
    seg = bits.reshape(*bits.shape[:-1], segments, seg_len)
    iota = jnp.arange(seg_len, dtype=jnp.int32)
    return jnp.sum(seg.astype(jnp.int32) * iota, axis=-1,
                   dtype=jnp.int32).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# random HV generation (design-time, like the paper's random IM)
# ---------------------------------------------------------------------------

def random_sparse_positions(key: jax.Array, shape: tuple[int, ...],
                            segments: int, seg_len: int) -> jax.Array:
    """Random position-domain HVs: (*shape, segments) uint8 in [0, seg_len)."""
    pos = jax.random.randint(key, (*shape, segments), 0, seg_len, dtype=jnp.int32)
    return pos.astype(jnp.uint8)


def random_dense_packed(key: jax.Array, shape: tuple[int, ...], dim: int) -> jax.Array:
    """Random dense (p = 50%) packed HVs: (*shape, D//32) uint32."""
    bits = jax.random.bernoulli(key, 0.5, (*shape, dim)).astype(jnp.uint8)
    return pack_bits(bits)


# ---------------------------------------------------------------------------
# elementwise packed ops
# ---------------------------------------------------------------------------

def xor(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.bitwise_xor(a, b)


def word_parity(words: jax.Array) -> jax.Array:
    """Per-word 1-bit parity (popcount mod 2) of packed uint32 words.

    The primitive of the reliability subsystem's ECC word codecs
    (repro.reliability.ecc): a parity-check bit over a masked word is
    ``word_parity(word & mask)``."""
    return lax_popcount(words).astype(jnp.uint32) & jnp.uint32(1)


def random_flip_mask(key: jax.Array, shape: tuple[int, ...], p,
                     bits: int = WORD) -> jax.Array:
    """Bernoulli(p) bit-flip masks in the packed domain: (*shape,) uint32
    words whose low ``bits`` bits are each independently set with
    probability ``p`` (high bits zero).

    ``p`` may be a traced scalar, so one jitted program serves a whole
    BER sweep.  XORing the mask into packed HV words / counter values is
    the reliability subsystem's fault injection (repro.reliability.faults);
    ``p == 0`` yields an all-zero mask, keeping the faulted datapath
    bit-exact with the fault-free one.
    """
    if not 1 <= bits <= WORD:
        raise ValueError(f"bits={bits} must be in [1, {WORD}]")
    u = jax.random.uniform(key, (*shape, bits), jnp.float32)
    flips = (u < p).astype(jnp.uint32)
    shifts = jnp.arange(bits, dtype=jnp.uint32)
    return jnp.sum(flips << shifts, axis=-1, dtype=jnp.uint32)


def or_(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.bitwise_or(a, b)


def and_(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.bitwise_and(a, b)


def or_reduce(words: jax.Array, axis: int) -> jax.Array:
    """OR-tree over `axis` — the paper's optimized spatial bundling.

    Lowered as an explicit pairwise tree (log2 N levels of wide elementwise
    ORs) rather than ``lax.reduce``: XLA CPU turns a variadic reduce over a
    middle axis into a scalar loop, which dominated the fleet serving step
    (~2.5x slower end-to-end).  OR is associative/commutative, so the tree
    is bit-exact with the linear reduction.
    """
    axis = axis % words.ndim
    n = words.shape[axis]
    if n == 0:
        raise ValueError("cannot OR-reduce an empty axis")
    while n > 1:
        half = n // 2
        a = jax.lax.slice_in_dim(words, 0, half, axis=axis)
        b = jax.lax.slice_in_dim(words, half, 2 * half, axis=axis)
        merged = a | b
        if n % 2:
            rest = jax.lax.slice_in_dim(words, 2 * half, n, axis=axis)
            merged = jnp.concatenate([merged, rest], axis=axis)
        words = merged
        n = words.shape[axis]
    return jnp.squeeze(words, axis)


def density(words: jax.Array, dim: int) -> jax.Array:
    """Fraction of set bits of packed HVs (reduces over the last axis)."""
    return popcount(words).astype(jnp.float32) / dim


def hamming(a: jax.Array, b: jax.Array) -> jax.Array:
    """Hamming distance between packed HVs (last axis = words)."""
    return popcount(xor(a, b))


def overlap(a: jax.Array, b: jax.Array) -> jax.Array:
    """AND+popcount similarity (paper's sparse AM metric; last axis = words)."""
    return popcount(and_(a, b))


# ---------------------------------------------------------------------------
# bit-plane (time-packed) representation: pack the REDUCE axis into words
# ---------------------------------------------------------------------------

def bit_transpose32(x: jax.Array) -> jax.Array:
    """32x32 bit transpose along axis -2: out[..., b, :] bit j = x[..., j, :]
    bit b (LSB-first).

    SWAR butterfly (Hacker's Delight transpose32): 5 stages of wide
    shift/xor/mask ops, elementwise over the trailing lane axis — no
    gather/scatter, so it runs on the VPU inside Pallas kernels and
    vectorizes under XLA alike.  Involution: applying it twice is identity.
    """
    if x.shape[-2] != 32:
        raise ValueError(f"axis -2 must have size 32, got {x.shape}")
    j, m = 16, jnp.uint32(0x0000FFFF)
    while j:
        sh = x.shape
        a = x.reshape(*sh[:-2], 32 // (2 * j), 2, j, sh[-1])
        lo, hi = a[..., 0, :, :], a[..., 1, :, :]
        t = ((lo >> j) ^ hi) & m
        lo = lo ^ (t << j)
        hi = hi ^ t
        x = jnp.stack([lo, hi], axis=-3).reshape(sh)
        j //= 2
        if j:
            m = m ^ (m << jnp.uint32(j))
    return x


def time_pack(words: jax.Array) -> jax.Array:
    """Repack (..., T, W) cycle-major words into time-packed bit planes.

    Returns (..., T // 32, 32, W) uint32 where out[..., g, b, w] carries, in
    bit j, bit b of word w at cycle 32 g + j.  This is the bit-plane dual of
    the packed HV stream: one word now holds 32 CYCLES of one bit position,
    so a masked popcount counts 32 cycles of temporal bundling at once.
    T must be a multiple of 32 (callers pad; padded cycles carry zeros).
    """
    t = words.shape[-2]
    if t % 32:
        raise ValueError(f"T={t} must be a multiple of 32 (pad the stream)")
    sh = words.shape
    return bit_transpose32(words.reshape(*sh[:-2], t // 32, 32, sh[-1]))


def bitplane_counts(words: jax.Array, dim: int) -> jax.Array:
    """(..., N, W) packed -> (..., D) int32 bit-position counts over N.

    The popcount-plane adder: time-pack the reduce axis, popcount each
    32-cycle plane, sum the group totals.  Bit-exact with the unpack-and-add
    adder tree, with no (..., N, D) unpacked expansion and no FP math.
    Requires N % 32 == 0 (use ``unpacked_counts`` for ragged N).
    """
    tp = time_pack(words)                                  # (..., G, 32, W)
    # dtype pinned so JAX_ENABLE_X64 cannot drift the count dtype
    pop = lax_popcount(tp).astype(jnp.int32)
    tot = jnp.sum(pop, axis=-3, dtype=jnp.int32)           # (..., 32, W)
    return tot.swapaxes(-1, -2).reshape(*tot.shape[:-2], dim)


# ---------------------------------------------------------------------------
# counting bundler (bit domain) — used by baseline spatial & temporal bundling
# ---------------------------------------------------------------------------

def unpacked_counts(words: jax.Array, axis: int, dim: int) -> jax.Array:
    """Sum of unpacked bits over `axis`: the adder-tree of the baseline.

    words: (..., N, ..., W) packed; returns (..., D) int32 counts with `axis`
    reduced.  When N is a multiple of 32 this routes to the bit-plane
    popcount adder (``bitplane_counts``) — bit-exact and ~an order of
    magnitude less traffic than unpacking.  Ragged N falls back to a scan
    over `axis` so the peak temporary is one unpacked slice, not the full
    (..., N, ..., D) expansion (which reaches tens of GB for long streams).
    """
    axis = axis % words.ndim
    n = words.shape[axis]
    if n and n % 32 == 0:
        return bitplane_counts(jnp.moveaxis(words, axis, -2), dim)
    moved = jnp.moveaxis(words, axis, 0)

    def step(acc, w):
        return acc + unpack_bits(w, dim).astype(jnp.int32), None

    init = jnp.zeros((*moved.shape[1:-1], dim), jnp.int32)
    acc, _ = jax.lax.scan(step, init, moved)
    return acc


def threshold_pack(counts: jax.Array, thr) -> jax.Array:
    """Thinning: counts (..., D) -> packed (..., D//32) of [counts >= thr]."""
    return pack_bits((counts >= thr).astype(jnp.uint8))


@functools.partial(jax.jit, static_argnames=("dim",))
def majority_pack(counts: jax.Array, n: int | jax.Array, dim: int) -> jax.Array:
    """Dense-HDC majority rule: bit = [count > n/2] (ties broken low)."""
    del dim
    return pack_bits((counts * 2 > n).astype(jnp.uint8))


def np_pack_bits(bits: np.ndarray) -> np.ndarray:
    """NumPy mirror of pack_bits for test fixtures."""
    d = bits.shape[-1]
    w = d // WORD
    b = bits.reshape(*bits.shape[:-1], w, WORD).astype(np.uint32)
    return (b << np.arange(WORD, dtype=np.uint32)).sum(-1).astype(np.uint32)
