"""Associative memory (AM): similarity search over class HVs.

Sparse HDC similarity = popcount(AND(query, class)) — only 1-bits carry
information (paper Sec. II-D).  Dense HDC similarity = D - Hamming distance.
The hardware searches the two classes sequentially; here the search is a
batched packed popcount "matmul": (B, W) x (C, W) -> (B, C) scores.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hv


def am_scores_sparse(query: jax.Array, classes: jax.Array) -> jax.Array:
    """(..., W) uint32 query vs (C, W) class HVs -> (..., C) int32 overlap."""
    return hv.popcount(jnp.bitwise_and(query[..., None, :], classes), axis=-1)


def am_scores_dense(query: jax.Array, classes: jax.Array, dim: int) -> jax.Array:
    """Dense similarity = D - Hamming(query, class)."""
    return dim - hv.popcount(jnp.bitwise_xor(query[..., None, :], classes), axis=-1)


def am_predict(scores: jax.Array) -> jax.Array:
    """argmax over classes; ties resolve to the lower class index
    (= interictal for the 2-class iEEG system, the safe default)."""
    return hv.argmax32(scores, axis=-1)
