"""AdamW with global-norm clipping, cosine schedule, microbatch gradient
accumulation, and optional low-precision optimizer state (bf16 m/v) for
very large models (jamba-398B on 256 x 16 GiB chips needs it).

Dependency-free (no optax in the container); state is a pytree sharded
identically to the parameters (ZeRO-3 via the fsdp axis).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" for 100B+ models
    accum_steps: int = 1              # microbatch gradient accumulation


def schedule(opt: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(opt.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - opt.warmup_steps)
                    / jnp.maximum(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    return opt.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def init_state(params: Any, opt: OptConfig) -> dict:
    dt = jnp.dtype(opt.state_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads: Any, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def apply_updates(params: Any, grads: Any, state: dict, opt: OptConfig
                  ) -> tuple[Any, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, opt.clip_norm)
    step = state["step"] + 1
    lr = schedule(opt, step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(opt.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(sdt), v32.astype(sdt))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
