"""Error-feedback int8 gradient compression for the thin inter-pod links.

Cross-pod gradient all-reduce is the bandwidth bottleneck of multi-pod data
parallelism (the `pod` axis rides DCI links, ~an order of magnitude slower
than intra-pod ICI).  Standard remedy: quantize the cross-pod reduction to
int8 with per-tensor scales and keep the quantization error in a local
residual that is re-added next step (error feedback), which preserves
convergence (Karimireddy et al., 2019).

Usage inside a train step (optional, cfg.grad_compress):

    grads, residual = compress_decompress(grads, residual)

The quantize->dequantize round-trip is inserted *before* XLA's cross-pod
all-reduce so the partitioner reduces the low-precision representation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _q(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads: Any, residual: Any) -> tuple[Any, Any]:
    """Returns (dequantized grads to feed the reducer, new residual)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, scale = _q(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq).astype(r.dtype)

    out = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, res


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
