"""Model assembly for the architecture zoo.

Families
--------
dense   pre-norm GQA transformer (qwen3*, llama3.2-3b, command-r-35b)
moe     dense attention + MoE FFN (deepseek-moe-16b, moonshot-v1-16b-a3b);
        `first_k_dense` leading layers keep a dense FFN
ssm     attention-free Mamba-1 stack (falcon-mamba-7b)
hybrid  Jamba period blocks: per `attn_period` layers 1 attention + rest
        Mamba; FFN alternates MLP / MoE (moe_period=2)
encdec  bidirectional encoder + causal decoder with cross attention
        (seamless-m4t-medium; audio frontend stubbed)
vlm     dense decoder consuming [media embeddings ; text embeddings]
        (internvl2-2b; ViT frontend stubbed)

All stacks scan over stacked layer parameters (HLO size / compile time O(1)
in depth) with optional per-layer remat.  Caches thread through the same
scans, so decode is a single fused while-free step.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.config import ArchConfig
from repro.models.layers import (embed, embed_spec, mlp, mlp_spec, rmsnorm,
                                 rmsnorm_spec, unembed)
from repro.models.params import ParamSpec, stack_layers
from repro.runtime.sharding import ShardCtx, constrain

XENT_CHUNK = 512


# ===========================================================================
# parameter specs
# ===========================================================================

def _dense_layer_spec(cfg: ArchConfig) -> dict:
    return {"ln1": rmsnorm_spec(cfg.d_model), "attn": attn.attention_spec(cfg),
            "ln2": rmsnorm_spec(cfg.d_model), "mlp": mlp_spec(cfg.d_model, cfg.d_ff)}


def _moe_layer_spec(cfg: ArchConfig) -> dict:
    return {"ln1": rmsnorm_spec(cfg.d_model), "attn": attn.attention_spec(cfg),
            "ln2": rmsnorm_spec(cfg.d_model), "moe": moe_mod.moe_spec(cfg)}


def _mamba_layer_spec(cfg: ArchConfig) -> dict:
    return {"ln": rmsnorm_spec(cfg.d_model), "mamba": mb.mamba_spec(cfg)}


def _hybrid_block_spec(cfg: ArchConfig) -> dict:
    """One Jamba period block: sublayer 0 = attention, 1..p-1 = mamba;
    FFN alternates MLP (even sublayers) / MoE (odd sublayers)."""
    p = cfg.attn_period
    return {
        "attn": {"ln": rmsnorm_spec(cfg.d_model), "attn": attn.attention_spec(cfg)},
        "mamba": stack_layers(p - 1, _mamba_layer_spec(cfg)),
        "mlp": stack_layers(p // 2, {"ln": rmsnorm_spec(cfg.d_model),
                                     "mlp": mlp_spec(cfg.d_model, cfg.d_ff)}),
        "moe": stack_layers(p // 2, {"ln": rmsnorm_spec(cfg.d_model),
                                     "moe": moe_mod.moe_spec(cfg)}),
    }


def _encdec_layer_specs(cfg: ArchConfig) -> tuple[dict, dict]:
    enc = _dense_layer_spec(cfg)
    dec = {"ln1": rmsnorm_spec(cfg.d_model), "attn": attn.attention_spec(cfg),
           "ln_x": rmsnorm_spec(cfg.d_model),
           "cross": attn.attention_spec(cfg, cross=True),
           "ln2": rmsnorm_spec(cfg.d_model), "mlp": mlp_spec(cfg.d_model, cfg.d_ff)}
    return enc, dec


def model_spec(cfg: ArchConfig) -> dict:
    spec: dict[str, Any] = {
        "embed": embed_spec(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = ParamSpec((cfg.d_model, cfg.vocab), ("fsdp", "tp"))
    fam = cfg.family
    if fam in ("dense", "vlm"):
        spec["layers"] = stack_layers(cfg.n_layers, _dense_layer_spec(cfg))
    elif fam == "moe":
        if cfg.first_k_dense:
            spec["dense_layers"] = stack_layers(cfg.first_k_dense,
                                                _dense_layer_spec(cfg))
        spec["layers"] = stack_layers(cfg.n_layers - cfg.first_k_dense,
                                      _moe_layer_spec(cfg))
    elif fam == "ssm":
        spec["layers"] = stack_layers(cfg.n_layers, _mamba_layer_spec(cfg))
    elif fam == "hybrid":
        assert cfg.n_layers % cfg.attn_period == 0
        spec["blocks"] = stack_layers(cfg.n_layers // cfg.attn_period,
                                      _hybrid_block_spec(cfg))
    elif fam in ("encdec", "audio"):
        enc, dec = _encdec_layer_specs(cfg)
        spec["enc_layers"] = stack_layers(cfg.enc_layers, enc)
        spec["enc_norm"] = rmsnorm_spec(cfg.d_model)
        spec["layers"] = stack_layers(cfg.n_layers, dec)
    else:
        raise ValueError(fam)
    return spec


# ===========================================================================
# layer applications (one layer, unstacked params)
# ===========================================================================

def _apply_dense_layer(lp, x, cfg, ctx):
    x = x + attn.attention_train(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, ctx)
    x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return constrain(x, ("batch", None, None), ctx)


def _apply_moe_layer(lp, x, cfg, ctx):
    x = x + attn.attention_train(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, ctx)
    out, aux = moe_mod.moe_layer(lp["moe"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg, ctx)
    return constrain(x + out, ("batch", None, None), ctx), aux


def _apply_mamba_layer(lp, x, cfg, ctx):
    x = x + mb.mamba_train(lp["mamba"], rmsnorm(x, lp["ln"], cfg.norm_eps), cfg, ctx)
    return constrain(x, ("batch", None, None), ctx)


def _apply_hybrid_block(bp, x, cfg, ctx):
    """Unrolled period block (train path)."""
    p = cfg.attn_period
    aux_total = jnp.float32(0.0)
    mlp_i = moe_i = 0
    for j in range(p):
        if j == 0:
            sub = bp["attn"]
            x = x + attn.attention_train(sub["attn"], rmsnorm(x, sub["ln"], cfg.norm_eps), cfg, ctx)
        else:
            sub = jax.tree.map(lambda a: a[j - 1], bp["mamba"])
            x = x + mb.mamba_train(sub["mamba"], rmsnorm(x, sub["ln"], cfg.norm_eps), cfg, ctx)
        if j % 2 == 1:
            sub = jax.tree.map(lambda a: a[moe_i], bp["moe"])
            out, aux = moe_mod.moe_layer(sub["moe"], rmsnorm(x, sub["ln"], cfg.norm_eps), cfg, ctx)
            x = x + out
            aux_total = aux_total + aux
            moe_i += 1
        else:
            sub = jax.tree.map(lambda a: a[mlp_i], bp["mlp"])
            x = x + mlp(sub["mlp"], rmsnorm(x, sub["ln"], cfg.norm_eps))
            mlp_i += 1
        x = constrain(x, ("batch", None, None), ctx)
    return x, aux_total


def _apply_dec_layer(lp, x, enc_out, cfg, ctx):
    x = x + attn.attention_train(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, ctx)
    x = x + attn.attention_cross(
        lp["cross"], rmsnorm(x, lp["ln_x"], cfg.norm_eps), enc_out, cfg, ctx)
    x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return constrain(x, ("batch", None, None), ctx)


# ===========================================================================
# stacked-scan runners
# ===========================================================================

def _scan_stack(layer_fn, stacked, x, cfg, *, with_aux: bool):
    """Scan `layer_fn` over stacked layer params.  layer_fn(lp, x) -> x or
    (x, aux).  Remat per layer when cfg.remat."""
    def step(carry, lp):
        if with_aux:
            x, aux = carry
            x, a = layer_fn(lp, x)
            return (x, aux + a), None
        return layer_fn(lp, carry), None

    if cfg.remat:
        step = jax.checkpoint(step)
    init = (x, jnp.float32(0.0)) if with_aux else x
    out, _ = jax.lax.scan(step, init, stacked)
    return out if not with_aux else out


# ===========================================================================
# backbone forwards (tokens/embeddings -> final hidden states)
# ===========================================================================

def backbone_train(params: dict, x: jax.Array, cfg: ArchConfig, ctx: ShardCtx,
                   enc_out: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, d) embedded inputs -> (hidden (B, L, d), aux_loss)."""
    fam = cfg.family
    aux = jnp.float32(0.0)
    if fam in ("dense", "vlm"):
        x = _scan_stack(lambda lp, h: _apply_dense_layer(lp, h, cfg, ctx),
                        params["layers"], x, cfg, with_aux=False)
    elif fam == "moe":
        if cfg.first_k_dense:
            x = _scan_stack(lambda lp, h: _apply_dense_layer(lp, h, cfg, ctx),
                            params["dense_layers"], x, cfg, with_aux=False)
        x, aux = _scan_stack(lambda lp, h: _apply_moe_layer(lp, h, cfg, ctx),
                             params["layers"], x, cfg, with_aux=True)
    elif fam == "ssm":
        x = _scan_stack(lambda lp, h: _apply_mamba_layer(lp, h, cfg, ctx),
                        params["layers"], x, cfg, with_aux=False)
    elif fam == "hybrid":
        x, aux = _scan_stack(lambda bp, h: _apply_hybrid_block(bp, h, cfg, ctx),
                             params["blocks"], x, cfg, with_aux=True)
    elif fam in ("encdec", "audio"):
        assert enc_out is not None
        x = _scan_stack(lambda lp, h: _apply_dec_layer(lp, h, enc_out, cfg, ctx),
                        params["layers"], x, cfg, with_aux=False)
    else:
        raise ValueError(fam)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def encoder_forward(params: dict, frames: jax.Array, cfg: ArchConfig,
                    ctx: ShardCtx) -> jax.Array:
    """Bidirectional encoder over (stub) frame embeddings (B, Le, d)."""
    def enc_layer(lp, h):
        h = h + attn.attention_train(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                     cfg, ctx, causal=False)
        h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return constrain(h, ("batch", None, None), ctx)
    h = _scan_stack(enc_layer, params["enc_layers"], frames, cfg, with_aux=False)
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


# ===========================================================================
# losses
# ===========================================================================

def chunked_xent(params: dict, hidden: jax.Array, labels: jax.Array,
                 cfg: ArchConfig, ctx: ShardCtx) -> jax.Array:
    """Causal-LM cross entropy without materializing (B, L, V) logits:
    scan over sequence chunks, remat the chunk projection."""
    b, l, d = hidden.shape
    chunk = min(XENT_CHUNK, l)
    n = l // chunk
    hs = hidden[:, : n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]

    def step(acc, inp):
        hc, lc = inp
        logits = unembed(table, hc, tied=cfg.tie_embeddings).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "tp"), ctx)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.float32(0.0), (hs, ls))
    return total / (b * n * chunk)


# ===========================================================================
# public entry points
# ===========================================================================

def embed_inputs(params: dict, batch: dict, cfg: ArchConfig, ctx: ShardCtx) -> jax.Array:
    """Tokens (+ optional stubbed media embeddings) -> (B, L, d)."""
    x = embed(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "media" in batch:
        x = jnp.concatenate([batch["media"].astype(x.dtype), x], axis=1)
    return constrain(x, ("batch", None, None), ctx)


def loss_fn(params: dict, batch: dict, cfg: ArchConfig, ctx: ShardCtx
            ) -> tuple[jax.Array, dict]:
    """batch: tokens (B, L), labels (B, L) [, media (B, M, d) | frames]."""
    enc_out = None
    if cfg.family in ("encdec", "audio"):
        enc_out = encoder_forward(params, batch["frames"].astype(
            jnp.dtype(cfg.dtype)), cfg, ctx)
    x = embed_inputs(params, batch, cfg, ctx)
    hidden, aux = backbone_train(params, x, cfg, ctx, enc_out=enc_out)
    if cfg.family == "vlm" and "media" in batch:
        hidden = hidden[:, batch["media"].shape[1]:]    # loss on text positions
    xent = chunked_xent(params, hidden, batch["labels"], cfg, ctx)
    loss = xent + 0.01 * aux
    return loss, {"xent": xent, "aux": aux}
