"""Attention: GQA with RoPE and optional qk-norm.

Three entry points:

* ``attention_train``  — full-sequence causal (or bidirectional) attention via
  **chunked online softmax** over KV blocks (lax.scan).  The L x L score
  matrix is never materialized: per scan step the live tile is
  (B, H, L, chunk) — this is what makes prefill_32k lowerable and is flash
  attention restructured for the MXU/VMEM rather than CUDA shared memory.
* ``attention_decode`` — one query token against a (B, S, KV, hd) cache.
  With the cache sequence-sharded over the ``data`` axis (long-context SP),
  the softmax reductions over S lower to all-reduces — XLA's SPMD partitioner
  derives the log-sum-exp combine automatically because the reduction is
  expressed as plain max/sum over the sharded dim.
* ``attention_cross``  — encoder-decoder cross attention (no causal mask).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import ParamSpec, apply_rope, rmsnorm
from repro.runtime.sharding import constrain

DEFAULT_KV_CHUNK = 512


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def attention_spec(cfg: ArchConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    spec = {
        "wq": ParamSpec((d, h, hd), ("fsdp", "tp", None)),
        "wk": ParamSpec((d, kv, hd), ("fsdp", "tp", None)),
        "wv": ParamSpec((d, kv, hd), ("fsdp", "tp", None)),
        "wo": ParamSpec((h, hd, d), ("tp", None, "fsdp"), fan_in_dims=(0, 1)),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        spec["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return spec


# ---------------------------------------------------------------------------
# shared projection helpers
# ---------------------------------------------------------------------------

def _project_qkv(params, x, kv_x, cfg: ArchConfig, ctx, positions,
                 kv_positions, rope: bool):
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", kv_x, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", kv_x, params["wv"])
    if cfg.qk_norm and "q_norm" in params:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "tp", None), ctx)
    k = constrain(k, ("batch", None, "tp", None), ctx)
    v = constrain(v, ("batch", None, "tp", None), ctx)
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd) for GQA."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (train / prefill / cross)
# ---------------------------------------------------------------------------

DEFAULT_Q_BLOCK = 4096


def _chunked_attention(q, k, v, *, causal: bool, q_offset: int,
                       kv_chunk: int, bf16_intermediates: bool = False,
                       q_block: int = DEFAULT_Q_BLOCK) -> jax.Array:
    """q: (B, Lq, H, hd), k/v: (B, Lk, KV, hd) — GROUPED GQA: KV heads are
    never expanded; query heads are reshaped to (KV, G) and contracted
    against the raw KV tensors (half the KV bytes of the repeat-KV
    formulation, and no sharded broadcast+reshape for the partitioner).

    Flash-style double tiling in pure XLA: the query axis is split into
    `q_block` tiles (python loop, static), and each tile online-softmax-scans
    only the KV chunks it can causally see — fully-masked (tile, chunk) pairs
    are never computed NOR written, which for causal attention halves both
    the score FLOPs and the dominant HBM score traffic (§Perf iteration 2).

    bf16_intermediates: scores/probabilities are written bf16; the running
    max/sum and the output accumulator stay fp32.
    """
    b, lq, h, hd = q.shape
    lk, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    kv_chunk = min(kv_chunk, lk)
    n_chunks = -(-lk // kv_chunk)
    pad = n_chunks * kv_chunk - lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cdt = jnp.bfloat16 if bf16_intermediates else jnp.float32
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qt = (q.astype(cdt) * cdt(scale)).reshape(b, lq, n_kv, g, hd) \
        .transpose(0, 2, 3, 1, 4)                                # (B,KV,G,Lq,hd)
    kt = k.transpose(0, 2, 3, 1).astype(cdt)                     # (B,KV,hd,Lk)
    vt = v.transpose(0, 2, 1, 3).astype(cdt)                     # (B,KV,Lk,hd)
    kt = kt.reshape(b, n_kv, hd, n_chunks, kv_chunk).transpose(3, 0, 1, 2, 4)
    vt = vt.reshape(b, n_kv, n_chunks, kv_chunk, hd).transpose(2, 0, 1, 3, 4)

    q_block = min(q_block, lq)
    n_qb = -(-lq // q_block)

    def attend_tile(q_tile, tile_start, tile_len, n_vis):
        """q_tile: (B,KV,G,tile_len,hd); scans its n_vis visible KV chunks."""
        q_pos = q_offset + tile_start + jnp.arange(tile_len)

        def step(carry, inp):
            m_prev, s_prev, acc = carry
            idx, kc, vc = inp
            scores = jnp.einsum("bkglh,bkhc->bkglc", q_tile, kc)   # cdt out
            kv_pos = idx * kv_chunk + jnp.arange(kv_chunk)
            if causal:
                mask = kv_pos[None, :] <= q_pos[:, None]
            else:
                mask = jnp.ones((tile_len, kv_chunk), bool)
            mask = mask & (kv_pos < lk)[None, :]                   # padding
            sc = jnp.where(mask[None, None, None],
                           scores.astype(jnp.float32), -jnp.inf)
            m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
            # guard fully-masked rows (m == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe,
                                     -jnp.inf))
            corr = jnp.where(jnp.isfinite(m_prev), corr, 0.0)
            s_new = s_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkglc,bkcd->bkgld", p.astype(cdt), vc,
                preferred_element_type=jnp.float32)
            return (m_new, s_new, acc), None

        init = (jnp.full((b, n_kv, g, tile_len), -jnp.inf, jnp.float32),
                jnp.zeros((b, n_kv, g, tile_len), jnp.float32),
                jnp.zeros((b, n_kv, g, tile_len, hd), jnp.float32))
        (m, s, acc), _ = jax.lax.scan(
            step, init, (jnp.arange(n_vis), kt[:n_vis], vt[:n_vis]))
        return acc / jnp.maximum(s, 1e-30)[..., None]

    outs = []
    for i in range(n_qb):
        start = i * q_block
        tl = min(q_block, lq - start)
        q_tile = jax.lax.dynamic_slice_in_dim(qt, start, tl, axis=3)
        if causal:
            n_vis = min(n_chunks, -(-(q_offset + start + tl) // kv_chunk))
        else:
            n_vis = n_chunks
        outs.append(attend_tile(q_tile, start, tl, max(n_vis, 1)))
    out = jnp.concatenate(outs, axis=3) if n_qb > 1 else outs[0]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, lq, h, hd).astype(q.dtype)


def attention_train(params: dict, x: jax.Array, cfg: ArchConfig, ctx,
                    *, causal: bool = True, kv_chunk: int | None = None
                    ) -> jax.Array:
    b, l, _ = x.shape
    positions = jnp.arange(l)
    q, k, v = _project_qkv(params, x, x, cfg, ctx, positions, positions, True)
    out = _chunked_attention(q, k, v, causal=causal, q_offset=0,
                             kv_chunk=kv_chunk or cfg.attn_kv_chunk,
                             bf16_intermediates=cfg.attn_bf16_intermediates)
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


def attention_cross(params: dict, x: jax.Array, enc_out: jax.Array,
                    cfg: ArchConfig, ctx, *, kv_chunk: int | None = None
                    ) -> jax.Array:
    lq, lk = x.shape[1], enc_out.shape[1]
    q, k, v = _project_qkv(params, x, enc_out, cfg, ctx,
                           jnp.arange(lq), jnp.arange(lk), False)
    out = _chunked_attention(q, k, v, causal=False, q_offset=0,
                             kv_chunk=kv_chunk or cfg.attn_kv_chunk,
                             bf16_intermediates=cfg.attn_bf16_intermediates)
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


# ---------------------------------------------------------------------------
# prefill (returns KV cache) and single-token decode
# ---------------------------------------------------------------------------

def attention_prefill(params: dict, x: jax.Array, cfg: ArchConfig, ctx,
                      *, kv_chunk: int | None = None):
    """Causal attention that also returns the (B, L, KV, hd) cache."""
    b, l, _ = x.shape
    positions = jnp.arange(l)
    q, k, v = _project_qkv(params, x, x, cfg, ctx, positions, positions, True)
    out = _chunked_attention(q, k, v, causal=True, q_offset=0,
                             kv_chunk=kv_chunk or cfg.attn_kv_chunk,
                             bf16_intermediates=cfg.attn_bf16_intermediates)
    out = jnp.einsum("blhk,hkd->bld", out, params["wo"])
    k = constrain(k, ("batch", "kv_seq", None, "kv_tp"), ctx)
    v = constrain(v, ("batch", "kv_seq", None, "kv_tp"), ctx)
    return out, (k, v)


def attention_cross_decode(params: dict, x: jax.Array, cross_cache: tuple,
                           cfg: ArchConfig, ctx) -> jax.Array:
    """Decode-time cross attention: q from x (B, 1, d) over a static
    (k, v) cache computed from the encoder output at prefill."""
    k_cache, v_cache = cross_cache
    b = x.shape[0]
    hd, h, n_kv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    g = h // n_kv
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    qg = q.reshape(b, 1, n_kv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bqkgh,bskh->bkgqs", (qg * scale).astype(jnp.float32),
                        k_cache.astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


def cross_cache_from_encoder(params: dict, enc_out: jax.Array) -> tuple:
    """Compute the static cross-attention (k, v) cache once at prefill."""
    k = jnp.einsum("bld,dhk->blhk", enc_out, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", enc_out, params["wv"])
    return k, v


def attention_decode(params: dict, x: jax.Array, cache: tuple, pos: jax.Array,
                     cfg: ArchConfig, ctx) -> tuple[jax.Array, tuple]:
    """x: (B, 1, d); cache: (k, v) each (B, S, KV, hd); pos: scalar int.

    The cache stays sequence-sharded ("kv_seq" -> data axis) for long-context
    decode; softmax reductions over S become all-reduces under SPMD.
    """
    b, _, _ = x.shape
    k_cache, v_cache = cache
    s = k_cache.shape[1]
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k_new = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v_new = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    if cfg.qk_norm and "q_norm" in params:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k_new = rmsnorm(k_new, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, jnp.full((1,), pos), cfg.rope_theta)
    k_new = apply_rope(k_new, jnp.full((1,), pos), cfg.rope_theta)

    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    k_cache = constrain(k_cache, ("batch", "kv_seq", None, "kv_tp"), ctx)
    v_cache = constrain(v_cache, ("batch", "kv_seq", None, "kv_tp"), ctx)

    hd, h, n_kv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    g = h // n_kv
    qg = q.reshape(b, 1, n_kv, g, hd)                    # grouped, no KV expand
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bqkgh,bskh->bkgqs", (qg * scale).astype(jnp.float32),
                        k_cache.astype(jnp.float32))     # (B, KV, G, 1, S)
    mask = jnp.arange(s)[None, None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    out = jnp.einsum("blhk,hkd->bld", out, params["wo"])
    return out, (k_cache, v_cache)
