"""Mixture-of-Experts layer: token-choice top-k routing, expert parallelism.

Two dispatch implementations, deliberately mirroring the paper's CompIM
insight (sparse structure lives in *indices*, not one-hot expansions):

* ``dense`` — the naive baseline: every expert processes every token and the
  outputs are combined with the (mostly-zero) router weights.  This is the
  one-hot datapath: correct, simple, and E/k times too much compute — the
  MoE analogue of the 1024-wire sparse-HDC baseline.  (A GShard (T, E, cap)
  one-hot dispatch einsum is the intermediate point; at 1M tokens x 64
  experts it is not even materializable, which we document rather than
  build — exactly like the paper drops the LUT-based shift binding.)

* ``index`` — the CompIM-domain implementation: tokens are *sorted by expert
  id* (positions!), capacity-sliced into a dense (E, cap, d) block, run
  through a block-diagonal expert einsum (experts sharded over the `tp`
  axis), and scattered back with router weights.  Compute drops to
  k/E + capacity slack; the collectives become the all-to-all-class
  patterns the §Perf loop inspects.

Router: softmax over experts, top-k, weights renormalized over the selected
experts; load-balancing auxiliary loss (Switch-style) returned to the
caller.  Dropped tokens (over capacity) fall back to the shared/zero path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import mlp, mlp_spec
from repro.models.params import ParamSpec
from repro.runtime.sharding import constrain


def moe_spec(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    eff = cfg.moe_d_ff or cfg.d_ff
    spec = {
        "router": ParamSpec((d, cfg.n_experts), ("fsdp", None), init="small"),
        "w_gate": ParamSpec((cfg.n_experts, d, eff), ("tp", "fsdp", None),
                            fan_in_dims=(1,)),
        "w_up": ParamSpec((cfg.n_experts, d, eff), ("tp", "fsdp", None),
                          fan_in_dims=(1,)),
        "w_down": ParamSpec((cfg.n_experts, eff, d), ("tp", None, "fsdp"),
                            fan_in_dims=(1,)),
    }
    if cfg.n_shared_experts:
        spec["shared"] = mlp_spec(d, cfg.n_shared_experts * eff)
    return spec


def _route(params, x_flat: jax.Array, cfg: ArchConfig):
    """x_flat: (T, d) -> (weights (T,k), ids (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x_flat, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32),
                       axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(density * mean_probs)
    return weights.astype(x_flat.dtype), ids, aux


def _experts_dense(params, x_flat: jax.Array, weights, ids, cfg: ArchConfig,
                   ctx) -> jax.Array:
    """Naive: all experts on all tokens, weighted combine."""
    combine = jnp.zeros((x_flat.shape[0], cfg.n_experts), x_flat.dtype)
    combine = jax.vmap(lambda c, i, w: c.at[i].add(w))(combine, ids, weights)

    def one_expert(wg, wu, wd):
        h = jax.nn.silu(x_flat @ wg) * (x_flat @ wu)
        return h @ wd                                      # (T, d)

    outs = jax.vmap(one_expert)(params["w_gate"], params["w_up"],
                                params["w_down"])          # (E, T, d)
    return jnp.einsum("etd,te->td", outs, combine)


def _experts_index(params, x_flat: jax.Array, weights, ids, cfg: ArchConfig,
                   ctx) -> jax.Array:
    """CompIM-domain dispatch: sort token indices by expert, capacity-slice,
    block-diagonal einsum over `tp`-sharded experts, weighted scatter-back."""
    t, d = x_flat.shape
    k, e = cfg.experts_per_token, cfg.n_experts
    cap = int(t * k / e * cfg.capacity_factor) + 1

    flat_ids = ids.reshape(-1)                             # (T*k,)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_ids)                          # stable
    sorted_ids = flat_ids[order]
    sorted_tok = order // k

    # position of each routed token within its expert's queue
    same = sorted_ids[:, None] == jnp.arange(e)            # (T*k, E) bool
    pos_in_e = (jnp.cumsum(same.astype(jnp.int32), axis=0) - 1)
    pos = jnp.take_along_axis(pos_in_e, sorted_ids[:, None], axis=1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, sorted_ids * cap + pos, e * cap)  # drop -> overflow row

    xs = jnp.take(x_flat, sorted_tok, axis=0)              # (T*k, d) gather
    disp = jnp.zeros((e * cap + 1, d), x_flat.dtype).at[slot].set(xs)
    disp = disp[:-1].reshape(e, cap, d)
    disp = constrain(disp, ("tp", None, None), ctx)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", disp, params["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_e = constrain(out_e, ("tp", None, None), ctx)

    flat_out = jnp.concatenate(
        [out_e.reshape(e * cap, d), jnp.zeros((1, d), out_e.dtype)], axis=0)
    gathered = jnp.take(flat_out, slot, axis=0)            # (T*k, d)
    contrib = gathered * (flat_w[order] * keep)[:, None]
    return jnp.zeros((t, d), x_flat.dtype).at[sorted_tok].add(contrib)


def _experts_local_index(params, x_flat: jax.Array, weights, ids,
                         cfg: ArchConfig, ctx) -> jax.Array:
    """DP-local index dispatch (the hillclimbed path, see EXPERIMENTS §Perf).

    The global-semantics `index` path sorts ALL tokens jointly: at 1M tokens
    x 512 devices the partitioner materializes global sort/cumsum traffic
    (hundreds of GB of collectives).  Real EP systems dispatch *per DP
    shard* with a local capacity.  We express that in pure pjit by reshaping
    tokens to (n_dp, T_loc, ...) — the leading dim sharded over the DP axes —
    and vmapping the local dispatch: every sort/cumsum/scatter becomes
    shard-local, and the only cross-device movement left is the
    (n_dp, E, cap_loc, d) dispatch block resharding from DP-sharded to
    expert-sharded (the all-to-all EP actually needs).
    """
    t, d = x_flat.shape
    n_dp = 1
    if ctx.mesh is not None:
        sizes = ctx.axis_sizes
        n_dp = int(np.prod([sizes[a] for a in ctx.rules.get("batch", ())])) or 1
    if t % n_dp:
        n_dp = 1
    t_loc = t // n_dp
    k, e = cfg.experts_per_token, cfg.n_experts
    cap = int(t_loc * k / e * cfg.capacity_factor) + 1
    xs = constrain(x_flat.reshape(n_dp, t_loc, d), ("batch", None, None), ctx)
    ws = weights.reshape(n_dp, t_loc, k)
    is_ = ids.reshape(n_dp, t_loc, k)

    def build(xf, w, i):
        """Per-DP-shard dispatch block (all ops shard-local under vmap)."""
        flat_ids = i.reshape(-1)
        order = jnp.argsort(flat_ids)
        sorted_ids = flat_ids[order]
        sorted_tok = order // k
        same = sorted_ids[:, None] == jnp.arange(e)
        pos_in_e = jnp.cumsum(same.astype(jnp.int32), axis=0) - 1
        pos = jnp.take_along_axis(pos_in_e, sorted_ids[:, None], axis=1)[:, 0]
        keep = pos < cap
        slot = jnp.where(keep, sorted_ids * cap + pos, e * cap)
        disp = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[sorted_tok])
        wgt = w.reshape(-1)[order] * keep
        return disp[:-1].reshape(e, cap, d), slot, wgt, sorted_tok

    disp, slot, wgt, sorted_tok = jax.vmap(build)(xs, ws, is_)
    # the ONLY cross-device movement: DP-sharded dispatch -> expert-sharded
    disp = constrain(disp, ("batch", "tp", None, None), ctx)
    h = jax.nn.silu(jnp.einsum("secd,edf->secf", disp, params["w_gate"]))
    h = h * jnp.einsum("secd,edf->secf", disp, params["w_up"])
    out_e = jnp.einsum("secf,efd->secd", h, params["w_down"])
    out_e = constrain(out_e, ("batch", "tp", None, None), ctx)

    def gather_back(oe, sl, wg, st):
        flat = jnp.concatenate([oe.reshape(e * cap, d),
                                jnp.zeros((1, d), oe.dtype)], axis=0)
        contrib = jnp.take(flat, sl, axis=0) * wg[:, None]
        return jnp.zeros((t_loc, d), oe.dtype).at[st].add(contrib)

    out = jax.vmap(gather_back)(out_e, slot, wgt, sorted_tok)
    return constrain(out, ("batch", None, None), ctx).reshape(t, d)


def moe_layer(params: dict, x: jax.Array, cfg: ArchConfig, ctx
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (out, aux_loss)."""
    b, l, d = x.shape
    x_flat = x.reshape(b * l, d)
    weights, ids, aux = _route(params, x_flat, cfg)
    if cfg.moe_dispatch == "dense":
        out = _experts_dense(params, x_flat, weights, ids, cfg, ctx)
    elif cfg.moe_dispatch == "index":
        out = _experts_index(params, x_flat, weights, ids, cfg, ctx)
    elif cfg.moe_dispatch == "local_index":
        out = _experts_local_index(params, x_flat, weights, ids, cfg, ctx)
    else:
        raise ValueError(cfg.moe_dispatch)
    if "shared" in params:
        out = out + mlp(params["shared"], x_flat)
    return out.reshape(b, l, d), aux
