"""Serving paths: prefill (build caches) and single-token decode.

Cache layouts (stacked over layers so decode scans them):

  dense/vlm : {"k","v"}           (n_layers, B, S, KV, hd)
  moe       : {"dense": {...}, "moe": {...}} per sub-stack
  ssm       : {"ssm", "conv"}     (n_layers, B, di, st) / (n_layers, B, k-1, di)
  hybrid    : per period-block: {"k","v"} (n_blocks, B, S, KV, hd) for the
              attention sublayer + stacked mamba states (n_blocks, p-1, ...)
  encdec    : {"k","v"} decoder self + {"ck","cv"} static cross caches

``decode_*`` shapes lower decode_step (one token against a seq_len cache),
``prefill_*`` lowers prefill.  Caches are sharded via logical axes
("batch", "kv_seq", None, "kv_tp") — the ShardCtx decides whether batch-DP or
sequence-parallel KV applies (see runtime/sharding.py).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.config import ArchConfig
from repro.models.layers import embed, mlp, rmsnorm, unembed
from repro.models.model import encoder_forward
from repro.runtime.sharding import ShardCtx, constrain


# ===========================================================================
# cache structure
# ===========================================================================

def _kv_struct(cfg: ArchConfig, n: int, batch: int, seq: int, dtype):
    hd = cfg.resolved_head_dim
    return jnp.zeros((n, batch, seq, cfg.n_kv_heads, hd), dtype)


def init_caches(cfg: ArchConfig, batch: int, seq: int, dtype,
                enc_len: int = 0) -> Any:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"k": _kv_struct(cfg, cfg.n_layers, batch, seq, dtype),
                "v": _kv_struct(cfg, cfg.n_layers, batch, seq, dtype)}
    if fam == "moe":
        n_moe = cfg.n_layers - cfg.first_k_dense
        out = {"moe": {"k": _kv_struct(cfg, n_moe, batch, seq, dtype),
                       "v": _kv_struct(cfg, n_moe, batch, seq, dtype)}}
        if cfg.first_k_dense:
            out["dense"] = {"k": _kv_struct(cfg, cfg.first_k_dense, batch, seq, dtype),
                            "v": _kv_struct(cfg, cfg.first_k_dense, batch, seq, dtype)}
        return out
    if fam == "ssm":
        n = cfg.n_layers
        return {"ssm": jnp.zeros((n, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)}
    if fam == "hybrid":
        nb = cfg.n_layers // cfg.attn_period
        p = cfg.attn_period
        return {"k": _kv_struct(cfg, nb, batch, seq, dtype),
                "v": _kv_struct(cfg, nb, batch, seq, dtype),
                "ssm": jnp.zeros((nb, p - 1, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((nb, p - 1, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)}
    if fam in ("encdec", "audio"):
        return {"k": _kv_struct(cfg, cfg.n_layers, batch, seq, dtype),
                "v": _kv_struct(cfg, cfg.n_layers, batch, seq, dtype),
                "ck": _kv_struct(cfg, cfg.n_layers, batch, enc_len, dtype),
                "cv": _kv_struct(cfg, cfg.n_layers, batch, enc_len, dtype)}
    raise ValueError(fam)


def _pad_cache(k: jax.Array, v: jax.Array, seq: int, ctx: ShardCtx):
    """Grow (B, L, KV, hd) prefill K/V to the full (B, seq, KV, hd) cache."""
    pad = seq - k.shape[1]
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k = constrain(k, ("batch", "kv_seq", None, "kv_tp"), ctx)
    v = constrain(v, ("batch", "kv_seq", None, "kv_tp"), ctx)
    return k, v


# ===========================================================================
# per-layer decode applications
# ===========================================================================

def _dec_dense_layer(lp, x, kc, vc, pos, cfg, ctx):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a, (kc, vc) = attn.attention_decode(lp["attn"], h, (kc, vc), pos, cfg, ctx)
    x = x + a
    x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x, kc, vc


def _dec_moe_layer(lp, x, kc, vc, pos, cfg, ctx):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a, (kc, vc) = attn.attention_decode(lp["attn"], h, (kc, vc), pos, cfg, ctx)
    x = x + a
    out, _ = moe_mod.moe_layer(lp["moe"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg, ctx)
    return x + out, kc, vc


def _dec_mamba_layer(lp, x, state, cfg, ctx):
    h = rmsnorm(x, lp["ln"], cfg.norm_eps)
    out, state = mb.mamba_decode(lp["mamba"], h, state, cfg, ctx)
    return x + out, state


# ===========================================================================
# prefill
# ===========================================================================

def prefill(params: dict, batch: dict, cfg: ArchConfig, ctx: ShardCtx,
            cache_seq: int) -> tuple[jax.Array, Any]:
    """Run the full prompt, return (last-position logits (B, V), caches).

    batch: tokens (B, L) [, media (B, M, d) | frames (B, Le, d)].
    """
    fam = cfg.family
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], batch["tokens"])
    if fam == "vlm" and "media" in batch:
        x = jnp.concatenate([batch["media"].astype(x.dtype), x], axis=1)
    x = constrain(x, ("batch", None, None), ctx)
    enc_out = None
    if fam in ("encdec", "audio"):
        enc_out = encoder_forward(params, batch["frames"].astype(dtype), cfg, ctx)

    def prefill_dense_stack(stacked, x):
        def step(h, lp):
            a, (k, v) = attn.attention_prefill(lp["attn"],
                                               rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, ctx)
            h = h + a
            if "mlp" in lp:
                h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            else:
                out, _ = moe_mod.moe_layer(lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg, ctx)
                h = h + out
            h = constrain(h, ("batch", None, None), ctx)
            kp, vp = _pad_cache(k, v, cache_seq, ctx)
            return h, (kp.astype(dtype), vp.astype(dtype))
        if cfg.remat:
            step = jax.checkpoint(step)
        return jax.lax.scan(step, x, stacked)

    caches: Any
    if fam in ("dense", "vlm"):
        x, (ks, vs) = prefill_dense_stack(params["layers"], x)
        caches = {"k": ks, "v": vs}
    elif fam == "moe":
        caches = {}
        if cfg.first_k_dense:
            x, (kd, vd) = prefill_dense_stack(params["dense_layers"], x)
            caches["dense"] = {"k": kd, "v": vd}
        x, (km, vm) = prefill_dense_stack(params["layers"], x)
        caches["moe"] = {"k": km, "v": vm}
    elif fam == "ssm":
        def step(h, lp):
            out, st = mb.mamba_prefill(lp["mamba"],
                                       rmsnorm(h, lp["ln"], cfg.norm_eps), cfg, ctx)
            return constrain(h + out, ("batch", None, None), ctx), st
        if cfg.remat:
            step = jax.checkpoint(step)
        x, sts = jax.lax.scan(step, x, params["layers"])
        caches = {"ssm": sts["ssm"], "conv": sts["conv"].astype(dtype)}
    elif fam == "hybrid":
        p = cfg.attn_period

        def block_step(h, bp):
            sub = bp["attn"]
            a, (k, v) = attn.attention_prefill(sub["attn"],
                                               rmsnorm(h, sub["ln"], cfg.norm_eps), cfg, ctx)
            h = h + a
            ssm_states, conv_states = [], []
            mlp_i = moe_i = 0
            for j in range(p):
                if j > 0:
                    s = jax.tree.map(lambda a_: a_[j - 1], bp["mamba"])
                    out, st = mb.mamba_prefill(s["mamba"],
                                               rmsnorm(h, s["ln"], cfg.norm_eps), cfg, ctx)
                    h = h + out
                    ssm_states.append(st["ssm"])
                    conv_states.append(st["conv"])
                if j % 2 == 1:
                    s = jax.tree.map(lambda a_: a_[moe_i], bp["moe"])
                    out, _ = moe_mod.moe_layer(
                        s["moe"], rmsnorm(h, s["ln"], cfg.norm_eps), cfg, ctx)
                    h = h + out
                    moe_i += 1
                else:
                    s = jax.tree.map(lambda a_: a_[mlp_i], bp["mlp"])
                    h = h + mlp(s["mlp"], rmsnorm(h, s["ln"], cfg.norm_eps))
                    mlp_i += 1
                h = constrain(h, ("batch", None, None), ctx)
            kp, vp = _pad_cache(k, v, cache_seq, ctx)
            return h, (kp.astype(dtype), vp.astype(dtype),
                       jnp.stack(ssm_states), jnp.stack(conv_states).astype(dtype))

        if cfg.remat:
            block_step = jax.checkpoint(block_step)
        x, (ks, vs, ssms, convs) = jax.lax.scan(block_step, x, params["blocks"])
        caches = {"k": ks, "v": vs, "ssm": ssms, "conv": convs}
    elif fam in ("encdec", "audio"):
        def step(h, lp):
            a, (k, v) = attn.attention_prefill(lp["attn"],
                                               rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg, ctx)
            h = h + a
            h = h + attn.attention_cross(lp["cross"], rmsnorm(h, lp["ln_x"], cfg.norm_eps),
                                         enc_out, cfg, ctx)
            h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            h = constrain(h, ("batch", None, None), ctx)
            ck, cv = attn.cross_cache_from_encoder(lp["cross"], enc_out)
            kp, vp = _pad_cache(k, v, cache_seq, ctx)
            return h, (kp.astype(dtype), vp.astype(dtype),
                       ck.astype(dtype), cv.astype(dtype))
        if cfg.remat:
            step = jax.checkpoint(step)
        x, (ks, vs, cks, cvs) = jax.lax.scan(step, x, params["layers"])
        caches = {"k": ks, "v": vs, "ck": cks, "cv": cvs}
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, x[:, -1], tied=cfg.tie_embeddings)
    return constrain(logits, ("batch", "tp"), ctx), caches


# ===========================================================================
# decode
# ===========================================================================

def decode_step(params: dict, tokens: jax.Array, caches: Any, pos: jax.Array,
                cfg: ArchConfig, ctx: ShardCtx) -> tuple[jax.Array, Any]:
    """tokens: (B, 1) -> (logits (B, V), updated caches)."""
    fam = cfg.family
    x = embed(params["embed"], tokens)
    x = constrain(x, ("batch", None, None), ctx)

    def dec_dense_stack(stacked, cache, x):
        def step(h, inp):
            lp, kc, vc = inp
            h, kc, vc = (_dec_moe_layer if "moe" in lp else _dec_dense_layer)(
                lp, h, kc, vc, pos, cfg, ctx)
            return constrain(h, ("batch", None, None), ctx), (kc, vc)
        return jax.lax.scan(step, x, (stacked, cache["k"], cache["v"]))

    if fam in ("dense", "vlm"):
        x, (ks, vs) = dec_dense_stack(params["layers"], caches, x)
        new_caches = {"k": ks, "v": vs}
    elif fam == "moe":
        new_caches = {}
        if cfg.first_k_dense:
            x, (kd, vd) = dec_dense_stack(params["dense_layers"], caches["dense"], x)
            new_caches["dense"] = {"k": kd, "v": vd}
        x, (km, vm) = dec_dense_stack(params["layers"], caches["moe"], x)
        new_caches["moe"] = {"k": km, "v": vm}
    elif fam == "ssm":
        def step(h, inp):
            lp, ssm, conv = inp
            h, st = _dec_mamba_layer(lp, h, {"ssm": ssm, "conv": conv}, cfg, ctx)
            return constrain(h, ("batch", None, None), ctx), (st["ssm"], st["conv"])
        x, (ssms, convs) = jax.lax.scan(
            step, x, (params["layers"], caches["ssm"], caches["conv"]))
        new_caches = {"ssm": ssms, "conv": convs}
    elif fam == "hybrid":
        p = cfg.attn_period

        def block_step(h, inp):
            bp, kc, vc, ssm, conv = inp
            sub = bp["attn"]
            a, (kc, vc) = attn.attention_decode(
                sub["attn"], rmsnorm(h, sub["ln"], cfg.norm_eps), (kc, vc), pos, cfg, ctx)
            h = h + a
            ssm_new, conv_new = [], []
            mlp_i = moe_i = 0
            for j in range(p):
                if j > 0:
                    s = jax.tree.map(lambda a_: a_[j - 1], bp["mamba"])
                    h2, st = _dec_mamba_layer(
                        s, h, {"ssm": ssm[j - 1], "conv": conv[j - 1]}, cfg, ctx)
                    h = h2
                    ssm_new.append(st["ssm"])
                    conv_new.append(st["conv"])
                if j % 2 == 1:
                    s = jax.tree.map(lambda a_: a_[moe_i], bp["moe"])
                    out, _ = moe_mod.moe_layer(
                        s["moe"], rmsnorm(h, s["ln"], cfg.norm_eps), cfg, ctx)
                    h = h + out
                    moe_i += 1
                else:
                    s = jax.tree.map(lambda a_: a_[mlp_i], bp["mlp"])
                    h = h + mlp(s["mlp"], rmsnorm(h, s["ln"], cfg.norm_eps))
                    mlp_i += 1
                h = constrain(h, ("batch", None, None), ctx)
            return h, (kc, vc, jnp.stack(ssm_new), jnp.stack(conv_new))

        x, (ks, vs, ssms, convs) = jax.lax.scan(
            block_step, x,
            (params["blocks"], caches["k"], caches["v"], caches["ssm"], caches["conv"]))
        new_caches = {"k": ks, "v": vs, "ssm": ssms, "conv": convs}
    elif fam in ("encdec", "audio"):
        def step(h, inp):
            lp, kc, vc, ck, cv = inp
            a, (kc, vc) = attn.attention_decode(
                lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), (kc, vc), pos, cfg, ctx)
            h = h + a
            h = h + attn.attention_cross_decode(
                lp["cross"], rmsnorm(h, lp["ln_x"], cfg.norm_eps), (ck, cv), cfg, ctx)
            h = h + mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return constrain(h, ("batch", None, None), ctx), (kc, vc)
        x, (ks, vs) = jax.lax.scan(
            step, x, (params["layers"], caches["k"], caches["v"],
                      caches["ck"], caches["cv"]))
        new_caches = {"k": ks, "v": vs, "ck": caches["ck"], "cv": caches["cv"]}
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, x[:, 0], tied=cfg.tie_embeddings)
    return constrain(logits, ("batch", "tp"), ctx), new_caches
