"""Common transformer layers: RMSNorm, RoPE, SwiGLU MLP, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="ones")


def mlp_spec(d: int, ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d, ff), ("fsdp", "tp")),
        "w_up": ParamSpec((d, ff), ("fsdp", "tp")),
        "w_down": ParamSpec((ff, d), ("tp", "fsdp")),
    }


def embed_spec(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("tp", "fsdp"), init="embed")


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def mlp(params: dict, x: jax.Array) -> jax.Array:
    """SwiGLU MLP; TP: gate/up column-sharded, down row-sharded."""
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(gate) * up, params["w_down"])


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, L, H, hd); positions: (L,) or (B, L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., L, hd/2)
    if angles.ndim == 2:                                 # (L, hd/2) -> broadcast B
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]                  # (B, L, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jax.Array, x: jax.Array, *, tied: bool) -> jax.Array:
    """Logits; tied => table is (V, d), else head is (d, V)."""
    if tied:
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)
