"""Mamba-1 selective SSM layer (falcon-mamba-7b, jamba hybrid).

Structure (Gu & Dao 2023): in_proj -> (x, z); causal depthwise conv (k=4);
SiLU; data-dependent (dt, B, C); selective state-space scan over time with
diagonal A; gate by SiLU(z); out_proj.

Training/prefill uses an **associative scan** over the time axis (the
recurrence h_t = a_t * h_{t-1} + b_t is a linear first-order recurrence, so
``jax.lax.associative_scan`` gives O(L log L) work with O(log L) depth —
the TPU-native counterpart of the CUDA chunked-scan kernel).
Decode keeps the (B, d_inner, d_state) state and a (B, d_inner, k-1) conv
tail and advances one step per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamSpec
from repro.runtime.sharding import constrain


def mamba_spec(cfg: ArchConfig) -> dict:
    d, di, st, dtr, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.dt_rank, cfg.ssm_conv)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("fsdp", "tp")),
        "conv_w": ParamSpec((k, di), (None, "tp")),
        "conv_b": ParamSpec((di,), ("tp",), init="zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * st), ("tp", None)),
        "dt_proj_w": ParamSpec((dtr, di), (None, "tp")),
        "dt_proj_b": ParamSpec((di,), ("tp",), init="ones"),
        "a_log": ParamSpec((di, st), ("tp", None), init="ones"),
        "d_skip": ParamSpec((di,), ("tp",), init="ones"),
        "out_proj": ParamSpec((di, d), ("tp", "fsdp")),
    }


def _ssm_inputs(params, xc, cfg: ArchConfig, mask=None):
    """xc: (B, L, di) post-conv activations -> dA (B,L,di,st), dBx, C.

    mask: optional (L,) validity; masked steps get dt=0 => da=1, dbx=0,
    i.e. the recurrence passes the state through unchanged (used so padded
    prefill steps cannot contaminate the final decode state)."""
    st, dtr = cfg.ssm_state, cfg.dt_rank
    proj = jnp.einsum("bld,de->ble", xc, params["x_proj"])
    dt, b, c = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("blr,rd->bld", dt, params["dt_proj_w"])
                         + params["dt_proj_b"])                    # (B,L,di)
    if mask is not None:
        dt = dt * mask[None, :, None].astype(dt.dtype)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # (di, st)
    da = jnp.exp(dt[..., None].astype(jnp.float32) * a)            # (B,L,di,st)
    dbx = (dt[..., None] * b[..., None, :]).astype(jnp.float32) * \
        xc[..., None].astype(jnp.float32)                          # (B,L,di,st)
    return da, dbx, c.astype(jnp.float32)


def _conv_train(params, x: jax.Array, k: int) -> jax.Array:
    """Causal depthwise conv over time: x (B, L, di)."""
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * params["conv_w"][i] for i in range(k))
    return out + params["conv_b"]


SSM_CHUNK = 256  # time chunk: bounds the live (B, Q, di, st) state expansion


def _combine(left, right):
    """Associative combinator of the linear recurrence h' = a*h + b."""
    a_l, b_l = left
    a_r, b_r = right
    return a_l * a_r, b_l * a_r + b_r


def mamba_train(params: dict, x: jax.Array, cfg: ArchConfig, ctx) -> jax.Array:
    """Full-sequence selective scan. x: (B, L, d) -> (B, L, d).

    The (B, L, di, st) expanded state NEVER materializes: time is split into
    SSM_CHUNK blocks; within a block the recurrence is an associative scan
    (O(log Q) depth on the VPU), across blocks a sequential lax.scan carries
    the (B, di, st) boundary state — the TPU equivalent of Mamba's chunked
    CUDA kernel (recompute-free because per-chunk inputs are re-derived from
    the small (B, Q, di) conv activations inside the scan body).
    """
    b, l, _ = x.shape
    xi = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xr, z = jnp.split(xi, 2, axis=-1)                              # (B,L,di)
    xr = constrain(xr, ("batch", None, "tp"), ctx)
    xc = jax.nn.silu(_conv_train(params, xr, cfg.ssm_conv))

    q = min(cfg.ssm_chunk or SSM_CHUNK, l)
    n_chunks = -(-l // q)
    pad = n_chunks * q - l
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    xcc = xc.reshape(b, n_chunks, q, cfg.d_inner).transpose(1, 0, 2, 3)

    def chunk_step(h0, xc_chunk):                                  # (B,Q,di)
        da, dbx, c = _ssm_inputs(params, xc_chunk, cfg)            # (B,Q,di,st)
        cum_a, s = jax.lax.associative_scan(_combine, (da, dbx), axis=1)
        h = cum_a * h0[:, None] + s                                # seed carry
        y = jnp.einsum("blds,bls->bld", h, c)                      # (B,Q,di)
        return h[:, -1], y

    if cfg.ssm_checkpoint_chunks:
        chunk_step = jax.checkpoint(chunk_step)
    h0 = jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, xcc)                      # (K,B,Q,di)
    y = ys.transpose(1, 0, 2, 3).reshape(b, n_chunks * q, cfg.d_inner)[:, :l]
    y = y + xc[:, :l].astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return jnp.einsum("ble,ed->bld", y, params["out_proj"])


def mamba_prefill(params: dict, x: jax.Array, cfg: ArchConfig, ctx
                  ) -> tuple[jax.Array, dict]:
    """Full-sequence scan that also returns the decode state: the final
    (B, di, st) SSM state and the last k-1 pre-conv activations."""
    b, l, _ = x.shape
    xi = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xr, z = jnp.split(xi, 2, axis=-1)
    xr = constrain(xr, ("batch", None, "tp"), ctx)
    xc = jax.nn.silu(_conv_train(params, xr, cfg.ssm_conv))

    q = min(SSM_CHUNK, l)
    n_chunks = -(-l // q)
    pad = n_chunks * q - l
    xcp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0))) if pad else xc
    xcc = xcp.reshape(b, n_chunks, q, cfg.d_inner).transpose(1, 0, 2, 3)
    # padded steps get dt=0 (state pass-through) so h_last == h at t = l-1
    valid = (jnp.arange(n_chunks * q) < l).astype(jnp.float32)
    masks = valid.reshape(n_chunks, q)

    def chunk_step(h0, inp):
        xc_chunk, m = inp
        da, dbx, c = _ssm_inputs(params, xc_chunk, cfg, mask=m)
        cum_a, s = jax.lax.associative_scan(_combine, (da, dbx), axis=1)
        h = cum_a * h0[:, None] + s
        y = jnp.einsum("blds,bls->bld", h, c)
        return h[:, -1], y

    h0 = jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (xcc, masks))
    y = ys.transpose(1, 0, 2, 3).reshape(b, n_chunks * q, cfg.d_inner)[:, :l]
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    k = cfg.ssm_conv
    conv_tail = jax.lax.dynamic_slice_in_dim(xr, l - (k - 1), k - 1, axis=1)
    state = {"ssm": h_last, "conv": conv_tail}
    return out, state


def mamba_init_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    return {
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(params: dict, x: jax.Array, state: dict, cfg: ArchConfig,
                 ctx) -> tuple[jax.Array, dict]:
    """One token step. x: (B, 1, d); state: {"ssm", "conv"}."""
    xi = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xr, z = jnp.split(xi, 2, axis=-1)                              # (B,1,di)
    window = jnp.concatenate([state["conv"], xr], axis=1)          # (B,k,di)
    xc = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)[:, None]                                  # (B,1,di)
    da, dbx, c = _ssm_inputs(params, xc, cfg)
    h = state["ssm"] * da[:, 0] + dbx[:, 0]                        # (B,di,st)
    y = jnp.einsum("bds,bs->bd", h, c[:, 0])[:, None]              # (B,1,di)
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    new_state = {"ssm": h, "conv": window[:, 1:]}
    return out, new_state
