"""Unified architecture configuration for the assigned model zoo.

One dataclass covers all six families (dense / moe / ssm / hybrid / encdec /
vlm / audio); family-specific fields are zero/None when unused.  Exact
figures for each assigned architecture live in ``repro/configs/<id>.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 => attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qk_norm: bool = False
    attn_bias: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim (fine-grained MoE)
    moe_period: int = 1          # MoE every `moe_period` layers
    first_k_dense: int = 0       # leading dense layers (deepseek-moe: 1)
    moe_dispatch: str = "index"  # "index" (optimized) | "dense" (naive baseline)
    capacity_factor: float = 1.25

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0         # 0 => ceil(d_model / 16)
    ssm_chunk: int = 256         # time tile of the chunked selective scan
    ssm_checkpoint_chunks: bool = False  # remat each chunk (§Perf: bounds the
                                         # assoc-scan bwd tree working set)

    # --- hybrid (jamba): one attention layer per `attn_period` layers ---
    attn_period: int = 0

    # --- encoder-decoder ---
    enc_layers: int = 0          # >0 => encdec; n_layers = decoder layers
    cross_attention: bool = False

    # --- modality frontend stubs ---
    frontend: str | None = None  # "vit_stub" | "audio_stub"
    num_media_tokens: int = 256

    # --- common ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # §Perf knob: keep chunked-attention probabilities/accumulator in bf16
    # (fp32 running max/sum retained) — halves the dominant HBM term of
    # long-context prefill at <1e-2 relative error (see EXPERIMENTS §Perf)
    attn_bf16_intermediates: bool = False
    attn_kv_chunk: int = 512     # KV tile of chunked attention (§Perf: larger
                                 # tiles amortize accumulator read/write rounds)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can run 500k-token decode (ssm / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(2, self.attn_period or 2) if self.family == "hybrid" else 2,
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16 if self.n_heads else 0,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=32 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 8),
            enc_layers=2 if self.enc_layers else 0,
            num_media_tokens=8 if self.frontend else 0,
            dtype="float32",
            remat=False,
            # avoid MoE capacity drops at smoke-test batch sizes (drops are a
            # batch-composition effect, not what smoke tests should assert on)
            capacity_factor=8.0,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def param_count(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter estimates (embedding included)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d

    def mlp(f):
        return 3 * d * f  # gated SwiGLU

    mamba = (d * 2 * cfg.d_inner + cfg.d_inner * cfg.ssm_conv
             + cfg.d_inner * (cfg.dt_rank + 2 * cfg.ssm_state)
             + cfg.dt_rank * cfg.d_inner + cfg.d_inner * cfg.ssm_state
             + cfg.d_inner * d) if cfg.ssm_state else 0

    total = active = 0
    n_attn_layers = 0
    for layer in range(cfg.n_layers):
        is_attn = (cfg.family != "ssm") and (
            cfg.attn_period == 0 or layer % cfg.attn_period == 0)
        mixer = attn if is_attn else mamba
        if cfg.family == "ssm":
            mixer = mamba
        n_attn_layers += is_attn
        is_moe = (cfg.is_moe and layer >= cfg.first_k_dense
                  and (layer % cfg.moe_period == cfg.moe_period - 1 or cfg.moe_period == 1))
        if is_moe:
            eff = cfg.moe_d_ff or ff
            tot_ffn = (cfg.n_experts * mlp(eff)
                       + cfg.n_shared_experts * mlp(eff) + d * cfg.n_experts)
            act_ffn = (cfg.experts_per_token * mlp(eff)
                       + cfg.n_shared_experts * mlp(eff) + d * cfg.n_experts)
        elif ff:
            tot_ffn = act_ffn = mlp(ff)
        else:
            tot_ffn = act_ffn = 0
        total += mixer + tot_ffn
        active += mixer + act_ffn
    enc = cfg.enc_layers * (attn + mlp(ff)) if cfg.enc_layers else 0
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return total + enc + emb, active + enc + emb
