"""Parameter specification system: declare each tensor once (shape + logical
axes + init), derive everything else (random init for smoke tests, abstract
ShapeDtypeStructs for the dry-run, NamedShardings for pjit) from the spec.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple            # logical axis per dim (see runtime/sharding.py)
    init: str = "normal"   # normal | zeros | ones | embed | small
    fan_in_dims: tuple[int, ...] = ()   # dims whose product is fan-in (normal)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec_leaves(tree: Any):
    return jax.tree.leaves(tree, is_leaf=lambda s: isinstance(s, ParamSpec))


def abstract(tree: Any, dtype) -> Any:
    """ParamSpec tree -> ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        tree, is_leaf=lambda s: isinstance(s, ParamSpec))


def initialize(key: jax.Array, tree: Any, dtype) -> Any:
    """ParamSpec tree -> concrete random params (smoke tests / examples)."""
    leaves = spec_leaves(tree)
    keys = jax.random.split(key, len(leaves))
    it = iter(range(len(leaves)))

    def init_one(s: ParamSpec):
        k = keys[next(it)]
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        if s.init == "embed":
            return (jax.random.normal(k, s.shape) * 0.02).astype(dtype)
        fan_in = (np.prod([s.shape[d] for d in s.fan_in_dims])
                  if s.fan_in_dims else s.shape[0])
        scale = 1.0 / math.sqrt(max(float(fan_in), 1.0))
        if s.init == "small":
            scale *= 0.1
        return (jax.random.normal(k, s.shape) * scale).astype(dtype)

    return jax.tree.map(init_one, tree, is_leaf=lambda s: isinstance(s, ParamSpec))


def count_params(tree: Any) -> int:
    return sum(int(np.prod(s.shape)) for s in spec_leaves(tree))


def stack_layers(n: int, spec: Any) -> Any:
    """Prepend a scan (layer) dim to every ParamSpec in `spec`."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), (None, *s.axes), s.init,
                            tuple(d + 1 for d in s.fan_in_dims)),
        spec, is_leaf=lambda s: isinstance(s, ParamSpec))
