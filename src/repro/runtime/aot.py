"""Ahead-of-time compilation: serialized fleet/engine executables + cache.

Every bucket shape of the serving step pays ~0.8-1.9 s of trace+compile on
its first push (the ``fleet.S*.fleet_compile`` rows in BENCH_fleet.json), so
a restarted or autoscaled worker stalls its whole tile set before emitting
its first decision.  This module kills that cold-start tax with two stacked
mechanisms, both exercised by ``benchmarks/bench_coldstart.py``:

* **Serialized XLA executables** (``jax.experimental.serialize_executable``
  over the donation-free export-wrapped program).  ``save_artifact``
  enumerates the executable set of a fleet/engine (every variant x backend
  x bucket x tile shape, including the faulted and adapt steps — the
  producers declare their own set via ``StreamingFleet.aot_entries()`` /
  ``ServingEngine.aot_entries()``), compiles each and ships the PjRt
  executable itself (``entries/*.xlaexec``).  A worker that loads the
  artifact skips BOTH Python tracing and XLA compilation:
  ``AOTArtifact.compile`` unpickles and loads the binary — milliseconds.
* **Serialized StableHLO** (``jax.export``, ``entries/*.jaxexport``).  The
  portable middle tier: when the executable is absent, signature-mismatched
  or unloadable on this backend, the exported program is deserialized and
  recompiled — tracing is still skipped, XLA compile is paid once.
* **Persistent compilation cache.**  ``save_artifact`` also pre-COMPILES
  every entry with JAX's persistent compilation cache pointed into the
  artifact (``<dir>/xla_cache``), so the XLA executables themselves ship
  with it.  The cache serves plain-JIT restarts: point
  ``compilation_cache(<dir>/xla_cache)`` (or ``load_artifact(...,
  enable_cache=True)``) at it and a re-trace's compile becomes a disk hit
  instead of an XLA compile.  CI persists the same directory across
  Cache use is opt-in and must stay scoped: jaxlib 0.4.3x's persistent
  cache corrupts the heap (glibc abort / segfault) when enabled around the
  donated fleet-step program — on cache WRITES as well as hits — so
  nothing in this module leaves the cache enabled implicitly, and CI jobs
  set no ``JAX_COMPILATION_CACHE_DIR`` (the executable tiers above are
  unaffected: they never touch the cache).

Artifacts are **versioned**: the manifest records ``artifact_key()`` — the
jax version, the device kind, and a hash of the kernel/serving sources
(``kernel_fingerprint``).  ``load_artifact`` compares that key against the
running environment and returns ``None`` on any mismatch (with a warning),
so consumers fall back to plain JIT instead of running stale executables;
``ckpt/checkpoint.py`` records the same key in its manifest ``aot`` entry,
giving checkpoints a validity pointer to their executables
(``StreamingFleet.from_artifact`` threads it back through here).

Layout of one artifact directory::

    <dir>/manifest.json         {"version", "key", "entries": [...]}
    <dir>/entries/e_00000.xlaexec   pickled PjRt executable + arg/out trees
    <dir>/entries/e_00000.jaxexport serialized StableHLO (jax.export)
    <dir>/xla_cache/...         persistent-compilation-cache files

Everything degrades gracefully: a missing directory, an unreadable blob, a
stale key, or a jax build without ``jax.export`` serialization all fall back
to JIT — AOT is an optimization, never a correctness dependency (the AOT and
JIT paths are bit-exact; tests/test_aot.py pins this per variant/backend).
"""

from __future__ import annotations

import glob
import hashlib
import json
import os
import pickle
import time
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import numpy as np

try:  # jax.export with pytree serialization (>= 0.4.36); degrade without it
    from jax import export as _jax_export

    _HAVE_EXPORT = hasattr(_jax_export, "register_pytree_node_serialization")
except Exception:  # pragma: no cover - import-level environment guard
    _jax_export = None
    _HAVE_EXPORT = False

try:  # PjRt compiled-executable pickling; degrade to StableHLO + recompile
    from jax.experimental import serialize_executable as _jax_se

    _HAVE_EXEC = hasattr(_jax_se, "deserialize_and_load")
except Exception:  # pragma: no cover - import-level environment guard
    _jax_se = None
    _HAVE_EXEC = False

MANIFEST = "manifest.json"
ENTRY_DIR = "entries"
XLA_CACHE_DIR = "xla_cache"
ARTIFACT_VERSION = 1

# the sources whose edits change the serving programs: the kernels, the
# serving layers that assemble them into the jitted step, and the core
# primitives they call.  Anything else (benchmarks, launchers, models/)
# cannot change an executable, so it does not invalidate artifacts.
_FINGERPRINT_SUBDIRS = ("kernels", "serve", "core", "reliability", "runtime")


def _repro_root() -> str:
    import repro

    if getattr(repro, "__file__", None):  # regular package
        return os.path.dirname(os.path.abspath(repro.__file__))
    return os.path.abspath(list(repro.__path__)[0])  # namespace package


def kernel_fingerprint(root: str | None = None) -> str:
    """Digest of the kernel/serving sources that determine the compiled
    programs (sorted relpath + bytes of every ``.py`` under
    ``_FINGERPRINT_SUBDIRS``).  Part of ``artifact_key``: an edited kernel
    invalidates every serialized executable."""
    root = root or _repro_root()
    h = hashlib.sha256()
    for sub in _FINGERPRINT_SUBDIRS:
        pat = os.path.join(root, sub, "**", "*.py")
        for path in sorted(glob.glob(pat, recursive=True)):
            h.update(os.path.relpath(path, root).encode())
            with open(path, "rb") as f:
                h.update(f.read())
    return h.hexdigest()[:16]


def device_kind(device=None) -> str:
    d = device if device is not None else jax.local_devices()[0]
    return f"{d.platform}:{d.device_kind}"


def artifact_key(*, device=None, root: str | None = None) -> dict:
    """The validity key an artifact is pinned to: serialized executables are
    only safe to reuse under the same jax version, on the same device kind,
    with unchanged kernel sources."""
    return {
        "jax": jax.__version__,
        "device": device_kind(device),
        "kernels": kernel_fingerprint(root),
    }


def register_pytree_serialization(cls: type, name: str) -> bool:
    """Register a (meta-field-free) dataclass pytree for ``jax.export``
    serialization; idempotent, False when export serialization is
    unavailable.  Producers call this next to their
    ``register_dataclass`` so their state types can cross the export
    boundary."""
    if not _HAVE_EXPORT:
        return False
    try:
        _jax_export.register_pytree_node_serialization(
            cls,
            serialized_name=name,
            serialize_auxdata=lambda aux: b"",
            deserialize_auxdata=lambda b: (),
        )
    except ValueError:  # already registered (idempotent re-import)
        pass
    return True


# ---------------------------------------------------------------------------
# persistent compilation cache plumbing
# ---------------------------------------------------------------------------


def _reset_cache_state() -> None:
    # jax initializes the persistent cache AT MOST ONCE per process, at the
    # first compile — a dir configured after that (the usual case here:
    # training compiles run long before an artifact is saved/loaded) would
    # silently never take effect.  reset_cache() returns the module to its
    # uninitialized state so the next compile picks up the new dir.
    try:
        from jax.experimental.compilation_cache import compilation_cache as cc

        cc.reset_cache()
    except Exception:  # pragma: no cover - private-ish API moved/absent
        pass


def enable_compilation_cache(path: str) -> None:
    """Point JAX's persistent compilation cache at ``path`` (created if
    missing) with thresholds at zero, so every serving executable persists.
    Process-global, like the cache itself."""
    os.makedirs(path, exist_ok=True)
    _reset_cache_state()
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:  # older jax: size threshold flag absent
        pass


def disable_compilation_cache() -> None:
    _reset_cache_state()
    jax.config.update("jax_compilation_cache_dir", None)


def compilation_cache_dir() -> str | None:
    return jax.config.jax_compilation_cache_dir


class compilation_cache:
    """Context manager: run a block under (or explicitly without) the
    persistent compilation cache, restoring the previous setting after —
    the cold-start benchmark uses this to measure a genuinely cache-free
    fresh JIT inside a process whose CI environment has the cache on."""

    def __init__(self, path: str | None):
        self._path = path
        self._prev: str | None = None

    def __enter__(self):
        self._prev = compilation_cache_dir()
        if self._path is None:
            disable_compilation_cache()
        else:
            enable_compilation_cache(self._path)
        return self

    def __exit__(self, *exc):
        if self._prev is None:
            disable_compilation_cache()
        else:
            enable_compilation_cache(self._prev)
        return False


# ---------------------------------------------------------------------------
# artifact build / load
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AOTEntry:
    """One executable to ahead-of-time compile: a jitted callable plus the
    abstract (ShapeDtypeStruct pytree) arguments of ONE input signature.
    ``static`` holds trailing ``static_argnames``-style concrete values the
    jit needs at trace time; they are baked into the exported program, so
    loaders call the compiled entry with ``args`` only.

    ``cache_args``, when set, is a second signature to pre-compile into the
    persistent cache ONLY (not exported): device-PINNED avals hash to a
    different cache key than the portable ``args`` form, and a plain-JIT
    restart that merely shares the cache directory compiles the pinned form
    (its operands are committed to their tile device)."""

    name: str
    fn: Callable
    args: tuple
    static: tuple = ()
    cache_args: tuple | None = None


def _aval_tree(x: Any) -> Any:
    return jax.tree.map(
        lambda a: a
        if isinstance(a, jax.ShapeDtypeStruct) or not hasattr(a, "shape")
        else jax.ShapeDtypeStruct(a.shape, a.dtype),
        x,
    )


def save_artifact(
    path: str,
    entries: Sequence[AOTEntry],
    *,
    key: dict | None = None,
) -> dict:
    """Compile + serialize ``entries`` into a deploy artifact at ``path``.

    For every entry this (1) exports + serializes the lowered StableHLO to
    ``entries/e_<i>.jaxexport``, (2) pickles the compiled PjRt executable of
    the donation-free export-wrapped program to ``entries/e_<i>.xlaexec``
    (the tier the load path prefers: no tracing, no XLA compile), and
    (3) compiles BOTH the export-wrapped and the plain-jit form of the
    program with the persistent compilation cache pointed into the
    artifact, for plain-JIT restarts that merely share the cache directory.
    Returns the manifest dict.

    Entries whose export fails (e.g. a jax build without export
    serialization) are still cache-compiled and recorded with
    ``"exported": false`` — the load path then JIT-compiles them against
    the shipped cache, which is the graceful middle tier.
    """
    os.makedirs(os.path.join(path, ENTRY_DIR), exist_ok=True)
    manifest: dict = {
        "version": ARTIFACT_VERSION,
        "key": key or artifact_key(),
        "entries": [],
    }
    names = set()
    with compilation_cache(os.path.join(path, XLA_CACHE_DIR)):
        for i, e in enumerate(entries):
            if e.name in names:
                raise ValueError(f"duplicate AOT entry name {e.name!r}")
            names.add(e.name)
            rec = {"name": e.name, "file": None, "exported": False}
            t0 = time.perf_counter()
            # plain-jit compile: populates the cache for workers that JIT
            # with the shared cache dir but never load the blobs
            e.fn.lower(*e.args, *e.static).compile()
            if e.cache_args is not None:
                e.fn.lower(*e.cache_args, *e.static).compile()
            blob = None
            if _HAVE_EXPORT:
                try:
                    blob = _jax_export.export(e.fn)(
                        *e.args, *e.static).serialize()
                except Exception as ex:  # unexportable program: cache-only
                    warnings.warn(
                        f"AOT entry {e.name!r}: export failed "
                        f"({type(ex).__name__}: {ex}); shipping "
                        "compilation-cache entry only",
                        stacklevel=2,
                    )
            if blob is not None:
                fname = f"e_{i:05d}.jaxexport"
                with open(os.path.join(path, ENTRY_DIR, fname), "wb") as f:
                    f.write(blob)
                rec["file"] = fname
                rec["exported"] = True
                # the load path compiles the DESERIALIZED program, whose
                # cache key differs from the plain jit's — pre-compile that
                # form too so loads are pure cache hits
                compiled = _compile_exported(
                    _jax_export.deserialize(blob), e.args)
                # ship the XLA executable itself (the load path then skips
                # XLA entirely).  This is the donation-free export-wrapped
                # form — exactly what the load path would have compiled
                exec_blob = _serialize_executable(e.name, compiled)
                if exec_blob is not None:
                    xname = f"e_{i:05d}.xlaexec"
                    with open(os.path.join(path, ENTRY_DIR, xname),
                              "wb") as f:
                        f.write(exec_blob)
                    rec["executable"] = xname
            rec["compile_s"] = round(time.perf_counter() - t0, 4)
            manifest["entries"].append(rec)
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return manifest


def _compile_exported(exported, args: tuple):
    """Lower+compile a deserialized export under the active cache config."""
    return jax.jit(exported.call).lower(*_aval_tree(args)).compile()


def _serialize_executable(name: str, compiled) -> bytes | None:
    """Pickle a ``jax.stages.Compiled`` (PjRt executable + arg/out trees);
    None when this jax/backend cannot serialize executables."""
    if not _HAVE_EXEC:
        return None
    try:
        blob, in_tree, out_tree = _jax_se.serialize(compiled)
        return pickle.dumps((blob, in_tree, out_tree))
    except Exception as ex:
        warnings.warn(
            f"AOT entry {name!r}: executable serialization failed "
            f"({type(ex).__name__}: {ex}); shipping StableHLO only",
            stacklevel=2,
        )
        return None


def _signature_matches(compiled, args: tuple) -> bool:
    """Shape/dtype agreement between a loaded executable's baked input
    signature and the avals a caller wants it for."""
    try:
        have = jax.tree_util.tree_leaves(compiled.args_info)
        want = jax.tree_util.tree_leaves(_aval_tree(args))
        return len(have) == len(want) and all(
            tuple(h.shape) == tuple(w.shape)
            and np.dtype(h.dtype) == np.dtype(w.dtype)
            for h, w in zip(have, want)
        )
    except Exception:  # malformed args_info: treat as a miss, not an error
        return False


class AOTArtifact:
    """A loaded (key-validated) deploy artifact.

    ``compile(name, *args)`` returns the ready-to-call compiled executable
    for one entry — the shipped PjRt executable when one matches (no
    tracing, no XLA compile), else a recompile of the serialized StableHLO
    (no tracing) — or ``None`` when the entry is missing or unloadable
    (callers fall back to JIT).
    """

    def __init__(self, path: str, manifest: dict):
        self.path = path
        self.manifest = manifest
        self._by_name = {e["name"]: e for e in manifest["entries"]}

    @property
    def key(self) -> dict:
        return self.manifest["key"]

    @property
    def names(self) -> list[str]:
        return [e["name"] for e in self.manifest["entries"]]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def load_exported(self, name: str):
        """The deserialized ``jax.export.Exported`` for one entry (None when
        absent/unavailable)."""
        rec = self._by_name.get(name)
        if rec is None or not rec.get("exported") or not _HAVE_EXPORT:
            return None
        try:
            with open(os.path.join(self.path, ENTRY_DIR, rec["file"]),
                      "rb") as f:
                return _jax_export.deserialize(f.read())
        except Exception as ex:
            warnings.warn(
                f"AOT entry {name!r}: failed to deserialize "
                f"({type(ex).__name__}: {ex}); falling back to JIT",
                stacklevel=2,
            )
            return None

    def load_executable(self, name: str, args: tuple | None = None):
        """The shipped XLA executable for one entry as a ready-to-call
        ``jax.stages.Compiled`` — no tracing, no XLA compile.  None when the
        entry ships no executable, this backend cannot load one, or ``args``
        disagree with the baked input signature (the caller then takes the
        StableHLO-recompile tier)."""
        rec = self._by_name.get(name)
        if rec is None or not rec.get("executable") or not _HAVE_EXEC:
            return None
        try:
            with open(os.path.join(self.path, ENTRY_DIR,
                                   rec["executable"]), "rb") as f:
                blob, in_tree, out_tree = pickle.load(f)
            loaded = _jax_se.deserialize_and_load(blob, in_tree, out_tree)
        except Exception as ex:
            warnings.warn(
                f"AOT entry {name!r}: executable load failed "
                f"({type(ex).__name__}: {ex}); recompiling from StableHLO",
                stacklevel=2,
            )
            return None
        if args is not None and not _signature_matches(loaded, args):
            return None
        return loaded

    def compile(self, name: str, *args):
        """Compiled executable for entry ``name`` at the given abstract
        args, or None (caller JIT-compiles instead).  Prefers the shipped
        XLA executable; recompiles the serialized StableHLO when the
        executable is absent or signature-mismatched."""
        loaded = self.load_executable(name, args)
        if loaded is not None:
            return loaded
        exported = self.load_exported(name)
        if exported is None:
            return None
        try:
            return _compile_exported(exported, args)
        except Exception as ex:
            warnings.warn(
                f"AOT entry {name!r}: compile of deserialized executable "
                f"failed ({type(ex).__name__}: {ex}); falling back to JIT",
                stacklevel=2,
            )
            return None


def stale_fields(saved: dict, current: dict) -> dict:
    """``{field: (saved, current)}`` for every artifact-key field that
    disagrees — empty means the artifact is valid here."""
    return {
        k: (saved.get(k), current[k])
        for k in current
        if saved.get(k) != current[k]
    }


def load_artifact(
    path: str,
    *,
    expected_key: dict | None = None,
    enable_cache: bool = False,
) -> AOTArtifact | None:
    """Load + key-validate a deploy artifact; ``None`` (with a warning) on
    any mismatch or unreadable manifest — the graceful-JIT-fallback
    contract.

    ``enable_cache=True`` additionally turns on the artifact's persistent
    XLA compilation cache *globally* for the rest of the process, so that
    re-traces of covered programs become cache hits.  It is off by
    default: warmed workers deserialize their executables from the
    ``jax.export`` blobs and never need the cache, and cache-HIT
    recompiles of the large fleet-step program segfault jaxlib 0.4.3x on
    CPU.  Prefer scoping cache use explicitly with the
    ``compilation_cache(...)`` context manager."""
    manifest_path = os.path.join(path, MANIFEST)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as ex:
        warnings.warn(
            f"AOT artifact {path!r}: unreadable manifest "
            f"({type(ex).__name__}: {ex}); falling back to JIT",
            stacklevel=2,
        )
        return None
    current = expected_key or artifact_key()
    bad = stale_fields(manifest.get("key", {}), current)
    if bad:
        warnings.warn(
            f"AOT artifact {path!r} is stale: "
            + ", ".join(f"{k}: saved {s!r} != current {c!r}"
                        for k, (s, c) in sorted(bad.items()))
            + "; falling back to JIT",
            stacklevel=2,
        )
        return None
    if enable_cache:
        enable_compilation_cache(os.path.join(path, XLA_CACHE_DIR))
    return AOTArtifact(path, manifest)
