"""HLO-graph cost analyzer with while-loop trip-count multiplication.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which makes it
useless for scan-over-layers models (everything interesting lives inside
scans: layers, KV chunks, SSM chunks, xent chunks).  This analyzer parses the
optimized (SPMD-partitioned, per-device) HLO text and walks the call graph,
multiplying each while body by its trip count — XLA conveniently records
``backend_config={"known_trip_count":{"n":...}}`` on canonicalized loops.

Counted:
  flops        2 * numel(output) * K for every `dot` (K = product of lhs
               contracting dim sizes); convolutions approximated the same
               way via the kernel size.  Elementwise/vector flops are not
               counted (roofline convention: MXU work).
  bytes        2 * output bytes (read + write proxy) of every fusion, dot,
               copy, (dynamic-)slice/update-slice op — on optimized HLO all
               dataflow lands in these, so this approximates HBM traffic.
  collectives  output bytes per op kind (all-reduce / all-gather /
               reduce-scatter / all-to-all / collective-permute), async
               (-start) pairs counted once.

All totals are per-device (the partitioned module is per-device).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# Ops whose outputs represent real HBM traffic on TPU.  Deliberately
# excludes top-level elementwise/layout ops (broadcast, iota, compare,
# arithmetic, reshape, slice, pad): the TPU backend fuses those into their
# consumers, but the CPU backend we lower with leaves many unfused — counting
# them would overstate the memory roofline term ~10x.
_BYTES_OPS = ("fusion", "dot", "convolution", "copy", "dynamic-slice",
              "dynamic-update-slice", "transpose", "reduce", "concatenate",
              "scatter", "gather", "sort", "convert", "bitcast-convert")

_SHAPE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.+\s+\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+")
# lazy shape match: first "<shape> <opcode>(" occurrence after "= " wins —
# tuple shapes contain "(" and "/*index=N*/" comments, so the shape group
# cannot be matched structurally; opcode tokens are plain words
_OP_RE = re.compile(r"=\s+(.*?)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")


def _shape_numel_bytes(shape_str: str) -> tuple[float, float]:
    """Sum over array elements in a (possibly tuple) shape string."""
    numel = bytes_ = 0.0
    for dtype, dims in _SHAPE_ELEM_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = float(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1.0
        numel += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return numel, bytes_


def _dims_of(shape_str: str) -> list[int]:
    m = _SHAPE_ELEM_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


def _parse_computations(text: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HEAD_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)
    return comps


def analyze_hlo(text: str) -> dict:
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        m = _COMP_HEAD_RE.match(line)
        if m and m.group(1):
            entry = m.group(2)
    if entry is None:   # fall back: last computation
        entry = list(comps)[-1]

    memo: dict[str, Costs] = {}

    def comp_cost(name: str, stack=()) -> Costs:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Costs()
        total = Costs()
        shapes: dict[str, str] = {}
        for line in comps[name]:
            nm = _NAME_RE.match(line)
            m = _OP_RE.search(line)
            if not nm or not m:
                continue
            opname = nm.group(1)
            shape_str, opcode = m.group(1), m.group(2)
            rest = line[m.end():]
            shapes[opname] = shape_str
            if opcode == "parameter" or opcode.endswith("-done"):
                continue
            numel, obytes = _shape_numel_bytes(shape_str)

            if opcode == "dot":
                # operands: %lhs, %rhs, ... lhs_contracting_dims={...}
                ops = re.findall(r"%([\w\.\-]+)", rest)
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                k = 1.0
                if ops and lc and ops[0] in shapes:
                    ldims = _dims_of(shapes[ops[0]])
                    for ci in (int(c) for c in lc.group(1).split(",") if c):
                        if ci < len(ldims):
                            k *= ldims[ci]
                total.flops += 2.0 * numel * k
                total.bytes += 2.0 * obytes
            elif opcode == "convolution":
                total.flops += 2.0 * numel * 9.0   # coarse; convs are rare here
                total.bytes += 2.0 * obytes
            elif any(opcode == c or opcode == c + "-start" for c in _COLL_KINDS):
                kind = opcode.removesuffix("-start")
                b = obytes / 2.0 if opcode.endswith("-start") else obytes
                total.coll[kind] = total.coll.get(kind, 0.0) + b
                total.coll["n_ops"] = total.coll.get("n_ops", 0.0) + 1.0
                total.bytes += obytes
            elif opcode == "while":
                trip = 1.0
                mt = _TRIP_RE.search(rest)
                if mt:
                    trip = float(mt.group(1))
                body = _CALLS_RE.search(rest)
                cond = _COND_RE.search(rest)
                if body:
                    total.add(comp_cost(body.group(1), stack + (name,)), trip)
                if cond:
                    total.add(comp_cost(cond.group(1), stack + (name,)), trip)
            elif opcode in ("call", "conditional", "async-start"):
                for callee in _CALLS_RE.findall(rest):
                    total.add(comp_cost(callee, stack + (name,)), 1.0)
                total.bytes += obytes
            elif opcode == "fusion":
                # fusion internals are elementwise; count the traffic only
                total.bytes += 2.0 * obytes
            elif opcode in _BYTES_OPS:
                total.bytes += 2.0 * obytes
        memo[name] = total
        return total

    c = comp_cost(entry)
    out = {"flops": c.flops, "bytes": c.bytes, "collectives": dict(c.coll),
           "entry": entry}
    return out
