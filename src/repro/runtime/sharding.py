"""Sharding rules: logical parameter/activation axes -> mesh axes.

Logical axes used by the model zoo:

  fsdp      parameter & optimizer-state sharding axis (ZeRO-3 style)
  tp        tensor-parallel axis (attention heads, FFN hidden, experts, vocab)
  batch     data-parallel activation axis
  kv_seq    sequence axis of decode KV caches
  kv_tp     head_dim axis of decode KV caches (TP fallback when batch is wide)
  None      replicated

Rules are carried in a ShardCtx so they can vary per step kind:

* default             batch -> (pod, data); kv_seq unsharded; kv_tp -> model
* seq_sharded_kv      long-context decode with tiny batches (long_500k has
                      global_batch=1): batch unsharded, kv_seq -> (pod, data)
                      — sequence parallelism over the KV cache; softmax
                      reductions over the sharded seq dim lower to
                      all-reduces (the LSE combine falls out of SPMD).

A dim that a rule cannot divide evenly is silently replicated (e.g. 8 KV
heads over a 16-way model axis), exactly like Megatron's GQA TP fallback.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _base_rules(axis_names: tuple[str, ...]) -> dict:
    dp = ("pod", "data") if "pod" in axis_names else ("data",)
    return {
        "fsdp": dp,
        "tp": ("model",),
        "batch": dp,
        "kv_seq": (),
        "kv_tp": ("model",),
        "stage": ("pod",) if "pod" in axis_names else (),
    }


@dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh | None
    rules: dict = field(default_factory=dict)

    @property
    def axis_sizes(self) -> dict:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))


def make_ctx(mesh: Mesh | None, *, seq_sharded_kv: bool = False) -> ShardCtx:
    if mesh is None:
        return ShardCtx(None, {})
    rules = _base_rules(tuple(mesh.axis_names))
    if seq_sharded_kv:
        rules = rules | {"batch": (), "kv_seq": rules["fsdp"], "kv_tp": ("model",)}
    return ShardCtx(mesh, rules)


def to_pspec(axes: tuple, rules: dict) -> P:
    """Logical axes tuple (one entry per tensor dim; entries are logical axis
    names, tuples of them, or None) -> PartitionSpec."""
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        names = (ax,) if isinstance(ax, str) else tuple(ax)
        phys: list[str] = []
        for n in names:
            phys.extend(rules.get(n, ()))
        out.append(tuple(phys) if len(phys) > 1 else (phys[0] if phys else None))
    return P(*out)


def _sanitize(pspec: P, shape: tuple[int, ...] | None, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (e.g. 8 KV
    heads cannot shard over a 16-way model axis -> replicate)."""
    if shape is None:
        return pspec
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(pspec) + (None,) * (len(shape) - len(pspec))):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        total = int(np.prod([sizes[n] for n in names]))
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def sharding_for(axes: tuple, ctx: ShardCtx,
                 shape: tuple[int, ...] | None = None) -> NamedSharding | None:
    if ctx.mesh is None:
        return None
    pspec = to_pspec(axes, ctx.rules)
    return NamedSharding(ctx.mesh, _sanitize(pspec, shape, ctx.mesh))


def constrain(x: jax.Array, axes: tuple, ctx: ShardCtx) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding_for(axes, ctx, x.shape))


def tree_shardings(spec_tree: Any, ctx: ShardCtx):
    """Map a tree of ParamSpec (models.params) to NamedShardings."""
    from repro.models import params as pmod
    return jax.tree.map(
        lambda s: sharding_for(s.axes, ctx, s.shape),
        spec_tree, is_leaf=lambda s: isinstance(s, pmod.ParamSpec))
