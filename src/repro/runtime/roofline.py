"""Roofline analysis from compiled dry-run artifacts.

Hardware constants (TPU v5e, per chip):
  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI link bandwidth  ~50 GB/s per link

Three terms (seconds, per device — ``compiled.cost_analysis()`` on an SPMD-
partitioned module reports per-device flops/bytes):

  compute    = HLO_flops / peak
  memory     = HLO_bytes_accessed / HBM_bw
  collective = sum_k w_k * bytes_k / ICI_bw, with per-kind weights
               all-reduce 2.0 (reduce-scatter + all-gather equivalent),
               all-gather / reduce-scatter / all-to-all / collective-permute
               1.0 — bytes are the per-device output sizes parsed from the
               partitioned HLO.

The bottleneck is the max term.  MODEL_FLOPS / HLO_flops measures how much
of compiled compute is algorithmically useful (catches remat/dispatch
waste); remat recompute intentionally shows up here.
"""

from __future__ import annotations

import re

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0,
                "ragged-all-to-all": 1.0}

# `bf16[4,128]{1,0}` or tuple `(bf16[...], f32[...])`
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"ragged-all-to-all)"
    r"(-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d]))
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-collective-kind output bytes (per device) from partitioned HLO.

    Async pairs (-start/-done) are counted once via the -start op; bare sync
    ops count directly.  `-done` ops never match (no '(' pattern on their
    operand list start... they do, so we exclude by op name suffix)."""
    out: dict[str, float] = {}
    ops = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind, _ = m.group(1), m.group(2), m.group(3)
        # skip -done lines: their def name contains '-done'
        line_start = hlo_text.rfind("\n", 0, m.start()) + 1
        head = hlo_text[line_start:m.start()]
        if "-done" in head:
            continue
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_str)
        ops += 1
    out["n_ops"] = ops
    return out


def collective_seconds(colls: dict) -> float:
    return sum(_COLL_WEIGHT.get(k, 1.0) * v
               for k, v in colls.items() if k != "n_ops") / ICI_BW


def roofline_terms(cost: dict, colls: dict, cfg, shape, mesh,
                   *, n_total: int, n_active: int) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = collective_seconds(colls)
    n_dev = int(np.prod(mesh.devices.shape))

    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    mult = 6.0 if shape.kind == "train" else 2.0
    n_embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    model_flops_global = mult * max(n_active - n_embed, 1) * tokens
    model_flops_per_dev = model_flops_global / n_dev

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s,
             "bottleneck": max((("compute", compute_s), ("memory", memory_s),
                                ("collective", coll_s)), key=lambda kv: kv[1])[0],
             "model_flops_per_device": model_flops_per_dev,
             "useful_flops_fraction": (model_flops_per_dev / flops
                                       if flops else 0.0),
             "step_time_bound_s": max(compute_s, memory_s, coll_s)}
    return terms


def memory_analysis_dict(mem) -> dict:
    """Normalize compiled.memory_analysis() across backends."""
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(mem)[:500]
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["peak_bytes_per_device_est"] = (
            out["argument_size_in_bytes"] + out["output_size_in_bytes"]
            + out["temp_size_in_bytes"] - out.get("alias_size_in_bytes", 0))
    return out
