"""Step builders: jit-compiled train / prefill / decode with full sharding
specifications (params, optimizer state, batch, caches).

These are the functions the launcher runs and the dry-run lowers.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import model as model_mod
from repro.models import serve as serve_mod
from repro.models.config import ArchConfig
from repro.optim import adamw, compress
from repro.runtime.sharding import (ShardCtx, make_ctx, sharding_for,
                                    tree_shardings)


# ---------------------------------------------------------------------------
# sharding trees for non-param step inputs
# ---------------------------------------------------------------------------

def batch_shardings(batch_tree: Any, ctx: ShardCtx):
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes = {"tokens": ("batch", None), "labels": ("batch", None),
                "media": ("batch", None, None), "frames": ("batch", None, None),
                "pos": ()}.get(name)
        if axes is None:
            axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return sharding_for(axes, ctx, tuple(leaf.shape))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(cache_tree: Any, ctx: ShardCtx):
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        rank = len(leaf.shape)
        if name in ("k", "v", "ck", "cv"):           # (n, B, S, KV, hd)
            axes = (None, "batch", "kv_seq", None, "kv_tp")
        elif name == "ssm":                          # (..., B, di, st)
            axes = (None,) * (rank - 3) + ("batch", "tp", None)
        elif name == "conv":                         # (..., B, k-1, di)
            axes = (None,) * (rank - 3) + ("batch", None, "tp")
        else:
            axes = (None,) * rank
        return sharding_for(axes, ctx, tuple(leaf.shape))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def opt_state_shardings(spec_tree: Any, ctx: ShardCtx):
    ps = tree_shardings(spec_tree, ctx)
    return {"m": ps, "v": ps,
            "step": sharding_for((), ctx, ())}


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt: adamw.OptConfig, ctx: ShardCtx,
                    grad_compress: bool = False):
    """Returns train_step(params, opt_state, batch[, residual]) -> ...

    Gradient accumulation: opt.accum_steps microbatches via lax.scan (keeps
    peak activation memory at 1/accum of the global batch)."""

    def loss_of(params, batch):
        return model_mod.loss_fn(params, batch, cfg, ctx)

    def compute_grads(params, batch):
        if opt.accum_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads
        n = opt.accum_steps
        micro = jax.tree.map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

        def acc_step(carry, mb):
            loss_acc, grads_acc = carry
            (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
            return (loss_acc + loss / n,
                    jax.tree.map(lambda a, g: a + g / n, grads_acc, grads)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc_step, (jnp.float32(0.0), zeros), micro)
        return loss, {"xent": loss, "aux": jnp.float32(0.0)}, grads

    if grad_compress:
        def train_step(params, opt_state, batch, residual):
            loss, metrics, grads = compute_grads(params, batch)
            grads, residual = compress.compress_decompress(grads, residual)
            params, opt_state, om = adamw.apply_updates(params, grads, opt_state, opt)
            return params, opt_state, residual, loss, {**metrics, **om}
    else:
        def train_step(params, opt_state, batch):
            loss, metrics, grads = compute_grads(params, batch)
            params, opt_state, om = adamw.apply_updates(params, grads, opt_state, opt)
            return params, opt_state, loss, {**metrics, **om}
    return train_step


def jit_train_step(cfg: ArchConfig, opt: adamw.OptConfig, mesh: Mesh | None,
                   batch_specs: Any, grad_compress: bool = False):
    """jit with explicit in/out shardings; also returns the abstract arg
    structure so the dry-run can .lower() without allocating anything."""
    ctx = make_ctx(mesh)
    spec = model_mod.model_spec(cfg)
    p_shard = tree_shardings(spec, ctx)
    o_shard = opt_state_shardings(spec, ctx)
    b_shard = batch_shardings(batch_specs, ctx)
    step = make_train_step(cfg, opt, ctx, grad_compress)
    in_shardings = (p_shard, o_shard, b_shard)
    out_shardings = (p_shard, o_shard, None, None)
    if grad_compress:
        in_shardings = in_shardings + (p_shard,)
        out_shardings = (p_shard, o_shard, p_shard, None, None)
    if mesh is None:
        return jax.jit(step), ctx, spec
    return (jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings),
            ctx, spec)


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def jit_prefill(cfg: ArchConfig, mesh: Mesh | None, batch_specs: Any,
                cache_seq: int, *, seq_sharded_kv: bool = False):
    ctx = make_ctx(mesh, seq_sharded_kv=seq_sharded_kv)
    spec = model_mod.model_spec(cfg)

    def fn(params, batch):
        return serve_mod.prefill(params, batch, cfg, ctx, cache_seq)

    if mesh is None:
        return jax.jit(fn), ctx, spec
    p_shard = tree_shardings(spec, ctx)
    b_shard = batch_shardings(batch_specs, ctx)
    return (jax.jit(fn, in_shardings=(p_shard, b_shard), out_shardings=None),
            ctx, spec)


def jit_decode_step(cfg: ArchConfig, mesh: Mesh | None, decode_specs: dict,
                    *, seq_sharded_kv: bool = False):
    """decode_specs: {"tokens", "caches", "pos"} (abstract or concrete)."""
    ctx = make_ctx(mesh, seq_sharded_kv=seq_sharded_kv)
    spec = model_mod.model_spec(cfg)

    def fn(params, tokens, caches, pos):
        return serve_mod.decode_step(params, tokens, caches, pos, cfg, ctx)

    if mesh is None:
        return jax.jit(fn), ctx, spec
    p_shard = tree_shardings(spec, ctx)
    t_shard = sharding_for(("batch", None), ctx, tuple(decode_specs["tokens"].shape))
    c_shard = cache_shardings(decode_specs["caches"], ctx)
    logits_shard = None
    return (jax.jit(fn,
                    in_shardings=(p_shard, t_shard, c_shard, None),
                    out_shardings=(logits_shard, c_shard)),
            ctx, spec)
