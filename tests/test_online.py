"""Online continual learning: fit_iterative one-shot equivalence + backend
parity, SeizureSession.adapt gating, fleet-vs-session adapt bit-exactness,
and mid-stream checkpoint save/restore."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import online
from repro.core.pipeline import BACKENDS, HDCConfig, HDCPipeline, VARIANTS
from repro.serve.engine import SeizureSession
from repro.serve.fleet import StreamingFleet

jax.config.update("jax_platform_name", "cpu")

# tiny geometry keeps every jit compile in milliseconds
DIM, SEGMENTS, CHANNELS, WINDOW = 256, 8, 8, 32


def _cfg(variant: str, **overrides) -> HDCConfig:
    base = dict(dim=DIM, segments=SEGMENTS, channels=CHANNELS, window=WINDOW,
                variant=variant, spatial_threshold=1, temporal_threshold=4)
    base.update(overrides)
    return HDCConfig(**base)


def _train_data(seed: int, frames: int = 8):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, 64, (1, frames * WINDOW, CHANNELS), np.uint8))
    labels = np.asarray(rng.integers(0, 2, (1, frames), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    return codes, jnp.asarray(labels)


def _trained(variant: str, seed: int = 0, **overrides) -> HDCPipeline:
    codes, labels = _train_data(seed)
    pipe = HDCPipeline.init(jax.random.PRNGKey(seed), _cfg(variant, **overrides))
    return pipe.train_one_shot(codes, labels)


def _chunk(rng, t):
    return rng.integers(0, 64, (t, CHANNELS), np.uint8)


# ---------------------------------------------------------------------------
# fit_iterative
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_fit_iterative_zero_epochs_is_one_shot(variant):
    """The counter-file state seeds from the one-shot accumulation, so zero
    retraining epochs must reproduce train_one_shot bit-exactly."""
    codes, labels = _train_data(1)
    pipe = HDCPipeline.init(jax.random.PRNGKey(1), _cfg(variant))
    one = pipe.train_one_shot(codes, labels)
    it0 = pipe.fit_iterative(codes, labels, epochs=0)
    np.testing.assert_array_equal(np.asarray(one.class_hvs),
                                  np.asarray(it0.class_hvs))
    np.testing.assert_array_equal(np.asarray(one.am_state.counts),
                                  np.asarray(it0.am_state.counts))
    np.testing.assert_array_equal(np.asarray(one.am_state.n),
                                  np.asarray(it0.am_state.n))


@pytest.mark.parametrize("variant", VARIANTS)
def test_fit_iterative_backends_bit_exact(variant):
    codes, labels = _train_data(2)
    pipe = HDCPipeline.init(jax.random.PRNGKey(2), _cfg(variant))
    trained = {b: pipe.with_backend(b).fit_iterative(codes, labels, epochs=3,
                                                     margin=1.0)
               for b in BACKENDS}
    np.testing.assert_array_equal(np.asarray(trained["jnp"].class_hvs),
                                  np.asarray(trained["pallas"].class_hvs))
    np.testing.assert_array_equal(np.asarray(trained["jnp"].am_state.counts),
                                  np.asarray(trained["pallas"].am_state.counts))


def test_fit_iterative_reduces_training_errors():
    """On a noisy-but-learnable stream, retraining epochs must cut the number
    of misclassified training frames (the classic iterative-HD claim)."""
    rng = np.random.default_rng(3)
    frames = 24
    # class-conditional code statistics with heavy overlap: class 1 draws
    # from a narrow sub-alphabet of class 0's, so one-shot prototypes confuse
    stream = rng.integers(0, 64, (1, frames * WINDOW, CHANNELS))
    labels = np.asarray(rng.integers(0, 2, (1, frames), np.int32))
    labels[0, :2] = (0, 1)
    for f in np.nonzero(labels[0])[0]:
        seg = slice(f * WINDOW, (f + 1) * WINDOW)
        narrow = rng.integers(0, 12, (WINDOW, CHANNELS))
        keep = rng.random((WINDOW, CHANNELS)) < 0.9  # 10% signal dilution
        stream[0, seg] = np.where(keep, stream[0, seg], narrow)
    codes, labels = jnp.asarray(stream.astype(np.uint8)), jnp.asarray(labels)
    pipe = HDCPipeline.init(jax.random.PRNGKey(3), _cfg("sparse_compim"))
    pipe = pipe.calibrate_density(codes, target=0.25)
    one = pipe.train_one_shot(codes, labels)
    it = pipe.fit_iterative(codes, labels, epochs=10)
    _, preds_one = one.infer(codes)
    _, preds_it = it.infer(codes)
    err_one = int((np.asarray(preds_one) != np.asarray(labels)).sum())
    err_it = int((np.asarray(preds_it) != np.asarray(labels)).sum())
    assert err_one > 0, "stream unexpectedly separable; pick another seed"
    assert err_it < err_one


def test_fit_iterative_validation():
    codes, labels = _train_data(4)
    pipe = HDCPipeline.init(jax.random.PRNGKey(4), _cfg("sparse_compim"))
    with pytest.raises(ValueError, match="epochs"):
        pipe.fit_iterative(codes, labels, epochs=-1)
    with pytest.raises(ValueError, match="no examples"):
        pipe.fit_iterative(codes, jnp.zeros_like(labels), epochs=1)


def test_with_cfg_drops_am_state_with_class_hvs():
    pipe = _trained("sparse_compim", seed=5)
    assert pipe.am_state is not None
    recal = pipe.with_cfg(temporal_threshold=pipe.cfg.temporal_threshold + 1)
    assert recal.class_hvs is None and recal.am_state is None
    kept = pipe.with_backend("pallas")
    assert kept.class_hvs is not None and kept.am_state is not None


# ---------------------------------------------------------------------------
# core update rule
# ---------------------------------------------------------------------------

def test_update_gates_and_clamps():
    state = online.OnlineAMState(
        counts=jnp.asarray([[2, 0, 1], [0, 3, 0]], jnp.int32),
        n=jnp.asarray([1, 1], jnp.int32))
    bits = jnp.asarray([1, 1, 0], jnp.int32)
    # correct, confident -> no update
    st, applied = online.update(state, bits, jnp.asarray(0),
                                jnp.asarray([5, 1], jnp.int32))
    assert not bool(applied)
    np.testing.assert_array_equal(np.asarray(st.counts),
                                  np.asarray(state.counts))
    # wrong -> add to true (0), subtract from rival (1), clamp at zero
    st, applied = online.update(state, bits, jnp.asarray(0),
                                jnp.asarray([1, 5], jnp.int32))
    assert bool(applied)
    np.testing.assert_array_equal(np.asarray(st.counts),
                                  [[3, 1, 1], [0, 2, 0]])
    np.testing.assert_array_equal(np.asarray(st.n), [2, 0])
    # correct but low margin -> confidence gate fires
    _, applied = online.update(state, bits, jnp.asarray(0),
                               jnp.asarray([5, 4], jnp.int32), margin=2.0)
    assert bool(applied)
    # label -1 masks the update
    _, applied = online.update(state, bits, jnp.asarray(-1),
                               jnp.asarray([1, 5], jnp.int32))
    assert not bool(applied)


# ---------------------------------------------------------------------------
# SeizureSession.adapt
# ---------------------------------------------------------------------------

def test_session_adapt_semantics():
    pipe = _trained("sparse_compim", seed=6)
    sess = SeizureSession(pipe)
    with pytest.raises(ValueError, match="no frame emitted"):
        sess.adapt(1)
    rng = np.random.default_rng(0)
    [dec] = sess.push(_chunk(rng, WINDOW))
    with pytest.raises(ValueError, match="not in"):
        sess.adapt(7)
    before = np.asarray(sess.class_hvs)
    # feeding back the predicted label with no margin: gate must not fire
    assert sess.adapt(dec.prediction) is False
    np.testing.assert_array_equal(np.asarray(sess.class_hvs), before)
    # feeding back the other label: gate fires and the AM personalizes
    assert sess.adapt(1 - dec.prediction) is True
    assert not np.array_equal(np.asarray(sess.class_hvs), before)
    # the pipeline object itself stays immutable
    np.testing.assert_array_equal(np.asarray(pipe.class_hvs), before)


def test_session_adapt_requires_am_state():
    pipe = _trained("sparse_compim", seed=6)
    bare = dataclasses.replace(pipe, am_state=None)
    sess = SeizureSession(bare)
    rng = np.random.default_rng(0)
    sess.push(_chunk(rng, WINDOW))
    with pytest.raises(ValueError, match="am_state"):
        sess.adapt(1)


def test_session_adapt_changes_decisions():
    """Persistent wrong-label feedback must eventually flip the session's
    prediction for a repeated frame (the AM really moves)."""
    codes, labels = _train_data(7)
    pipe = HDCPipeline.init(jax.random.PRNGKey(7), _cfg("sparse_compim"))
    pipe = pipe.calibrate_density(codes, 0.25).train_one_shot(codes, labels)
    sess = SeizureSession(pipe)
    rng = np.random.default_rng(1)
    chunk = _chunk(rng, WINDOW)
    [dec] = sess.push(chunk)
    target = 1 - dec.prediction
    for _ in range(8):
        sess.adapt(target)
        [dec] = sess.push(chunk)
        if dec.prediction == target:
            break
    assert dec.prediction == target


# ---------------------------------------------------------------------------
# fleet adapt: bit-exact with per-session loops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["sparse_compim", "sparse_naive", "dense"])
def test_fleet_adapt_matches_session_loop(variant):
    """Random chunk schedules + random masked feedback: the fleet's single
    jitted adapt step must reproduce per-session SeizureSession.adapt calls
    bit-exactly — applied gates, counter files, class rows, and every
    subsequent decision."""
    pipes = {"a": _trained(variant, seed=0, temporal_threshold=4),
             "b": _trained(variant, seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a", "b", "a"]
    fleet = StreamingFleet(pipes, owners, buckets=(8, 16, 64))
    sessions = [SeizureSession(pipes[o]) for o in owners]
    rng = np.random.default_rng(7)
    adapts = 0
    for _ in range(8):
        lens = rng.integers(0, 90, len(owners))
        chunks = [_chunk(rng, int(t)) for t in lens]
        fleet_out = fleet.push(chunks)
        emitted = []
        for i, sess in enumerate(sessions):
            sess_out = sess.push(chunks[i])
            assert len(fleet_out[i]) == len(sess_out)
            for f, s in zip(fleet_out[i], sess_out):
                np.testing.assert_array_equal(f.scores, s.scores)
                np.testing.assert_array_equal(f.frame_hv, s.frame_hv)
            emitted.append(len(sess_out) > 0)
        labels = rng.integers(0, 2, len(owners))
        feedback = rng.random(len(owners)) < 0.7  # some sessions stay silent
        masked = np.where(np.logical_and(emitted, feedback), labels, -1)
        applied = fleet.adapt(masked)
        for i, sess in enumerate(sessions):
            if masked[i] >= 0:
                assert sess.adapt(int(labels[i])) == bool(applied[i])
                adapts += bool(applied[i])
            else:
                assert not applied[i]
            np.testing.assert_array_equal(np.asarray(sess.class_hvs),
                                          fleet.class_rows[i])
    assert adapts > 0  # the schedule really exercised gated updates


def test_fleet_adapt_validation():
    pipe = _trained("sparse_compim", seed=3)
    fleet = StreamingFleet({"p": pipe}, ["p", "p"])
    with pytest.raises(ValueError, match="one label per session"):
        fleet.adapt([1])
    with pytest.raises(ValueError, match="n_classes"):
        fleet.adapt([2, 0])
    # adapt before any frame: silently skipped for every session
    assert not fleet.adapt([1, 1]).any()
    bare = dataclasses.replace(pipe, am_state=None)
    no_state = StreamingFleet({"p": bare}, ["p"])
    with pytest.raises(ValueError, match="am_state"):
        no_state.adapt([1])


def test_fleet_adapt_per_patient_class_density():
    """Patients may configure different class_density targets; the fleet's
    re-threshold must honor each session's own value (bit-exact with the
    per-session loop, which reads it from the pipeline cfg)."""
    pipes = {"a": _trained("sparse_compim", seed=0, class_density=0.3),
             "b": _trained("sparse_compim", seed=1, class_density=0.6)}
    owners = ["a", "b"]
    fleet = StreamingFleet(pipes, owners, buckets=(WINDOW,))
    sessions = [SeizureSession(pipes[o]) for o in owners]
    rng = np.random.default_rng(5)
    chunk = _chunk(rng, WINDOW)
    fleet_out = fleet.push([chunk, chunk])
    for i, sess in enumerate(sessions):
        sess.push(chunk)
    labels = [1 - fleet_out[i][0].prediction for i in range(2)]  # force gates
    applied = fleet.adapt(labels)
    assert applied.all()
    for i, sess in enumerate(sessions):
        assert sess.adapt(labels[i]) is True
        np.testing.assert_array_equal(np.asarray(sess.class_hvs),
                                      fleet.class_rows[i])


# ---------------------------------------------------------------------------
# durable fleets: checkpoint save/restore
# ---------------------------------------------------------------------------

def _assert_same_decisions(a, b):
    for da, db in zip(a, b):
        assert len(da) == len(db)
        for x, y in zip(da, db):
            assert x.frame_index == y.frame_index
            assert x.prediction == y.prediction
            np.testing.assert_array_equal(x.scores, y.scores)
            np.testing.assert_array_equal(x.frame_hv, y.frame_hv)


@pytest.mark.parametrize("variant", ["sparse_compim", "dense"])
def test_fleet_checkpoint_resumes_mid_stream(tmp_path, variant):
    """save -> restore into a FRESH fleet mid-stream (partial windows,
    adapted AMs) must continue bit-exactly with the uninterrupted fleet."""
    pipes = {"a": _trained(variant, seed=0, temporal_threshold=4),
             "b": _trained(variant, seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a"]
    rng = np.random.default_rng(11)
    fleet = StreamingFleet(pipes, owners, buckets=(8, 32))
    # advance mid-stream: odd lengths leave partial accumulator fills
    sched1 = [[_chunk(rng, int(t)) for t in rng.integers(0, 50, 3)]
              for _ in range(3)]
    sched2 = [[_chunk(rng, int(t)) for t in rng.integers(0, 50, 3)]
              for _ in range(3)]
    for chunks in sched1:
        out = fleet.push(chunks)
        labels = np.where([len(o) > 0 for o in out],
                          rng.integers(0, 2, 3), -1)
        fleet.adapt(labels)
    step = fleet.save(str(tmp_path))
    assert step.endswith("step_00000000")
    saved_fill = fleet.fill_levels.copy()
    ref = [fleet.push(chunks) for chunks in sched2]

    fresh = StreamingFleet(pipes, owners, buckets=(8, 32))
    assert fresh.restore(str(tmp_path)) == 0
    np.testing.assert_array_equal(fresh.fill_levels, saved_fill)
    got = [fresh.push(chunks) for chunks in sched2]
    for r, g in zip(ref, got):
        _assert_same_decisions(r, g)


def test_fleet_checkpoint_validates_geometry(tmp_path):
    fleet = StreamingFleet({"p": _trained("sparse_compim", seed=0)}, ["p"])
    fleet.save(str(tmp_path))
    other = StreamingFleet({"p": _trained("sparse_compim", seed=0)},
                           ["p", "p"])
    with pytest.raises(ValueError, match="does not match"):
        other.restore(str(tmp_path))
    # same geometry/session count but a DIFFERENT patient bank: the state
    # would silently score foreign frames against the restored class rows
    foreign = StreamingFleet({"p": _trained("sparse_compim", seed=1)}, ["p"])
    with pytest.raises(ValueError, match="does not match"):
        foreign.restore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        fleet.restore(str(tmp_path / "empty"))


def test_fleet_checkpoint_elastic_onto_mesh(tmp_path):
    """A fleet saved unsharded restores onto a mesh (and keeps deciding
    identically) — the elastic-restore contract."""
    pipes = {"a": _trained("sparse_compim", seed=0)}
    owners = ["a", "a"]
    rng = np.random.default_rng(2)
    plain = StreamingFleet(pipes, owners, buckets=(16, 32))
    plain.push([_chunk(rng, 20), _chunk(rng, 45)])
    plain.save(str(tmp_path))
    mesh = jax.make_mesh((1,), ("data",))
    sharded = StreamingFleet(pipes, owners, buckets=(16, 32), mesh=mesh)
    sharded.restore(str(tmp_path))
    chunks = [_chunk(rng, 40), _chunk(rng, 40)]
    _assert_same_decisions(plain.push(chunks), sharded.push(chunks))


def test_fleet_reset_restores_trained_am(tmp_path):
    pipe = _trained("sparse_compim", seed=9)
    fleet = StreamingFleet({"p": pipe}, ["p"])
    rng = np.random.default_rng(3)
    [out] = fleet.push([_chunk(rng, WINDOW)])
    assert fleet.adapt([1 - out[0].prediction]).all()
    assert not np.array_equal(fleet.class_rows[0], np.asarray(pipe.class_hvs))
    fleet.reset()
    np.testing.assert_array_equal(fleet.class_rows[0],
                                  np.asarray(pipe.class_hvs))
    np.testing.assert_array_equal(fleet.fill_levels, [0])
