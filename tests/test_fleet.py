"""StreamingFleet: fleet-vs-loop bit-exactness (random chunk schedules,
sparse + dense variants), masked emission at window boundaries, bucketed
compile-count guard, sharded placement, and the engine's padded dispatch."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import HDCConfig, HDCPipeline, VARIANTS
from repro.serve.dispatch import datapath_key
from repro.serve.engine import SeizureSession, ServingEngine
from repro.serve.fleet import StreamingFleet

jax.config.update("jax_platform_name", "cpu")

# tiny geometry keeps every jit compile in milliseconds
DIM, SEGMENTS, CHANNELS, WINDOW = 256, 8, 8, 32


def _cfg(variant: str, **overrides) -> HDCConfig:
    base = dict(dim=DIM, segments=SEGMENTS, channels=CHANNELS, window=WINDOW,
                variant=variant, spatial_threshold=1, temporal_threshold=4)
    base.update(overrides)
    return HDCConfig(**base)


def _trained(variant: str, seed: int, **overrides) -> HDCPipeline:
    rng = np.random.default_rng(seed)
    cfg = _cfg(variant, **overrides)
    codes = jnp.asarray(rng.integers(0, 64, (2, 4 * WINDOW, CHANNELS), np.uint8))
    frames = codes.shape[1] // cfg.window
    labels = np.asarray(rng.integers(0, 2, (2, frames), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    pipe = HDCPipeline.init(jax.random.PRNGKey(seed), cfg)
    return pipe.train_one_shot(codes, jnp.asarray(labels))


def _chunk(rng, t):
    return rng.integers(0, 64, (t, CHANNELS), np.uint8)


def _assert_decisions_equal(fleet_dec, session_dec):
    assert len(fleet_dec) == len(session_dec)
    for f, s in zip(fleet_dec, session_dec):
        assert f.frame_index == s.frame_index
        assert f.prediction == s.prediction
        np.testing.assert_array_equal(f.scores, s.scores)
        np.testing.assert_array_equal(f.frame_hv, s.frame_hv)


# ---------------------------------------------------------------------------
# fleet vs per-session loops: bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_fleet_matches_sessions_random_schedule(variant):
    """Random per-session chunk lengths (0, sub-window, window-crossing,
    beyond-max-bucket) must reproduce per-patient SeizureSession loops
    bit-exactly: frame indices, HVs, scores and predictions."""
    # two patients: different codebooks AND different calibrated thresholds
    pipes = {"a": _trained(variant, seed=0, temporal_threshold=4),
             "b": _trained(variant, seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a", "b", "a"]
    fleet = StreamingFleet(pipes, owners, buckets=(8, 16, 64))
    sessions = [SeizureSession(pipes[o]) for o in owners]

    rng = np.random.default_rng(7)
    total = 0
    for _ in range(10):
        lens = rng.integers(0, 90, len(owners))  # 90 > max bucket: splits too
        chunks = [_chunk(rng, int(t)) for t in lens]
        fleet_out = fleet.push(chunks)
        for i, sess in enumerate(sessions):
            _assert_decisions_equal(fleet_out[i], sess.push(chunks[i]))
            total += len(fleet_out[i])
    assert total > 0  # schedule produced real decisions
    np.testing.assert_array_equal(
        fleet.fill_levels, [s.cycles_buffered for s in sessions])


def test_fleet_many_sessions_one_push(no_recompiles):
    """A wide fleet (S >> patients) advances in one step call per bucket."""
    pipe = _trained("sparse_compim", seed=3)
    s = 64
    fleet = StreamingFleet({"p": pipe}, ["p"] * s, buckets=(WINDOW,))
    rng = np.random.default_rng(0)
    chunk = _chunk(rng, WINDOW)
    out = fleet.push([chunk] * s)
    ref = SeizureSession(pipe).push(chunk)
    assert len(ref) == 1
    for dec_list in out:
        _assert_decisions_equal(dec_list, ref)
    # steady state: the single bucketed program is compiled; further pushes
    # must not trigger any XLA compile (shared analysis/guards sanitizer)
    with no_recompiles():
        fleet.push([chunk] * s)


# ---------------------------------------------------------------------------
# masked emission at window boundaries
# ---------------------------------------------------------------------------

def test_masked_emission_at_window_boundaries():
    pipe = _trained("sparse_compim", seed=5)
    fleet = StreamingFleet({"p": pipe}, ["p"] * 3, buckets=(8, 32))
    rng = np.random.default_rng(1)
    # session 0: exactly one window; session 1: one cycle short; session 2: idle
    out = fleet.push([_chunk(rng, WINDOW), _chunk(rng, WINDOW - 1), _chunk(rng, 0)])
    assert [len(o) for o in out] == [1, 0, 0]
    assert out[0][0].frame_index == 0
    np.testing.assert_array_equal(fleet.fill_levels, [0, WINDOW - 1, 0])
    np.testing.assert_array_equal(fleet.frame_indices, [1, 0, 0])
    # one more cycle completes session 1's frame at the boundary; session 0
    # starts its next frame; session 2 stays idle
    out = fleet.push([_chunk(rng, 3), _chunk(rng, 1), _chunk(rng, 0)])
    assert [len(o) for o in out] == [0, 1, 0]
    assert out[1][0].frame_index == 0
    np.testing.assert_array_equal(fleet.fill_levels, [3, 0, 0])
    # a multi-window chunk emits two frames with consecutive indices
    out = fleet.push([_chunk(rng, 2 * WINDOW - 3), _chunk(rng, 0), _chunk(rng, 0)])
    assert [d.frame_index for d in out[0]] == [1, 2]
    np.testing.assert_array_equal(fleet.fill_levels, [0, 0, 0])


def test_fleet_reset_and_validation():
    pipe = _trained("sparse_compim", seed=5)
    fleet = StreamingFleet({"p": pipe}, ["p", "p"])
    rng = np.random.default_rng(2)
    fleet.push([_chunk(rng, WINDOW), _chunk(rng, 5)])
    fleet.reset()
    np.testing.assert_array_equal(fleet.fill_levels, [0, 0])
    np.testing.assert_array_equal(fleet.frame_indices, [0, 0])
    with pytest.raises(ValueError, match="one chunk per session"):
        fleet.push([_chunk(rng, 5)])
    with pytest.raises(ValueError, match="chunk must be"):
        fleet.push([_chunk(rng, 5), _chunk(rng, 5)[:, :3]])
    with pytest.raises(KeyError, match="owners"):
        StreamingFleet({"p": pipe}, ["p", "nobody"])
    untrained = HDCPipeline.init(jax.random.PRNGKey(0), _cfg("sparse_compim"))
    with pytest.raises(ValueError, match="untrained"):
        StreamingFleet({"p": untrained}, ["p"])
    mixed = {"p": pipe, "q": _trained("sparse_compim", seed=6, window=2 * WINDOW)}
    with pytest.raises(ValueError, match="mismatch"):
        StreamingFleet(mixed, ["p", "q"])


# ---------------------------------------------------------------------------
# compile-count guard: bucketed chunk lengths must not fan out recompiles
# ---------------------------------------------------------------------------

def test_bucketed_lengths_bound_compiles(no_recompiles):
    pipe = _trained("sparse_compim", seed=9)
    buckets = (8, 32)
    fleet = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=buckets)
    rng = np.random.default_rng(3)
    lengths = (1, 3, 8, 5, 20, 32, 17, 40, 2, 31, 9, 64)
    # every chunk length (incl. > max bucket, split over rounds) maps onto
    # the fixed bucket set: at most one XLA compile per bucket...
    with no_recompiles(allow=len(buckets)):
        for t in lengths:
            fleet.push([_chunk(rng, t), _chunk(rng, max(0, t - 1))])
    # ...and replaying every length is pure steady state: zero compiles
    with no_recompiles():
        for t in lengths:
            fleet.push([_chunk(rng, t), _chunk(rng, max(0, t - 1))])


class _NoCacheSize:
    """Wraps the jitted step but hides the private ``_cache_size`` API."""

    def __init__(self, fn):
        self._fn = fn

    def __call__(self, *args, **kwargs):
        return self._fn(*args, **kwargs)


def test_compile_count_bucket_fallback(monkeypatch):
    """If jax's private ``_cache_size`` disappears, ``compile_count`` falls
    back to counting distinct bucket shapes — and must still count
    multi-bucket pushes correctly (one entry per bucket, not per push)."""
    pipe = _trained("sparse_compim", seed=9)
    fleet = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=(8, 32))
    monkeypatch.setattr(fleet, "_step", _NoCacheSize(fleet._step))
    assert not hasattr(fleet._step, "_cache_size")
    assert fleet.compile_count == 0
    rng = np.random.default_rng(4)
    fleet.push([_chunk(rng, 5), _chunk(rng, 3)])     # bucket 8
    assert fleet.compile_count == 1
    fleet.push([_chunk(rng, 7), _chunk(rng, 0)])     # bucket 8 again
    assert fleet.compile_count == 1
    # 40 > max bucket: splits into a 32-round AND an 8-round in ONE push
    fleet.push([_chunk(rng, 40), _chunk(rng, 12)])
    assert fleet.compile_count == 2
    # decisions through the wrapped step still work
    out = fleet.push([_chunk(rng, WINDOW), _chunk(rng, 0)])
    assert len(out[0]) >= 1


def test_push_codes_matches_push():
    """The zero-scatter stacked-ingest path (per-tile staging rings, one
    device put per tile per round) must be bit-exact with the ragged-list
    path — including reused staging buffers across pushes with shrinking
    lengths (stale ring bytes must never leak into decisions)."""
    pipes = {"a": _trained("sparse_compim", seed=0, temporal_threshold=4),
             "b": _trained("sparse_compim", seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a"]
    fleet_list = StreamingFleet(pipes, owners, buckets=(8, 32))
    fleet_codes = StreamingFleet(pipes, owners, buckets=(8, 32))
    rng = np.random.default_rng(21)
    # equal lengths first (fills the staging rings), then shorter and
    # ragged-length pushes that leave stale bytes behind
    for t, ragged in ((40, False), (32, False), (5, False), (17, True),
                      (3, True), (0, False), (9, False)):
        if ragged:
            lens = rng.integers(0, t + 1, len(owners))
        else:
            lens = np.full(len(owners), t)
        chunks = [_chunk(rng, int(L)) for L in lens]
        via_list = fleet_list.push(chunks)
        batch = np.zeros((len(owners), t, CHANNELS), np.uint8)
        for i, c in enumerate(chunks):
            batch[i, :len(c)] = c
        via_codes = fleet_codes.push_codes(batch, lengths=lens)
        for da, db in zip(via_list, via_codes):
            _assert_decisions_equal(da, db)
    np.testing.assert_array_equal(fleet_list.fill_levels,
                                  fleet_codes.fill_levels)


def test_staging_ring_double_buffer_discipline():
    """The staging rings are zero-copy-aliased by device_put on CPU, so a
    slot may be rewritten only after the round that read it completed:
    consecutive rounds must alternate slots and record a completion marker
    per slot, and results must stay bit-exact across slot reuse."""
    pipe = _trained("sparse_compim", seed=3)
    fleet = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=(WINDOW,))
    sessions = [SeizureSession(pipe) for _ in range(2)]
    rng = np.random.default_rng(9)
    # 4 full-bucket rounds -> each slot reused twice
    for i in range(4):
        chunks = [_chunk(rng, WINDOW), _chunk(rng, WINDOW)]
        out = fleet.push(chunks)
        for j, s in enumerate(sessions):
            _assert_decisions_equal(out[j], s.push(chunks[j]))
    assert fleet._stage_phase == 4
    for per_tile in fleet._stage_busy:
        # both (slot, bucket) buffers carry a completion marker
        assert {(0, WINDOW), (1, WINDOW)} <= set(per_tile)


def test_stage_probes_stages_and_backend_guard():
    """stage_probes exposes the four stage callables for a jnp fleet (the
    bench + CI spatial-share gate depend on them) and refuses a pallas
    fleet, whose fused kernel has no separable stages to time."""
    pipe = _trained("sparse_compim", seed=3)
    fleet = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=(WINDOW,))
    rng = np.random.default_rng(2)
    batch = np.stack([_chunk(rng, WINDOW)] * 2)
    probes = fleet.stage_probes(batch)
    assert set(probes) == {"ingest", "spatial", "temporal", "am"}
    for fn, scale in probes.values():
        assert scale >= 1
        fn()  # runs and blocks without error
    pallas = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=(WINDOW,),
                            backend="pallas")
    with pytest.raises(ValueError, match="backend='jnp'"):
        pallas.stage_probes(batch)


def test_push_codes_validation():
    pipe = _trained("sparse_compim", seed=3)
    fleet = StreamingFleet({"p": pipe}, ["p"] * 2, buckets=(8,))
    with pytest.raises(ValueError, match="push_codes needs"):
        fleet.push_codes(np.zeros((3, 8, CHANNELS), np.uint8))
    with pytest.raises(ValueError, match="lengths must be"):
        fleet.push_codes(np.zeros((2, 8, CHANNELS), np.uint8),
                         lengths=[9, 0])
    assert fleet.push_codes(np.zeros((2, 0, CHANNELS), np.uint8)) == [[], []]


@pytest.mark.parametrize("variant", VARIANTS)
def test_fleet_pallas_backend_matches_jnp(variant):
    """backend="pallas" (fused code-domain VMEM kernel, interpret mode on
    CPU) must reproduce the jnp bit-plane path decision-for-decision."""
    pipes = {"a": _trained(variant, seed=0, temporal_threshold=4),
             "b": _trained(variant, seed=1, temporal_threshold=6)}
    owners = ["a", "b", "b"]
    fj = StreamingFleet(pipes, owners, buckets=(8, 32), backend="jnp")
    fp = StreamingFleet(pipes, owners, buckets=(8, 32), backend="pallas")
    rng = np.random.default_rng(5)
    for _ in range(3):
        chunks = [_chunk(rng, int(t))
                  for t in rng.integers(0, 40, len(owners))]
        for a, b in zip(fj.push(chunks), fp.push(chunks)):
            _assert_decisions_equal(a, b)


def test_push_raw_matches_push():
    """push_raw + collect_decisions is push; raw rounds expose the schedule
    (n_emit / frame_base) and per-tile device outputs without syncing."""
    pipes = {"a": _trained("sparse_compim", seed=0, temporal_threshold=4),
             "b": _trained("sparse_compim", seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a"]
    fleet_a = StreamingFleet(pipes, owners, buckets=(8, 32))
    fleet_b = StreamingFleet(pipes, owners, buckets=(8, 32))
    rng = np.random.default_rng(12)
    for _ in range(5):
        lens = rng.integers(0, 70, len(owners))
        chunks = [_chunk(rng, int(t)) for t in lens]
        via_push = fleet_a.push(chunks)
        rounds = fleet_b.push_raw(chunks)
        assert all(isinstance(r.tiles, tuple) for r in rounds)
        via_raw = fleet_b.collect_decisions(rounds)
        for da, db in zip(via_push, via_raw):
            _assert_decisions_equal(da, db)
        # schedule consistency: emitted counts sum to collected decisions
        total = sum(int(r.n_emit.sum()) for r in rounds)
        assert total == sum(len(d) for d in via_raw)


# ---------------------------------------------------------------------------
# sharded placement
# ---------------------------------------------------------------------------

def test_fleet_on_mesh_matches_unsharded():
    """A 1-device data mesh must not change any decision (SPMD placement is
    a deployment knob, not a modeling knob)."""
    pipes = {"a": _trained("sparse_compim", seed=0, temporal_threshold=4),
             "b": _trained("sparse_compim", seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a", "b"]
    mesh = jax.make_mesh((1,), ("data",))
    plain = StreamingFleet(pipes, owners, buckets=(16, 32))
    sharded = StreamingFleet(pipes, owners, buckets=(16, 32), mesh=mesh)
    rng = np.random.default_rng(11)
    for _ in range(4):
        chunks = [_chunk(rng, int(t))
                  for t in rng.integers(0, 40, len(owners))]
        for a, b in zip(sharded.push(chunks), plain.push(chunks)):
            _assert_decisions_equal(a, b)


# ---------------------------------------------------------------------------
# engine: single padded dispatch on the same machinery
# ---------------------------------------------------------------------------

def test_engine_mixed_codebooks_matches_direct_infer():
    """Patients with DIFFERENT design-time codebooks (distinct init keys) in
    one bank: the single owner-gathered dispatch must match each pipeline's
    own infer bit-exactly, including padded batch sizes."""
    bank = {"a": _trained("sparse_compim", seed=0, temporal_threshold=4),
            "b": _trained("sparse_compim", seed=1, temporal_threshold=6),
            "c": _trained("sparse_compim", seed=2, temporal_threshold=5)}
    engine = ServingEngine(bank)
    rng = np.random.default_rng(4)
    for pids in (["a"], ["b", "a", "c"], ["c", "c", "a", "b", "a"]):
        reqs = [(pid, _chunk(rng, 2 * WINDOW)) for pid in pids]
        decisions = engine.serve(reqs)
        for (pid, codes), dec in zip(reqs, decisions):
            s, p = bank[pid].infer(jnp.asarray(codes[None]))
            np.testing.assert_array_equal(dec.scores, np.asarray(s)[0])
            np.testing.assert_array_equal(dec.predictions, np.asarray(p)[0])
            frames = bank[pid].encode_frames(jnp.asarray(codes[None]))
            np.testing.assert_array_equal(dec.frames, np.asarray(frames)[0])


def test_engine_batch_sizes_bucketed():
    from repro.serve import engine as engine_mod
    if not hasattr(engine_mod._serve_dispatch, "_cache_size"):
        pytest.skip("jax private _cache_size API unavailable")
    bank = {"a": _trained("sparse_compim", seed=0)}
    engine = ServingEngine(bank)
    rng = np.random.default_rng(4)
    before = engine_mod._serve_dispatch._cache_size()
    for b in (1, 2, 3, 4, 3, 2, 4):
        engine.serve([("a", _chunk(rng, WINDOW)) for _ in range(b)])
    # batch sizes 1..4 pad onto power-of-two buckets {1, 2, 4}
    assert engine_mod._serve_dispatch._cache_size() - before <= 3


def test_datapath_key_normalizes_only_per_patient_fields():
    import dataclasses

    cfg = _cfg("sparse_compim")
    same = dataclasses.replace(cfg, temporal_threshold=99, backend="pallas")
    assert datapath_key(cfg) == datapath_key(same)
    other = dataclasses.replace(cfg, window=2 * WINDOW)
    assert datapath_key(cfg) != datapath_key(other)


# ---------------------------------------------------------------------------
# benchmark harness: errors must propagate (no silent CSV-only failures)
# ---------------------------------------------------------------------------

def test_bench_run_propagates_errors(tmp_path, capsys):
    bench_run = pytest.importorskip("benchmarks.run")
    rc = bench_run.main(["no_such_bench", "--out-dir", str(tmp_path)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "no_such_bench.ERROR" in out
    payload = json.loads((tmp_path / "BENCH_no_such_bench.json").read_text())
    assert payload["status"] == "error"
    assert "ModuleNotFoundError" in payload["error"]


def test_bench_json_written_for_ok_module(tmp_path):
    from benchmarks.common import write_bench_json
    rows = [{"name": "x", "us_per_call": "1", "derived": "ok"}]
    path = write_bench_json(str(tmp_path), "demo", rows)
    payload = json.loads(open(path).read())
    assert payload == {"module": "demo", "status": "ok", "rows": rows,
                       "error": None}
