"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle, with
shape sweeps and hypothesis property tests."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import classifier, hv
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg
from repro.kernels.hdc_encoder.kernel import encoder_pallas
from repro.kernels.hdc_encoder.ref import encoder_ref
from repro.kernels.hdc_encoder.ops import encode_frames_fused
from repro.kernels.hdc_am.kernel import am_search_pallas
from repro.kernels.hdc_am.ref import am_search_ref
from repro.kernels.hdc_am.ops import am_search
from repro.kernels.dense_hdc.kernel import dense_encoder_pallas
from repro.kernels.dense_hdc.ref import dense_encoder_ref
from repro.kernels.dense_hdc.ops import dense_encode_frames_fused
from repro.kernels.lbp.kernel import lbp_pallas
from repro.kernels.lbp.ref import lbp_ref
from repro.kernels.lbp.ops import lbp_codes

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# hdc_encoder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,window,c,segments,seg_len", [
    (1, 1, 32, 4, 8, 128),
    (2, 3, 64, 16, 8, 128),
    (1, 2, 32, 8, 4, 64),
    (2, 1, 64, 64, 8, 128),     # paper-shaped channels
    (1, 1, 32, 4, 16, 128),
])
def test_encoder_kernel_vs_ref_shapes(b, f, window, c, segments, seg_len):
    key = jax.random.PRNGKey(b * 100 + f)
    k1, k2 = jax.random.split(key)
    pos = hv.random_sparse_positions(k1, (b, f, window, c), segments, seg_len)
    elec = hv.random_sparse_positions(k2, (c,), segments, seg_len)
    kw = dict(window=window, segments=segments, seg_len=seg_len,
              temporal_threshold=max(1, window // 8))
    out_k = encoder_pallas(pos, elec, interpret=True, **kw)
    out_r = encoder_ref(pos, elec, **kw)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("thinning,thr_s", [(False, 1), (True, 1), (True, 2)])
def test_encoder_kernel_spatial_modes(thinning, thr_s):
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    pos = hv.random_sparse_positions(k1, (1, 2, 64, 16), 8, 128)
    elec = hv.random_sparse_positions(k2, (16,), 8, 128)
    kw = dict(window=64, segments=8, seg_len=128, temporal_threshold=8,
              spatial_thinning=thinning, spatial_threshold=thr_s)
    np.testing.assert_array_equal(
        np.asarray(encoder_pallas(pos, elec, interpret=True, **kw)),
        np.asarray(encoder_ref(pos, elec, **kw)))


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@settings(max_examples=8, deadline=None)
def test_encoder_kernel_property(seed, thr):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    pos = hv.random_sparse_positions(k1, (1, 1, 32, 8), 8, 128)
    elec = hv.random_sparse_positions(k2, (8,), 8, 128)
    kw = dict(window=32, segments=8, seg_len=128, temporal_threshold=thr)
    np.testing.assert_array_equal(
        np.asarray(encoder_pallas(pos, elec, interpret=True, **kw)),
        np.asarray(encoder_ref(pos, elec, **kw)))


def test_encode_frames_fused_matches_core_classifier():
    """The fused kernel path must be bit-exact with core.classifier on the
    paper configuration and real (synthetic-patient) codes."""
    cfg = classifier.HDCConfig()
    params = classifier.init_params(jax.random.PRNGKey(42), cfg)
    codes = jnp.asarray(ieeg.make_patient(3, n_seizures=1).records[0].codes[None, :2048])
    fused = encode_frames_fused(params, codes, cfg, use_kernel=True)
    unfused = classifier.encode_frames(params, codes, cfg)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


# ---------------------------------------------------------------------------
# hdc_am
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,c,words", [(1, 2, 32), (7, 2, 32), (300, 4, 32),
                                       (64, 2, 16), (5, 8, 64)])
@pytest.mark.parametrize("mode", ["overlap", "hamming"])
def test_am_kernel_vs_ref(b, c, words, mode):
    key = jax.random.PRNGKey(b + c)
    k1, k2 = jax.random.split(key)
    q = jax.random.bits(k1, (b, words), dtype=jnp.uint32)
    cls = jax.random.bits(k2, (c, words), dtype=jnp.uint32)
    dim = words * 32
    out_k = am_search_pallas(q, cls, mode=mode, dim=dim, interpret=True)
    out_r = am_search_ref(q, cls, mode=mode, dim=dim)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_am_ops_leading_dims():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    q = jax.random.bits(k1, (3, 5, 32), dtype=jnp.uint32)
    cls = jax.random.bits(k2, (2, 32), dtype=jnp.uint32)
    out = am_search(q, cls, mode="overlap", dim=1024)
    assert out.shape == (3, 5, 2)
    np.testing.assert_array_equal(
        np.asarray(out.reshape(-1, 2)),
        np.asarray(am_search_ref(q.reshape(-1, 32), cls, mode="overlap", dim=1024)))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_am_kernel_score_bounds(seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.bits(k1, (4, 32), dtype=jnp.uint32)
    cls = jax.random.bits(k2, (2, 32), dtype=jnp.uint32)
    s = np.asarray(am_search_pallas(q, cls, mode="overlap", dim=1024, interpret=True))
    qpop = np.asarray(hv.popcount(q))
    assert (s >= 0).all() and (s <= qpop[:, None]).all()


# ---------------------------------------------------------------------------
# dense_hdc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,window,c,dim", [
    (1, 1, 32, 4, 1024), (2, 2, 64, 8, 1024), (1, 1, 32, 16, 512)])
def test_dense_kernel_vs_ref(b, f, window, c, dim):
    key = jax.random.PRNGKey(b * 7 + f)
    k1, k2 = jax.random.split(key)
    item = jax.random.bits(k1, (b, f, window, c, dim // 32), dtype=jnp.uint32)
    elec = jax.random.bits(k2, (c, dim // 32), dtype=jnp.uint32)
    out_k = dense_encoder_pallas(item, elec, window=window, dim=dim, interpret=True)
    out_r = dense_encoder_ref(item, elec, window=window, dim=dim)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_dense_fused_matches_core():
    dcfg = HDCConfig(variant="dense")
    pipe = HDCPipeline.init(jax.random.PRNGKey(7), dcfg)
    codes = jnp.asarray(ieeg.make_patient(5, n_seizures=1).records[0].codes[None, :1024])
    fused = dense_encode_frames_fused(pipe.params, codes, dcfg, use_kernel=True)
    unfused = pipe.encode_frames(codes)   # jnp backend = unfused reference
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


# ---------------------------------------------------------------------------
# lbp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,c,bits", [(1, 100, 4, 6), (3, 257, 8, 6),
                                        (2, 64, 64, 4), (1, 1000, 2, 8)])
def test_lbp_kernel_vs_ref(b, t, c, bits):
    x = jax.random.normal(jax.random.PRNGKey(t), (b, t, c))
    out_k = lbp_pallas(x, bits=bits, interpret=True)
    out_r = lbp_ref(x, bits=bits)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_lbp_matches_numpy_reference():
    """Kernel output must agree with the numpy preprocessing used by the
    synthetic-data generator (channel-major ieeg.lbp_codes_np)."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2, 300, 5)).astype(np.float32)
    out = np.asarray(lbp_codes(jnp.asarray(x), use_kernel=True))
    ref = np.stack([ieeg.lbp_codes_np(x[i].T).T for i in range(2)])
    np.testing.assert_array_equal(out, ref)


def test_lbp_long_stream_chunking():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 40006, 3))
    out = lbp_codes(x, use_kernel=True)
    assert out.shape == (1, 40000, 3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(lbp_ref(x)))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_lbp_codes_in_range(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 64, 3))
    out = np.asarray(lbp_pallas(x, bits=6, interpret=True))
    assert out.dtype == np.uint8 and (out < 64).all()


# ---------------------------------------------------------------------------
# hdc_fleet: bit-plane masked temporal bundling (ref + fused kernel)
# ---------------------------------------------------------------------------

def _einsum_slot_counts(words, filled, lengths, window):
    """Dense-mask oracle: the pre-bit-plane formulation (unpack -> f32
    einsum against host-built cycle masks), kept as the reference here."""
    s, t, w = words.shape
    k_max = (t - 1) // window + 1
    j = np.arange(t)
    ordinal = (filled[:, None] + j[None, :]) // window
    valid = j[None, :] < lengths[:, None]
    n_emit = (filled + lengths) // window
    rows = np.arange(k_max)
    frame = ((ordinal[:, None, :] == rows[None, :, None])
             & (rows[None, :, None] < n_emit[:, None, None])
             & valid[:, None, :])
    tail = (ordinal >= n_emit[:, None]) & valid
    masks = np.concatenate([frame, tail[:, None, :]], 1).astype(np.float32)
    bits = ((words[..., None] >> np.arange(32, dtype=np.uint32)) & 1)
    bits = bits.reshape(s, t, w * 32).astype(np.float32)
    return np.einsum("skt,std->skd", masks, bits).astype(np.int32)


@pytest.mark.parametrize("t_pad,window", [(8, 32), (32, 32), (64, 32),
                                          (96, 64), (64, 17)])
def test_fleet_counts_ref_matches_einsum_oracle(t_pad, window):
    from repro.kernels.hdc_fleet.ref import fleet_counts_ref
    rng = np.random.default_rng(t_pad * 100 + window)
    s, w = 7, 4
    words = rng.integers(0, 2**32, (s, t_pad, w), dtype=np.uint32)
    filled = rng.integers(0, window, s).astype(np.int32)
    lengths = rng.integers(0, t_pad + 1, s).astype(np.int32)
    got = np.asarray(fleet_counts_ref(
        jnp.asarray(words), jnp.asarray(filled), jnp.asarray(lengths),
        window=window, dim=w * 32))
    want = _einsum_slot_counts(words, filled, lengths, window)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mode,threshold", [("or", 0), ("thin", 2),
                                            ("majority", 0)])
def test_fleet_kernel_vs_ref(mode, threshold):
    """The fused code-domain kernel (VMEM table gather + spatial bundle +
    bit transpose + masked popcount) must match the jnp bit-plane path for
    every spatial-bundle mode, with per-session owner-gathered tables."""
    from repro.kernels.hdc_fleet.kernel import fleet_counts_pallas
    from repro.kernels.hdc_fleet.ref import emission_masks, fleet_counts_ref
    rng = np.random.default_rng(3)
    s, t, c, w, window, p, k = 5, 64, 6, 2, 32, 3, 8
    dim = w * 32
    tables = rng.integers(0, 2**32, (p, c, k, w), dtype=np.uint32)
    owner = rng.integers(0, p, s).astype(np.int32)
    codes = rng.integers(0, k, (s, t, c), dtype=np.uint8)
    filled = jnp.asarray(rng.integers(0, window, s), jnp.int32)
    lengths = jnp.asarray(rng.integers(0, t + 1, s), jnp.int32)
    # gather + spatial bundle in numpy -> per-cycle words for the ref path
    bound = tables[owner[:, None, None],
                   np.arange(c)[None, None, :], codes]     # (s, t, c, w)
    bits = ((bound[..., None] >> np.arange(32, dtype=np.uint32)) & 1)
    bits = bits.reshape(s, t, c, dim)
    if mode == "or":
        spat = bits.any(axis=2)
    elif mode == "thin":
        spat = bits.sum(axis=2) >= threshold
    else:
        spat = bits.sum(axis=2) * 2 > c
    words = hv.np_pack_bits(spat.astype(np.uint8))
    ref = np.asarray(fleet_counts_ref(
        jnp.asarray(words), filled, lengths, window=window, dim=dim))
    tm = emission_masks(filled, lengths, t_pad=t, window=window)
    got = np.asarray(fleet_counts_pallas(
        jnp.asarray(tables), jnp.asarray(owner), jnp.asarray(codes), tm,
        mode=mode, dim=dim, threshold=threshold, interpret=True))
    np.testing.assert_array_equal(got, ref)


def test_fleet_fused_ops_matches_code_domain_jnp():
    """ops.fleet_counts_fused (codes in, counts out, incl. the 32-padding of
    the cycle axis) must match owner_spatial_codes + fleet_counts for a real
    trained bank and a ragged (non-32-multiple) chunk."""
    from repro.kernels.hdc_fleet import ops as fleet_ops
    from repro.serve import dispatch

    cfg = classifier.HDCConfig(dim=256, segments=8, channels=8, window=32,
                               temporal_threshold=4)
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 64, (2, 4 * 32, 8), np.uint8))
    labels = jnp.asarray([[0, 1, 0, 1], [1, 0, 1, 0]])
    pipes = [HDCPipeline.init(jax.random.PRNGKey(i), cfg).train_one_shot(
        codes, labels) for i in range(2)]
    tables, _ = dispatch.stack_bound_tables(pipes)
    owner = jnp.asarray([0, 1, 1, 0, 1], jnp.int32)
    chunk = jnp.asarray(rng.integers(0, 64, (5, 43, 8), np.uint8))
    filled = jnp.asarray(rng.integers(0, 32, 5), jnp.int32)
    lengths = jnp.asarray(rng.integers(0, 44, 5), jnp.int32)
    got = np.asarray(fleet_ops.fleet_counts_fused(
        tables, owner, chunk, filled, lengths, cfg))
    words = dispatch.owner_spatial_codes(tables, owner, chunk, cfg)
    want = np.asarray(fleet_ops.fleet_counts(words, filled, lengths, cfg))
    np.testing.assert_array_equal(got, want)


@given(st.integers(0, 2**63))
@settings(max_examples=10, deadline=None)
def test_fleet_counts_ref_property(seed):
    from repro.kernels.hdc_fleet.ref import fleet_counts_ref
    rng = np.random.default_rng(seed)
    s, t_pad, w, window = 4, 40, 2, 16
    words = rng.integers(0, 2**32, (s, t_pad, w), dtype=np.uint32)
    filled = rng.integers(0, window, s).astype(np.int32)
    lengths = rng.integers(0, t_pad + 1, s).astype(np.int32)
    got = np.asarray(fleet_counts_ref(
        jnp.asarray(words), jnp.asarray(filled), jnp.asarray(lengths),
        window=window, dim=w * 32))
    want = _einsum_slot_counts(words, filled, lengths, window)
    np.testing.assert_array_equal(got, want)
