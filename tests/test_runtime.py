"""Runtime tests: checkpoint roundtrip, elastic reshard, fault-tolerant
resume (bitwise-identical continuation), gradient compression, optimizer."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.optim import adamw, compress

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(key):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return {"a": jax.random.normal(k1, (4, 8)),
            "nested": {"b": jax.random.normal(k2, (3,)),
                       "c": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(0)
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored = ckpt.restore(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory (simulated mid-save crash) is never listed."""
    tree = _tree(1)
    ckpt.save(str(tmp_path), 3, tree)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_gc_keeps_last(tmp_path):
    tree = _tree(2)
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        c.save_async(s, tree)
        c.wait()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_async_checkpoint(tmp_path):
    tree = _tree(3)
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    c.save_async(7, tree)
    c.wait()
    step, restored = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_adamw_decreases_loss():
    opt = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([2.0, -3.0])}
    state = adamw.init_state(params, opt)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"]))

    losses = []
    for _ in range(50):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw.apply_updates(params, g, state, opt)
        losses.append(float(loss_fn(params)))
    assert losses[-1] < 0.1 * losses[0]


def test_adamw_bf16_state():
    opt = adamw.OptConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw.init_state(params, opt)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,), jnp.bfloat16) * 0.1}
    p2, s2, _ = adamw.apply_updates(params, g, state, opt)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["v"]["w"].dtype == jnp.bfloat16


def test_grad_compression_error_feedback():
    """Quantization error is carried in the residual, so the SUM of applied
    updates converges to the true gradient sum (error feedback property)."""
    g = {"w": jnp.asarray(np.linspace(-1e-3, 2e-3, 64), jnp.float32)}
    residual = compress.init_residual(g)
    applied = jnp.zeros(64)
    for _ in range(16):
        deq, residual = compress.compress_decompress(g, residual)
        applied = applied + deq["w"].astype(jnp.float32)
    true_sum = g["w"] * 16
    err = float(jnp.abs(applied - true_sum).max() / jnp.abs(true_sum).max())
    assert err < 0.05, err


def test_grad_compression_int8_range():
    g = {"w": jnp.asarray([1e4, -2e4, 3.3], jnp.float32)}
    res = compress.init_residual(g)
    deq, _ = compress.compress_decompress(g, res)
    assert jnp.isfinite(deq["w"]).all()


def test_schedule_shape():
    opt = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(opt, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] < 0.01                    # cosine decayed


# ---------------------------------------------------------------------------
# end-to-end fault tolerance (subprocess: own device env)
# ---------------------------------------------------------------------------

def _run_train(args, env_extra=None):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)


@pytest.mark.slow
def test_fault_tolerant_resume_bitwise(tmp_path):
    """Train 8 steps straight vs train-with-injected-crash-at-5 + auto-resume:
    final losses must match exactly (stateless data pipeline + checkpoint)."""
    base = ["--arch", "qwen3-0.6b", "--reduced", "--steps", "8",
            "--batch", "2", "--seq", "32", "--ckpt-every", "2"]
    r1 = _run_train(base + ["--ckpt-dir", str(tmp_path / "a"), "--fresh"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = _run_train(base + ["--ckpt-dir", str(tmp_path / "b"), "--fresh",
                            "--fail-at", "5"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "restarting from latest checkpoint" in r2.stdout

    def final_loss(out):
        lines = [ln for ln in out.splitlines() if ln.startswith("done: final_loss=")]
        return float(lines[-1].split("=")[1].split()[0])

    assert abs(final_loss(r1.stdout) - final_loss(r2.stdout)) < 1e-5


@pytest.mark.slow
def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint written while training on a 1x2 mesh restores and continues
    on a 2x1 mesh (elastic restart after losing/gaining devices)."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=2"}
    base = ["--arch", "qwen3-0.6b", "--reduced", "--steps", "4",
            "--batch", "2", "--seq", "32", "--ckpt-every", "2",
            "--ckpt-dir", str(tmp_path)]
    r1 = _run_train(base + ["--mesh", "1x2", "--fresh"], env_extra=env)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = _run_train(["--arch", "qwen3-0.6b", "--reduced", "--steps", "8",
                     "--batch", "2", "--seq", "32", "--ckpt-every", "2",
                     "--ckpt-dir", str(tmp_path), "--mesh", "2x1"],
                    env_extra=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "restored step 4" in r2.stdout
