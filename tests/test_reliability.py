"""Reliability subsystem: packed-domain fault injection + ECC-protected AMs.

Three layers under test:

* the primitives — ``hv.word_parity`` / ``hv.random_flip_mask`` and the
  SECDED / parity word codecs (every single-bit flip of the 39-bit
  codeword must correct, every double flip must detect);
* the fault model — ``FaultConfig`` validation, the static/traced split,
  transient vs stuck semantics;
* the fleet integration — BER = 0 must be BIT-EXACT with the unmodified
  step on BOTH backends (the acceptance gate), high BER must actually
  corrupt decisions, and SECDED must demonstrably recover single-bit AM
  faults at fleet scale with its energy priced through hwmodel constants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hv, hwmodel
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg
from repro.reliability import ecc
from repro.reliability.faults import (FaultConfig, FaultPlan, component_keys,
                                      flip_counts, step_seed, xor_mask)
from repro.serve.fleet import StreamingFleet

jax.config.update("jax_platform_name", "cpu")

DIM, SEGMENTS, CHANNELS, WINDOW = 256, 8, 8, 32


def _cfg(**overrides) -> HDCConfig:
    kw = dict(dim=DIM, segments=SEGMENTS, channels=CHANNELS, window=WINDOW,
              temporal_threshold=4)
    kw.update(overrides)
    return HDCConfig(**kw)


def _trained(seed: int = 0, **overrides) -> tuple[HDCPipeline, HDCConfig]:
    cfg = _cfg(**overrides)
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, cfg.codes, (2, 4 * WINDOW, CHANNELS), np.uint8))
    labels = jnp.asarray([[0, 1, 0, 1], [1, 0, 1, 0]])
    pipe = HDCPipeline.init(jax.random.PRNGKey(seed), cfg).train_one_shot(
        codes, labels)
    return pipe, cfg


def _decisions(fleet, chunks, rounds=3, seed=5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(rounds):
        batch = [rng.integers(0, 64, (WINDOW, CHANNELS), np.uint8)
                 for _ in range(chunks)]
        out.append(fleet.push(batch))
    return out


def _assert_decisions_equal(a, b):
    for ra, rb in zip(a, b):
        for da, db in zip(ra, rb):
            assert len(da) == len(db)
            for x, y in zip(da, db):
                assert x.prediction == y.prediction
                np.testing.assert_array_equal(x.scores, y.scores)
                np.testing.assert_array_equal(x.frame_hv, y.frame_hv)


# ---------------------------------------------------------------------------
# packed-domain primitives
# ---------------------------------------------------------------------------

def test_word_parity():
    w = jnp.asarray([0, 1, 3, 0xFFFFFFFF, 0x80000001], jnp.uint32)
    np.testing.assert_array_equal(np.asarray(hv.word_parity(w)),
                                  [0, 1, 0, 0, 0])


def test_random_flip_mask_extremes():
    key = jax.random.PRNGKey(0)
    zero = hv.random_flip_mask(key, (16,), 0.0)
    np.testing.assert_array_equal(np.asarray(zero), 0)
    full = hv.random_flip_mask(key, (16,), 1.0)
    np.testing.assert_array_equal(np.asarray(full), 0xFFFFFFFF)
    low = hv.random_flip_mask(key, (16,), 1.0, bits=5)
    np.testing.assert_array_equal(np.asarray(low), 0x1F)  # high bits stay 0
    for bad in (0, 33, -1):
        with pytest.raises(ValueError, match="bits"):
            hv.random_flip_mask(key, (4,), 0.5, bits=bad)


def test_random_flip_mask_rate():
    m = hv.random_flip_mask(jax.random.PRNGKey(1), (2048,), 0.1)
    rate = sum(int(x).bit_count() for x in np.asarray(m)) / (2048 * 32)
    assert 0.08 < rate < 0.12


# ---------------------------------------------------------------------------
# ECC codecs
# ---------------------------------------------------------------------------

def test_secded_roundtrip_clean():
    words = jnp.asarray(np.random.default_rng(2).integers(
        0, 1 << 32, 256, np.uint32))
    check = ecc.encode(words, "secded")
    corrected, status = ecc.decode(words, check, "secded")
    np.testing.assert_array_equal(np.asarray(corrected), np.asarray(words))
    np.testing.assert_array_equal(np.asarray(status), ecc.CLEAN)


@pytest.mark.parametrize("bit", range(32))
def test_secded_corrects_every_single_data_bit(bit):
    words = jnp.asarray([0x5A5A5A5A], jnp.uint32)
    check = ecc.encode(words, "secded")
    corrupt = words ^ jnp.uint32(1 << bit)
    corrected, status = ecc.decode(corrupt, check, "secded")
    assert int(status[0]) == ecc.CORRECTED
    assert int(corrected[0]) == int(words[0])


@pytest.mark.parametrize("bit", range(7))
def test_secded_tolerates_every_single_check_bit(bit):
    words = jnp.asarray([0xDEADBEEF], jnp.uint32)
    check = ecc.encode(words, "secded") ^ jnp.uint32(1 << bit)
    corrected, status = ecc.decode(words, check, "secded")
    assert int(status[0]) == ecc.CORRECTED  # data already clean
    assert int(corrected[0]) == int(words[0])


def test_secded_detects_double_flips():
    words = jnp.asarray([0x12345678], jnp.uint32)
    check = ecc.encode(words, "secded")
    rng = np.random.default_rng(3)
    for _ in range(32):
        b1, b2 = rng.choice(32, size=2, replace=False)
        corrupt = words ^ jnp.uint32((1 << int(b1)) | (1 << int(b2)))
        _, status = ecc.decode(corrupt, check, "secded")
        assert int(status[0]) == ecc.UNCORRECTABLE


def test_parity_detects_but_never_corrects():
    words = jnp.asarray([0xCAFEBABE], jnp.uint32)
    check = ecc.encode(words, "parity")
    corrupt = words ^ jnp.uint32(1 << 7)
    corrected, status = ecc.decode(corrupt, check, "parity")
    assert int(status[0]) == ecc.UNCORRECTABLE
    assert int(corrected[0]) == int(corrupt[0])  # no repair
    _, clean_status = ecc.decode(words, check, "parity")
    assert int(clean_status[0]) == ecc.CLEAN


def test_scheme_validation():
    for fn in (ecc.n_check_bits, ecc.ops_per_word):
        with pytest.raises(ValueError, match="unknown ECC scheme"):
            fn("hamming74")
    assert ecc.n_check_bits("none") == 0
    assert ecc.n_check_bits("parity") == 1
    assert ecc.n_check_bits("secded") == 7


def test_ecc_energy_model():
    """Decode cost is priced through hwmodel gate constants and ordered
    none < parity < secded; overhead is relative to the raw AM read."""
    e = {s: ecc.read_energy_nj(s, 2, DIM // 32) for s in ecc.SCHEMES}
    assert e["none"] == 0.0 < e["parity"] < e["secded"]
    o = {s: ecc.read_overhead(s, 2, DIM // 32) for s in ecc.SCHEMES}
    assert o["none"] == 0.0 < o["parity"] < o["secded"]
    # scales linearly with the word count and through the constants
    assert ecc.read_energy_nj("secded", 2, 16) == pytest.approx(
        2 * ecc.read_energy_nj("secded", 2, 8))
    hot = hwmodel.HWConstants(e_gate_op=hwmodel.C16.e_gate_op * 10)
    assert ecc.read_energy_nj("parity", 2, 8, hot) == pytest.approx(
        10 * ecc.read_energy_nj("parity", 2, 8))


# ---------------------------------------------------------------------------
# fault model
# ---------------------------------------------------------------------------

def test_fault_config_validation():
    with pytest.raises(ValueError, match="mode"):
        FaultConfig(mode="cosmic")
    with pytest.raises(ValueError, match="unknown ECC scheme"):
        FaultConfig(ecc="bch")
    with pytest.raises(ValueError, match="BER"):
        FaultConfig(am=1.5)
    with pytest.raises(ValueError, match="ber"):
        FaultConfig(am=0.1).with_ber(-0.2)


def test_fault_config_plan_and_vector():
    fc = FaultConfig(tables=1e-3, counts=0.0, ecc="secded")
    plan = fc.plan()
    assert plan == FaultPlan(tables=True, am=False, counts=True,
                             ecc="secded")
    assert plan.any_target
    np.testing.assert_allclose(fc.ber_vector(), [1e-3, 0.0, 0.0],
                               rtol=1e-6)
    moved = fc.with_ber(0.25)
    assert moved.tables == moved.counts == 0.25 and moved.am is None
    assert moved.plan() == plan  # same static structure: no recompile
    assert not FaultConfig(ecc="secded").plan().any_target


def test_step_seed_schedule():
    stuck = FaultPlan(am=True, mode="stuck")
    trans = FaultPlan(am=True, mode="transient")
    # stuck: same seed every round (persistent cells); transient: fresh
    assert (step_seed(stuck, tile=1, n_tiles=2, phase=0)
            == step_seed(stuck, tile=1, n_tiles=2, phase=9))
    assert (step_seed(trans, tile=1, n_tiles=2, phase=0)
            != step_seed(trans, tile=1, n_tiles=2, phase=1))
    # transient seeds never collide with the stuck per-tile range
    stuck_seeds = {step_seed(stuck, tile=t, n_tiles=2, phase=0)
                   for t in range(2)}
    trans_seeds = {step_seed(trans, tile=t, n_tiles=2, phase=p)
                   for t in range(2) for p in range(4)}
    assert not stuck_seeds & trans_seeds
    assert len(trans_seeds) == 8


def test_stuck_mask_depends_on_data():
    """Stuck-at reads flip only where the stored bit differs from the stuck
    value: flipping all stored bits flips the faulted subset's mask too."""
    key = component_keys(7)[1]
    w = jnp.asarray(np.random.default_rng(4).integers(
        0, 1 << 32, 64, np.uint32))
    m1 = np.asarray(xor_mask(w, key, 0.3, mode="stuck"))
    m2 = np.asarray(xor_mask(~w, key, 0.3, mode="stuck"))
    sel = m1 | m2
    np.testing.assert_array_equal(m1 ^ m2, sel)  # complementary inside sel
    # same key, same data -> identical mask (persistence)
    m3 = np.asarray(xor_mask(w, key, 0.3, mode="stuck"))
    np.testing.assert_array_equal(m1, m3)


def test_flip_counts_stays_in_range():
    counts = jnp.full((128,), 5, jnp.int32)
    out = np.asarray(flip_counts(counts, jax.random.PRNGKey(8), 1.0,
                                 bits=3, mode="transient"))
    assert out.dtype == np.int32
    assert (out >= 0).all() and (out <= 7).all()  # only low 3 bits exist


# ---------------------------------------------------------------------------
# fleet integration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("ecc_scheme", ["none", "secded"])
def test_zero_ber_bit_exact_with_unfaulted_fleet(backend, ecc_scheme):
    """The acceptance gate: a fleet with the fault machinery compiled in
    but BER = 0 must be BIT-EXACT with a fleet built without it, on both
    the jnp and the pallas (interpret off-TPU) kernel paths."""
    pipe, cfg = _trained(backend=backend)
    fc = FaultConfig(tables=0.0, am=0.0, counts=0.0, ecc=ecc_scheme)
    clean = StreamingFleet({"p": pipe}, ["p"] * 5, buckets=(WINDOW,))
    faulted = StreamingFleet({"p": pipe}, ["p"] * 5, buckets=(WINDOW,),
                             faults=fc)
    _assert_decisions_equal(_decisions(clean, 5), _decisions(faulted, 5))
    assert faulted.ecc_stats.sum() == 0
    assert faulted.fault_config == fc


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_high_ber_corrupts_decisions(backend):
    pipe, cfg = _trained(backend=backend)
    fc = FaultConfig(tables=0.05, am=0.05, counts=0.05)
    clean = StreamingFleet({"p": pipe}, ["p"] * 5, buckets=(WINDOW,))
    faulted = StreamingFleet({"p": pipe}, ["p"] * 5, buckets=(WINDOW,),
                             faults=fc)
    a = _decisions(clean, 5)
    b = _decisions(faulted, 5)
    same = all(
        np.array_equal(x.frame_hv, y.frame_hv)
        for ra, rb in zip(a, b) for da, db in zip(ra, rb)
        for x, y in zip(da, db))
    assert not same


def test_set_ber_walks_grid_without_recompiles():
    pipe, cfg = _trained()
    fleet = StreamingFleet({"p": pipe}, ["p"] * 4, buckets=(WINDOW,),
                           faults=FaultConfig(am=0.0))
    _decisions(fleet, 4, rounds=1)
    compiles = fleet.compile_count
    for ber in (1e-3, 1e-2, 0.0):
        fleet.set_ber(ber)
        fleet.reset()
        _decisions(fleet, 4, rounds=1)
    assert fleet.compile_count == compiles
    assert fleet.fault_config.am == 0.0
    with pytest.raises(ValueError, match="faults"):
        StreamingFleet({"p": pipe}, ["p"] * 4,
                       buckets=(WINDOW,)).set_ber(0.1)


def test_secded_recovers_am_faults_at_fleet_scale():
    """Low-BER AM faults under SECDED: decisions identical to the clean
    fleet, corrected counter fires, nothing uncorrectable."""
    pipe, cfg = _trained()
    # ~1 flip per 2 rows/step at this BER; double flips per 39-bit word
    # are vanishingly rare, so SECDED recovers every read
    fc = FaultConfig(am=2e-4, ecc="secded", seed=11)
    clean = StreamingFleet({"p": pipe}, ["p"] * 6, buckets=(WINDOW,))
    protected = StreamingFleet({"p": pipe}, ["p"] * 6, buckets=(WINDOW,),
                               faults=fc)
    _assert_decisions_equal(_decisions(clean, 6, rounds=6),
                            _decisions(protected, 6, rounds=6))
    stats = protected.ecc_stats.sum(axis=0)
    assert stats[0] > 0           # corrected events observed
    assert stats[2] == 0          # nothing uncorrectable
    assert stats[1] == stats[0]   # detected == corrected here


def test_unprotected_am_faults_shift_scores():
    """Same BER without ECC: the injected flips reach the similarity
    scores (control for the SECDED recovery test)."""
    pipe, cfg = _trained()
    base = StreamingFleet({"p": pipe}, ["p"] * 6, buckets=(WINDOW,))
    raw = StreamingFleet({"p": pipe}, ["p"] * 6, buckets=(WINDOW,),
                         faults=FaultConfig(am=0.02, seed=11))
    a = _decisions(base, 6, rounds=4)
    b = _decisions(raw, 6, rounds=4)
    same = all(
        np.array_equal(x.scores, y.scores)
        for ra, rb in zip(a, b) for da, db in zip(ra, rb)
        for x, y in zip(da, db))
    assert not same


def test_stuck_faults_are_persistent():
    """Stuck mode: identical inputs see identical corruption every round
    (same ECC event count per round), unlike transient mode."""
    pipe, cfg = _trained()
    chunk = np.random.default_rng(6).integers(
        0, 64, (WINDOW, CHANNELS), np.uint8)

    def per_round_events(mode):
        fleet = StreamingFleet(
            {"p": pipe}, ["p"] * 4, buckets=(WINDOW,),
            faults=FaultConfig(am=0.01, mode=mode, ecc="secded", seed=3))
        events = []
        for _ in range(3):
            before = fleet.ecc_stats.sum()
            fleet.push([chunk] * 4)
            events.append(int(fleet.ecc_stats.sum() - before))
        return events

    stuck = per_round_events("stuck")
    assert stuck[0] > 0 and len(set(stuck)) == 1
    trans = per_round_events("transient")
    assert len(set(trans)) > 1  # fresh masks round to round


def test_ecc_stats_reset():
    pipe, cfg = _trained()
    fleet = StreamingFleet({"p": pipe}, ["p"] * 4, buckets=(WINDOW,),
                           faults=FaultConfig(am=0.02, ecc="secded"))
    _decisions(fleet, 4, rounds=2)
    assert fleet.ecc_stats.sum() > 0
    assert fleet.ecc_stats.shape == (4, 3)
    fleet.reset()
    assert fleet.ecc_stats.sum() == 0
