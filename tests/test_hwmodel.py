"""Validation of the hardware energy/area model against the paper's claims.

The model is validated on *structure* (dominant modules) and *ratio bands*
(optimized vs naive vs dense); absolute scale is anchored to the paper's
published optimized-design numbers (12.5 nJ, 0.059 mm²)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import classifier, hwmodel
from repro.core import im as im_mod
from repro.data import ieeg

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def reports():
    cfg = classifier.HDCConfig(spatial_threshold=1)
    params = classifier.init_params(jax.random.PRNGKey(42), cfg)
    dparams = im_mod.make_dense_im(jax.random.PRNGKey(7), channels=cfg.channels,
                                   codes=cfg.codes, dim=cfg.dim)
    codes = jnp.asarray(ieeg.make_patient(11, n_seizures=1).records[0].codes[:2048])
    es, asc = hwmodel.calibration_factors(params, codes, cfg)
    return {
        v: hwmodel.report(v, dparams if v == "dense" else params, codes, cfg,
                          e_scale=es, a_scale=asc)
        for v in hwmodel.VARIANTS
    }


def test_energy_ordering(reports):
    e = {v: reports[v]["energy_total_nj"] for v in hwmodel.VARIANTS}
    assert e["sparse_opt"] < e["sparse_compim"] < e["sparse_naive"] < e["dense"]


def test_area_ordering(reports):
    a = {v: reports[v]["area_total_mm2"] for v in hwmodel.VARIANTS}
    assert a["sparse_opt"] < a["sparse_compim"] < a["sparse_naive"] < a["dense"]


def test_calibration_anchors_optimized_design(reports):
    r = reports["sparse_opt"]
    assert abs(r["energy_total_nj"] - 12.5) < 0.1
    assert abs(r["area_total_mm2"] - 0.059) < 0.001


def test_ratio_bands_vs_paper(reports):
    """Paper: 1.72-1.73x E / 2.20x A vs naive; 7.50x E / 3.24x A vs dense.
    Our model must land in the same band (factor-of-two tolerance)."""
    so, sn, dn = (reports[v] for v in ("sparse_opt", "sparse_naive", "dense"))
    e_naive = sn["energy_total_nj"] / so["energy_total_nj"]
    a_naive = sn["area_total_mm2"] / so["area_total_mm2"]
    e_dense = dn["energy_total_nj"] / so["energy_total_nj"]
    a_dense = dn["area_total_mm2"] / so["area_total_mm2"]
    assert 1.2 < e_naive < 3.5, e_naive
    assert 1.4 < a_naive < 4.5, a_naive
    assert 4.0 < e_dense < 16.0, e_dense
    assert 1.8 < a_dense < 6.5, a_dense


def test_naive_dominant_modules(reports):
    """Fig. 1c: binding(+decoder) dominates naive energy; binding+spatial
    bundling dominate naive area."""
    r = reports["sparse_naive"]
    eb = r["energy_breakdown"]
    ab = r["area_breakdown"]
    bind_dec_e = eb["binding"] + eb["decoder"]
    assert bind_dec_e == max(
        bind_dec_e, eb["im"], eb["spatial_bundling"], eb["temporal_bundling"], eb["am"])
    assert ab["spatial_bundling"] + ab["binding"] + ab["decoder"] > 0.5


def test_compim_shrinks_im_and_removes_decoder(reports):
    naive, comp = reports["sparse_naive"], reports["sparse_compim"]
    assert comp["area_um2"]["decoder"] == 0.0
    assert comp["area_um2"]["im"] < 0.2 * naive["area_um2"]["im"]
    assert comp["energy_nj"]["im"] < naive["energy_nj"]["im"]


def test_no_thinning_shrinks_spatial(reports):
    comp, opt = reports["sparse_compim"], reports["sparse_opt"]
    assert opt["area_um2"]["spatial_bundling"] < 0.5 * comp["area_um2"]["spatial_bundling"]
    assert opt["energy_nj"]["spatial_bundling"] < comp["energy_nj"]["spatial_bundling"]


def test_latency_matches_paper(reports):
    # 256-cycle frame + sequential 2-class AM search at 10 MHz ~ 25.6-25.8 us
    assert abs(reports["sparse_opt"]["latency_us_at_10mhz"] - 25.6) < 0.5


def test_energy_per_channel(reports):
    r = reports["sparse_opt"]
    # paper: 0.195 nJ/channel
    assert abs(r["energy_per_channel_nj"] - r["energy_total_nj"] / 64) < 1e-9


# ---------------------------------------------------------------------------
# uncalibrated regression pins: the ordering claims must hold in the RAW
# model (e_scale = a_scale = 1), so a constants/inventory edit that only
# survives because calibration rescales it still trips a test
# ---------------------------------------------------------------------------

def test_uncalibrated_energy_ordering():
    cfg = classifier.HDCConfig(spatial_threshold=1)
    params = classifier.init_params(jax.random.PRNGKey(3), cfg)
    dparams = im_mod.make_dense_im(jax.random.PRNGKey(4),
                                   channels=cfg.channels, codes=cfg.codes,
                                   dim=cfg.dim)
    codes = jnp.asarray(
        ieeg.make_patient(5, n_seizures=1).records[0].codes[:512])
    e = {v: sum(hwmodel.energy_per_prediction(
            v, dparams if v == "dense" else params, codes, cfg).values())
         for v in hwmodel.VARIANTS}
    assert e["sparse_opt"] < e["sparse_compim"] < e["sparse_naive"] < e["dense"]


def test_uncalibrated_area_inventory_ordering():
    cfg = classifier.HDCConfig(spatial_threshold=1)
    inv = {v: hwmodel.area_inventory(v, cfg) for v in hwmodel.VARIANTS}
    tot = {v: sum(a.values()) for v, a in inv.items()}
    assert tot["sparse_opt"] < tot["sparse_compim"] < tot["sparse_naive"] < tot["dense"]
    # the CompIM claim at module granularity: the 56-bit-entry table is a
    # fraction of the naive one-hot IM, and the one-hot->binary decoder
    # disappears entirely (fused into the table contents)
    assert inv["sparse_compim"]["im"] == inv["sparse_opt"]["im"]
    assert inv["sparse_compim"]["im"] < inv["sparse_naive"]["im"]
    assert inv["sparse_naive"]["im"] < inv["dense"]["im"]
    assert inv["sparse_compim"]["decoder"] == 0.0
    assert inv["sparse_naive"]["decoder"] > 0.0


def test_gate_energy_fj():
    c = hwmodel.C16
    assert hwmodel.gate_energy_fj({}) == 0.0
    assert hwmodel.gate_energy_fj({"xor2": 1}) == pytest.approx(2 * c.e_gate_op)
    assert hwmodel.gate_energy_fj({"and2": 2, "fa": 3}) == pytest.approx(
        2 * c.e_gate_op + 3 * c.e_fa_op)
    assert hwmodel.gate_energy_fj(
        {"or2": 1, "ff": 1, "cmp_bit": 1}) == pytest.approx(
        c.e_gate_op + c.e_ff_toggle + c.e_cmp_bit)
    with pytest.raises(ValueError, match="unknown gate kinds"):
        hwmodel.gate_energy_fj({"nand9": 1})
