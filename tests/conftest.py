import pytest

from repro.analysis import guards


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture
def no_recompiles():
    """The repro.analysis.guards.no_recompiles context manager: wrap a
    steady-state region to assert it triggers zero XLA compilations."""
    return guards.no_recompiles


@pytest.fixture
def no_transfers():
    """The repro.analysis.guards.no_transfers context manager: wrap a
    device-side region to assert it performs no implicit host syncs."""
    return guards.no_transfers
