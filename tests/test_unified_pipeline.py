"""Unified HDCPipeline API: variant x backend parity, serving engine
batching (per-patient configs), and streaming session state."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier, hv
from repro.core.pipeline import BACKENDS, HDCConfig, HDCPipeline, VARIANTS
from repro.data import ieeg
from repro.serve.engine import SeizureSession, ServingEngine

jax.config.update("jax_platform_name", "cpu")

WINDOW = 256


@pytest.fixture(scope="module")
def patient():
    return ieeg.make_patient(11, n_seizures=2)


@pytest.fixture(scope="module")
def train_data(patient):
    # slice straddling the seizure onset so BOTH classes have examples
    # (train_one_shot now rejects empty classes — the all-zero-HV bugfix)
    rec = patient.records[0]
    start = (rec.onset_sample // WINDOW - 4) * WINDOW
    codes = jnp.asarray(rec.codes[None, start:start + 2048])
    labels = ieeg.frame_labels(rec, WINDOW)[start // WINDOW:][: 2048 // WINDOW]
    assert set(labels) == {0, 1}
    return codes, jnp.asarray(labels[None])


def _cfg(variant: str, backend: str = "jnp") -> HDCConfig:
    # spatial_threshold=1 keeps sparse_naive comparable with the OR-tree path
    return HDCConfig(variant=variant, backend=backend, spatial_threshold=1)


# ---------------------------------------------------------------------------
# variant x backend parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_backends_bit_exact(variant, train_data):
    """jnp and pallas backends must be bit-exact for every variant, through
    encode, train and infer."""
    codes, labels = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), _cfg(variant))
    trained = {}
    for backend in BACKENDS:
        p = pipe.with_backend(backend).train_one_shot(codes, labels)
        trained[backend] = p
    np.testing.assert_array_equal(
        np.asarray(trained["jnp"].encode_frames(codes)),
        np.asarray(trained["pallas"].encode_frames(codes)))
    np.testing.assert_array_equal(
        np.asarray(trained["jnp"].class_hvs), np.asarray(trained["pallas"].class_hvs))
    s_jnp, p_jnp = trained["jnp"].infer(codes)
    s_pal, p_pal = trained["pallas"].infer(codes)
    np.testing.assert_array_equal(np.asarray(s_jnp), np.asarray(s_pal))
    np.testing.assert_array_equal(np.asarray(p_jnp), np.asarray(p_pal))


@pytest.mark.parametrize("thr", [1, 2])
def test_sparse_naive_backend_parity_across_thresholds(thr, train_data):
    """The pallas rewrite of sparse_naive (forced spatial thinning) must stay
    bit-exact beyond threshold 1 — the default config uses threshold 2."""
    codes, _ = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(42),
                            HDCConfig(variant="sparse_naive",
                                      spatial_threshold=thr))
    np.testing.assert_array_equal(
        np.asarray(pipe.encode_frames(codes)),
        np.asarray(pipe.with_backend("pallas").encode_frames(codes)))


def test_sparse_pipeline_matches_legacy_classifier(train_data):
    """The unified surface must reproduce the pre-redesign sparse entry
    points bit-exactly (no behavior change, only dispatch)."""
    codes, _ = train_data
    cfg = HDCConfig()
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), cfg)
    legacy_params = classifier.init_params(jax.random.PRNGKey(42), cfg)
    np.testing.assert_array_equal(
        np.asarray(pipe.encode_frames(codes)),
        np.asarray(classifier.encode_frames(legacy_params, codes, cfg)))


def test_dense_variant_routable(train_data):
    """HDCConfig(variant='dense') is a first-class pipeline citizen (the old
    classifier.spatial_encode raised on it)."""
    codes, labels = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(7), _cfg("dense"))
    pipe = pipe.train_one_shot(codes, labels)
    scores, preds = pipe.infer(codes)
    assert scores.shape == (1, codes.shape[1] // WINDOW, 2)
    # dense similarity is D - Hamming: bounded by D
    assert (np.asarray(scores) <= pipe.cfg.dim).all()
    with pytest.raises(ValueError, match="pipeline"):
        classifier.spatial_encode(pipe.params, codes, pipe.cfg)


def test_calibrate_density_programs_threshold(train_data):
    codes, _ = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), HDCConfig())
    lo = pipe.calibrate_density(codes, 0.10)
    hi = pipe.calibrate_density(codes, 0.50)
    assert lo.cfg.temporal_threshold > hi.cfg.temporal_threshold
    dens = np.asarray(hv.density(lo.encode_frames(codes), lo.cfg.dim))
    assert (dens <= 0.15).all()


def test_trained_state_dropped_on_encoder_change(train_data):
    """Class HVs are trained through the inference encoder; changing its
    operating point must not silently keep stale prototypes."""
    codes, labels = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(42),
                            HDCConfig()).train_one_shot(codes, labels)
    # backend switch is bit-exact -> trained state kept
    assert pipe.with_backend("pallas").class_hvs is not None
    # no-op override -> kept
    same = pipe.with_cfg(temporal_threshold=pipe.cfg.temporal_threshold)
    assert same.class_hvs is not None
    # re-calibration changes the encoder -> dropped, infer refuses
    recal = pipe.calibrate_density(codes, 0.10)
    assert recal.cfg.temporal_threshold != pipe.cfg.temporal_threshold
    assert recal.class_hvs is None
    with pytest.raises(ValueError, match="train_one_shot"):
        recal.infer(codes)


def test_with_cfg_guards():
    pipe = HDCPipeline.init(jax.random.PRNGKey(0), HDCConfig())
    with pytest.raises(ValueError, match="re-init"):
        pipe.with_cfg(dim=2048)
    with pytest.raises(ValueError, match="re-init"):
        pipe.with_cfg(window=128)   # temporal_threshold would go stale
    with pytest.raises(ValueError, match="dense"):
        pipe.with_cfg(variant="dense")
    with pytest.raises(ValueError, match="backend"):
        pipe.with_backend("cuda")


def test_pipeline_is_pytree(train_data):
    """HDCPipeline flattens/unflattens (params + class HVs as leaves)."""
    codes, labels = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), HDCConfig())
    pipe = pipe.train_one_shot(codes, labels)
    leaves, treedef = jax.tree_util.tree_flatten(pipe)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(rebuilt.class_hvs),
                                  np.asarray(pipe.class_hvs))
    assert rebuilt.cfg == pipe.cfg


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def _trained_bank(train_data, targets=(0.10, 0.50)):
    codes, labels = train_data
    base = HDCPipeline.init(jax.random.PRNGKey(42), HDCConfig())
    return {f"p{i}": base.calibrate_density(codes, t).train_one_shot(codes, labels)
            for i, t in enumerate(targets)}


def test_engine_respects_per_patient_config(train_data, patient):
    """Regression for the old serve example's silent hazard: two patients
    with different calibrated temporal thresholds must get DIFFERENT frames
    for the same codes (the old loop encoded everyone with cfgs[0])."""
    bank = _trained_bank(train_data)
    assert (bank["p0"].cfg.temporal_threshold
            != bank["p1"].cfg.temporal_threshold)
    engine = ServingEngine(bank)
    req = patient.records[1].codes[:WINDOW]
    d0, d1 = engine.serve([("p0", req), ("p1", req)])
    assert not np.array_equal(d0.frames, d1.frames)


def test_engine_matches_direct_infer(train_data, patient):
    """Batched gather-by-patient serving == per-pipeline infer, bit-exact,
    including interleaved request order."""
    bank = _trained_bank(train_data)
    engine = ServingEngine(bank)
    reqs = [("p1", patient.records[1].codes[:WINDOW]),
            ("p0", patient.records[1].codes[256:256 + WINDOW]),
            ("p1", patient.records[1].codes[512:512 + WINDOW])]
    decisions = engine.serve(reqs)
    for (pid, codes), dec in zip(reqs, decisions):
        s, p = bank[pid].infer(jnp.asarray(codes[None]))
        np.testing.assert_array_equal(dec.scores, np.asarray(s)[0])
        np.testing.assert_array_equal(dec.predictions, np.asarray(p)[0])
        assert dec.patient_id == pid


def test_engine_rejects_mixed_length_batch(train_data, patient):
    """A shorter request must not silently broadcast into the frame buffer."""
    bank = _trained_bank(train_data)
    engine = ServingEngine(bank)
    with pytest.raises(ValueError, match="shape"):
        engine.serve([("p0", patient.records[1].codes[: 2 * WINDOW]),
                      ("p1", patient.records[1].codes[:WINDOW])])


def test_engine_rejects_untrained_and_unknown(train_data):
    codes, _ = train_data
    untrained = HDCPipeline.init(jax.random.PRNGKey(42), HDCConfig())
    with pytest.raises(ValueError, match="untrained"):
        ServingEngine({"p": untrained})
    bank = _trained_bank(train_data)
    engine = ServingEngine(bank)
    with pytest.raises(KeyError):
        engine.serve([("nobody", np.zeros((WINDOW, 64), np.uint8))])


# ---------------------------------------------------------------------------
# streaming sessions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["sparse_compim", "dense"])
def test_session_chunked_push_matches_one_shot(variant, train_data, patient):
    """Sub-window chunked pushes carry accumulator state across calls and
    reproduce the one-shot encoder bit-exactly."""
    codes, labels = train_data
    key = jax.random.PRNGKey(42 if variant != "dense" else 7)
    pipe = HDCPipeline.init(key, _cfg(variant)).train_one_shot(codes, labels)
    stream = patient.records[1].codes[: 3 * WINDOW]

    sess = SeizureSession(pipe)
    out = []
    pos = 0
    for chunk in (100, 50, 300, 200, 118):    # window-crossing odd chunks
        out += sess.push(stream[pos:pos + chunk])
        pos += chunk
    assert pos == stream.shape[0] and len(out) == 3
    assert sess.cycles_buffered == 0

    frames = np.asarray(pipe.encode_frames(jnp.asarray(stream[None])))[0]
    scores = np.asarray(pipe.scores(jnp.asarray(frames)))
    for i, dec in enumerate(out):
        assert dec.frame_index == i
        np.testing.assert_array_equal(dec.frame_hv, frames[i])
        np.testing.assert_array_equal(dec.scores, scores[i])


def test_session_partial_frame_buffers(train_data, patient):
    codes, labels = train_data
    pipe = HDCPipeline.init(jax.random.PRNGKey(42),
                            HDCConfig()).train_one_shot(codes, labels)
    sess = SeizureSession(pipe)
    assert sess.push(patient.records[1].codes[:100]) == []
    assert sess.cycles_buffered == 100
    out = sess.push(patient.records[1].codes[100:WINDOW])
    assert len(out) == 1 and sess.cycles_buffered == 0


# ---------------------------------------------------------------------------
# cached packed IM (perf satellite)
# ---------------------------------------------------------------------------

def test_im_packed_cache_consistent():
    from repro.core import im as im_mod
    params = im_mod.make_im(jax.random.PRNGKey(3), channels=8, codes=16,
                            dim=256, segments=8)
    assert params.item_packed_cache is not None
    np.testing.assert_array_equal(
        np.asarray(params.item_packed),
        np.asarray(hv.positions_to_packed(params.item_pos, 256, 8)))
    np.testing.assert_array_equal(
        np.asarray(params.elec_packed),
        np.asarray(hv.positions_to_packed(params.elec_pos, 256, 8)))
    # uncached construction still derives on the fly
    bare = im_mod.IMParams(item_pos=params.item_pos, elec_pos=params.elec_pos,
                           dim=256, segments=8)
    np.testing.assert_array_equal(np.asarray(bare.item_packed),
                                  np.asarray(params.item_packed))
