"""System tests for the sparse HDC classifier: binding equivalences, bundling
invariants, and end-to-end one-shot seizure detection on synthetic patients."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import am, binding, bundling, classifier, hv, metrics
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg

jax.config.update("jax_platform_name", "cpu")

CFG = classifier.HDCConfig()


# ---------------------------------------------------------------------------
# binding
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_binding_domains_equivalent(seed):
    """CompIM position binding == naive packed segmented-shift binding."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = hv.random_sparse_positions(k1, (4,), 8, 128)
    b = hv.random_sparse_positions(k2, (4,), 8, 128)
    ap = hv.positions_to_packed(a, 1024, 8)
    bp = hv.positions_to_packed(b, 1024, 8)
    naive = binding.bind_segmented_packed(ap, bp, 1024, 8)
    posd = hv.positions_to_packed(binding.bind_positions(a, b, 128), 1024, 8)
    np.testing.assert_array_equal(np.asarray(naive), np.asarray(posd))


def test_binding_preserves_sparsity():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = hv.random_sparse_positions(k1, (16,), 8, 128)
    b = hv.random_sparse_positions(k2, (16,), 8, 128)
    bound = binding.bind_positions(a, b, 128)
    packed = hv.positions_to_packed(bound, 1024, 8)
    assert (np.asarray(hv.popcount(packed)) == 8).all()


def test_unbind_inverts_bind():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = hv.random_sparse_positions(k1, (8,), 8, 128)
    b = hv.random_sparse_positions(k2, (8,), 8, 128)
    bound = binding.bind_positions(a, b, 128)
    np.testing.assert_array_equal(
        np.asarray(binding.unbind_positions(bound, b, 128)), np.asarray(a))


def test_roll_segments():
    bits = np.zeros((1, 256), np.uint8)
    bits[0, 0] = 1      # segment 0, position 0 (segments of 32 when S=8, D=256)
    shifts = np.zeros((1, 8), np.int32)
    shifts[0, 0] = 5
    rolled = binding.roll_segments_bits(jnp.asarray(bits), jnp.asarray(shifts), 8)
    out = np.asarray(rolled)[0]
    assert out[5] == 1 and out.sum() == 1


# ---------------------------------------------------------------------------
# bundling
# ---------------------------------------------------------------------------

def test_spatial_or_vs_thinned_threshold1_equal():
    """With threshold 1, the adder tree + thinning == the OR tree (the basis
    of the paper's Sec. III-B simplification)."""
    key = jax.random.PRNGKey(2)
    pos = hv.random_sparse_positions(key, (10, 64), 8, 128)
    ored = bundling.spatial_bundle_or_positions(pos, 1024, 8)
    thin1 = bundling.spatial_bundle_thinned_positions(pos, 1024, 8, 1)
    np.testing.assert_array_equal(np.asarray(ored), np.asarray(thin1))


def test_spatial_density_bound():
    """64 one-bit-per-segment HVs can fill at most 50% of a 1024-bit HV."""
    key = jax.random.PRNGKey(3)
    pos = hv.random_sparse_positions(key, (20, 64), 8, 128)
    bundled = bundling.spatial_bundle_or_positions(pos, 1024, 8)
    dens = np.asarray(hv.density(bundled, 1024))
    assert (dens <= 0.5).all()
    assert (dens > 0.2).all()    # and it is far from degenerate


def test_counts_domains_agree():
    key = jax.random.PRNGKey(4)
    pos = hv.random_sparse_positions(key, (6, 64), 8, 128)
    packed = hv.positions_to_packed(pos, 1024, 8)
    via_pos = bundling.spatial_counts_positions(pos, 1024, 8)
    via_bits = bundling.spatial_counts_packed(packed, 1024)
    np.testing.assert_array_equal(np.asarray(via_pos), np.asarray(via_bits))


def test_temporal_counts_bounded_by_window():
    key = jax.random.PRNGKey(5)
    pos = hv.random_sparse_positions(key, (2, 256, 64), 8, 128)
    spat = bundling.spatial_bundle_or_positions(pos, 1024, 8)   # (2, 256, W)
    counts = bundling.temporal_counts(spat, 1024)
    assert counts.shape == (2, 1024)
    assert (np.asarray(counts) <= 256).all()


def test_threshold_for_density():
    rng = np.random.default_rng(6)
    counts = jnp.asarray(rng.integers(0, 256, (4, 1024)))
    for target in (0.1, 0.25, 0.5):
        thr = int(bundling.threshold_for_density(counts, target))
        dens = float(hv.density(hv.threshold_pack(counts, thr), 1024).mean())
        assert dens <= target + 0.05, (target, thr, dens)


# ---------------------------------------------------------------------------
# AM
# ---------------------------------------------------------------------------

def test_am_scores_sparse_counts_shared_bits():
    q = hv.pack_bits(jnp.asarray(np.eye(1, 64, 3, dtype=np.uint8)
                                 + np.eye(1, 64, 7, dtype=np.uint8)))
    cls = hv.pack_bits(jnp.asarray(np.stack([
        np.eye(1, 64, 3, dtype=np.uint8)[0],                       # shares bit 3
        np.zeros(64, np.uint8)])))                                  # shares none
    s = np.asarray(am.am_scores_sparse(q, cls))
    assert s[0, 0] == 1 and s[0, 1] == 0


def test_am_predict_tiebreak_low():
    scores = jnp.asarray([[5, 5], [3, 9]])
    np.testing.assert_array_equal(np.asarray(am.am_predict(scores)), [0, 1])


# ---------------------------------------------------------------------------
# variants agree / end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def patient():
    return ieeg.make_patient(11, n_seizures=3)


@pytest.fixture(scope="module")
def params():
    return classifier.init_params(jax.random.PRNGKey(42), CFG)


def test_naive_and_compim_pipelines_bitwise_equal(params, patient):
    """The CompIM datapath must be bit-exact with the naive baseline when both
    use the same spatial thinning threshold (the paper's optimization is
    functionality-preserving for the IM/binding stage)."""
    codes = jnp.asarray(patient.records[0].codes[None, :2048])
    cfg_naive = dataclasses.replace(CFG, variant="sparse_naive", spatial_threshold=1)
    cfg_comp = dataclasses.replace(CFG, variant="sparse_compim",
                                   spatial_thinning=True, spatial_threshold=1)
    cfg_opt = dataclasses.replace(CFG, variant="sparse_compim", spatial_thinning=False)
    f_naive = classifier.encode_frames(params, codes, cfg_naive)
    f_comp = classifier.encode_frames(params, codes, cfg_comp)
    f_opt = classifier.encode_frames(params, codes, cfg_opt)
    np.testing.assert_array_equal(np.asarray(f_naive), np.asarray(f_comp))
    # threshold-1 thinning == OR bundling (Sec. III-B argument)
    np.testing.assert_array_equal(np.asarray(f_naive), np.asarray(f_opt))


def test_one_shot_detection_end_to_end(params, patient):
    """One-shot learning on seizure 1, detection on seizures 2..n."""
    rec = patient.records[0]
    codes = jnp.asarray(rec.codes[None])
    labels = jnp.asarray(ieeg.frame_labels(rec, CFG.window)[None])
    cfg = classifier.with_density_target(params, codes, CFG, 0.25)
    class_hvs = HDCPipeline(params=params, cfg=cfg).train_one_shot(
        codes, labels).class_hvs
    dens = np.asarray(hv.density(class_hvs, CFG.dim))
    assert (np.abs(dens - 0.5) < 0.12).all(), f"class densities {dens} not ~50%"
    results = []
    for rec2 in patient.records[1:]:
        _, preds = classifier.infer(params, class_hvs, jnp.asarray(rec2.codes[None]), cfg)
        results.append(metrics.detection_metrics(
            np.asarray(preds[0]), ieeg.onset_frame(rec2, cfg.window)))
    agg = metrics.aggregate(results)
    assert agg["detection_accuracy"] >= 0.5
    assert agg["false_alarm_rate"] <= 0.5


def test_dense_baseline_end_to_end(patient):
    dcfg = HDCConfig(variant="dense")
    rec = patient.records[0]
    codes = jnp.asarray(rec.codes[None])
    labels = jnp.asarray(ieeg.frame_labels(rec, dcfg.window)[None])
    pipe = HDCPipeline.init(jax.random.PRNGKey(7), dcfg).train_one_shot(
        codes, labels)
    results = []
    for rec2 in patient.records[1:]:
        _, preds = pipe.infer(jnp.asarray(rec2.codes[None]))
        results.append(metrics.detection_metrics(
            np.asarray(preds[0]), ieeg.onset_frame(rec2, dcfg.window)))
    agg = metrics.aggregate(results)
    assert agg["detection_accuracy"] >= 0.5


def test_encode_frames_shapes_and_no_saturation(params, patient):
    codes = jnp.asarray(patient.records[0].codes[None, :1024])
    frames = classifier.encode_frames(params, codes, CFG)
    assert frames.shape == (1, 4, CFG.words)
    dens = np.asarray(hv.density(frames, CFG.dim))
    assert (dens < 1.0).all() and (dens > 0.0).all()


def test_lbp_codes():
    x = np.asarray([0, 1, 2, 1, 0, 1, 2, 3, 4], dtype=np.float32)
    codes = ieeg.lbp_codes_np(x, bits=6)
    # diffs signs: +,+,-,-,+,+,+,+ -> first code uses d[0..5] LSB=d[5]? check shape
    assert codes.shape == (3,)
    assert codes.dtype == np.uint8
    assert (codes < 64).all()


def test_metrics_postprocess():
    preds = np.asarray([0, 1, 0, 0, 1, 1, 1, 0])
    fired = metrics.postprocess(preds, k=2, m=3)
    assert fired[5] == 1 and fired[1] == 0


def test_metrics_postprocess_stream_start_requires_full_k():
    """Regression: the old ``min(k, f - lo + 1)`` relaxation degenerated to
    1-of-1 at stream start — a single ictal flicker at frame 0 fired the
    detector.  The full k votes are required at every frame."""
    flicker = np.asarray([1, 0, 0, 0, 0])
    np.testing.assert_array_equal(metrics.postprocess(flicker, k=2, m=3),
                                  [0, 0, 0, 0, 0])
    # frame 0 can never fire with k=2; frame 1 fires only with 2 real votes
    burst = np.asarray([1, 1, 1, 0, 0])
    np.testing.assert_array_equal(metrics.postprocess(burst, k=2, m=3),
                                  [0, 1, 1, 0, 0])
    # a frame-0-only false alarm no longer corrupts the delay metric
    r = metrics.detection_metrics(flicker, onset_frame=2)
    assert not r.detected and not r.false_alarm
    with pytest.raises(ValueError, match="1 <= k <= m"):
        metrics.postprocess(flicker, k=4, m=3)


def test_metrics_delay():
    preds = np.zeros(20, np.int32)
    preds[12:] = 1
    r = metrics.detection_metrics(preds, onset_frame=10)
    assert r.detected and r.delay_frames == 3.0 and not r.false_alarm


# ---------------------------------------------------------------------------
# config geometry validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(dim=1000),                    # not a multiple of 32 (words truncates)
    dict(dim=0),
    dict(dim=96, segments=7),          # dim % segments != 0 (seg_len truncates)
    dict(segments=0),
    dict(dim=4096, segments=8),        # seg_len 512 wraps the uint8 positions
    dict(lbp_bits=9),                  # codes would overflow uint8
    dict(lbp_bits=0),
    dict(window=0),
    dict(n_classes=0),
    dict(class_density=1.5),           # silently thins class HVs to zero
    dict(class_density=0.0),
])
def test_config_rejects_corrupt_geometry(bad):
    with pytest.raises(ValueError):
        classifier.HDCConfig(**bad)


def test_config_dense_skips_segment_checks():
    # the dense datapath has no segment structure: big dims stay legal
    cfg = classifier.HDCConfig(variant="dense", dim=4096, segments=8)
    assert cfg.words == 128


def test_train_rejects_empty_class(patient):
    """A class with zero training examples would silently yield an all-zero
    class HV that still scores plausibly in the AM — reject instead."""
    rec = patient.records[0]
    codes = jnp.asarray(rec.codes[None, :2048])
    frames = 2048 // CFG.window
    all_interictal = jnp.zeros((1, frames), jnp.int32)
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), CFG)
    with pytest.raises(ValueError, match="no examples"):
        pipe.train_one_shot(codes, all_interictal)
    with pytest.raises(ValueError, match="no examples"):
        pipe.fit_iterative(codes, all_interictal, epochs=2)
    dense_pipe = HDCPipeline.init(jax.random.PRNGKey(7),
                                  HDCConfig(variant="dense"))
    with pytest.raises(ValueError, match="no examples"):
        dense_pipe.train_one_shot(codes, all_interictal)
    with pytest.raises(ValueError, match=r"labels must be in"):
        pipe.train_one_shot(codes, all_interictal + 7)
