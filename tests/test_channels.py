"""Channel-fault tolerance (reliability/channels.py + the masked fleet
datapath): electrode fault models, online health quarantine with
hysteresis, per-session channel masks threaded through the jitted fleet
step (all-live bit-exactness, reduced-channel-oracle parity, recompile-free
mask walks, checkpoint/snapshot/lifecycle carriage), ingest validation, and
the dense temporal-counter physical-width fault plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg
from repro.kernels.hdc_fleet import ops as fleet_ops
from repro.reliability import channels as chan
from repro.reliability import faults as rel_faults
from repro.serve import dispatch
from repro.serve.engine import SeizureSession, SessionSnapshot
from repro.serve.fleet import StreamingFleet
from repro.serve.lifecycle import ElasticFleet

jax.config.update("jax_platform_name", "cpu")

DIM, SEGMENTS, CHANNELS, WINDOW = 256, 8, 8, 32

# (variant, spatial_thinning): every spatial-bundle mode the mask touches
MODES = [("sparse_compim", False), ("sparse_compim", True),
         ("sparse_naive", True), ("dense", False)]


def _cfg(variant: str, **overrides) -> HDCConfig:
    base = dict(dim=DIM, segments=SEGMENTS, channels=CHANNELS, window=WINDOW,
                variant=variant, spatial_threshold=1, temporal_threshold=4)
    base.update(overrides)
    return HDCConfig(**base)


def _trained(variant: str, seed: int, **overrides) -> HDCPipeline:
    rng = np.random.default_rng(seed)
    cfg = _cfg(variant, **overrides)
    codes = jnp.asarray(rng.integers(0, 64, (2, 4 * WINDOW, CHANNELS),
                                     np.uint8))
    labels = np.asarray(rng.integers(0, 2, (2, 4), np.int32))
    labels[0, :2] = (0, 1)
    pipe = HDCPipeline.init(jax.random.PRNGKey(seed), cfg)
    return pipe.train_one_shot(codes, jnp.asarray(labels))


def _chunk(rng, t):
    return rng.integers(0, 64, (t, CHANNELS), np.uint8)


def _assert_decisions_equal(a, b):
    assert len(a) == len(b)
    for f, s in zip(a, b):
        assert f.frame_index == s.frame_index
        assert f.prediction == s.prediction
        np.testing.assert_array_equal(f.scores, s.scores)
        np.testing.assert_array_equal(f.frame_hv, s.frame_hv)


# ---------------------------------------------------------------------------
# electrode fault models
# ---------------------------------------------------------------------------

def test_signal_faults_shift_code_statistics():
    """Each signal-level fault leaves other channels untouched and drives
    the faulted channel's LBP statistics the way the monitor expects."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((CHANNELS, 4096)).astype(np.float32)
    healthy_ent, _ = chan.channel_stats(ieeg.lbp_codes_np(x).T)
    for kind in chan.CHANNEL_FAULT_TYPES:
        y = chan.inject_signal_fault(x, 3, kind, np.random.default_rng(1))
        assert y.shape == x.shape
        others = [c for c in range(CHANNELS) if c != 3]
        np.testing.assert_array_equal(y[others], x[others])
        ent, stuck = chan.channel_stats(ieeg.lbp_codes_np(y).T)
        if kind == "dead":
            assert ent[3] < 0.1 and stuck[3] > 1000
        elif kind == "gain_drift":
            # near-healthy: constant-gain invariance holds except at
            # near-tie first differences
            assert ent[3] > 0.8 * healthy_ent[3]
        else:
            assert ent[3] < healthy_ent[3]


def test_signal_fault_transform_validates_kind():
    with pytest.raises(ValueError, match="kind"):
        chan.signal_fault_transform([(0, "exploded")])


def test_make_record_signal_transform_hook():
    """A dead-channel transform flows through the exact production
    preprocessing: the record's codes for that channel collapse to 0."""
    rng = np.random.default_rng(2)
    rec = ieeg.make_record(
        rng, channels=CHANNELS, pre_s=2.0, ictal_s=2.0, post_s=1.0,
        signal_transform=chan.signal_fault_transform([(5, "dead")]))
    assert (rec.codes[:, 5] == 0).all()
    assert (rec.codes[:, 0] != 0).any()


def test_make_record_signal_transform_shape_guard():
    rng = np.random.default_rng(3)
    with pytest.raises(ValueError, match="preserve"):
        ieeg.make_record(rng, channels=CHANNELS, pre_s=1.0, ictal_s=1.0,
                         post_s=1.0, signal_transform=lambda x, r: x[:, :-1])


def test_inject_code_fault_models():
    rng = np.random.default_rng(4)
    codes = rng.integers(0, 64, (100, CHANNELS), np.uint8)
    dead = chan.inject_code_fault(codes, 2, "dead", rng)
    assert (dead[:, 2] == 0).all()
    np.testing.assert_array_equal(np.delete(dead, 2, axis=1),
                                  np.delete(codes, 2, axis=1))
    for kind in ("saturated", "line_noise", "dropout"):
        out = chan.inject_code_fault(codes, 2, kind, rng)
        assert out.shape == codes.shape and out.dtype == np.uint8
        assert (out[:, 2] < 64).all()
    with pytest.raises(ValueError, match="gain_drift"):
        chan.inject_code_fault(codes, 2, "gain_drift", rng)
    with pytest.raises(ValueError, match="start"):
        chan.inject_code_fault(codes, 2, "dead", rng, start=100)


def test_degrade_batch_mask_matches_faults():
    rng = np.random.default_rng(5)
    batch = rng.integers(0, 64, (3, 64, CHANNELS), np.uint8)
    out, mask = chan.degrade_batch(batch, 2, "dead", seed=0)
    assert mask.shape == (3, CHANNELS)
    assert (mask.sum(axis=1) == CHANNELS - 2).all()
    for s in range(3):
        live = np.nonzero(mask[s])[0]
        np.testing.assert_array_equal(out[s][:, live], batch[s][:, live])
        assert (out[s][:, mask[s] == 0] == 0).all()  # dead -> code 0
    out0, mask0 = chan.degrade_batch(batch, 0, "dead", seed=0)
    np.testing.assert_array_equal(out0, batch)
    assert (mask0 == 1).all()
    with pytest.raises(ValueError, match="n_failed"):
        chan.degrade_batch(batch, CHANNELS + 1, "dead")


# ---------------------------------------------------------------------------
# online channel-health monitor
# ---------------------------------------------------------------------------

def _blocks(dead=(), t=256, seed=0):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 64, (t, CHANNELS), np.uint8)
    for ch in dead:
        codes[:, ch] = 0
    return codes


def test_monitor_quarantine_and_reinstate_hysteresis():
    mon = chan.ChannelHealthMonitor(CHANNELS)
    assert (mon.observe(_blocks(dead=(3,))) == 1).all()  # 1 strike: no trip
    mask = mon.observe(_blocks(dead=(3,), seed=1))
    assert mask[3] == 0 and mask.sum() == CHANNELS - 1
    assert mon.n_quarantined == 1
    # recovery: reinstates only after reinstate_after consecutive healthy
    for i in range(mon.reinstate_after - 1):
        assert mon.observe(_blocks(seed=2 + i))[3] == 0
    assert mon.observe(_blocks(seed=9))[3] == 1
    events = [(e["event"], e["channel"]) for e in mon.events]
    assert events == [("quarantine", 3), ("reinstate", 3)]


def test_monitor_does_not_quarantine_gain_drift():
    rng = np.random.default_rng(6)
    x = rng.standard_normal((CHANNELS, 4096)).astype(np.float32)
    y = chan.inject_signal_fault(x, 3, "gain_drift", rng)
    mon = chan.ChannelHealthMonitor(CHANNELS)
    for _ in range(4):
        mon.observe(ieeg.lbp_codes_np(y).T)
    assert mon.n_quarantined == 0


def test_monitor_shape_validation():
    mon = chan.ChannelHealthMonitor(CHANNELS)
    with pytest.raises(ValueError, match="code block"):
        mon.observe(np.zeros((16, CHANNELS + 1), np.uint8))


def test_fleet_monitor_merges_session_events():
    fm = chan.FleetChannelMonitor(2, CHANNELS)
    batch = np.stack([_blocks(dead=(1,)), _blocks(dead=(4,), seed=7)])
    fm.observe(batch)
    masks = fm.observe(batch)
    assert masks.shape == (2, CHANNELS)
    assert masks[0, 1] == 0 and masks[1, 4] == 0
    assert {(e["session"], e["channel"]) for e in fm.events} == \
        {(0, 1), (1, 4)}
    assert fm.n_quarantined == 2
    with pytest.raises(ValueError, match="batch"):
        fm.observe(batch[:1])


# ---------------------------------------------------------------------------
# ingest validation
# ---------------------------------------------------------------------------

def test_validate_signal_rejects_non_finite():
    x = np.zeros((2, 16), np.float32)
    x[1, 3] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        ieeg.validate_signal(x)
    x[1, 3] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        ieeg.lbp_codes_np(x)


def test_validate_signal_clamps_to_adc_rails():
    x = np.asarray([[-10.0, 0.5, 10.0]], np.float32)
    out = ieeg.validate_signal(x, adc_limit=2.0)
    np.testing.assert_array_equal(out, [[-2.0, 0.5, 2.0]])
    with pytest.raises(ValueError, match="positive"):
        ieeg.validate_signal(x, adc_limit=0.0)


def test_session_push_validates_codes():
    sess = SeizureSession(_trained("sparse_compim", seed=0))
    rng = np.random.default_rng(8)
    with pytest.raises(ValueError, match="code chunk"):
        sess.push(rng.integers(0, 64, (16, CHANNELS + 1), np.uint8))
    with pytest.raises(ValueError, match="lbp_codes_np"):
        sess.push(rng.random((16, CHANNELS), np.float32))
    bad = rng.integers(0, 64, (16, CHANNELS), np.int64)
    bad[3, 2] = 64
    with pytest.raises(ValueError, match="alphabet"):
        sess.push(bad)
    sess.push(rng.integers(0, 64, (WINDOW, CHANNELS), np.uint8))  # clean


# ---------------------------------------------------------------------------
# dense temporal-counter physical width (reliability/faults.py)
# ---------------------------------------------------------------------------

def test_counter_bits_value_vs_physical_width():
    plan = rel_faults.FaultConfig(counts=0.0).plan()
    assert rel_faults.counter_bits(plan, 32) == 6   # ceil(log2(33))
    assert rel_faults.counter_bits(plan, 128) == 8
    phys = rel_faults.FaultConfig(counts=0.0, counts_bits=8).plan()
    assert rel_faults.counter_bits(phys, 32) == 8
    # default stays equality-compatible with pre-counts_bits plans
    assert plan == rel_faults.FaultConfig(counts=0.0,
                                          counts_bits=None).plan()
    with pytest.raises(ValueError, match="counts_bits"):
        rel_faults.FaultConfig(counts=0.0, counts_bits=0)
    with pytest.raises(ValueError, match="counts_bits"):
        rel_faults.FaultConfig(counts=0.0, counts_bits=33)


# ---------------------------------------------------------------------------
# masked fleet datapath
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant,thinning", MODES)
def test_all_live_mask_bit_exact_with_unmasked_fleet(variant, thinning):
    """channel_masking=True with every channel live must change nothing:
    same decisions, scores and frame HVs as the mask-free fleet."""
    pipes = {"a": _trained(variant, seed=0, spatial_thinning=thinning),
             "b": _trained(variant, seed=1, spatial_thinning=thinning)}
    owners = ["a", "b", "a"]
    plain = StreamingFleet(pipes, owners, buckets=(16, 32))
    masked = StreamingFleet(pipes, owners, buckets=(16, 32),
                            channel_masking=True)
    assert masked.channel_masking and not plain.channel_masking
    rng = np.random.default_rng(9)
    for _ in range(4):
        chunks = [_chunk(rng, int(t))
                  for t in rng.integers(0, 40, len(owners))]
        a, b = plain.push(chunks), masked.push(chunks)
        for i in range(len(owners)):
            _assert_decisions_equal(a[i], b[i])


def test_masked_fleet_matches_physically_reduced_sessions():
    """Quarantining channels in the fleet == running plain sessions on a
    pipeline whose dead channels never existed (the implant oracle),
    projected back through the mask: decisions agree frame-for-frame."""
    variant = "sparse_compim"
    pipes = {"a": _trained(variant, seed=0)}
    masked = StreamingFleet(pipes, ["a"], buckets=(WINDOW,),
                            channel_masking=True)
    mask = np.ones(CHANNELS, np.uint8)
    mask[[2, 5]] = 0
    masked.set_channel_mask(mask)
    live = np.nonzero(mask)[0]

    # oracle: same trained params, tables sliced to the live channels
    pipe = pipes["a"]
    tables, _ = dispatch.stack_bound_tables([pipe])
    red_cfg = dispatch.reduced_channel_config(pipe.cfg, len(live))
    rng = np.random.default_rng(10)
    chunk = rng.integers(0, 64, (2 * WINDOW, CHANNELS), np.uint8)
    owner = jnp.zeros((1,), jnp.int32)
    got = dispatch.owner_spatial_codes(
        tables, owner, jnp.asarray(chunk[None]), pipe.cfg,
        chan_mask=jnp.asarray(mask[None]))
    want = dispatch.owner_spatial_codes(
        jnp.asarray(np.asarray(tables)[:, live]), owner,
        jnp.asarray(chunk[None][:, :, live]), red_cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    out = masked.push([chunk])  # and the full fleet step consumes the mask
    assert len(out[0]) == 2


@pytest.mark.parametrize("variant,thinning", MODES)
@pytest.mark.parametrize("n_dead", [1, 2, 3])
def test_masked_spatial_matches_reduced_oracle(variant, thinning, n_dead):
    """owner_spatial_codes under a mask == the same encode on the
    physically-reduced channel set, for every bundle mode, jnp AND the
    fused kernel path."""
    pipe = _trained(variant, seed=3, spatial_thinning=thinning,
                    spatial_threshold=2)
    other = _trained(variant, seed=7, spatial_thinning=thinning,
                     spatial_threshold=2)
    cfg = pipe.cfg
    # two DISTINCT codebooks: stack_bound_tables dedupes shared params, so
    # [pipe, pipe] would collapse to a one-row bank and owner=1 would read
    # past it (the jnp gather clamps; the kernel's BlockSpec does not)
    tables, rows = dispatch.stack_bound_tables([pipe, other])
    assert tables.shape[0] == 2 and list(rows) == [0, 1]
    rng = np.random.default_rng(11 + n_dead)
    s, t = 3, 2 * WINDOW
    codes = rng.integers(0, 64, (s, t, CHANNELS), np.uint8)
    owner = jnp.asarray(rng.integers(0, 2, s), jnp.int32)
    mask = np.ones((s, CHANNELS), np.uint8)
    for i in range(s):
        mask[i, rng.choice(CHANNELS, n_dead, replace=False)] = 0

    got = dispatch.owner_spatial_codes(tables, owner, jnp.asarray(codes),
                                       cfg, chan_mask=jnp.asarray(mask))
    # per-session oracle: each session has its own live set
    for i in range(s):
        live = np.nonzero(mask[i])[0]
        red_cfg = dispatch.reduced_channel_config(cfg, len(live))
        want = dispatch.owner_spatial_codes(
            jnp.asarray(np.asarray(tables)[:, live]), owner[i:i + 1],
            jnp.asarray(codes[i:i + 1][:, :, live]), red_cfg)
        np.testing.assert_array_equal(np.asarray(got)[i],
                                      np.asarray(want)[0])

    # fused-kernel path: masked counts == counts of the masked words
    filled = jnp.zeros(s, jnp.int32)
    lengths = jnp.full((s,), t, jnp.int32)
    k = np.asarray(fleet_ops.fleet_counts_fused(
        tables, owner, jnp.asarray(codes), filled, lengths, cfg,
        chan_mask=jnp.asarray(mask)))
    want_k = np.asarray(fleet_ops.fleet_counts(got, filled, lengths, cfg))
    np.testing.assert_array_equal(k, want_k)


@given(st.integers(0, 2**CHANNELS - 2), st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_masked_oracle_parity_property(maskbits, seed):
    """Random masks (any live subset, never empty): masked encode equals
    the reduced-channel oracle for a thinned and an OR-tree variant."""
    mask = np.asarray([(maskbits >> i) & 1 for i in range(CHANNELS)],
                      np.uint8) ^ 1  # complement: maskbits=0 -> all live
    live = np.nonzero(mask)[0]
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 64, (1, WINDOW, CHANNELS), np.uint8)
    owner = jnp.zeros((1,), jnp.int32)
    for variant, thinning in (("sparse_compim", False),
                              ("sparse_naive", True)):
        pipe = _trained(variant, seed=4, spatial_thinning=thinning,
                        spatial_threshold=2)
        tables, _ = dispatch.stack_bound_tables([pipe])
        got = dispatch.owner_spatial_codes(
            tables, owner, jnp.asarray(codes), pipe.cfg,
            chan_mask=jnp.asarray(mask[None]))
        red_cfg = dispatch.reduced_channel_config(pipe.cfg, len(live))
        want = dispatch.owner_spatial_codes(
            jnp.asarray(np.asarray(tables)[:, live]), owner,
            jnp.asarray(codes[:, :, live]), red_cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mask_walk_is_recompile_free(no_recompiles):
    """Walking a mask grid is a traced-operand update: zero XLA compiles
    after the warmup push."""
    pipes = {"a": _trained("sparse_compim", seed=0)}
    fleet = StreamingFleet(pipes, ["a", "a"], buckets=(WINDOW,),
                           channel_masking=True)
    rng = np.random.default_rng(12)
    chunks = [_chunk(rng, WINDOW) for _ in range(2)]
    fleet.push(chunks)  # warmup: compile the one bucket
    with no_recompiles():
        for ch in range(CHANNELS - 1):
            mask = np.ones((2, CHANNELS), np.uint8)
            mask[:, ch] = 0
            fleet.set_channel_mask(mask)
            out = fleet.push(chunks)
            assert all(len(o) == 1 for o in out)
        fleet.set_channel_mask(np.ones(CHANNELS, np.uint8))
        fleet.push(chunks)


def test_set_channel_mask_validation():
    pipes = {"a": _trained("sparse_compim", seed=0)}
    plain = StreamingFleet(pipes, ["a", "a"], buckets=(WINDOW,))
    with pytest.raises(ValueError, match="channel_masking"):
        plain.set_channel_mask(np.ones(CHANNELS, np.uint8))
    np.testing.assert_array_equal(plain.channel_masks,
                                  np.ones((2, CHANNELS), np.uint8))
    fleet = StreamingFleet(pipes, ["a", "a"], buckets=(WINDOW,),
                           channel_masking=True)
    with pytest.raises(ValueError, match="mask"):
        fleet.set_channel_mask(np.ones((2, CHANNELS + 1), np.uint8))
    with pytest.raises(ValueError, match="0 or 1"):
        fleet.set_channel_mask(np.full(CHANNELS, 2, np.uint8))
    with pytest.raises(ValueError, match="sessions"):
        fleet.set_channel_mask(np.ones(CHANNELS, np.uint8), sessions=[5])
    # per-session restriction + (C,) broadcast
    m = np.ones(CHANNELS, np.uint8)
    m[0] = 0
    fleet.set_channel_mask(m, sessions=[1])
    got = fleet.channel_masks
    assert got[1, 0] == 0 and got[0, 0] == 1


def test_mask_survives_reset_and_checkpoint(tmp_path):
    """Masks describe electrode health, not stream state: reset keeps
    them; save/restore round-trips them; a mask-free checkpoint restores
    as all-live."""
    pipes = {"a": _trained("sparse_compim", seed=0)}
    fleet = StreamingFleet(pipes, ["a", "a"], buckets=(WINDOW,),
                           channel_masking=True)
    mask = np.ones((2, CHANNELS), np.uint8)
    mask[0, 3] = 0
    fleet.set_channel_mask(mask)
    rng = np.random.default_rng(13)
    chunks = [_chunk(rng, WINDOW) for _ in range(2)]
    out_before = fleet.push(chunks)
    fleet.reset()
    np.testing.assert_array_equal(fleet.channel_masks, mask)
    out_after = fleet.push(chunks)  # same mask -> same decisions
    for i in range(2):
        _assert_decisions_equal(out_before[i], out_after[i])

    fleet.save(str(tmp_path / "ck"))
    other = StreamingFleet(pipes, ["a", "a"], buckets=(WINDOW,),
                           channel_masking=True)
    other.restore(str(tmp_path / "ck"))
    np.testing.assert_array_equal(other.channel_masks, mask)
    _assert_decisions_equal(fleet.push(chunks)[0], other.push(chunks)[0])

    plain = StreamingFleet(pipes, ["a", "a"], buckets=(WINDOW,))
    plain.push(chunks)
    plain.save(str(tmp_path / "ck2"))
    other.restore(str(tmp_path / "ck2"))  # no mask in meta: all-live
    np.testing.assert_array_equal(other.channel_masks,
                                  np.ones((2, CHANNELS), np.uint8))


# ---------------------------------------------------------------------------
# snapshot + lifecycle carriage
# ---------------------------------------------------------------------------

def test_snapshot_channel_mask_roundtrip():
    pipe = _trained("sparse_compim", seed=0)
    sess = SeizureSession(pipe)
    sess.push(np.random.default_rng(14).integers(
        0, 64, (WINDOW, CHANNELS), np.uint8))
    snap = sess.snapshot()
    assert snap.channel_mask is None  # engine sessions don't mask
    blob = snap.to_bytes()
    assert SessionSnapshot.from_bytes(blob).channel_mask is None  # compat
    mask = np.ones(CHANNELS, np.uint8)
    mask[6] = 0
    import dataclasses
    snap2 = dataclasses.replace(snap, channel_mask=mask)
    back = SessionSnapshot.from_bytes(snap2.to_bytes())
    np.testing.assert_array_equal(back.channel_mask, mask)


def test_elastic_fleet_mask_follows_session(tmp_path):
    """Quarantine follows the SESSION through evict/readmit: the snapshot
    carries the mask, a fresh admission starts all-live, and elastic
    save/restore round-trips the whole mask table."""
    bank = {f"p{i}": _trained("sparse_compim", seed=i) for i in range(2)}
    fleet = ElasticFleet(bank, tile=4, max_tiles=2, buckets=(WINDOW,),
                         channel_masking=True)
    sid = fleet.admit("p0")
    slot = fleet._sid_slot[sid]
    m = np.ones(CHANNELS, np.uint8)
    m[2] = 0
    fleet.set_channel_mask(m, sessions=[slot])
    rng = np.random.default_rng(15)
    fleet.push_sessions({sid: _chunk(rng, WINDOW)})

    snap = fleet.evict([sid])[sid]
    np.testing.assert_array_equal(snap.channel_mask, m)

    sid2 = fleet.admit("p1")  # fresh admission (may reuse the slot)
    slot2 = fleet._sid_slot[sid2]
    np.testing.assert_array_equal(fleet.channel_masks[slot2],
                                  np.ones(CHANNELS, np.uint8))

    sid3 = fleet.admit("p0", snapshot=snap)  # reconnect: mask comes back
    slot3 = fleet._sid_slot[sid3]
    np.testing.assert_array_equal(fleet.channel_masks[slot3], m)

    fleet.save(str(tmp_path / "ck"))
    other = ElasticFleet(bank, tile=4, max_tiles=2, buckets=(WINDOW,),
                         channel_masking=True)
    other.restore(str(tmp_path / "ck"))
    np.testing.assert_array_equal(other.channel_masks, fleet.channel_masks)
