"""Elastic fleet lifecycle (serve/lifecycle.py): admission/eviction slot
invariants, reconnect-with-state bit-exactness, spill/compaction,
overload shedding, recompile-free guarantees, incremental checkpoints,
and crash recovery via restore+replay — including a real SIGTERM kill of
``launch/serve.py``."""

import json
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.serve.engine import SeizureSession, SessionSnapshot
from repro.serve.lifecycle import CapacityError, ElasticFleet

jax.config.update("jax_platform_name", "cpu")

DIM, SEGMENTS, CHANNELS, WINDOW = 256, 8, 8, 32
BUCKETS = (32, 64)


def _trained(seed: int) -> HDCPipeline:
    rng = np.random.default_rng(seed)
    cfg = HDCConfig(dim=DIM, segments=SEGMENTS, channels=CHANNELS,
                    window=WINDOW, variant="sparse_compim",
                    spatial_threshold=1, temporal_threshold=4)
    codes = jnp.asarray(rng.integers(0, 64, (2, 4 * WINDOW, CHANNELS),
                                     np.uint8))
    labels = np.asarray(rng.integers(0, 2, (2, 4), np.int32))
    labels[0, :2] = (0, 1)
    pipe = HDCPipeline.init(jax.random.PRNGKey(seed), cfg)
    return pipe.train_one_shot(codes, jnp.asarray(labels))


@pytest.fixture(scope="module")
def bank():
    return {f"p{i}": _trained(i) for i in range(2)}


def _fleet(bank, **kw):
    kw.setdefault("tile", 4)
    kw.setdefault("max_tiles", 2)
    kw.setdefault("queue_limit", 2)
    kw.setdefault("buckets", BUCKETS)
    return ElasticFleet(bank, **kw)


def _chunk(rng, t):
    return rng.integers(0, 64, (t, CHANNELS), np.uint8)


def _assert_same_decisions(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x.frame_index == y.frame_index
        assert x.prediction == y.prediction
        np.testing.assert_array_equal(x.scores, y.scores)


def _slot_invariants(fleet):
    """The free-slot-map safety properties every op must preserve."""
    occupied = set(fleet._slot_sid)
    free = set().union(*fleet._free) if fleet._free else set()
    # bijection: no two live sessions alias one slot, maps agree
    assert len(fleet._sid_slot) == len(set(fleet._sid_slot.values()))
    assert {s: k for k, s in fleet._sid_slot.items()} == fleet._slot_sid
    # partition: every slot is exactly one of free/occupied
    assert free.isdisjoint(occupied)
    assert free | occupied == set(range(fleet.capacity))
    # the fleet-wide emission invariant dead slots rely on
    assert (fleet._filled_h < WINDOW).all()


# ---------------------------------------------------------------------------
# admission / eviction / reconnect
# ---------------------------------------------------------------------------

def test_admit_push_evict_matches_sessions(bank):
    rng = np.random.default_rng(0)
    fleet = _fleet(bank)
    s0, s1 = fleet.admit("p0"), fleet.admit("p1")
    ref0, ref1 = SeizureSession(bank["p0"]), SeizureSession(bank["p1"])
    for t in (WINDOW + 7, 2 * WINDOW, 5, 0, WINDOW - 5):
        c0, c1 = _chunk(rng, t), _chunk(rng, max(t - 3, 0))
        decs = fleet.push_sessions({s0: c0, s1: c1})
        _assert_same_decisions(decs[s0], ref0.push(c0))
        _assert_same_decisions(decs[s1], ref1.push(c1))
        _slot_invariants(fleet)
    snaps = fleet.evict([s0, s1])
    assert snaps[s0].patient_id == "p0"
    assert fleet.sessions == {} and fleet.free_slots == fleet.capacity
    _slot_invariants(fleet)


def test_evict_readmit_bit_exact_with_uninterrupted(bank):
    """Reconnect-with-state: evict mid-window, round-trip the snapshot
    through its wire encoding, readmit, and stay bit-exact with a session
    that never dropped."""
    rng = np.random.default_rng(1)
    fleet = _fleet(bank)
    sid = fleet.admit("p0")
    ref = SeizureSession(bank["p0"])
    c1 = _chunk(rng, WINDOW + 11)  # ends mid-window: filled = 11
    _assert_same_decisions(fleet.push_sessions({sid: c1})[sid], ref.push(c1))

    snap = fleet.evict([sid])[sid]
    assert snap.filled == 11 and snap.frame_index == 1
    snap = SessionSnapshot.from_bytes(snap.to_bytes())  # wire round-trip

    sid2 = fleet.admit("p0", snapshot=snap)
    c2 = _chunk(rng, 2 * WINDOW)
    _assert_same_decisions(fleet.push_sessions({sid2: c2})[sid2],
                           ref.push(c2))
    # adaptation state survived the drop too
    assert fleet.adapt({sid2: 1}) == {sid2: True}
    assert ref.adapt(1)
    c3 = _chunk(rng, WINDOW)
    _assert_same_decisions(fleet.push_sessions({sid2: c3})[sid2],
                           ref.push(c3))


def test_snapshot_interops_with_engine_session(bank):
    """A fleet eviction resumes in a plain SeizureSession and vice versa."""
    rng = np.random.default_rng(2)
    fleet = _fleet(bank)
    sid = fleet.admit("p1")
    ref = SeizureSession(bank["p1"])
    c1 = _chunk(rng, WINDOW + 3)
    fleet.push_sessions({sid: c1})
    ref.push(c1)

    # fleet -> engine
    resumed = SeizureSession.from_snapshot(bank["p1"],
                                           fleet.evict([sid])[sid])
    c2 = _chunk(rng, WINDOW)
    _assert_same_decisions(resumed.push(c2), ref.push(c2))

    # engine -> fleet
    sid2 = fleet.admit("p1", snapshot=resumed.snapshot())
    c3 = _chunk(rng, WINDOW - 3)
    _assert_same_decisions(fleet.push_sessions({sid2: c3})[sid2],
                           ref.push(c3))


def test_admission_validation(bank):
    fleet = _fleet(bank)
    with pytest.raises(KeyError):
        fleet.admit("nobody")
    sid = fleet.admit("p0")
    snap = fleet.evict([sid])[sid]
    with pytest.raises(ValueError, match="belongs to patient"):
        fleet.admit("p1", snapshot=snap)
    with pytest.raises(KeyError):
        fleet.evict([99])
    with pytest.raises(KeyError):
        fleet.push_sessions({99: np.zeros((4, CHANNELS), np.uint8)})
    with pytest.raises(KeyError):
        fleet.adapt({99: 1})


# ---------------------------------------------------------------------------
# spill / compaction / backpressure
# ---------------------------------------------------------------------------

def test_spill_compact_and_capacity_error(bank):
    rng = np.random.default_rng(3)
    fleet = _fleet(bank)
    sids = [fleet.admit("p0") for _ in range(4)]
    assert fleet.n_tiles == 1 and fleet.free_slots == 0
    spilled = fleet.admit("p1")  # 5th session: spill
    assert fleet.n_tiles == 2 and fleet.capacity == 8
    assert fleet.stats["spills"] == 1
    _slot_invariants(fleet)

    ref = SeizureSession(bank["p1"])
    c = _chunk(rng, WINDOW)
    _assert_same_decisions(fleet.push_sessions({spilled: c})[spilled],
                           ref.push(c))

    for _ in range(3):
        fleet.admit("p0")
    with pytest.raises(CapacityError):
        fleet.admit("p0")

    # drain tile 0, then compact: the spilled tile's survivors migrate
    # into earlier free slots and the trailing tile is dropped
    fleet.evict(sids, with_state=False)
    extras = [s for s in fleet.sessions if s not in (spilled,)]
    fleet.evict(extras, with_state=False)
    assert fleet.compact() == 1
    assert fleet.n_tiles == 1 and fleet.capacity == 4
    assert fleet.slot_of(spilled) < 4
    _slot_invariants(fleet)
    c2 = _chunk(rng, WINDOW)
    _assert_same_decisions(fleet.push_sessions({spilled: c2})[spilled],
                           ref.push(c2))


def test_offer_queue_shed_drain_and_degraded_adapt(bank):
    rng = np.random.default_rng(4)
    fleet = _fleet(bank, max_tiles=1, queue_limit=2)
    keep = fleet.admit("p0")
    fleet.push_sessions({keep: _chunk(rng, WINDOW)})
    others = [fleet.admit("p0") for _ in range(3)]
    assert fleet.free_slots == 0

    assert fleet.offer("p1")[0] == "queued"
    assert fleet.offer("p1")[0] == "queued"
    assert fleet.offer("p1")[0] == "shed"
    assert fleet.queue_depth == 2 and fleet.stats["shed"] == 1
    assert fleet.overloaded

    # degraded decision-only mode: adapt sheds, decisions keep flowing
    assert fleet.adapt({keep: 1}) == {keep: False}
    assert fleet.stats["adapt_shed"] == 1
    decs = fleet.push_sessions({keep: _chunk(rng, WINDOW)})
    assert len(decs[keep]) == 1

    # evictions drain the queue oldest-first
    fleet.evict(others[:2], with_state=False)
    assert fleet.queue_depth == 0 and not fleet.overloaded
    assert sorted(fleet.sessions.values()).count("p1") == 2
    _slot_invariants(fleet)
    # adaptation works again once the pressure clears
    assert fleet.adapt({keep: 1}) == {keep: True}


# ---------------------------------------------------------------------------
# recompile-free lifecycle (ISSUE acceptance criterion)
# ---------------------------------------------------------------------------

def test_lifecycle_recompile_free_after_warmup(bank, no_recompiles):
    rng = np.random.default_rng(5)
    fleet = _fleet(bank, max_tiles=2)
    fleet.warmup()
    with no_recompiles():
        sids = [fleet.admit("p0"), fleet.admit("p1")]
        fleet.push_sessions({sids[0]: _chunk(rng, WINDOW + 5),
                             sids[1]: _chunk(rng, 2 * WINDOW)})
        snap = fleet.evict([sids[0]])[sids[0]]
        s2 = fleet.admit("p0", snapshot=snap)
        for _ in range(3):
            fleet.admit("p1")
        assert fleet.n_tiles == 2          # spilled, still recompile-free
        fleet.push_sessions({s2: _chunk(rng, WINDOW)})
        fleet.adapt({s2: 1})
        doomed = [s for s in fleet.sessions if fleet.slot_of(s) >= 4]
        fleet.evict(doomed, with_state=False)
        fleet.compact()                    # migration + tile drop
        assert fleet.n_tiles == 1


# ---------------------------------------------------------------------------
# property tests: free-slot-map invariants under arbitrary op sequences
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "evict", "compact",
                                           "offer"]),
                          st.integers(0, 7)),
                min_size=1, max_size=14))
def test_slot_map_invariants_hold_under_churn(ops):
    bank = {"p0": _trained(0)}
    fleet = ElasticFleet(bank, tile=2, max_tiles=2, queue_limit=1,
                         buckets=BUCKETS)
    for op, arg in ops:
        if op == "admit":
            try:
                fleet.admit("p0")
            except CapacityError:
                pass
        elif op == "offer":
            fleet.offer("p0")
        elif op == "evict":
            live = sorted(fleet.sessions)
            if live:
                fleet.evict([live[arg % len(live)]],
                            with_state=bool(arg % 2))
        elif op == "compact":
            fleet.compact()
        _slot_invariants(fleet)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3 * WINDOW))
def test_eviction_readmission_bit_exact_property(seed, t1):
    """Any split point (mid-window or not): drop + resume == never dropped."""
    bank = {"p0": _trained(0)}
    rng = np.random.default_rng(seed)
    fleet = ElasticFleet(bank, tile=2, max_tiles=1, buckets=BUCKETS)
    ref = SeizureSession(bank["p0"])
    sid = fleet.admit("p0")
    c1, c2 = _chunk(rng, t1), _chunk(rng, WINDOW + 1)
    _assert_same_decisions(fleet.push_sessions({sid: c1})[sid], ref.push(c1))
    snap = fleet.evict([sid])[sid]
    sid2 = fleet.admit("p0", snapshot=snap)
    _assert_same_decisions(fleet.push_sessions({sid2: c2})[sid2],
                           ref.push(c2))


# ---------------------------------------------------------------------------
# durability: incremental checkpoints, restore, replay
# ---------------------------------------------------------------------------

def test_incremental_checkpoint_hard_links_clean_tiles(bank, tmp_path):
    rng = np.random.default_rng(6)
    root = str(tmp_path / "ckpt")
    fleet = _fleet(bank, max_tiles=2)
    a = fleet.admit("p0")
    for _ in range(4):
        fleet.admit("p0")               # spill to 2 tiles
    spilled = [s for s in fleet.sessions if fleet.slot_of(s) >= 4][0]
    fleet.push_sessions({a: _chunk(rng, WINDOW),
                         spilled: _chunk(rng, WINDOW)})
    p0 = fleet.save(root)
    fleet.push_sessions({spilled: _chunk(rng, 8)})  # only tile 1 advances
    p1 = fleet.save(root)

    def files(p):
        with open(os.path.join(p, "manifest.json")) as f:
            return {leaf["key"]: os.path.join(p, leaf["file"])
                    for leaf in json.load(f)["leaves"]}
    f0, f1 = files(p0), files(p1)
    for key in f0:
        same = os.stat(f0[key]).st_ino == os.stat(f1[key]).st_ino
        if key.startswith("tile_00/"):
            assert same, f"clean tile leaf {key} was re-serialized"
    assert any(os.stat(f0[k]).st_ino != os.stat(f1[k]).st_ino
               for k in f0 if k.startswith("tile_01/")), \
        "dirty tile must be rewritten"


def test_restore_replay_matches_uninterrupted_run(bank, tmp_path):
    """The crash-recovery contract: checkpoint, keep serving, crash,
    restore + replay the post-checkpoint events in a NEW fleet — its
    decisions (replayed and future) are bit-exact with the fleet that
    never died."""
    rng = np.random.default_rng(7)
    root = str(tmp_path / "ckpt")
    fleet = _fleet(bank, max_tiles=2, log_rounds=64)
    a, b = fleet.admit("p0"), fleet.admit("p1")
    fleet.push_sessions({a: _chunk(rng, 2 * WINDOW + 5),
                         b: _chunk(rng, WINDOW)})
    fleet.save(root)
    ckpt_op = fleet.op_id

    # post-checkpoint traffic the crash will wipe: churn + decisions
    live_results = []
    c1, c2 = _chunk(rng, WINDOW + 2), _chunk(rng, WINDOW)
    live_results.append(fleet.push_sessions({a: c1, b: c1}))
    snap = fleet.evict([b])[b]
    b2 = fleet.admit("p1", snapshot=snap)
    live_results.append(fleet.push_sessions({a: c2, b2: c2}))
    events = fleet.events_since(ckpt_op)
    post = _chunk(rng, 2 * WINDOW)
    live_final = fleet.push_sessions({a: post, b2: post})

    restored = _fleet(bank, max_tiles=2, log_rounds=64)
    step = restored.restore(root)
    assert step == 0 and restored.sessions == {a: "p0", b: "p1"}
    replayed = restored.replay(events)
    replay_pushes = [v for v in replayed.values() if isinstance(v, dict)
                     and all(isinstance(k, int) for k in v)]
    pushes = [r for r in replay_pushes if any(
        isinstance(d, list) for d in r.values())]
    assert len(pushes) == len(live_results)
    for live, redo in zip(live_results, pushes):
        assert live.keys() == redo.keys()
        for sid in live:
            _assert_same_decisions(live[sid], redo[sid])
    re_final = restored.push_sessions({a: post, b2: post})
    for sid in live_final:
        _assert_same_decisions(live_final[sid], re_final[sid])
    assert restored.sessions == fleet.sessions


def test_restore_rejects_mismatched_bank(bank, tmp_path):
    root = str(tmp_path / "ckpt")
    fleet = _fleet(bank)
    fleet.admit("p0")
    fleet.save(root)
    other = ElasticFleet({"p0": _trained(7), "p1": _trained(8)},
                         tile=4, max_tiles=2, buckets=BUCKETS)
    with pytest.raises(ValueError, match="does not match"):
        other.restore(root)


def test_replay_gap_detection(bank):
    fleet = _fleet(bank)
    fleet.admit("p0")
    with pytest.raises(ValueError, match="gap"):
        fleet.replay([(fleet.op_id + 3, "compact", ())])


def test_events_since_reports_ring_overflow(bank):
    fleet = _fleet(bank, log_rounds=2)
    sid = fleet.admit("p0")
    for _ in range(4):
        fleet.evict([sid], with_state=False)
        sid = fleet.admit("p0")
    with pytest.raises(ValueError, match="dropped"):
        fleet.events_since(0)


def test_checkpoint_resume_under_churn_property(bank, tmp_path):
    """Randomized churn + checkpoint at an arbitrary point: restore+replay
    reconverges to the live fleet's exact session table and decisions."""
    rng = np.random.default_rng(11)
    root = str(tmp_path / "ckpt")
    fleet = _fleet(bank, max_tiles=2, log_rounds=256)
    for _ in range(3):
        fleet.admit("p0")
    fleet.save(root)
    ckpt_op = fleet.op_id
    for i in range(12):
        live = sorted(fleet.sessions)
        r = rng.integers(0, 4)
        if r == 0 and live:
            fleet.evict([live[int(rng.integers(len(live)))]],
                        with_state=False)
        elif r == 1:
            fleet.offer("p1")
        elif r == 2:
            fleet.compact()
        elif live:
            fleet.push_sessions({live[0]: _chunk(rng, int(
                rng.integers(1, WINDOW + 1)))})
    events = fleet.events_since(ckpt_op)
    restored = _fleet(bank, max_tiles=2, log_rounds=256)
    restored.restore(root)
    restored.replay(events)
    assert restored.sessions == fleet.sessions
    assert restored.op_id == fleet.op_id
    np.testing.assert_array_equal(restored._filled_h, fleet._filled_h)
    np.testing.assert_array_equal(restored._fidx_h, fleet._fidx_h)
    live = sorted(fleet.sessions)
    if live:
        c = _chunk(rng, 2 * WINDOW)
        d_live = fleet.push_sessions({live[0]: c})
        d_redo = restored.push_sessions({live[0]: c})
        _assert_same_decisions(d_live[live[0]], d_redo[live[0]])


# ---------------------------------------------------------------------------
# SIGTERM: a real kill of launch/serve.py leaves a resumable checkpoint
# ---------------------------------------------------------------------------

def test_sigterm_writes_final_checkpoint_and_exits_clean(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    env = dict(os.environ, PYTHONPATH="src", REPRO_FLEET_TILE="64",
               JAX_PLATFORMS="cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--hdc-fleet",
         "--sessions", "4", "--patients", "1", "--rounds", "100000",
         "--chunk", "64", "--ckpt-dir", ckpt_dir, "--ckpt-every", "2"],
        env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.time() + 180
        while time.time() < deadline:
            if proc.poll() is not None:
                pytest.fail("serve exited early:\n" + proc.communicate()[0])
            if os.path.isdir(ckpt_dir) and any(
                    d.startswith("step_") and not d.endswith(".tmp")
                    for d in os.listdir(ckpt_dir)):
                break
            time.sleep(0.25)
        else:
            pytest.skip("serve did not reach first checkpoint in time")
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, out
    assert "caught SIGTERM" in out
    steps = [d for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    assert steps, "final checkpoint missing after SIGTERM"
