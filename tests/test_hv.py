"""Unit + property tests for hypervector primitives (core/hv.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import hv

jax.config.update("jax_platform_name", "cpu")


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (3, 5, 1024)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    assert packed.shape == (3, 5, 32)
    back = hv.unpack_bits(packed)
    np.testing.assert_array_equal(np.asarray(back), bits)


def test_pack_matches_numpy_mirror():
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, (4, 256)).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(hv.pack_bits(jnp.asarray(bits))), hv.np_pack_bits(bits))


def test_popcount():
    rng = np.random.default_rng(2)
    bits = rng.integers(0, 2, (7, 512)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(hv.popcount(packed)), bits.sum(-1))


@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_hamming_overlap_identities(a, b):
    aw = jnp.asarray([[a]], dtype=jnp.uint32)
    bw = jnp.asarray([[b]], dtype=jnp.uint32)
    ham = int(hv.hamming(aw, bw)[0])
    ovl = int(hv.overlap(aw, bw)[0])
    pa, pb = int(hv.popcount(aw)[0]), int(hv.popcount(bw)[0])
    # |a^b| = |a| + |b| - 2|a&b|
    assert ham == pa + pb - 2 * ovl


@given(st.lists(st.integers(0, 127), min_size=8, max_size=8))
@settings(max_examples=25, deadline=None)
def test_positions_roundtrip(pos):
    p = jnp.asarray([pos], dtype=jnp.uint8)
    packed = hv.positions_to_packed(p, 1024, 8)
    assert int(hv.popcount(packed)[0]) == 8   # exactly one bit per segment
    back = hv.packed_to_positions(packed, 1024, 8)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(p))


def test_positions_to_packed_matches_bits_path():
    key = jax.random.PRNGKey(3)
    pos = hv.random_sparse_positions(key, (6,), 8, 128)
    direct = hv.positions_to_packed(pos, 1024, 8)
    via_bits = hv.pack_bits(hv.positions_to_bits(pos, 1024, 8))
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(via_bits))


@pytest.mark.parametrize("dim,segments", [(1024, 8), (512, 8), (2048, 16), (256, 4)])
def test_positions_various_shapes(dim, segments):
    key = jax.random.PRNGKey(dim + segments)
    pos = hv.random_sparse_positions(key, (3, 4), segments, dim // segments)
    packed = hv.positions_to_packed(pos, dim, segments)
    assert packed.shape == (3, 4, dim // 32)
    np.testing.assert_array_equal(
        np.asarray(hv.packed_to_positions(packed, dim, segments)), np.asarray(pos))


def test_or_reduce_equals_any():
    rng = np.random.default_rng(4)
    bits = rng.integers(0, 2, (5, 9, 256)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    ored = hv.or_reduce(packed, axis=1)
    np.testing.assert_array_equal(
        np.asarray(hv.unpack_bits(ored)), bits.any(axis=1).astype(np.uint8))


def test_unpacked_counts_matches_dense_sum():
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (3, 17, 128)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    counts = hv.unpacked_counts(packed, axis=1, dim=128)
    np.testing.assert_array_equal(np.asarray(counts), bits.sum(axis=1))


def test_threshold_pack():
    counts = jnp.asarray(np.arange(64)[None, :])
    packed = hv.threshold_pack(counts, 32)
    bits = np.asarray(hv.unpack_bits(packed, 64))
    np.testing.assert_array_equal(bits[0], (np.arange(64) >= 32).astype(np.uint8))


def test_density():
    ones = jnp.full((1, 32), 0xFFFFFFFF, dtype=jnp.uint32)
    assert float(hv.density(ones, 1024)[0]) == 1.0
    zeros = jnp.zeros((1, 32), dtype=jnp.uint32)
    assert float(hv.density(zeros, 1024)[0]) == 0.0
