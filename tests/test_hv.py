"""Unit + property tests for hypervector primitives (core/hv.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import hv

jax.config.update("jax_platform_name", "cpu")


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (3, 5, 1024)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    assert packed.shape == (3, 5, 32)
    back = hv.unpack_bits(packed)
    np.testing.assert_array_equal(np.asarray(back), bits)


def test_pack_matches_numpy_mirror():
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, (4, 256)).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(hv.pack_bits(jnp.asarray(bits))), hv.np_pack_bits(bits))


def test_popcount():
    rng = np.random.default_rng(2)
    bits = rng.integers(0, 2, (7, 512)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(hv.popcount(packed)), bits.sum(-1))


@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_hamming_overlap_identities(a, b):
    aw = jnp.asarray([[a]], dtype=jnp.uint32)
    bw = jnp.asarray([[b]], dtype=jnp.uint32)
    ham = int(hv.hamming(aw, bw)[0])
    ovl = int(hv.overlap(aw, bw)[0])
    pa, pb = int(hv.popcount(aw)[0]), int(hv.popcount(bw)[0])
    # |a^b| = |a| + |b| - 2|a&b|
    assert ham == pa + pb - 2 * ovl


@given(st.lists(st.integers(0, 127), min_size=8, max_size=8))
@settings(max_examples=25, deadline=None)
def test_positions_roundtrip(pos):
    p = jnp.asarray([pos], dtype=jnp.uint8)
    packed = hv.positions_to_packed(p, 1024, 8)
    assert int(hv.popcount(packed)[0]) == 8   # exactly one bit per segment
    back = hv.packed_to_positions(packed, 1024, 8)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(p))


def test_positions_to_packed_matches_bits_path():
    key = jax.random.PRNGKey(3)
    pos = hv.random_sparse_positions(key, (6,), 8, 128)
    direct = hv.positions_to_packed(pos, 1024, 8)
    via_bits = hv.pack_bits(hv.positions_to_bits(pos, 1024, 8))
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(via_bits))


@pytest.mark.parametrize("dim,segments", [(1024, 8), (512, 8), (2048, 16), (256, 4)])
def test_positions_various_shapes(dim, segments):
    key = jax.random.PRNGKey(dim + segments)
    pos = hv.random_sparse_positions(key, (3, 4), segments, dim // segments)
    packed = hv.positions_to_packed(pos, dim, segments)
    assert packed.shape == (3, 4, dim // 32)
    np.testing.assert_array_equal(
        np.asarray(hv.packed_to_positions(packed, dim, segments)), np.asarray(pos))


def test_or_reduce_equals_any():
    rng = np.random.default_rng(4)
    bits = rng.integers(0, 2, (5, 9, 256)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    ored = hv.or_reduce(packed, axis=1)
    np.testing.assert_array_equal(
        np.asarray(hv.unpack_bits(ored)), bits.any(axis=1).astype(np.uint8))


def test_unpacked_counts_matches_dense_sum():
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (3, 17, 128)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    counts = hv.unpacked_counts(packed, axis=1, dim=128)
    np.testing.assert_array_equal(np.asarray(counts), bits.sum(axis=1))


def test_threshold_pack():
    counts = jnp.asarray(np.arange(64)[None, :])
    packed = hv.threshold_pack(counts, 32)
    bits = np.asarray(hv.unpack_bits(packed, 64))
    np.testing.assert_array_equal(bits[0], (np.arange(64) >= 32).astype(np.uint8))


def test_density():
    ones = jnp.full((1, 32), 0xFFFFFFFF, dtype=jnp.uint32)
    assert float(hv.density(ones, 1024)[0]) == 1.0
    zeros = jnp.zeros((1, 32), dtype=jnp.uint32)
    assert float(hv.density(zeros, 1024)[0]) == 0.0


# ---------------------------------------------------------------------------
# property tests: pack/unpack round trips, positions fallback, or_reduce
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**63), st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip_property(seed, batch, words):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (batch, words * 32)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(hv.unpack_bits(packed)), bits)
    np.testing.assert_array_equal(np.asarray(packed), hv.np_pack_bits(bits))


@given(st.integers(0, 2**63))
@settings(max_examples=25, deadline=None)
def test_positions_to_packed_word_fallback_property(seed):
    """seg_len % 32 != 0 takes the pack_bits fallback branch: dim=128,
    segments=8 -> seg_len=16.  Round trip + agreement with the bits path."""
    rng = np.random.default_rng(seed)
    dim, segments = 128, 8
    pos = jnp.asarray(
        rng.integers(0, dim // segments, (3, segments)), jnp.uint8)
    packed = hv.positions_to_packed(pos, dim, segments)
    via_bits = hv.pack_bits(hv.positions_to_bits(pos, dim, segments))
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(via_bits))
    np.testing.assert_array_equal(
        np.asarray(hv.packed_to_positions(packed, dim, segments)),
        np.asarray(pos))


@given(st.integers(0, 2**63), st.integers(1, 9))
@settings(max_examples=25, deadline=None)
def test_or_reduce_odd_lengths_property(seed, n):
    """OR tree over odd / 1-length axes equals numpy's any()."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (2, n, 64)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    ored = hv.or_reduce(packed, axis=1)
    np.testing.assert_array_equal(
        np.asarray(hv.unpack_bits(ored)), bits.any(axis=1).astype(np.uint8))


def test_or_reduce_length_one_axis():
    rng = np.random.default_rng(6)
    packed = jnp.asarray(rng.integers(0, 2**32, (4, 1, 8), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(hv.or_reduce(packed, axis=1)), np.asarray(packed)[:, 0])


# ---------------------------------------------------------------------------
# bit-plane counters: time_pack layout + equivalence with unpacked_counts
# ---------------------------------------------------------------------------

def test_time_pack_layout():
    rng = np.random.default_rng(7)
    words = rng.integers(0, 2**32, (2, 64, 3), dtype=np.uint32)
    tp = np.asarray(hv.time_pack(jnp.asarray(words)))  # (2, 2, 32, 3)
    assert tp.shape == (2, 2, 32, 3)
    for s in range(2):
        for g in range(2):
            for b in range(0, 32, 7):
                for w in range(3):
                    want = 0
                    for j in range(32):
                        want |= ((int(words[s, 32 * g + j, w]) >> b) & 1) << j
                    assert int(tp[s, g, b, w]) == want


def test_bit_transpose32_is_involution():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.integers(0, 2**32, (5, 32, 4), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(hv.bit_transpose32(hv.bit_transpose32(x))), np.asarray(x))


@given(st.integers(0, 2**63), st.sampled_from([32, 64, 96]))
@settings(max_examples=25, deadline=None)
def test_bitplane_counts_match_unpacked_counts(seed, n):
    """The popcount-plane adder is bit-exact with the unpack-and-add tree
    (and with a dense numpy sum) whenever the reduce length packs evenly."""
    rng = np.random.default_rng(seed)
    dim = 64
    bits = rng.integers(0, 2, (2, n, dim)).astype(np.uint8)
    packed = hv.pack_bits(jnp.asarray(bits))
    counts = hv.bitplane_counts(packed, dim)
    np.testing.assert_array_equal(np.asarray(counts), bits.sum(axis=1))
    # unpacked_counts routes n % 32 == 0 through the same bit-plane path
    np.testing.assert_array_equal(
        np.asarray(hv.unpacked_counts(packed, axis=1, dim=dim)),
        bits.sum(axis=1))


def test_unpacked_counts_ragged_fallback_matches_bitplane():
    """Ragged N uses the scan fallback; both paths agree with numpy."""
    rng = np.random.default_rng(9)
    dim = 96
    bits = rng.integers(0, 2, (3, 33, dim)).astype(np.uint8)  # 33 % 32 != 0
    packed = hv.pack_bits(jnp.asarray(bits))
    np.testing.assert_array_equal(
        np.asarray(hv.unpacked_counts(packed, axis=1, dim=dim)),
        bits.sum(axis=1))


def test_time_pack_rejects_ragged_t():
    with pytest.raises(ValueError, match="multiple of 32"):
        hv.time_pack(jnp.zeros((2, 33, 4), jnp.uint32))
    with pytest.raises(ValueError, match="size 32"):
        hv.bit_transpose32(jnp.zeros((2, 16, 4), jnp.uint32))
