"""Small-mesh dry-run smoke tests (subprocess: needs >1 fake device, while
the main test process must stay at 1 device).

These prove the sharding specs lower+compile on a mesh for one cell per step
kind; the full 512-device production sweep runs via launch/dryrun.py and is
recorded in EXPERIMENTS.md.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env, cwd=REPO,
                          timeout=timeout)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_small_mesh_lower_compile(kind):
    code = f"""
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.data import lm as lmdata
    from repro.models import params as pmod
    from repro.optim import adamw
    from repro.runtime import steps as steps_mod

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("qwen3-0.6b").reduced(d_model=256, n_heads=4,
                                           n_kv_heads=2, head_dim=64,
                                           vocab=1024, d_ff=512)
    kind = "{kind}"
    if kind == "train":
        shape = lmdata.ShapeSpec("t", 64, 4, "train")
        specs = lmdata.input_specs(cfg, shape)
        jitted, ctx, spec = steps_mod.jit_train_step(
            cfg, adamw.OptConfig(), mesh, specs)
        pa = pmod.abstract(spec, jnp.float32)
        mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                          spec, is_leaf=lambda s: isinstance(s, pmod.ParamSpec))
        opt = dict(m=mv, v=mv, step=jax.ShapeDtypeStruct((), jnp.int32))
        lowered = jitted.lower(pa, opt, specs)
    elif kind == "prefill":
        shape = lmdata.ShapeSpec("p", 64, 4, "prefill")
        specs = lmdata.input_specs(cfg, shape)
        jitted, ctx, spec = steps_mod.jit_prefill(cfg, mesh, specs, 64)
        pa = pmod.abstract(spec, jnp.float32)
        lowered = jitted.lower(pa, specs)
    else:
        shape = lmdata.ShapeSpec("d", 64, 4, "decode")
        specs = lmdata.input_specs(cfg, shape)
        jitted, ctx, spec = steps_mod.jit_decode_step(cfg, mesh, specs)
        pa = pmod.abstract(spec, jnp.float32)
        lowered = jitted.lower(pa, specs["tokens"], specs["caches"], specs["pos"])
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem is not None
    print("OK", kind, int(mem.temp_size_in_bytes))
    """
    r = _run_py(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK " + kind in r.stdout


@pytest.mark.slow
def test_multipod_axis_shards():
    """The 3-axis (pod, data, model) mesh lowers with the pod axis active."""
    code = """
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.data import lm as lmdata
    from repro.models import params as pmod
    from repro.optim import adamw
    from repro.runtime import steps as steps_mod

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("qwen3-0.6b").reduced(d_model=256, n_heads=4,
                                           n_kv_heads=2, head_dim=64,
                                           vocab=1024, d_ff=512)
    shape = lmdata.ShapeSpec("t", 64, 4, "train")
    specs = lmdata.input_specs(cfg, shape)
    jitted, ctx, spec = steps_mod.jit_train_step(cfg, adamw.OptConfig(), mesh, specs)
    pa = pmod.abstract(spec, jnp.float32)
    mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                      spec, is_leaf=lambda s: isinstance(s, pmod.ParamSpec))
    opt = dict(m=mv, v=mv, step=jax.ShapeDtypeStruct((), jnp.int32))
    compiled = jitted.lower(pa, opt, specs).compile()
    txt = compiled.as_text()
    assert ("all-reduce" in txt) or ("all-gather" in txt), "no cross-device collectives?"
    print("OK multipod")
    """
    r = _run_py(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK multipod" in r.stdout


def test_sweep_artifacts_when_present():
    """If the full 512-device sweep has produced artifacts, every non-skipped
    cell must be status=ok (this validates the committed sweep results)."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    files = [f for f in os.listdir(art)] if os.path.isdir(art) else []
    if len(files) < 10:
        pytest.skip("full sweep not run in this environment")
    bad = []
    for f in files:
        with open(os.path.join(art, f)) as fh:
            rec = json.load(fh)
        if rec.get("status") not in ("ok", "skipped"):
            bad.append((f, rec.get("error", "")[:100]))
    assert not bad, bad
