"""Code-domain spatial datapath: owner_spatial_codes vs the reference
owner_spatial_encode (all variants, mixed owners, odd geometries), the fused
code-domain kernel, owner_encode_frames threading, and adaptive tile sizing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.pipeline import HDCConfig, HDCPipeline, VARIANTS
from repro.serve import dispatch, fleet as fleet_mod

jax.config.update("jax_platform_name", "cpu")


def _bank(variant: str, *, n_patients: int = 2, dim=256, segments=8,
          channels=8, window=32, **overrides):
    cfg = HDCConfig(dim=dim, segments=segments, channels=channels,
                    window=window, variant=variant, spatial_threshold=1,
                    temporal_threshold=4, **overrides)
    rng = np.random.default_rng(7)
    codes = jnp.asarray(rng.integers(0, cfg.codes, (2, 4 * window, channels),
                                     np.uint8))
    labels = jnp.asarray([[0, 1, 0, 1], [1, 0, 1, 0]])
    pipes = [HDCPipeline.init(jax.random.PRNGKey(i), cfg).train_one_shot(
        codes, labels) for i in range(n_patients)]
    tables, _ = dispatch.stack_bound_tables(pipes)
    return cfg, tables


# ---------------------------------------------------------------------------
# owner_spatial_codes vs owner_spatial_encode: bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("thinning", [False, True])
def test_spatial_codes_matches_reference(variant, thinning):
    if variant == "dense" and thinning:
        pytest.skip("thinning is a sparse knob")
    cfg, tables = _bank(variant, spatial_thinning=thinning)
    rng = np.random.default_rng(1)
    s, t = 5, 48
    owner = jnp.asarray(rng.integers(0, tables.shape[0], s), jnp.int32)
    codes = jnp.asarray(rng.integers(0, cfg.codes, (s, t, cfg.channels),
                                     np.uint8))
    got = np.asarray(dispatch.owner_spatial_codes(tables, owner, codes, cfg))
    want = np.asarray(dispatch.owner_spatial_encode(tables, owner, codes, cfg))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dim,segments,channels", [
    (192, 8, 6),    # seg_len=24: not a 32-multiple; non-power-of-two C
    (224, 7, 5),    # seg_len=32 but 7 segments; odd C
    (256, 16, 3),   # C < 4: tiny OR tree / count pad
    (160, 5, 33),   # C just past a pad boundary
])
def test_spatial_codes_odd_geometries(dim, segments, channels):
    """seg_len % 32 != 0 (positions_to_packed falls back to pack_bits) and
    channel counts that are not powers of two must stay bit-exact on both
    the OR-tree and the channel-padded count paths."""
    for variant, thinning in (("sparse_compim", False),
                              ("sparse_compim", True),
                              ("sparse_naive", False)):
        cfg, tables = _bank(variant, dim=dim, segments=segments,
                            channels=channels, spatial_thinning=thinning)
        rng = np.random.default_rng(dim + channels)
        s, t = 4, 24
        owner = jnp.asarray(rng.integers(0, tables.shape[0], s), jnp.int32)
        codes = jnp.asarray(rng.integers(0, cfg.codes, (s, t, channels),
                                         np.uint8))
        got = np.asarray(dispatch.owner_spatial_codes(
            tables, owner, codes, cfg))
        want = np.asarray(dispatch.owner_spatial_encode(
            tables, owner, codes, cfg))
        np.testing.assert_array_equal(got, want, err_msg=f"{variant}")


@given(st.integers(0, 2**31 - 1), st.integers(1, 64), st.integers(1, 4))
@settings(max_examples=12, deadline=None)
def test_spatial_codes_property(seed, t, n_patients):
    """Random chunk lengths (including t < block and t not a block multiple),
    random mixed owners, random codes: code-domain == reference."""
    cfg, tables = _bank("sparse_compim", n_patients=n_patients)
    rng = np.random.default_rng(seed)
    s = int(rng.integers(1, 7))
    owner = jnp.asarray(rng.integers(0, n_patients, s), jnp.int32)
    codes = jnp.asarray(rng.integers(0, cfg.codes, (s, t, cfg.channels),
                                     np.uint8))
    got = np.asarray(dispatch.owner_spatial_codes(tables, owner, codes, cfg))
    want = np.asarray(dispatch.owner_spatial_encode(tables, owner, codes, cfg))
    np.testing.assert_array_equal(got, want)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_spatial_codes_property_thinned(seed):
    rng = np.random.default_rng(seed)
    thr = int(rng.integers(1, 4))
    cfg, tables = _bank("sparse_naive", channels=6, spatial_threshold=thr)
    s, t = 3, int(rng.integers(1, 40))
    owner = jnp.asarray(rng.integers(0, tables.shape[0], s), jnp.int32)
    codes = jnp.asarray(rng.integers(0, cfg.codes, (s, t, 6), np.uint8))
    got = np.asarray(dispatch.owner_spatial_codes(tables, owner, codes, cfg))
    want = np.asarray(dispatch.owner_spatial_encode(tables, owner, codes, cfg))
    np.testing.assert_array_equal(got, want)


def test_spatial_codes_empty_chunk():
    cfg, tables = _bank("sparse_compim")
    owner = jnp.zeros((3,), jnp.int32)
    codes = jnp.zeros((3, 0, cfg.channels), jnp.uint8)
    out = dispatch.owner_spatial_codes(tables, owner, codes, cfg)
    assert out.shape == (3, 0, cfg.words)


def test_spatial_codes_out_of_range_codes_clamp_like_reference():
    """Codes >= 2**lbp_bits (stale staging bytes, hostile input) must not
    crash and must clamp exactly like the reference's advanced indexing."""
    cfg, tables = _bank("sparse_compim")
    rng = np.random.default_rng(2)
    owner = jnp.asarray([0, 1], jnp.int32)
    codes = jnp.asarray(rng.integers(0, 256, (2, 16, cfg.channels), np.uint8))
    got = np.asarray(dispatch.owner_spatial_codes(tables, owner, codes, cfg))
    want = np.asarray(dispatch.owner_spatial_encode(tables, owner, codes, cfg))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# owner_encode_frames rides the code-domain path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_owner_encode_frames_matches_pipeline(variant):
    cfg, tables = _bank(variant)
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(0, cfg.codes, (2, 2 * cfg.window + 5,
                                                    cfg.channels), np.uint8))
    pipes = [HDCPipeline.init(jax.random.PRNGKey(i), cfg) for i in range(2)]
    tables, _ = dispatch.stack_bound_tables(pipes)
    owner = jnp.asarray([1, 0], jnp.int32)
    thr = jnp.full((2,), cfg.temporal_threshold, jnp.int32)
    got = np.asarray(dispatch.owner_encode_frames(tables, owner, thr, codes,
                                                  cfg))
    for i, prow in enumerate([1, 0]):
        want = np.asarray(pipes[prow].encode_frames(codes[i][None]))[0]
        np.testing.assert_array_equal(got[i], want)


# ---------------------------------------------------------------------------
# adaptive tile sizing
# ---------------------------------------------------------------------------

class _FakeDevice:
    def __init__(self, stats):
        self._stats = stats

    def memory_stats(self):
        return self._stats


def test_derive_tile_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_FLEET_TILE", "128")
    assert fleet_mod.derive_tile(HDCConfig()) == 128
    # rejects: garbage, non-integers, non-powers-of-two, out-of-range
    for bad in ("-1", "abc", "12.5", "100", "32", "8192", "0"):
        monkeypatch.setenv("REPRO_FLEET_TILE", bad)
        with pytest.raises(ValueError, match="REPRO_FLEET_TILE"):
            fleet_mod.derive_tile(HDCConfig())
    # boundary powers of two pass
    for ok in ("64", "4096"):
        monkeypatch.setenv("REPRO_FLEET_TILE", ok)
        assert fleet_mod.derive_tile(HDCConfig()) == int(ok)


def test_derive_tile_cpu_fallback(monkeypatch):
    monkeypatch.delenv("REPRO_FLEET_TILE", raising=False)
    # CPU devices report no memory geometry -> cache-tuned default
    assert fleet_mod.derive_tile(
        HDCConfig(), device=_FakeDevice(None)) == fleet_mod.DEFAULT_TILE
    assert fleet_mod.derive_tile(
        HDCConfig(), device=_FakeDevice({})) == fleet_mod.DEFAULT_TILE


def test_derive_tile_memory_scaled(monkeypatch):
    monkeypatch.delenv("REPRO_FLEET_TILE", raising=False)
    cfg = HDCConfig()
    # a 16 GiB accelerator: large power-of-two tile, clamped to 4096
    big = fleet_mod.derive_tile(
        cfg, device=_FakeDevice({"bytes_limit": 16 << 30}))
    assert big == 4096
    # a tiny device floors at 64 and stays a power of two
    small = fleet_mod.derive_tile(
        cfg, device=_FakeDevice({"bytes_limit": 1 << 20}))
    assert small == 64
    mid = fleet_mod.derive_tile(
        cfg, device=_FakeDevice({"bytes_limit": 256 << 20}))
    assert 64 <= mid <= 4096 and mid & (mid - 1) == 0
    # more memory never shrinks the tile
    assert fleet_mod.derive_tile(
        cfg, device=_FakeDevice({"bytes_limit": 512 << 20})) >= mid


def test_derived_tile_capped_by_fleet_size(monkeypatch):
    """A memory-derived 4096 tile must not make a small fleet provision
    thousands of phantom rows: the derived tile caps at the fleet size
    rounded up to a power of two (explicit tile=/env stay uncapped)."""
    cfg = HDCConfig(dim=256, segments=8, channels=8, window=32,
                    temporal_threshold=4)
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 64, (2, 128, 8), np.uint8))
    labels = jnp.asarray([[0, 1, 0, 1], [1, 0, 1, 0]])
    pipe = HDCPipeline.init(jax.random.PRNGKey(0), cfg).train_one_shot(
        codes, labels)
    monkeypatch.delenv("REPRO_FLEET_TILE", raising=False)
    monkeypatch.setattr(fleet_mod, "derive_tile",
                        lambda *a, **k: 4096)
    f = fleet_mod.StreamingFleet({"p": pipe}, ["p"] * 100, buckets=(32,))
    provisioned = int(np.asarray(f.state.counts).shape[0])
    assert provisioned == 128  # next pow2 >= 100, not 4096
    # explicit constructor tile is the operator's choice: uncapped (200
    # sessions >= tile // 4, so capacity pads to the whole 512 tile)
    g = fleet_mod.StreamingFleet({"p": pipe}, ["p"] * 200, buckets=(32,),
                                 tile=512)
    assert int(np.asarray(g.state.counts).shape[0]) == 512


def test_fleet_tile_constructor_and_env(monkeypatch):
    cfg = HDCConfig(dim=256, segments=8, channels=8, window=32,
                    temporal_threshold=4)
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 64, (2, 128, 8), np.uint8))
    labels = jnp.asarray([[0, 1, 0, 1], [1, 0, 1, 0]])
    pipe = HDCPipeline.init(jax.random.PRNGKey(0), cfg).train_one_shot(
        codes, labels)
    # env tile must be a valid power of two in [64, 4096]; the constructor
    # tile= is the unvalidated escape hatch for out-of-range experiments
    monkeypatch.setenv("REPRO_FLEET_TILE", "64")
    f = fleet_mod.StreamingFleet({"p": pipe}, ["p"] * 9, buckets=(32,))
    assert f.n_tiles == 1  # 9 sessions fit one env-sized tile
    monkeypatch.delenv("REPRO_FLEET_TILE")
    g = fleet_mod.StreamingFleet({"p": pipe}, ["p"] * 9, buckets=(32,),
                                 tile=4)
    assert g.n_tiles == 3  # 9 sessions pad to 12 = 3 tiles of 4
    # tiling is a layout choice: decisions are bit-exact across tilings
    chunk = rng.integers(0, 64, (32, 8), np.uint8)
    for a, b in zip(f.push([chunk] * 9), g.push([chunk] * 9)):
        assert len(a) == len(b) == 1
        np.testing.assert_array_equal(a[0].frame_hv, b[0].frame_hv)
