"""Tests for the HLO-graph cost analyzer (runtime/hlo_cost.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.runtime.hlo_cost import analyze_hlo, _shape_numel_bytes

jax.config.update("jax_platform_name", "cpu")


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_shape_parse():
    assert _shape_numel_bytes("f32[4,8]{1,0}") == (32.0, 128.0)
    assert _shape_numel_bytes("bf16[10]") == (10.0, 20.0)
    n, b = _shape_numel_bytes("(s32[], f32[2,2]{1,0})")
    assert n == 5.0 and b == 20.0


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = _compiled_text(lambda x, y: x @ y, a, b)
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_trip_count_multiplied():
    """The whole point: a matmul inside a 10-step scan must count 10x."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def ten_matmuls(x):
        def step(c, _):
            return c @ c * 0.5, None
        out, _ = jax.lax.scan(step, x, None, length=10)
        return out

    r1 = analyze_hlo(_compiled_text(ten_matmuls, a))
    flops_one = 2 * 64 * 64 * 64
    assert r1["flops"] >= 9 * flops_one, r1["flops"]
    assert r1["flops"] <= 12 * flops_one, r1["flops"]


def test_nested_scan_trip_counts_compose():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def nested(x):
        def inner(c, _):
            return c @ c * 0.1, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    r = analyze_hlo(_compiled_text(nested, a))
    flops_one = 2 * 32 * 32 * 32
    assert r["flops"] >= 11 * flops_one   # 3*4 = 12 matmuls (tolerance 1)
    assert r["flops"] <= 14 * flops_one


def test_bytes_positive_and_scale():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r_small = analyze_hlo(_compiled_text(lambda x: x @ x, a))
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    r_big = analyze_hlo(_compiled_text(lambda x: x @ x, b))
    assert r_big["bytes"] > 3 * r_small["bytes"]


def test_collectives_counted_in_sharded_module(tmp_path):
    """Collectives inside a scan body count trip-count times (subprocess
    with 4 fake devices so the main process keeps 1)."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.runtime.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((4,), ("d",))
        sh = NamedSharding(mesh, P(None, "d"))

        def f(w, x):
            def step(c, _):
                y = jnp.einsum("ij,kj->ik", c, w)   # contract sharded dim
                return jax.lax.with_sharding_constraint(y, sh), None
            out, _ = jax.lax.scan(step, x, None, length=6)
            return out

        wa = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        xa = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = jax.jit(f, in_shardings=(sh, sh), out_shardings=sh).lower(wa, xa).compile()
        r = analyze_hlo(c.as_text())
        total = sum(v for k, v in r["collectives"].items() if k != "n_ops")
        assert total > 0, r
        print("COLL_OK", total)
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COLL_OK" in r.stdout
