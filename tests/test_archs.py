"""Per-architecture smoke tests: REDUCED same-family configs, one forward/
train step + prefill/decode consistency on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
from repro.data import lm as lmdata
from repro.models import model as M
from repro.models import params as P
from repro.models import serve as S
from repro.models.config import param_count
from repro.runtime.sharding import make_ctx
from repro.optim import adamw
from repro.runtime import steps as steps_mod

jax.config.update("jax_platform_name", "cpu")

CTX = make_ctx(None)


def _setup(arch):
    cfg = get_config(arch).reduced()
    spec = M.model_spec(cfg)
    params = P.initialize(jax.random.PRNGKey(0), spec, jnp.float32)
    return cfg, spec, params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg, spec, params = _setup(arch)
    shape = lmdata.ShapeSpec("t", 64, 2, "train")
    batch = lmdata.synth_batch(jax.random.PRNGKey(1), cfg, shape)
    opt = adamw.OptConfig(total_steps=10, warmup_steps=2)
    step = steps_mod.make_train_step(cfg, opt, CTX)
    opt_state = adamw.init_state(params, opt)
    params2, opt_state2, loss, metrics = jax.jit(step)(params, opt_state, batch)
    assert jnp.isfinite(loss), arch
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0
    # a second step decreases nothing catastrophic (still finite)
    _, _, loss2, _ = jax.jit(step)(params2, opt_state2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Prefill on L tokens == teacher forcing: decoding token L from the
    cache must give the same logits as prefill's last-position logits when
    the cache was built from the same prefix."""
    cfg, spec, params = _setup(arch)
    seq = 32
    shape = lmdata.ShapeSpec("p", seq, 2, "prefill")
    batch = lmdata.synth_batch(jax.random.PRNGKey(1), cfg, shape)
    tl = batch["tokens"].shape[1]

    logits_full, _ = jax.jit(
        lambda p, b: S.prefill(p, b, cfg, CTX, seq))(params, batch)

    # prefill on the prefix (all but last token), then decode the last token
    batch_prefix = dict(batch)
    batch_prefix["tokens"] = batch["tokens"][:, : tl - 1]
    _, caches = jax.jit(
        lambda p, b: S.prefill(p, b, cfg, CTX, seq))(params, batch_prefix)
    n_media = cfg.num_media_tokens if cfg.family == "vlm" else 0
    pos = jnp.asarray(tl - 1 + n_media, jnp.int32)
    logits_dec, _ = jax.jit(
        lambda p, t, c, q: S.decode_step(p, t, c, q, cfg, CTX))(
            params, batch["tokens"][:, tl - 1:], caches, pos)

    if cfg.family == "ssm":
        tol = 2e-4   # fp32 scan reassociation
    else:
        tol = 2e-4
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=1e-3, atol=tol, err_msg=arch)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_spec_consistency(arch):
    cfg, spec, params = _setup(arch)
    n_spec = P.count_params(spec)
    n_real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert n_spec == n_real


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_sane(arch):
    """The FULL config's parameter estimate should be in the arch's declared
    class (e.g. 16b ~ 10-20e9, 398b ~ 300-500e9)."""
    cfg = get_config(arch)
    total, active = param_count(cfg)
    expected = {
        "deepseek-moe-16b": (10e9, 25e9), "moonshot-v1-16b-a3b": (10e9, 32e9),
        "seamless-m4t-medium": (0.5e9, 3e9), "qwen3-0.6b": (0.4e9, 1e9),
        "command-r-35b": (25e9, 45e9), "llama3.2-3b": (2e9, 5e9),
        "qwen3-1.7b": (1.2e9, 2.5e9), "falcon-mamba-7b": (5e9, 9e9),
        "jamba-1.5-large-398b": (300e9, 500e9), "internvl2-2b": (1.5e9, 3.5e9),
    }[arch]
    assert expected[0] < total < expected[1], (arch, total)
    assert active <= total


def test_shape_applicability_matrix():
    """long_500k runs only for ssm/hybrid; everything else runs all shapes."""
    runs = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, sh in lmdata.SHAPES.items():
            ok, _ = shape_applicable(cfg, sh)
            runs[(arch, sname)] = ok
    assert runs[("falcon-mamba-7b", "long_500k")]
    assert runs[("jamba-1.5-large-398b", "long_500k")]
    assert not runs[("qwen3-0.6b", "long_500k")]
    assert not runs[("command-r-35b", "long_500k")]
    assert all(runs[(a, s)] for a in ARCH_IDS
               for s in ("train_4k", "prefill_32k", "decode_32k"))


@pytest.mark.parametrize("mode", ["index", "local_index"])
def test_moe_dispatch_modes_agree(mode):
    """Index-domain dispatch == dense (all-experts) compute when no tokens
    are dropped — the CompIM-equivalence property at the MoE layer."""
    from repro.models import moe as moe_mod
    cfg = get_config("deepseek-moe-16b").reduced(n_experts=4,
                                                 experts_per_token=2)
    cfg_ix = dataclasses.replace(cfg, moe_dispatch=mode, capacity_factor=8.0)
    cfg_dn = dataclasses.replace(cfg, moe_dispatch="dense")
    spec = moe_mod.moe_spec(cfg_ix)
    params = P.initialize(jax.random.PRNGKey(3), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    out_ix, aux_ix = moe_mod.moe_layer(params, x, cfg_ix, CTX)
    out_dn, aux_dn = moe_mod.moe_layer(params, x, cfg_dn, CTX)
    np.testing.assert_allclose(np.asarray(out_ix), np.asarray(out_dn),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_ix), float(aux_dn), rtol=1e-5)


def test_attention_bf16_intermediates_close():
    """The §Perf bf16-intermediate attention must track fp32 closely."""
    from repro.models import attention as A
    cfg = get_config("llama3.2-3b").reduced(d_model=128, n_heads=8,
                                            n_kv_heads=4, head_dim=16,
                                            vocab=512)
    cfg16 = dataclasses.replace(cfg, attn_bf16_intermediates=True)
    spec = A.attention_spec(cfg)
    params = P.initialize(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 128)) * 0.5
    o32 = A.attention_train(params, x, cfg, CTX)
    o16 = A.attention_train(params, x, cfg16, CTX)
    err = float(jnp.max(jnp.abs(o32 - o16)) / (jnp.max(jnp.abs(o32)) + 1e-9))
    assert err < 2e-2, err


def test_moe_capacity_drops_tokens():
    from repro.models import moe as moe_mod
    cfg = get_config("deepseek-moe-16b").reduced(
        n_experts=4, experts_per_token=2)
    cfg = dataclasses.replace(cfg, moe_dispatch="index", capacity_factor=0.25)
    spec = moe_mod.moe_spec(cfg)
    params = P.initialize(jax.random.PRNGKey(3), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    out, _ = moe_mod.moe_layer(params, x, cfg, CTX)   # must not crash
    assert jnp.isfinite(out).all()


def test_mamba_train_matches_decode_rollout():
    """Stepwise decode through mamba must reproduce the chunked train scan."""
    from repro.models import mamba as mb
    cfg = get_config("falcon-mamba-7b").reduced(d_model=32, ssm_state=4)
    spec = mb.mamba_spec(cfg)
    params = P.initialize(jax.random.PRNGKey(5), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 12, cfg.d_model)) * 0.1
    y_train = mb.mamba_train(params, x, cfg, CTX)
    state = {"ssm": jnp.zeros((2, cfg.d_inner, cfg.ssm_state)),
             "conv": jnp.zeros((2, cfg.ssm_conv - 1, cfg.d_inner))}
    outs = []
    for t in range(12):
        y, state = mb.mamba_decode(params, x[:, t:t + 1], state, cfg, CTX)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=1e-3, atol=1e-4)
