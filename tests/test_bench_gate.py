"""The CI benchmark gates (check_fleet_regression.py, check_reliability_gate.py).

The fleet gate's contract after the unknown-row fix: row families the
committed reference does not know yet are WARNINGS (new benchmarks land
ahead of their reference refresh), while known rows fail the gate when
they regress past tolerance, go missing, or stop parsing.  The reference
file itself stays strictly parsed — it is curated, so a malformed row
there is a repo bug.  The same known-row machinery gates the cold-start
ratios when --coldstart-fresh/--coldstart-reference are given, plus the
bitexact/fallback status rows which must start with "ok".

The reliability gate (extracted from the old ci.yml heredoc) fails when
any BER=0 sweep point is not bit-exact OR when the sweep has no BER=0
control points at all.
"""

import json

import pytest

from benchmarks import check_fleet_regression as gate
from benchmarks import check_reliability_gate as rel_gate

STAGE_ROWS = [
    {"name": "fleet.S8.stage_spatial", "derived": "share=20.0% of push"},
    {"name": "fleet.S8.stage_temporal", "derived": "share=30.0% of push"},
]


def _write(tmp_path, fname, rows, status="ok"):
    path = tmp_path / fname
    path.write_text(json.dumps(
        {"module": "fleet", "status": status, "rows": rows, "error": None}))
    return str(path)


def _speedup(name, x):
    return {"name": name, "derived": f"{x:.2f}x vs baseline"}


@pytest.fixture
def reference(tmp_path):
    return _write(tmp_path, "ref.json",
                  [_speedup("fleet.S8.speedup", 4.0)])


def test_gate_passes_within_tolerance(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 3.5)] + STAGE_ROWS)
    assert gate.main([fresh, reference, "--tolerance", "0.25"]) == 0


def test_gate_fails_on_regression(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 1.0)] + STAGE_ROWS)
    assert gate.main([fresh, reference, "--tolerance", "0.25"]) == 1


def test_unknown_row_family_warns_not_crashes(tmp_path, reference, capsys):
    """A fresh run with NEW speedup families (parseable or not) must not
    crash or fail the gate — the reference simply doesn't know them yet."""
    fresh = _write(tmp_path, "fresh.json", [
        _speedup("fleet.S8.speedup", 4.0),
        _speedup("fleet.newfamily.speedup", 9.0),
        {"name": "fleet.weird.speedup", "derived": "not a ratio at all"},
    ] + STAGE_ROWS)
    assert gate.main([fresh, reference]) == 0
    err = capsys.readouterr().err
    assert "fleet.newfamily.speedup" in err and "skipping" in err
    assert "fleet.weird.speedup" in err


def test_known_row_missing_fails(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.other.speedup", 4.0)] + STAGE_ROWS)
    assert gate.main([fresh, reference]) == 1


def test_known_row_unparseable_fails(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json", [
        {"name": "fleet.S8.speedup", "derived": "garbage"},
    ] + STAGE_ROWS)
    assert gate.main([fresh, reference]) == 1


def test_empty_reference_fails(tmp_path):
    ref = _write(tmp_path, "ref.json", [])
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 4.0)] + STAGE_ROWS)
    assert gate.main([fresh, ref]) == 1


def test_reference_stays_strict(tmp_path):
    ref = _write(tmp_path, "ref.json",
                 [{"name": "fleet.S8.speedup", "derived": "corrupt"}])
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 4.0)] + STAGE_ROWS)
    with pytest.raises(SystemExit):
        gate.main([fresh, ref])


def test_spatial_share_cap_still_gates(tmp_path, reference, capsys):
    fresh = _write(tmp_path, "fresh.json", [
        _speedup("fleet.S8.speedup", 4.0),
        {"name": "fleet.S8.stage_spatial", "derived": "share=80.0% of push"},
        {"name": "fleet.S8.stage_ingest", "derived": "mangled"},
    ])
    assert gate.main([fresh, reference, "--max-spatial-share", "0.5"]) == 1
    err = capsys.readouterr().err
    assert "fleet.S8.stage_ingest" in err  # mangled stage row only warns


def test_missing_spatial_breakdown_fails(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 4.0)])
    assert gate.main([fresh, reference]) == 1


# -- cold-start gating (--coldstart-fresh / --coldstart-reference) ----------

COLD_STATUS_ROWS = [
    {"name": "coldstart.bitexact", "derived": "ok identical decisions"},
    {"name": "coldstart.fallback", "derived": "ok stale artifact refused"},
]


@pytest.fixture
def fleet_fresh(tmp_path):
    return _write(tmp_path, "fleet_fresh.json",
                  [_speedup("fleet.S8.speedup", 4.0)] + STAGE_ROWS)


@pytest.fixture
def cold_reference(tmp_path):
    return _write(tmp_path, "cold_ref.json", [
        _speedup("coldstart.S8.warmcache.speedup", 2.0),
        _speedup("coldstart.S8.serialized.speedup", 4.0),
    ])


def _cold_args(fleet_fresh, reference, cold_fresh, cold_reference):
    return [fleet_fresh, reference,
            "--coldstart-fresh", cold_fresh,
            "--coldstart-reference", cold_reference]


def test_coldstart_gate_passes(tmp_path, fleet_fresh, reference,
                               cold_reference):
    cold = _write(tmp_path, "cold.json", [
        _speedup("coldstart.S8.warmcache.speedup", 3.0),
        _speedup("coldstart.S8.serialized.speedup", 6.0),
    ] + COLD_STATUS_ROWS)
    assert gate.main(
        _cold_args(fleet_fresh, reference, cold, cold_reference)) == 0


def test_coldstart_ratio_regression_fails(tmp_path, fleet_fresh, reference,
                                          cold_reference):
    cold = _write(tmp_path, "cold.json", [
        _speedup("coldstart.S8.warmcache.speedup", 2.0),
        _speedup("coldstart.S8.serialized.speedup", 1.1),  # floor is 3.0
    ] + COLD_STATUS_ROWS)
    assert gate.main(
        _cold_args(fleet_fresh, reference, cold, cold_reference)) == 1


def test_coldstart_bitexact_must_say_ok(tmp_path, fleet_fresh, reference,
                                        cold_reference):
    cold = _write(tmp_path, "cold.json", [
        _speedup("coldstart.S8.warmcache.speedup", 3.0),
        _speedup("coldstart.S8.serialized.speedup", 6.0),
        {"name": "coldstart.bitexact", "derived": "MISMATCH between paths"},
        COLD_STATUS_ROWS[1],
    ])
    assert gate.main(
        _cold_args(fleet_fresh, reference, cold, cold_reference)) == 1


def test_coldstart_missing_fallback_row_fails(tmp_path, fleet_fresh,
                                              reference, cold_reference):
    cold = _write(tmp_path, "cold.json", [
        _speedup("coldstart.S8.warmcache.speedup", 3.0),
        _speedup("coldstart.S8.serialized.speedup", 6.0),
        COLD_STATUS_ROWS[0],  # no coldstart.fallback row at all
    ])
    assert gate.main(
        _cold_args(fleet_fresh, reference, cold, cold_reference)) == 1


def test_coldstart_unknown_family_warns(tmp_path, fleet_fresh, reference,
                                        cold_reference, capsys):
    cold = _write(tmp_path, "cold.json", [
        _speedup("coldstart.S8.warmcache.speedup", 3.0),
        _speedup("coldstart.S8.serialized.speedup", 6.0),
        _speedup("coldstart.S64.serialized.speedup", 9.0),  # not in ref
    ] + COLD_STATUS_ROWS)
    assert gate.main(
        _cold_args(fleet_fresh, reference, cold, cold_reference)) == 0
    err = capsys.readouterr().err
    assert "coldstart.S64.serialized.speedup" in err and "skipping" in err


def test_coldstart_args_must_pair(fleet_fresh, reference):
    with pytest.raises(SystemExit):
        gate.main([fleet_fresh, reference, "--coldstart-fresh", "x.json"])


# -- elastic-fleet churn gate (--churn-fresh/--churn-reference) -------------

CHURN_STATUS_ROWS = [
    {"name": "churn.norecompile", "derived": "ok (0 compiles over 36 ops)"},
    {"name": "churn.recovery", "derived": "ok (5 ops replayed bit-exact)"},
]


@pytest.fixture
def churn_reference(tmp_path):
    return _write(tmp_path, "churn_ref.json", [
        _speedup("churn.S8.speedup", 2.0),
        _speedup("churn.S8.retention.speedup", 0.05),
    ])


def _churn_args(fleet_fresh, reference, churn_fresh, churn_reference):
    return [fleet_fresh, reference,
            "--churn-fresh", churn_fresh,
            "--churn-reference", churn_reference]


def test_churn_gate_passes(tmp_path, fleet_fresh, reference,
                           churn_reference):
    churn = _write(tmp_path, "churn.json", [
        _speedup("churn.S8.speedup", 2.5),
        _speedup("churn.S8.retention.speedup", 0.12),
    ] + CHURN_STATUS_ROWS)
    assert gate.main(
        _churn_args(fleet_fresh, reference, churn, churn_reference)) == 0


def test_churn_ratio_regression_fails(tmp_path, fleet_fresh, reference,
                                      churn_reference):
    churn = _write(tmp_path, "churn.json", [
        _speedup("churn.S8.speedup", 1.0),  # floor is 1.5
        _speedup("churn.S8.retention.speedup", 0.12),
    ] + CHURN_STATUS_ROWS)
    assert gate.main(
        _churn_args(fleet_fresh, reference, churn, churn_reference)) == 1


def test_churn_norecompile_must_say_ok(tmp_path, fleet_fresh, reference,
                                       churn_reference):
    churn = _write(tmp_path, "churn.json", [
        _speedup("churn.S8.speedup", 2.5),
        _speedup("churn.S8.retention.speedup", 0.12),
        {"name": "churn.norecompile",
         "derived": "FAILED: region compiled 3 XLA program(s)"},
        CHURN_STATUS_ROWS[1],
    ])
    assert gate.main(
        _churn_args(fleet_fresh, reference, churn, churn_reference)) == 1


def test_churn_missing_recovery_row_fails(tmp_path, fleet_fresh, reference,
                                          churn_reference):
    churn = _write(tmp_path, "churn.json", [
        _speedup("churn.S8.speedup", 2.5),
        _speedup("churn.S8.retention.speedup", 0.12),
        CHURN_STATUS_ROWS[0],  # no churn.recovery row at all
    ])
    assert gate.main(
        _churn_args(fleet_fresh, reference, churn, churn_reference)) == 1


def test_churn_args_must_pair(fleet_fresh, reference):
    with pytest.raises(SystemExit):
        gate.main([fleet_fresh, reference, "--churn-reference", "x.json"])


# -- reliability zero-BER gate (check_reliability_gate.py) ------------------

def _rel_point(ber, bitexact=True, scheme="none"):
    return {"variant": "sparse_opt", "density": 0.25, "scheme": scheme,
            "ber": ber, "zero_ber_bitexact": bitexact}


def _rel_write(tmp_path, points, fname="rel.json"):
    path = tmp_path / fname
    rows = [{"name": f"reliability.p{i}", "point": p}
            for i, p in enumerate(points)]
    rows.append({"name": "reliability.summary", "derived": "no point key"})
    path.write_text(json.dumps(
        {"module": "reliability", "status": "ok", "rows": rows}))
    return str(path)


def test_reliability_gate_passes(tmp_path, capsys):
    path = _rel_write(tmp_path, [
        _rel_point(0.0), _rel_point(0.0, scheme="secded"), _rel_point(0.01)])
    assert rel_gate.main([path]) == 0
    assert "bitexact=True" in capsys.readouterr().out


def test_reliability_gate_fails_on_nonexact_zero_ber(tmp_path):
    path = _rel_write(tmp_path, [
        _rel_point(0.0), _rel_point(0.0, bitexact=False, scheme="parity")])
    assert rel_gate.main([path]) == 1


def test_reliability_gate_fails_without_control_points(tmp_path):
    path = _rel_write(tmp_path, [_rel_point(0.01), _rel_point(0.03)])
    assert rel_gate.main([path]) == 1


def test_reliability_nonzero_points_do_not_gate(tmp_path):
    """Only BER=0 points carry the bit-exactness contract."""
    path = _rel_write(tmp_path, [
        _rel_point(0.0), _rel_point(0.01, bitexact=False)])
    assert rel_gate.main([path]) == 0
