"""The fleet perf-regression gate (benchmarks/check_fleet_regression.py).

The gate's contract after the unknown-row fix: row families the committed
reference does not know yet are WARNINGS (new benchmarks land ahead of
their reference refresh), while known rows fail the gate when they
regress past tolerance, go missing, or stop parsing.  The reference file
itself stays strictly parsed — it is curated, so a malformed row there is
a repo bug.
"""

import json

import pytest

from benchmarks import check_fleet_regression as gate

STAGE_ROWS = [
    {"name": "fleet.S8.stage_spatial", "derived": "share=20.0% of push"},
    {"name": "fleet.S8.stage_temporal", "derived": "share=30.0% of push"},
]


def _write(tmp_path, fname, rows, status="ok"):
    path = tmp_path / fname
    path.write_text(json.dumps(
        {"module": "fleet", "status": status, "rows": rows, "error": None}))
    return str(path)


def _speedup(name, x):
    return {"name": name, "derived": f"{x:.2f}x vs baseline"}


@pytest.fixture
def reference(tmp_path):
    return _write(tmp_path, "ref.json",
                  [_speedup("fleet.S8.speedup", 4.0)])


def test_gate_passes_within_tolerance(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 3.5)] + STAGE_ROWS)
    assert gate.main([fresh, reference, "--tolerance", "0.25"]) == 0


def test_gate_fails_on_regression(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 1.0)] + STAGE_ROWS)
    assert gate.main([fresh, reference, "--tolerance", "0.25"]) == 1


def test_unknown_row_family_warns_not_crashes(tmp_path, reference, capsys):
    """A fresh run with NEW speedup families (parseable or not) must not
    crash or fail the gate — the reference simply doesn't know them yet."""
    fresh = _write(tmp_path, "fresh.json", [
        _speedup("fleet.S8.speedup", 4.0),
        _speedup("fleet.newfamily.speedup", 9.0),
        {"name": "fleet.weird.speedup", "derived": "not a ratio at all"},
    ] + STAGE_ROWS)
    assert gate.main([fresh, reference]) == 0
    err = capsys.readouterr().err
    assert "fleet.newfamily.speedup" in err and "skipping" in err
    assert "fleet.weird.speedup" in err


def test_known_row_missing_fails(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.other.speedup", 4.0)] + STAGE_ROWS)
    assert gate.main([fresh, reference]) == 1


def test_known_row_unparseable_fails(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json", [
        {"name": "fleet.S8.speedup", "derived": "garbage"},
    ] + STAGE_ROWS)
    assert gate.main([fresh, reference]) == 1


def test_empty_reference_fails(tmp_path):
    ref = _write(tmp_path, "ref.json", [])
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 4.0)] + STAGE_ROWS)
    assert gate.main([fresh, ref]) == 1


def test_reference_stays_strict(tmp_path):
    ref = _write(tmp_path, "ref.json",
                 [{"name": "fleet.S8.speedup", "derived": "corrupt"}])
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 4.0)] + STAGE_ROWS)
    with pytest.raises(SystemExit):
        gate.main([fresh, ref])


def test_spatial_share_cap_still_gates(tmp_path, reference, capsys):
    fresh = _write(tmp_path, "fresh.json", [
        _speedup("fleet.S8.speedup", 4.0),
        {"name": "fleet.S8.stage_spatial", "derived": "share=80.0% of push"},
        {"name": "fleet.S8.stage_ingest", "derived": "mangled"},
    ])
    assert gate.main([fresh, reference, "--max-spatial-share", "0.5"]) == 1
    err = capsys.readouterr().err
    assert "fleet.S8.stage_ingest" in err  # mangled stage row only warns


def test_missing_spatial_breakdown_fails(tmp_path, reference):
    fresh = _write(tmp_path, "fresh.json",
                   [_speedup("fleet.S8.speedup", 4.0)])
    assert gate.main([fresh, reference]) == 1
