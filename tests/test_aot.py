"""AOT deploy artifacts (runtime/aot.py): fingerprint/key invalidation,
artifact roundtrips, warmed-fleet and prewarm-engine bit-exactness with the
JIT path, checkpoint-recorded artifacts, and stale-artifact JIT fallback."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import HDCConfig, HDCPipeline, VARIANTS
from repro.reliability.faults import FaultConfig
from repro.runtime import aot as aot_mod
from repro.serve.engine import ServingEngine
from repro.serve.fleet import StreamingFleet

jax.config.update("jax_platform_name", "cpu")

# tiny geometry keeps every compile in milliseconds (same as test_fleet)
DIM, SEGMENTS, CHANNELS, WINDOW = 256, 8, 8, 32


def _cfg(variant: str, **overrides) -> HDCConfig:
    base = dict(dim=DIM, segments=SEGMENTS, channels=CHANNELS, window=WINDOW,
                variant=variant, spatial_threshold=1, temporal_threshold=4)
    base.update(overrides)
    return HDCConfig(**base)


def _trained(variant: str, seed: int, **overrides) -> HDCPipeline:
    rng = np.random.default_rng(seed)
    cfg = _cfg(variant, **overrides)
    codes = jnp.asarray(rng.integers(0, 64, (2, 4 * WINDOW, CHANNELS), np.uint8))
    frames = codes.shape[1] // cfg.window
    labels = np.asarray(rng.integers(0, 2, (2, frames), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    pipe = HDCPipeline.init(jax.random.PRNGKey(seed), cfg)
    return pipe.train_one_shot(codes, jnp.asarray(labels))


def _chunks(seed: int, n: int, t: int = WINDOW) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 64, (t, CHANNELS), np.uint8) for _ in range(n)]


def _decisions(out) -> list[tuple]:
    return [(d.frame_index, d.prediction, tuple(np.asarray(d.scores)))
            for per_session in out for d in per_session]


# ---------------------------------------------------------------------------
# validity key: kernel fingerprint + artifact key + staleness
# ---------------------------------------------------------------------------

def test_kernel_fingerprint_stable_and_source_sensitive(tmp_path):
    root = tmp_path / "src"
    (root / "kernels").mkdir(parents=True)
    (root / "kernels" / "k.py").write_text("def f(): return 1\n")
    fp1 = aot_mod.kernel_fingerprint(root=str(root))
    assert fp1 == aot_mod.kernel_fingerprint(root=str(root))  # deterministic
    # non-.py files do not participate
    (root / "kernels" / "notes.md").write_text("irrelevant")
    assert aot_mod.kernel_fingerprint(root=str(root)) == fp1
    # kernel source changes MUST change the fingerprint
    (root / "kernels" / "k.py").write_text("def f(): return 2\n")
    assert aot_mod.kernel_fingerprint(root=str(root)) != fp1


def test_artifact_key_and_stale_fields():
    key = aot_mod.artifact_key()
    assert set(key) == {"jax", "device", "kernels"}
    assert aot_mod.stale_fields(key, dict(key)) == {}
    tampered = dict(key, jax="0.0.0-stale")
    bad = aot_mod.stale_fields(tampered, key)
    assert list(bad) == ["jax"]
    assert bad["jax"] == ("0.0.0-stale", key["jax"])


# ---------------------------------------------------------------------------
# fleet warmup + artifact roundtrip: bit-exact, compile_count honest
# ---------------------------------------------------------------------------

def test_warmup_precompiles_and_matches_jit(no_recompiles):
    pipe = _trained("sparse_compim", seed=0)
    jit_fleet = StreamingFleet({"p": pipe}, ["p"] * 4, buckets=(WINDOW,))
    warm = StreamingFleet({"p": pipe}, ["p"] * 4, buckets=(WINDOW,))
    stats = warm.warmup()  # no artifact: pre-lower + compile
    assert stats["compiled"] > 0 and stats["loaded"] == 0
    assert warm.aot_count == stats["compiled"]
    chunks = _chunks(7, 4)
    want = _decisions(jit_fleet.push(chunks))
    # pushes run through the installed executables: zero compiles on top
    # (a shape miss would fall back to jit and trip the sanitizer)
    with no_recompiles():
        got = warm.push(chunks)
    assert _decisions(got) == want


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("variant", VARIANTS)
def test_artifact_roundtrip_bitexact(tmp_path, variant, backend,
                                     no_recompiles):
    """save_aot -> load_artifact -> warmup(aot=...) must load (not compile)
    every executable and reproduce the JIT fleet bit-exactly, for every
    datapath variant on both backends."""
    pipes = {"a": _trained(variant, seed=0),
             "b": _trained(variant, seed=1, temporal_threshold=6)}
    owners = ["a", "b", "a"]
    kw = dict(buckets=(WINDOW,), backend=backend)
    StreamingFleet(pipes, owners, **kw).save_aot(str(tmp_path / "aot"))

    art = aot_mod.load_artifact(str(tmp_path / "aot"))
    assert art is not None and art.names
    warm = StreamingFleet(pipes, owners, **kw)
    stats = warm.warmup(aot=art)
    assert stats["loaded"] > 0 and stats["compiled"] == 0
    # the AOT executables ARE the compile count: jit cache stays cold but
    # the bucketed-compile guard must not pass vacuously at 0
    assert warm.aot_count == stats["loaded"]

    jit_fleet = StreamingFleet(pipes, owners, **kw)
    chunks = _chunks(11, len(owners))
    want = _decisions(jit_fleet.push(chunks))
    # the loaded executables serve every push: zero XLA compiles
    with no_recompiles():
        got = warm.push(chunks)
    assert _decisions(got) == want


def test_entries_ship_xla_executables(tmp_path):
    """Every exported entry also carries a serialized PjRt executable, and
    the load path hands it back without an XLA recompile; a signature
    mismatch falls through to None (callers then take the StableHLO tier)."""
    pipe = _trained("sparse_compim", seed=4)
    StreamingFleet({"p": pipe}, ["p"] * 2,
                   buckets=(WINDOW,)).save_aot(str(tmp_path / "aot"))
    art = aot_mod.load_artifact(str(tmp_path / "aot"))
    recs = art.manifest["entries"]
    assert recs and all(r.get("executable") for r in recs if r["exported"])
    name = recs[0]["name"]
    loaded = art.load_executable(name)
    assert loaded is not None
    good = tuple(jax.tree_util.tree_leaves(loaded.args_info))
    bad = tuple(jax.ShapeDtypeStruct((s.shape[0] + 1,) + tuple(s.shape[1:]),
                                     s.dtype) for s in good)
    assert art.load_executable(name, good) is not None
    assert art.load_executable(name, bad) is None


def test_faulted_fleet_artifact_roundtrip(tmp_path):
    """The faulted step (fault plan + SECDED ECC) exports and reloads too,
    with identical decisions AND identical ECC telemetry."""
    pipe = _trained("sparse_compim", seed=2)
    faults = FaultConfig(am=1e-2, seed=9, ecc="secded")
    kw = dict(buckets=(WINDOW,), faults=faults)
    StreamingFleet({"p": pipe}, ["p"] * 3, **kw).save_aot(str(tmp_path / "aot"))

    art = aot_mod.load_artifact(str(tmp_path / "aot"))
    warm = StreamingFleet({"p": pipe}, ["p"] * 3, **kw)
    assert warm.warmup(aot=art)["compiled"] == 0
    jit_fleet = StreamingFleet({"p": pipe}, ["p"] * 3, **kw)
    chunks = _chunks(13, 3)
    assert _decisions(warm.push(chunks)) == _decisions(jit_fleet.push(chunks))
    np.testing.assert_array_equal(warm.ecc_stats, jit_fleet.ecc_stats)


def test_stale_artifact_refuses_to_load(tmp_path):
    pipe = _trained("sparse_compim", seed=0)
    StreamingFleet({"p": pipe}, ["p"], buckets=(WINDOW,)).save_aot(
        str(tmp_path / "aot"))
    mpath = tmp_path / "aot" / aot_mod.MANIFEST
    manifest = json.loads(mpath.read_text())
    manifest["key"]["kernels"] = "deadbeefdeadbeef"
    mpath.write_text(json.dumps(manifest))
    with pytest.warns(UserWarning, match="kernels"):
        assert aot_mod.load_artifact(str(tmp_path / "aot")) is None


# ---------------------------------------------------------------------------
# checkpoint-recorded artifacts: from_artifact restore + stale JIT fallback
# ---------------------------------------------------------------------------

def _ckpt_manifest_path(root) -> str:
    steps = sorted(os.listdir(root))
    return os.path.join(root, steps[-1], "manifest.json")


def test_checkpoint_records_aot_entry_and_from_artifact_restores(tmp_path):
    pipes = {"p": _trained("sparse_compim", seed=4)}
    fleet = StreamingFleet(pipes, ["p"] * 3, buckets=(WINDOW,))
    chunks = _chunks(17, 3)
    fleet.push(chunks)  # advance state so restore is non-trivial
    root, aot_dir = str(tmp_path / "ckpt"), str(tmp_path / "aot")
    fleet.save(root, aot_dir=aot_dir)

    manifest = json.loads(open(_ckpt_manifest_path(root)).read())
    assert manifest["aot"]["path"] == aot_dir
    assert manifest["aot"]["key"] == aot_mod.artifact_key()

    restored = StreamingFleet.from_artifact(pipes, ["p"] * 3, root,
                                            buckets=(WINDOW,))
    assert restored.aot_count > 0  # warmed from the recorded artifact
    more = _chunks(19, 3)
    assert _decisions(restored.push(more)) == _decisions(fleet.push(more))


def test_stale_ckpt_aot_entry_falls_back_to_jit(tmp_path):
    """A checkpoint whose recorded AOT key no longer matches (here: written
    by another jax version) must warn, skip the artifact, and restore via
    plain JIT — with identical decisions."""
    pipes = {"p": _trained("sparse_compim", seed=4)}
    fleet = StreamingFleet(pipes, ["p"] * 2, buckets=(WINDOW,))
    chunks = _chunks(23, 2)
    fleet.push(chunks)
    root = str(tmp_path / "ckpt")
    fleet.save(root, aot_dir=str(tmp_path / "aot"))

    mpath = _ckpt_manifest_path(root)
    manifest = json.loads(open(mpath).read())
    manifest["aot"]["key"]["jax"] = "0.0.0-stale"
    with open(mpath, "w") as f:
        json.dump(manifest, f)

    with pytest.warns(UserWarning, match="stale"):
        restored = StreamingFleet.from_artifact(pipes, ["p"] * 2, root,
                                                buckets=(WINDOW,))
    more = _chunks(29, 2)
    assert _decisions(restored.push(more)) == _decisions(fleet.push(more))


# ---------------------------------------------------------------------------
# engine prewarm
# ---------------------------------------------------------------------------

def test_engine_prewarm_artifact_bitexact(tmp_path):
    pipes = {"a": _trained("sparse_compim", seed=0),
             "b": _trained("sparse_compim", seed=1)}
    t = 2 * WINDOW
    builder = ServingEngine(pipes)
    aot_mod.save_artifact(str(tmp_path / "aot"),
                          builder.aot_entries([1, 2, 4], t))

    art = aot_mod.load_artifact(str(tmp_path / "aot"))
    warm = ServingEngine(pipes)
    stats = warm.prewarm(4, t, aot=art)
    assert stats["loaded"] > 0 and stats["compiled"] == 0
    assert warm.aot_count == stats["loaded"]

    cold = ServingEngine(pipes)
    rng = np.random.default_rng(31)
    reqs = [(pid, jnp.asarray(rng.integers(0, 64, (t, CHANNELS), np.uint8)))
            for pid in ("a", "b", "a")]
    got = warm.serve(reqs)
    want = cold.serve(reqs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.predictions, w.predictions)
        np.testing.assert_array_equal(g.scores, w.scores)
