"""repro.analysis: RPR0xx linter (per-rule positive/negative/waiver),
HLO donation/dtype/host-escape audit, and the runtime sanitizer guards."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards
from repro.analysis.hlo_audit import (audit_entry, dtype_histogram,
                                      wide_buffer_histogram)
from repro.analysis.lint import RULES, lint_paths

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# linter harness: snippets written under a fake src/repro tree so module
# classification (packed-domain, src/) behaves as in the real repo
# ---------------------------------------------------------------------------

def _lint_snippet(tmp_path, source, rel="src/repro/core/mod.py"):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return lint_paths([str(tmp_path)])


def _codes(findings, waived=False):
    return [f.code for f in findings if f.waived == waived]


def test_rule_table_is_published():
    assert set(RULES) == {"RPR001", "RPR002", "RPR003", "RPR004", "RPR005"}


# -- RPR001: unpinned dtype in packed-domain modules ------------------------

def test_rpr001_flags_unpinned_reduction_and_factory(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    a = jnp.sum(x)\n"
        "    b = jnp.cumsum(x, axis=0)\n"
        "    c = jnp.arange(5)\n"
        "    return a, b, c\n"))
    assert _codes(found) == ["RPR001", "RPR001", "RPR001"]


def test_rpr001_accepts_pinned_dtypes(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    a = jnp.sum(x, dtype=jnp.int32)\n"
        "    b = jnp.arange(5, dtype=jnp.uint32)\n"
        "    c = jnp.zeros((3,), jnp.uint32)\n"   # positional dtype
        "    return a, b, c\n"))
    assert _codes(found) == []


def test_rpr001_only_packed_domain_modules(tmp_path):
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return jnp.sum(x)\n")
    assert _codes(_lint_snippet(tmp_path, src,
                                rel="src/repro/models/m.py")) == []
    assert _codes(_lint_snippet(tmp_path, src,
                                rel="src/repro/serve/s.py")) == ["RPR001"]


def test_rpr001_waiver_same_line_and_preceding(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    a = jnp.sum(x)  # repro-lint: disable=RPR001\n"
        "    # repro-lint: disable=all\n"
        "    b = jnp.arange(5)\n"
        "    return a, b\n"))
    assert _codes(found) == []
    assert _codes(found, waived=True) == ["RPR001", "RPR001"]


# -- RPR002: host sync inside traced code -----------------------------------

def test_rpr002_flags_item_in_jitted_fn(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import jax\n"
        "@jax.jit\n"
        "def step(state):\n"
        "    return state.item()\n"))
    assert "RPR002" in _codes(found)


def test_rpr002_traced_reachability_crosses_modules(tmp_path):
    # helper.py: np.asarray in a plain function -- clean in isolation
    (tmp_path / "src/repro/serve").mkdir(parents=True)
    (tmp_path / "src/repro/serve/helper.py").write_text(
        "import numpy as np\n"
        "def hot(x):\n"
        "    return np.asarray(x)\n"
        "def cold(x):\n"
        "    return np.asarray(x)\n")
    # main.py: a jit root calls helper.hot -- hot becomes traced, cold not
    (tmp_path / "src/repro/serve/main.py").write_text(
        "import jax\n"
        "import functools\n"
        "from repro.serve import helper\n"
        "def step(x):\n"
        "    return helper.hot(x)\n"
        "step_jit = jax.jit(functools.partial(step))\n")
    found = lint_paths([str(tmp_path)])
    rpr2 = [f for f in found if f.code == "RPR002"]
    assert len(rpr2) == 1
    assert "hot" in rpr2[0].message


def test_rpr002_ignores_host_side_code(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import numpy as np\n"
        "def build_tables(x):\n"          # never reaches a jit root
        "    return np.asarray(x).item()\n"))
    assert _codes(found) == []


def test_rpr002_traces_through_methods(tmp_path):
    # jax.jit(self._step) roots the method; self._inner() is an edge; the
    # sync two method-hops from the root is found (pre-PR the call graph
    # stopped at module-level functions and missed all three)
    found = _lint_snippet(tmp_path, (
        "import jax\n"
        "import numpy as np\n"
        "class Engine:\n"
        "    def __init__(self):\n"
        "        self.step = jax.jit(self._step)\n"
        "    def _step(self, x):\n"
        "        return self._inner(x)\n"
        "    def _inner(self, x):\n"
        "        return np.asarray(x) + 1\n"))
    rpr2 = [f for f in found if f.code == "RPR002"]
    assert len(rpr2) == 1 and "Engine._inner" in rpr2[0].message


def test_rpr002_method_jit_decorator_and_unreached_method(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import jax\n"
        "class Engine:\n"
        "    @jax.jit\n"
        "    def step(self, x):\n"
        "        return x.item()\n"          # traced: flagged
        "    def host_side(self, x):\n"
        "        return x.item()\n"))        # unreachable from a root: clean
    rpr2 = [f for f in found if f.code == "RPR002"]
    assert len(rpr2) == 1 and "Engine.step" in rpr2[0].message


def test_rpr002_scalar_cast_on_traced_operand(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def step(x, n):\n"
        "    k = int(np.ceil(3.0))\n"     # static host math: allowed
        "    return float(x) + k\n"))     # sync on traced operand: flagged
    assert _codes(found) == ["RPR002"]


# -- RPR003: nondeterminism in src/ -----------------------------------------

def test_rpr003_flags_global_rng_and_seedless_default_rng(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import random\n"
        "import numpy as np\n"
        "def f():\n"
        "    a = np.random.rand(3)\n"
        "    b = np.random.default_rng()\n"
        "    c = random.random()\n"
        "    return a, b, c\n"))
    assert _codes(found) == ["RPR003", "RPR003", "RPR003"]


def test_rpr003_accepts_seeded_rng_and_skips_tests_dir(tmp_path):
    clean = ("import numpy as np\n"
             "def f(seed):\n"
             "    return np.random.default_rng(seed).integers(0, 4)\n")
    assert _codes(_lint_snippet(tmp_path, clean)) == []
    dirty = ("import numpy as np\n"
             "def f():\n"
             "    return np.random.rand(3)\n")
    assert _codes(_lint_snippet(tmp_path, dirty,
                                rel="tests/test_x.py")) == []


# -- RPR004: mutable defaults -----------------------------------------------

def test_rpr004_flags_mutable_defaults(tmp_path):
    found = _lint_snippet(tmp_path, (
        "import numpy as np\n"
        "def f(x, acc=[], cfg={}, buf=np.zeros(3)):\n"
        "    return x\n"))
    assert _codes(found) == ["RPR004", "RPR004", "RPR004"]


def test_rpr004_accepts_immutable_defaults(tmp_path):
    found = _lint_snippet(tmp_path, (
        "def f(x, acc=None, cfg=(), name='a', n=3):\n"
        "    return x\n"))
    assert _codes(found) == []


# -- RPR005: Pallas kernel purity -------------------------------------------

_KERNEL_PRELUDE = (
    "import functools\n"
    "import jax\n"
    "from jax.experimental import pallas as pl\n")


def test_rpr005_flags_side_effects_in_kernel_body(tmp_path):
    found = _lint_snippet(tmp_path, _KERNEL_PRELUDE + (
        "def kernel(x_ref, o_ref):\n"
        "    print('debug')\n"
        "    o_ref[...] = x_ref[...]\n"
        "def run(x):\n"
        "    return pl.pallas_call(functools.partial(kernel),\n"
        "                          out_shape=x)(x)\n"))
    assert "RPR005" in _codes(found)


def test_rpr005_accepts_pure_kernel(tmp_path):
    found = _lint_snippet(tmp_path, _KERNEL_PRELUDE + (
        "def kernel(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...] + 1\n"
        "def run(x):\n"
        "    return pl.pallas_call(kernel, out_shape=x)(x)\n"))
    assert _codes(found) == []


def test_repo_src_is_lint_clean():
    """Satellite invariant: the shipped tree has zero unwaived findings."""
    assert _codes(lint_paths(["src"])) == []


# ---------------------------------------------------------------------------
# HLO audit
# ---------------------------------------------------------------------------

def _entry(fn, *args, name="prog"):
    return types.SimpleNamespace(name=name, fn=fn, args=args, static=())


def test_audit_confirms_donation_aliasing():
    def step(state, x):
        return state + x

    donated = jax.jit(step, donate_argnums=(0,))
    arg = jax.ShapeDtypeStruct((64, 64), jnp.int32)
    audit = audit_entry(_entry(donated, arg, arg), expected_donated=1)
    assert audit.ok and audit.aliased == 1 and audit.alias_pairs == 1


def test_audit_fails_deliberately_non_donated_program():
    def step(state, x):
        return state + x

    plain = jax.jit(step)  # same program, donation forgotten
    arg = jax.ShapeDtypeStruct((64, 64), jnp.int32)
    audit = audit_entry(_entry(plain, arg, arg), expected_donated=1)
    assert not audit.ok
    assert any("donation" in p for p in audit.problems)


def test_audit_flags_host_callback_custom_call():
    def prog(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((4,), jnp.float32),
            x)

    audit = audit_entry(_entry(jax.jit(prog),
                               jax.ShapeDtypeStruct((4,), jnp.float32)),
                        compile=False)
    assert not audit.ok
    assert audit.host_escapes


def test_dtype_histograms_flag_wide_buffers_not_weak_scalars():
    text = ("%0 = stablehlo.add %a, %b : tensor<8x4xi32>\n"
            "%c = stablehlo.constant dense<0> : tensor<i64>\n"     # weak lit
            "%1 = stablehlo.convert %c : tensor<1xi64>\n"          # 1-elem
            "%2 = stablehlo.iota : tensor<2x3xi64>\n")             # real leak
    assert dtype_histogram(text) == {"i32": 1, "i64": 3}
    assert wide_buffer_histogram(text) == {"i64": 1}


# ---------------------------------------------------------------------------
# runtime guards
# ---------------------------------------------------------------------------

def test_no_recompiles_passes_warm_and_catches_cold():
    f = jax.jit(lambda x: x * 2 + 1)
    x = jnp.arange(8, dtype=jnp.int32)
    f(x)  # warm
    with guards.no_recompiles():
        f(x)  # cache hit: fine
    g = jax.jit(lambda x: x * 3 - 1)
    with pytest.raises(guards.GuardViolation, match="compiled 1"):
        with guards.no_recompiles():
            g(x)  # cold compile inside the region


def test_no_recompiles_allowance_and_recorder():
    h = jax.jit(lambda x: x - 7)
    x = jnp.arange(4, dtype=jnp.int32)
    with guards.no_recompiles(allow=1) as rec:
        h(x)
    assert len(rec.compiled) == 1


def test_no_transfers_catches_planted_item():
    x = jnp.arange(8, dtype=jnp.int32)
    with pytest.raises(guards.GuardViolation, match="item"):
        with guards.no_transfers():
            x[0].item()  # the planted host sync
    assert x[0].item() == 0  # instrumentation fully restored


def test_no_transfers_catches_np_asarray_and_allows_device_math():
    x = jnp.arange(8, dtype=jnp.int32)
    with guards.no_transfers():
        y = (x * 2).sum()  # pure device work: fine
    with pytest.raises(guards.GuardViolation):
        with guards.no_transfers():
            np.asarray(x)
    np.testing.assert_array_equal(np.asarray(x), np.arange(8))


def test_no_transfers_donated_buffer_is_not_a_false_positive():
    """Reading a DONATED (deleted) array cannot transfer — the guard must
    step aside and let jax raise its informative use-after-donate error
    instead of a phantom host-sync verdict (PR 8 follow-on)."""
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    a = jnp.arange(8, dtype=jnp.int32)
    b = f(a)
    assert a.is_deleted()
    with guards.no_transfers():
        with pytest.raises(RuntimeError, match="deleted"):
            np.asarray(a)
        with pytest.raises(RuntimeError, match="deleted"):
            a.__array__()
        # live arrays keep being guarded in the same region
        with pytest.raises(guards.GuardViolation, match="asarray"):
            np.asarray(b)
    np.testing.assert_array_equal(np.asarray(b), np.arange(1, 9))


def test_no_transfers_allows_donating_fleet_step_reuse():
    """The original false positive: re-invoking a donating jitted step on
    fresh operands while an old reference floats around must pass clean."""
    f = jax.jit(lambda x: x * 2, donate_argnums=(0,))
    x = jnp.arange(4, dtype=jnp.int32)
    f(x)  # warm + donate
    with guards.no_transfers():
        y = jnp.arange(4, dtype=jnp.int32)
        for _ in range(3):
            y = f(y)  # steady-state donated reuse: no guard trip
    assert int(np.asarray(y)[1]) == 8


def test_guard_fixtures_are_exposed(no_recompiles, no_transfers):
    assert no_recompiles is guards.no_recompiles
    assert no_transfers is guards.no_transfers


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_json_report(tmp_path):
    from repro.analysis.__main__ import main

    clean = tmp_path / "src/repro/core/ok.py"
    clean.parent.mkdir(parents=True)
    clean.write_text("import jax.numpy as jnp\n"
                     "def f(x):\n"
                     "    return jnp.sum(x, dtype=jnp.int32)\n")
    out = tmp_path / "report.json"
    assert main([str(tmp_path), "--json", str(out)]) == 0
    import json
    report = json.loads(out.read_text())
    assert report["ok"] and report["lint"]["unwaived"] == 0

    dirty = tmp_path / "src/repro/core/bad.py"
    dirty.write_text("import jax.numpy as jnp\n"
                     "def f(x):\n"
                     "    return jnp.sum(x)\n")
    assert main([str(tmp_path)]) == 1


def test_cli_list_rules(capsys):
    from repro.analysis.__main__ import main

    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in RULES:
        assert code in out
