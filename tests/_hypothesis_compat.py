"""Shared hypothesis fallback: property tests skip (not error) when the
package is absent.  Test modules do ``from _hypothesis_compat import given,
settings, st`` (the tests/ dir is on sys.path via pytest's rootdir insertion).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
except ImportError:
    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategiesStub:
        """Any strategy name resolves to a no-op: the @given stub replaces
        the test body with a skip, so strategy values are never consumed."""
        def __getattr__(self, _name):
            return lambda *_args, **_kwargs: None

    st = _StrategiesStub()
