"""Data pipeline tests: stateless resume, host sharding, prefetch."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data import lm as lmdata
from repro.data.pipeline import Prefetcher, host_slice

jax.config.update("jax_platform_name", "cpu")


def test_batch_for_step_deterministic():
    cfg = get_config("qwen3-0.6b").reduced()
    shape = lmdata.ShapeSpec("t", 32, 4, "train")
    b1 = lmdata.batch_for_step(cfg, shape, 7)
    b2 = lmdata.batch_for_step(cfg, shape, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = lmdata.batch_for_step(cfg, shape, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_host_slice():
    batch = {"tokens": jnp.arange(32).reshape(8, 4)}
    s0 = host_slice(batch, process_index=0, process_count=2)
    s1 = host_slice(batch, process_index=1, process_count=2)
    assert s0["tokens"].shape == (4, 4)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s0["tokens"]), np.asarray(s1["tokens"])]),
        np.asarray(batch["tokens"]))


def test_prefetcher_order_and_completeness():
    cfg = get_config("qwen3-0.6b").reduced()
    shape = lmdata.ShapeSpec("t", 16, 2, "train")
    pf = Prefetcher(lambda s: lmdata.batch_for_step(cfg, shape, s), 3, 8, depth=2)
    steps = [s for s, _ in pf]
    assert steps == [3, 4, 5, 6, 7]


def test_input_specs_no_allocation_for_decode():
    """decode input specs must be ShapeDtypeStructs (a command-r 32k cache
    would be ~0.5 TB if materialized)."""
    cfg = get_config("command-r-35b")
    specs = lmdata.input_specs(cfg, lmdata.SHAPES["decode_32k"])
    leaves = jax.tree.leaves(specs["caches"])
    assert all(isinstance(leaf, jax.ShapeDtypeStruct) for leaf in leaves)
    total = sum(np.prod(leaf.shape) * leaf.dtype.itemsize for leaf in leaves)
    assert total > 1e11   # the abstract cache really is ~0.5 TB


def test_input_specs_all_cells_cheap():
    """Building input specs for every (arch x shape) must be allocation-free
    and fast (the dry-run sweeps all of them)."""
    from repro.configs.registry import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in lmdata.SHAPES.values():
            specs = lmdata.input_specs(cfg, shape)
            assert "tokens" in specs
