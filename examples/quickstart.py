"""Quickstart: one-shot iEEG seizure detection with sparse HDC.

Trains class hypervectors on one seizure of a synthetic patient and detects
the remaining seizures — the paper's core pipeline end to end (CompIM
position-domain datapath, spatial OR bundling, calibrated temporal thinning).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier, hdtrain, hv, metrics
from repro.data import ieeg


def main():
    cfg = classifier.HDCConfig()          # paper config: D=1024, 8 segments,
    print(f"config: D={cfg.dim}, {cfg.segments} segments, "
          f"{cfg.channels} channels, window={cfg.window}")

    params = classifier.init_params(jax.random.PRNGKey(42), cfg)
    patient = ieeg.make_patient(11, n_seizures=4)

    # --- one-shot training on seizure 1 -----------------------------------
    rec = patient.records[0]
    codes = jnp.asarray(rec.codes[None])
    labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
    cfg = classifier.with_density_target(params, codes, cfg, target=0.25)
    print(f"calibrated temporal threshold: {cfg.temporal_threshold} "
          f"(target max density 25%)")
    class_hvs = hdtrain.train_one_shot(params, codes, labels, cfg)
    print("class HV densities:", np.asarray(hv.density(class_hvs, cfg.dim)))

    # --- detect the held-out seizures --------------------------------------
    results = []
    for i, rec2 in enumerate(patient.records[1:], start=2):
        _, preds = classifier.infer(params, class_hvs,
                                    jnp.asarray(rec2.codes[None]), cfg)
        r = metrics.detection_metrics(np.asarray(preds[0]),
                                      ieeg.onset_frame(rec2, cfg.window))
        results.append(r)
        print(f"seizure {i}: detected={r.detected} "
              f"delay={r.delay_seconds:.1f}s false_alarm={r.false_alarm}")
    agg = metrics.aggregate(results)
    print(f"\naccuracy={agg['detection_accuracy']:.2f} "
          f"mean_delay={agg['mean_delay_s']:.1f}s "
          f"false_alarm_rate={agg['false_alarm_rate']:.2f}")


if __name__ == "__main__":
    main()
