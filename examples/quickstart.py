"""Quickstart: one-shot iEEG seizure detection with sparse HDC.

Trains class hypervectors on one seizure of a synthetic patient and detects
the remaining seizures — the paper's core pipeline end to end (CompIM
position-domain datapath, spatial OR bundling, calibrated temporal thinning),
through the unified `HDCPipeline` surface.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hv, metrics
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg


def main():
    cfg = HDCConfig()                     # paper config: D=1024, 8 segments,
    print(f"config: D={cfg.dim}, {cfg.segments} segments, "
          f"{cfg.channels} channels, window={cfg.window}, "
          f"variant={cfg.variant}, backend={cfg.backend}")

    pipe = HDCPipeline.init(jax.random.PRNGKey(42), cfg)
    patient = ieeg.make_patient(11, n_seizures=4)

    # --- one-shot training on seizure 1 -----------------------------------
    rec = patient.records[0]
    codes = jnp.asarray(rec.codes[None])
    labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
    pipe = pipe.calibrate_density(codes, target=0.25)
    print(f"calibrated temporal threshold: {pipe.cfg.temporal_threshold} "
          f"(target max density 25%)")
    pipe = pipe.train_one_shot(codes, labels)
    print("class HV densities:", np.asarray(hv.density(pipe.class_hvs, cfg.dim)))

    # --- detect the held-out seizures --------------------------------------
    results = []
    for i, rec2 in enumerate(patient.records[1:], start=2):
        _, preds = pipe.infer(jnp.asarray(rec2.codes[None]))
        r = metrics.detection_metrics(np.asarray(preds[0]),
                                      ieeg.onset_frame(rec2, cfg.window))
        results.append(r)
        print(f"seizure {i}: detected={r.detected} "
              f"delay={r.delay_seconds:.1f}s false_alarm={r.false_alarm}")
    agg = metrics.aggregate(results)
    print(f"\naccuracy={agg['detection_accuracy']:.2f} "
          f"mean_delay={agg['mean_delay_s']:.1f}s "
          f"false_alarm_rate={agg['false_alarm_rate']:.2f}")


if __name__ == "__main__":
    main()
