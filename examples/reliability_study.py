"""Reliability study: how bit errors degrade seizure detection, and what
word-level ECC on the associative memory buys back.

Three short experiments on synthetic patients, all through the reliability
subsystem's fleet-scale sweep (one StreamingFleet per design point, BER
walked via the traced operand — no recompiles along a curve):

  1. degradation curves — detection accuracy / delay / frame corruption vs
     BER for the paper-optimized design, all memory classes faulted;
  2. ECC tradeoff — AM-only faults under none / parity / SECDED, with the
     decode energy priced through the 16nm hwmodel gate constants;
  3. stuck-at vs transient — the same BER hurts differently when the
     faulty cells persist instead of resampling every read.

    PYTHONPATH=src python examples/reliability_study.py

REPRO_EXAMPLES_TINY=1 (CI smoke) shrinks the sweep grid so the study
finishes in seconds; the printed numbers are then smoke-test output, not
study results.
"""

import os

from repro.core.classifier import HDCConfig
from repro.reliability import ecc, sweep

TINY = os.environ.get("REPRO_EXAMPLES_TINY", "") == "1"

CFG = HDCConfig(dim=256, segments=8, window=64 if TINY else 128)
REC = (dict(pre_s=6.0, ictal_s=8.0, post_s=3.0) if TINY
       else dict(pre_s=12.0, ictal_s=16.0, post_s=6.0))
BERS = (0.0, 1e-2) if TINY else (0.0, 1e-3, 3e-3, 1e-2, 3e-2)
N_PATIENTS = 1 if TINY else 3
N_TEST = 1 if TINY else 2


def _curve(points, keys):
    for p in points:
        cells = " ".join(f"{k}={p[k]:.3f}" if isinstance(p[k], float)
                         else f"{k}={p[k]}" for k in keys)
        print(f"  ber={p['ber']:<7g} {cells}")


def main():
    print("== 1. degradation curves (sparse_opt, all targets faulted) ==")
    pts = sweep.run_sweep(
        variants=("sparse_opt",), densities=(0.25,), bers=BERS,
        schemes=("none",), base_cfg=CFG, n_patients=N_PATIENTS, n_test=N_TEST,
        record_kw=REC, seed=0)
    assert all(p["zero_ber_bitexact"] for p in pts if p["ber"] == 0.0)
    print("  (BER=0 verified bit-exact against the fault-free fleet)")
    _curve(pts, ("detection_accuracy", "mean_delay_s", "false_alarm_rate",
                 "frame_disagreement"))

    print("\n== 2. ECC tradeoff (AM-only faults, none/parity/secded) ==")
    for scheme in ecc.SCHEMES:
        pts = sweep.run_sweep(
            variants=("sparse_opt",), densities=(0.25,), bers=BERS[:4],
            schemes=(scheme,), targets=("am",), base_cfg=CFG,
            n_patients=N_PATIENTS, n_test=N_TEST, record_kw=REC, seed=1)
        nj = ecc.read_energy_nj(scheme, CFG.n_classes, CFG.words)
        ovh = ecc.read_overhead(scheme, CFG.n_classes, CFG.words)
        print(f" {scheme}: decode {nj * 1e3:.3f} pJ/AM-read "
              f"(+{ovh:.0%} of the raw similarity read)")
        _curve(pts, ("detection_accuracy", "frame_disagreement",
                     "ecc_corrected", "ecc_uncorrectable"))

    print("\n== 3. stuck-at vs transient (raw AM, ber=1e-2) ==")
    for mode in ("transient", "stuck"):
        pts = sweep.run_sweep(
            variants=("sparse_opt",), densities=(0.25,), bers=(1e-2,),
            schemes=("none",), targets=("am",), mode=mode, base_cfg=CFG,
            n_patients=N_PATIENTS, n_test=N_TEST, record_kw=REC, seed=2)
        p = pts[0]
        print(f"  {mode:<9s} acc={p['detection_accuracy']:.2f} "
              f"delay_s={p['mean_delay_s']:.2f} "
              f"disagree={p['frame_disagreement']:.3f}")

    print("\nFleet-scale sweeps over the full variant grid: "
          "PYTHONPATH=src python -m benchmarks.run reliability")


if __name__ == "__main__":
    main()
