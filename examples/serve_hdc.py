"""Serving example: batched multi-patient seizure-detection service.

Simulates a fleet of implant streams hitting one accelerator: requests are
(patient_id, 0.5 s of 64-channel iEEG); the service runs LBP -> sparse-HDC
encode (fused Pallas kernel) -> AM search and returns per-frame decisions.
Demonstrates request batching, per-patient class HVs, and the kernel path.

    PYTHONPATH=src python examples/serve_hdc.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier, hdtrain, metrics
from repro.data import ieeg
from repro.kernels.hdc_am.ops import am_search
from repro.kernels.hdc_encoder.ops import encode_frames_fused
from repro.kernels.lbp.ops import lbp_codes

N_PATIENTS = 3
BATCH = 6          # concurrent streams per service call


def main():
    cfg = classifier.HDCConfig()
    params = classifier.init_params(jax.random.PRNGKey(42), cfg)

    # --- provision per-patient class HVs (one-shot, offline) ---------------
    patients = [ieeg.make_patient(pid, n_seizures=2) for pid in range(1, N_PATIENTS + 1)]
    class_bank = []
    cfgs = []
    for pat in patients:
        rec = pat.records[0]
        codes = jnp.asarray(rec.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
        pcfg = classifier.with_density_target(params, codes, cfg, 0.25)
        class_bank.append(hdtrain.train_one_shot(params, codes, labels, pcfg))
        cfgs.append(pcfg)
    print(f"provisioned {N_PATIENTS} patients (one-shot class HVs)")

    # --- serve a batch of requests -----------------------------------------
    # each request: raw 0.5 s window (256 samples + LBP halo) x 64 channels
    reqs, req_pids = [], []
    for i in range(BATCH):
        pid = i % N_PATIENTS
        rec = patients[pid].records[1]
        t0 = (1000 + 300 * i)
        # raw-like signal reconstructed from codes is not available; use the
        # precomputed codes window directly (LBP kernel demo below uses raw)
        reqs.append(rec.codes[t0:t0 + cfg.window])
        req_pids.append(pid)
    codes_batch = jnp.asarray(np.stack(reqs))            # (B, 256, 64)

    t0 = time.perf_counter()
    pcfg = cfgs[0]
    frames = encode_frames_fused(params, codes_batch, pcfg)   # (B, 1, W)
    all_scores = []
    for i, pid in enumerate(req_pids):
        scores = am_search(frames[i], class_bank[pid], mode="overlap",
                           dim=cfg.dim)
        all_scores.append(np.asarray(scores))
    dt = (time.perf_counter() - t0) * 1e3
    for i, (pid, s) in enumerate(zip(req_pids, all_scores)):
        pred = int(np.argmax(s[0]))
        print(f"request {i}: patient {pid + 1} scores={s[0].tolist()} "
              f"-> {'ICTAL' if pred == 1 else 'interictal'}")
    print(f"\nbatch of {BATCH} served in {dt:.1f} ms "
          "(interpret-mode kernel on CPU; TPU runs the Mosaic kernel)")

    # --- LBP kernel demo on raw signal --------------------------------------
    raw = jax.random.normal(jax.random.PRNGKey(1), (2, 262, 64))
    codes = lbp_codes(raw)
    print(f"lbp kernel: raw {raw.shape} -> codes {codes.shape} "
          f"(range 0..{int(codes.max())})")


if __name__ == "__main__":
    main()
