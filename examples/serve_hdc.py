"""Serving example: batched multi-patient seizure-detection service.

Simulates a fleet of implant streams hitting one accelerator through
`repro.serve.engine`: requests are (patient_id, 0.5 s of 64-channel LBP
codes); the engine gathers them by patient, encodes each patient datapath
once (each patient carries its OWN calibrated temporal threshold — the old
per-request loop silently encoded everyone with patient 0's config), and
scores all frames with ONE batched AM search against the stacked per-patient
class-HV bank.  Also demonstrates the streaming `SeizureSession` API, which
carries the temporal accumulator across sub-window chunks.

    PYTHONPATH=src python examples/serve_hdc.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg
from repro.serve.engine import SeizureSession, ServingEngine

N_PATIENTS = 3
BATCH = 6          # concurrent streams per service call


def main():
    cfg = HDCConfig(backend="pallas")       # fused kernels (interpret on CPU)
    base = HDCPipeline.init(jax.random.PRNGKey(42), cfg)

    # --- provision per-patient pipelines (one-shot, offline) ---------------
    patients = [ieeg.make_patient(pid, n_seizures=2) for pid in range(1, N_PATIENTS + 1)]
    # distinct per-patient density targets -> distinct calibrated thresholds,
    # so the output visibly exercises the per-patient-config path
    targets = (0.10, 0.25, 0.50)
    pipelines = {}
    for pid, pat in enumerate(patients):
        rec = pat.records[0]
        codes = jnp.asarray(rec.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
        pipe = base.calibrate_density(codes, target=targets[pid % len(targets)])
        pipelines[pid] = pipe.train_one_shot(codes, labels)
    engine = ServingEngine(pipelines)
    thresholds = [pipelines[p].cfg.temporal_threshold for p in range(N_PATIENTS)]
    print(f"provisioned {N_PATIENTS} patients (one-shot class HVs, "
          f"temporal thresholds {thresholds})")

    # --- serve a batch of requests -----------------------------------------
    # each request: one 0.5 s window of LBP codes x 64 channels
    requests = []
    for i in range(BATCH):
        pid = i % N_PATIENTS
        rec = patients[pid].records[1]
        t0 = 1000 + 300 * i
        requests.append((pid, rec.codes[t0:t0 + cfg.window]))

    t0 = time.perf_counter()
    decisions = engine.serve(requests)
    dt = (time.perf_counter() - t0) * 1e3
    for d in decisions:
        print(f"request {d.request_id}: patient {d.patient_id + 1} "
              f"scores={d.scores[0].tolist()} "
              f"-> {'ICTAL' if d.predictions[0] == 1 else 'interictal'}")
    print(f"\nbatch of {BATCH} served in {dt:.1f} ms "
          "(interpret-mode kernels on CPU; TPU runs the Mosaic kernels)")

    # --- streaming session: sub-window chunks ------------------------------
    sess = SeizureSession(pipelines[0])
    stream = patients[0].records[1].codes[:2 * cfg.window]
    decs = []
    for chunk_start in range(0, stream.shape[0], 100):   # 100-cycle chunks
        decs += sess.push(stream[chunk_start:chunk_start + 100])
    print(f"streamed {stream.shape[0]} cycles in 100-cycle chunks -> "
          f"{len(decs)} frame decisions "
          f"({sess.cycles_buffered} cycles buffered toward the next frame)")


if __name__ == "__main__":
    main()
