"""Hardware design-space study: reproduce the paper's optimization story.

Walks the four design points (dense -> sparse-naive -> +CompIM ->
+no-thinning) through the switching-activity cost model and prints the
paper-style breakdowns and ratios, plus the density-hyperparameter trade-off
on one patient.  Functional datapaths come from the unified `HDCPipeline`.

    PYTHONPATH=src python examples/hw_study.py

REPRO_EXAMPLES_TINY=1 (CI smoke) shortens the calibration traces and the
density sweep so the study finishes in seconds; the printed ratios are then
smoke-test output, not study results.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwmodel, metrics
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg

TINY = os.environ.get("REPRO_EXAMPLES_TINY", "") == "1"


def main():
    # variant="sparse_naive" precomputes the packed IM tables, which the
    # eager hwmodel sweep reads repeatedly (params are key-deterministic
    # and identical across sparse variants)
    cfg = HDCConfig(variant="sparse_naive", spatial_threshold=1)
    pipe = HDCPipeline.init(jax.random.PRNGKey(42), cfg)
    dense_pipe = HDCPipeline.init(jax.random.PRNGKey(7), HDCConfig(variant="dense"))
    n_codes = 512 if TINY else 2048
    codes = jnp.asarray(ieeg.make_patient(11, n_seizures=1).records[0].codes[:n_codes])

    es, asc = hwmodel.calibration_factors(pipe.params, codes, cfg)
    print("== energy/area across design points (16nm model, calibrated to "
          "the paper's optimized design) ==")
    reports = {}
    for v in hwmodel.VARIANTS:
        p = dense_pipe.params if v == "dense" else pipe.params
        r = hwmodel.report(v, p, codes, cfg, e_scale=es, a_scale=asc)
        reports[v] = r
        print(f"\n{v}: E={r['energy_total_nj']:.2f} nJ/pred, "
              f"A={r['area_total_mm2']:.4f} mm2, "
              f"latency={r['latency_us_at_10mhz']:.1f} us")
        for mod in r["energy_nj"]:
            print(f"   {mod:18s} E {100 * r['energy_breakdown'][mod]:5.1f}%  "
                  f"A {100 * r['area_breakdown'].get(mod, 0):5.1f}%")

    sn, so, dn = (reports[k] for k in ("sparse_naive", "sparse_opt", "dense"))
    print("\n== headline ratios ==")
    print(f"opt vs naive : E {sn['energy_total_nj'] / so['energy_total_nj']:.2f}x "
          f"A {sn['area_total_mm2'] / so['area_total_mm2']:.2f}x  (paper 1.72x/2.20x)")
    print(f"dense vs opt : E {dn['energy_total_nj'] / so['energy_total_nj']:.2f}x "
          f"A {dn['area_total_mm2'] / so['area_total_mm2']:.2f}x  (paper 7.50x/3.24x)")

    print("\n== max-density hyperparameter (patient 11) ==")
    pat = ieeg.make_patient(11, n_seizures=2 if TINY else 3)
    rec = pat.records[0]
    c = jnp.asarray(rec.codes[None])
    labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
    # the detection sweep runs the (fast) CompIM datapath — same params
    sweep_pipe = pipe.with_cfg(variant="sparse_compim")
    for target in ((0.2,) if TINY else (0.1, 0.2, 0.3, 0.5)):
        ppipe = sweep_pipe.calibrate_density(c, target).train_one_shot(c, labels)
        rs = []
        for rec2 in pat.records[1:]:
            _, preds = ppipe.infer(jnp.asarray(rec2.codes[None]))
            rs.append(metrics.detection_metrics(
                np.asarray(preds[0]), ieeg.onset_frame(rec2, ppipe.cfg.window)))
        agg = metrics.aggregate(rs)
        print(f"  max density {target:.2f} (thr={ppipe.cfg.temporal_threshold:3d}): "
              f"acc={agg['detection_accuracy']:.2f} "
              f"delay={agg['mean_delay_s']:.1f}s")


if __name__ == "__main__":
    main()
