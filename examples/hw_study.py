"""Hardware design-space study: reproduce the paper's optimization story.

Walks the four design points (dense -> sparse-naive -> +CompIM ->
+no-thinning) through the switching-activity cost model and prints the
paper-style breakdowns and ratios, plus the density-hyperparameter trade-off
on one patient.

    PYTHONPATH=src python examples/hw_study.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier, dense, hdtrain, hwmodel, metrics
from repro.data import ieeg


def main():
    cfg = classifier.HDCConfig(spatial_threshold=1)
    params = classifier.init_params(jax.random.PRNGKey(42), cfg)
    dparams = dense.init_params(jax.random.PRNGKey(7), dense.DenseHDCConfig())
    codes = jnp.asarray(ieeg.make_patient(11, n_seizures=1).records[0].codes[:2048])

    es, asc = hwmodel.calibration_factors(params, codes, cfg)
    print("== energy/area across design points (16nm model, calibrated to "
          "the paper's optimized design) ==")
    reports = {}
    for v in hwmodel.VARIANTS:
        p = dparams if v == "dense" else params
        r = hwmodel.report(v, p, codes, cfg, e_scale=es, a_scale=asc)
        reports[v] = r
        print(f"\n{v}: E={r['energy_total_nj']:.2f} nJ/pred, "
              f"A={r['area_total_mm2']:.4f} mm2, "
              f"latency={r['latency_us_at_10mhz']:.1f} us")
        for mod in r["energy_nj"]:
            print(f"   {mod:18s} E {100 * r['energy_breakdown'][mod]:5.1f}%  "
                  f"A {100 * r['area_breakdown'].get(mod, 0):5.1f}%")

    sn, so, dn = (reports[k] for k in ("sparse_naive", "sparse_opt", "dense"))
    print("\n== headline ratios ==")
    print(f"opt vs naive : E {sn['energy_total_nj'] / so['energy_total_nj']:.2f}x "
          f"A {sn['area_total_mm2'] / so['area_total_mm2']:.2f}x  (paper 1.72x/2.20x)")
    print(f"dense vs opt : E {dn['energy_total_nj'] / so['energy_total_nj']:.2f}x "
          f"A {dn['area_total_mm2'] / so['area_total_mm2']:.2f}x  (paper 7.50x/3.24x)")

    print("\n== max-density hyperparameter (patient 11) ==")
    pat = ieeg.make_patient(11, n_seizures=3)
    rec = pat.records[0]
    c = jnp.asarray(rec.codes[None])
    labels = jnp.asarray(ieeg.frame_labels(rec, cfg.window)[None])
    for target in (0.1, 0.2, 0.3, 0.5):
        pcfg = classifier.with_density_target(params, c, cfg, target)
        chvs = hdtrain.train_one_shot(params, c, labels, pcfg)
        rs = []
        for rec2 in pat.records[1:]:
            _, preds = classifier.infer(params, chvs,
                                        jnp.asarray(rec2.codes[None]), pcfg)
            rs.append(metrics.detection_metrics(
                np.asarray(preds[0]), ieeg.onset_frame(rec2, pcfg.window)))
        agg = metrics.aggregate(rs)
        print(f"  max density {target:.2f} (thr={pcfg.temporal_threshold:3d}): "
              f"acc={agg['detection_accuracy']:.2f} "
              f"delay={agg['mean_delay_s']:.1f}s")


if __name__ == "__main__":
    main()
