"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on synthetic data, with checkpointing and resume.

This exercises the full framework stack (model zoo, optimizer, data pipeline,
checkpointing) at CPU-runnable scale.  On a real fleet the same launcher runs
the full configs on the production mesh (launch/train.py --mesh 16x16).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import params as P
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.runtime.sharding import make_ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-parameter qwen3-family config (CPU-trainable)
    cfg = dataclasses.replace(
        get_config("qwen3-0.6b"),
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab=8192, dtype="float32", remat=False)
    spec = M.model_spec(cfg)
    print(f"model: {P.count_params(spec)/1e6:.1f}M params "
          f"({cfg.n_layers}L d{cfg.d_model})")

    params = P.initialize(jax.random.PRNGKey(0), spec, jnp.float32)
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt_state = adamw.init_state(params, opt)
    ctx = make_ctx(None)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt, ctx))

    def make_batch(step):
        """Learnable synthetic stream: noisy affine bigram process —
        token[t+1] = 13 * token[t] + 7 (mod V) with 10% noise, so the
        model demonstrably learns (loss drops well below ln V)."""
        key = jax.random.PRNGKey(step)
        k0, k1, k2 = jax.random.split(key, 3)
        first = jax.random.randint(k0, (args.batch, 1), 0, cfg.vocab)
        toks = [first]
        for _ in range(args.seq):
            toks.append((13 * toks[-1] + 7) % cfg.vocab)
        seq = jnp.concatenate(toks, axis=1)
        noise_pos = jax.random.bernoulli(k1, 0.1, seq.shape)
        noise_tok = jax.random.randint(k2, seq.shape, 0, cfg.vocab)
        seq = jnp.where(noise_pos, noise_tok, seq).astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    t0, tok_per_step = time.time(), args.batch * args.seq
    for step in range(args.steps):
        batch = make_batch(step)
        params, opt_state, loss, metrics = step_fn(params, opt_state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({tok_per_step * (step + 1) / dt:.0f} tok/s)")
    final = float(loss)
    print(f"\nfinal loss {final:.4f} (init ~{jnp.log(cfg.vocab):.2f}) — "
          f"{'LEARNING' if final < 0.9 * float(jnp.log(cfg.vocab)) else 'check lr'}")


if __name__ == "__main__":
    main()
