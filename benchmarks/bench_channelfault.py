"""Electrode-fault graceful-degradation curves + quarantine parity.

The channel-fault question (``repro.reliability.channels``): how fast does
end-to-end seizure-detection quality decay as electrodes fail, and how much
does online quarantine (the per-session channel mask threaded through the
fleet's spatial encoder) buy back versus leaving the corrupted channel in
the bundle?

Grid: variant x density x fault kind x n_failed channels.  Per (variant,
density) cell one clean fleet and one ``channel_masking=True`` fleet serve
every point — masks move via ``set_channel_mask`` (a traced operand, zero
recompiles per curve).  Two correctness anchors ride along as CI-gated
status rows:

* ``channelfault.maskparity`` — the all-live masked fleet is BIT-EXACT
  (full per-frame score streams) with the unmasked fleet in every cell,
  and a masked ``dispatch.owner_spatial_codes`` spot-check equals the
  reduced-channel ORACLE (``dispatch.reduced_channel_config`` on the
  physically-shrunk channel set).
* ``channelfault.gracefuldeg`` — sparse variants degrade gracefully: the
  quarantined fleet retains at least ``CLIFF_RETENTION`` of clean accuracy
  at 1-2 failed channels (sparse bundling drops a channel's term instead
  of folding garbage into every spatial HV, so there must be no cliff).

Per-point ``channelfault.*.f<n>.speedup`` rows carry the accuracy
RETENTION ratio (quarantined / clean) in the same ``N.NNx `` format the
fleet perf gate parses, so ``check_fleet_regression.py`` holds the
degradation floor against the committed tiny reference.

BENCH_TINY=1 (CI smoke) shrinks to 2 patients / short records / a 3-point
failed-channel grid.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny
from repro.core.classifier import HDCConfig
from repro.reliability import channels as chan
from repro.reliability import sweep
from repro.serve import dispatch
from repro.serve.fleet import StreamingFleet

VARIANTS = ("dense", "sparse_naive", "sparse_compim", "sparse_opt")
SPARSE = ("sparse_naive", "sparse_compim", "sparse_opt")
CLIFF_RETENTION = 0.75  # floor on quarantined/clean accuracy at <=2 failed


def _config() -> dict:
    base = HDCConfig(dim=256, segments=8, window=128)
    if tiny():
        return dict(
            base_cfg=base, n_patients=2, n_test=1,
            record_kw=dict(pre_s=10.0, ictal_s=14.0, post_s=6.0),
            variants=("dense", "sparse_naive", "sparse_opt"),
            densities=(0.25,), kinds=("dead", "line_noise"),
            n_failed=(0, 1, 2),
        )
    return dict(
        base_cfg=base, n_patients=4, n_test=2,
        record_kw=dict(pre_s=16.0, ictal_s=20.0, post_s=8.0),
        variants=VARIANTS, densities=(0.15, 0.25, 0.35),
        kinds=chan.CODE_FAULT_TYPES,
        n_failed=(0, 1, 2, 4, 8, 16),
    )


def _oracle_parity(pipes: dict, cfg: HDCConfig, *, n_dead: int = 2,
                   seed: int = 1) -> bool:
    """Masked spatial encode == the same encode on the physically-reduced
    channel set (tables and codes sliced to the live channels, threshold
    renormalized by ``reduced_channel_config``)."""
    pipe = next(iter(pipes.values()))
    tables, _ = dispatch.stack_bound_tables([pipe])
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, cfg.codes,
                         (1, 2 * cfg.window, cfg.channels), np.uint8)
    mask = np.ones((1, cfg.channels), np.uint8)
    mask[0, rng.choice(cfg.channels, size=n_dead, replace=False)] = 0
    live_idx = np.nonzero(mask[0])[0]
    owner = jnp.zeros((1,), jnp.int32)
    got = dispatch.owner_spatial_codes(
        tables, owner, jnp.asarray(codes), cfg,
        chan_mask=jnp.asarray(mask))
    red_cfg = dispatch.reduced_channel_config(cfg, len(live_idx))
    want = dispatch.owner_spatial_codes(
        jnp.asarray(np.asarray(tables)[:, live_idx]), owner,
        jnp.asarray(codes[:, :, live_idx]), red_cfg)
    return bool((np.asarray(got) == np.asarray(want)).all())


def run() -> list[dict]:
    c = _config()
    sessions = sweep.make_sessions(
        n_patients=c["n_patients"], n_test=c["n_test"],
        channels=c["base_cfg"].channels, record_kw=c["record_kw"], seed=0)
    batch, owners = sessions["batch"], sessions["owners"]
    kinds = tuple(c["kinds"])
    rows: list[dict] = []
    parity_fail: list[str] = []
    cliff: list[str] = []
    min_retention = np.inf  # over sparse variants at n_failed <= 2

    for hw in c["variants"]:
        for density in c["densities"]:
            pipes, cfg = sweep.train_pipelines(hw, density, sessions,
                                               c["base_cfg"], seed=0)
            buckets = (cfg.window,)
            clean = StreamingFleet(pipes, owners, buckets=buckets)
            clean_preds, clean_scores = sweep.replay(clean, batch)
            clean_agg = sweep.detection_summary(clean_preds, sessions, cfg)
            masked = StreamingFleet(pipes, owners, buckets=buckets,
                                    channel_masking=True)
            m_preds, m_scores = sweep.replay(masked, batch)
            allive_ok = bool(np.array_equal(m_preds, clean_preds)
                             and np.array_equal(m_scores, clean_scores))
            oracle_ok = _oracle_parity(pipes, cfg)
            if not (allive_ok and oracle_ok):
                parity_fail.append(f"{hw}/d{density:g}"
                                   f"(allive={allive_ok},oracle={oracle_ok})")
            for ki, kind in enumerate(kinds):
                for n in c["n_failed"]:
                    faulted, mask = chan.degrade_batch(
                        batch, n, kind, seed=100 + 13 * n + ki)
                    # unmasked arm: the corrupted channel stays in the bundle
                    u_preds, _ = sweep.replay(clean, faulted)
                    u_agg = sweep.detection_summary(u_preds, sessions, cfg)
                    # quarantined arm: the monitor's oracle mask drops it
                    masked.set_channel_mask(mask)
                    q_preds, _ = sweep.replay(masked, faulted)
                    q_agg = sweep.detection_summary(q_preds, sessions, cfg)
                    retention = (q_agg["detection_accuracy"]
                                 / max(clean_agg["detection_accuracy"], 1e-9))
                    if hw in SPARSE and 1 <= n <= 2:
                        min_retention = min(min_retention, retention)
                        if retention < CLIFF_RETENTION:
                            cliff.append(f"{hw}/d{density:g}/{kind}/f{n}"
                                         f"={retention:.2f}")
                    point = {
                        "variant": hw, "density": float(density),
                        "kind": kind, "n_failed": int(n),
                        "sessions": len(owners),
                        "frames": int(clean_preds.size),
                        "clean_accuracy": clean_agg["detection_accuracy"],
                        "unmasked_accuracy": u_agg["detection_accuracy"],
                        "masked_accuracy": q_agg["detection_accuracy"],
                        "retention": float(retention),
                        "unmasked_delay_s": u_agg["mean_delay_s"],
                        "masked_delay_s": q_agg["mean_delay_s"],
                        "unmasked_false_alarm_rate":
                            u_agg["false_alarm_rate"],
                        "masked_false_alarm_rate": q_agg["false_alarm_rate"],
                        "masked_vs_unmasked_disagreement":
                            float(np.mean(q_preds != u_preds)),
                    }
                    rows.append({
                        "name": (f"channelfault.{hw}.d{density:g}.{kind}"
                                 f".f{n}.speedup"),
                        "us_per_call": "",
                        "derived": (
                            f"{retention:.2f}x retention"
                            f";acc={q_agg['detection_accuracy']:.2f}"
                            f";unmasked_acc="
                            f"{u_agg['detection_accuracy']:.2f}"
                            f";clean_acc="
                            f"{clean_agg['detection_accuracy']:.2f}"
                            f";delay_s={q_agg['mean_delay_s']:.2f}"
                            f";fa={q_agg['false_alarm_rate']:.2f}"),
                        "point": point,
                    })

    cells = len(c["variants"]) * len(c["densities"])
    rows.append({
        "name": "channelfault.maskparity", "us_per_call": "",
        "derived": (f"ok all-live bit-exact + reduced-channel oracle parity "
                    f"({cells} cells)" if not parity_fail
                    else "FAIL " + ",".join(parity_fail)),
        "point": {"cells": cells, "failed": parity_fail},
    })
    rows.append({
        "name": "channelfault.gracefuldeg", "us_per_call": "",
        "derived": (f"ok min_retention@f<=2={min_retention:.2f} "
                    f"(floor {CLIFF_RETENTION})" if not cliff
                    else "CLIFF " + ",".join(cliff)),
        "point": {"min_retention": float(min_retention),
                  "floor": CLIFF_RETENTION, "cliffs": cliff},
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
