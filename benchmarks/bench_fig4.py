"""Paper Fig. 4: detection delay & accuracy vs maximum HV density after
bundling, sparse (with our optimizations) vs the dense HDC baseline.

Synthetic one-shot protocol: train class HVs on seizure 1 of each patient,
test on the remaining seizures; sweep the temporal-thinning target density.
Derived values = (accuracy, mean delay seconds) per operating point."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier, dense, hdtrain, metrics
from repro.data import ieeg

PATIENTS = (1, 2, 3, 11)
N_SEIZURES = 3
DENSITIES = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5)


def _eval_sparse(params, patients, cfg0, target) -> dict:
    results = []
    for pat in patients:
        rec = pat.records[0]
        codes = jnp.asarray(rec.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec, cfg0.window)[None])
        cfg = classifier.with_density_target(params, codes, cfg0, target)
        chvs = hdtrain.train_one_shot(params, codes, labels, cfg)
        for rec2 in pat.records[1:]:
            _, preds = classifier.infer(params, chvs,
                                        jnp.asarray(rec2.codes[None]), cfg)
            results.append(metrics.detection_metrics(
                np.asarray(preds[0]), ieeg.onset_frame(rec2, cfg.window)))
    return metrics.aggregate(results)


def _eval_sparse_per_patient_best(params, patients, cfg0) -> dict:
    """The paper's 'stars': tune max density per patient (best delay among
    operating points with full detection, else best accuracy)."""
    per_patient = []
    for pat in patients:
        best = None
        for target in DENSITIES:
            agg = _eval_sparse(params, [pat], cfg0, target)
            key = (agg["detection_accuracy"], -agg["mean_delay_s"]
                   if np.isfinite(agg["mean_delay_s"]) else -1e9)
            if best is None or key > best[0]:
                best = (key, agg)
        per_patient.append(best[1])
    return {
        "detection_accuracy": float(np.mean([a["detection_accuracy"]
                                             for a in per_patient])),
        "mean_delay_s": float(np.nanmean([a["mean_delay_s"]
                                          for a in per_patient])),
    }


def run() -> list[dict]:
    cfg0 = classifier.HDCConfig()
    params = classifier.init_params(jax.random.PRNGKey(42), cfg0)
    patients = [ieeg.make_patient(p, n_seizures=N_SEIZURES) for p in PATIENTS]
    rows = []
    for target in DENSITIES:
        agg = _eval_sparse(params, patients, cfg0, target)
        rows.append({"name": f"fig4.sparse_opt.density_{target}",
                     "us_per_call": "",
                     "derived": (f"acc={agg['detection_accuracy']:.2f}"
                                 f";delay_s={agg['mean_delay_s']:.2f}"
                                 f";fa={agg['false_alarm_rate']:.2f}")})
    best = _eval_sparse_per_patient_best(params, patients, cfg0)
    rows.append({"name": "fig4.sparse_opt.per_patient_tuned",
                 "us_per_call": "",
                 "derived": (f"acc={best['detection_accuracy']:.2f}"
                             f";delay_s={best['mean_delay_s']:.2f}"
                             " (paper: tuned sparse beats dense delay)")})

    dcfg = dense.DenseHDCConfig()
    dparams = dense.init_params(jax.random.PRNGKey(7), dcfg)
    results = []
    for pat in patients:
        rec = pat.records[0]
        codes = jnp.asarray(rec.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec, dcfg.window)[None])
        chvs = dense.train_one_shot(dparams, codes, labels, dcfg)
        for rec2 in pat.records[1:]:
            _, preds = dense.infer(dparams, chvs, jnp.asarray(rec2.codes[None]), dcfg)
            results.append(metrics.detection_metrics(
                np.asarray(preds[0]), ieeg.onset_frame(rec2, dcfg.window)))
    agg = metrics.aggregate(results)
    rows.append({"name": "fig4.dense_baseline",
                 "us_per_call": "",
                 "derived": (f"acc={agg['detection_accuracy']:.2f}"
                             f";delay_s={agg['mean_delay_s']:.2f}"
                             f";fa={agg['false_alarm_rate']:.2f}")})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
