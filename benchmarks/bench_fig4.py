"""Paper Fig. 4: detection delay & accuracy vs maximum HV density after
bundling, sparse (with our optimizations) vs the dense HDC baseline.

Synthetic one-shot protocol: train class HVs on seizure 1 of each patient,
test on the remaining seizures; sweep the temporal-thinning target density.
All datapaths run through the unified `HDCPipeline` (variant-dispatched).
Derived values = (accuracy, mean delay seconds) per operating point."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg

PATIENTS = (1, 2, 3, 11)
N_SEIZURES = 3
DENSITIES = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5)


def _eval_one_shot(base: HDCPipeline, patients, target: float | None) -> dict:
    """One-shot train on seizure 1, test on the rest; `target` calibrates
    the sparse temporal threshold (None for the dense variant)."""
    results = []
    for pat in patients:
        rec = pat.records[0]
        codes = jnp.asarray(rec.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec, base.cfg.window)[None])
        pipe = base if target is None else base.calibrate_density(codes, target)
        pipe = pipe.train_one_shot(codes, labels)
        for rec2 in pat.records[1:]:
            _, preds = pipe.infer(jnp.asarray(rec2.codes[None]))
            results.append(metrics.detection_metrics(
                np.asarray(preds[0]), ieeg.onset_frame(rec2, pipe.cfg.window)))
    return metrics.aggregate(results)


def _eval_sparse_per_patient_best(base: HDCPipeline, patients) -> dict:
    """The paper's 'stars': tune max density per patient (best delay among
    operating points with full detection, else best accuracy)."""
    per_patient = []
    for pat in patients:
        best = None
        for target in DENSITIES:
            agg = _eval_one_shot(base, [pat], target)
            key = (agg["detection_accuracy"], -agg["mean_delay_s"]
                   if np.isfinite(agg["mean_delay_s"]) else -1e9)
            if best is None or key > best[0]:
                best = (key, agg)
        per_patient.append(best[1])
    return {
        "detection_accuracy": float(np.mean([a["detection_accuracy"]
                                             for a in per_patient])),
        "mean_delay_s": float(np.nanmean([a["mean_delay_s"]
                                          for a in per_patient])),
    }


def run() -> list[dict]:
    sparse = HDCPipeline.init(jax.random.PRNGKey(42), HDCConfig())
    patients = [ieeg.make_patient(p, n_seizures=N_SEIZURES) for p in PATIENTS]
    rows = []
    for target in DENSITIES:
        agg = _eval_one_shot(sparse, patients, target)
        rows.append({"name": f"fig4.sparse_opt.density_{target}",
                     "us_per_call": "",
                     "derived": (f"acc={agg['detection_accuracy']:.2f}"
                                 f";delay_s={agg['mean_delay_s']:.2f}"
                                 f";fa={agg['false_alarm_rate']:.2f}")})
    best = _eval_sparse_per_patient_best(sparse, patients)
    rows.append({"name": "fig4.sparse_opt.per_patient_tuned",
                 "us_per_call": "",
                 "derived": (f"acc={best['detection_accuracy']:.2f}"
                             f";delay_s={best['mean_delay_s']:.2f}"
                             " (paper: tuned sparse beats dense delay)")})

    dense = HDCPipeline.init(jax.random.PRNGKey(7), HDCConfig(variant="dense"))
    agg = _eval_one_shot(dense, patients, None)
    rows.append({"name": "fig4.dense_baseline",
                 "us_per_call": "",
                 "derived": (f"acc={agg['detection_accuracy']:.2f}"
                             f";delay_s={agg['mean_delay_s']:.2f}"
                             f";fa={agg['false_alarm_rate']:.2f}")})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
