"""Bit-error degradation curves + ECC tradeoff at fleet scale.

Two sections, both built on ``repro.reliability.sweep`` (one StreamingFleet
per grid cell, BER walked via the traced operand — zero recompiles per
curve):

* the MAIN GRID — all four hwmodel variants x density x BER with raw
  (unprotected) memories and all three fault targets live (codebook bank,
  AM rows, temporal counters): the paper-architecture robustness curves.
* the ECC section — sparse_opt with AM-ONLY faults under none / parity /
  SECDED protection: what word-level ECC buys back (accuracy, frame
  disagreement) and what it costs (decode energy per AM read, priced
  through the ``core.hwmodel`` gate constants).

Every BER = 0 point is verified BIT-EXACT (full per-frame score streams)
against a fault-free fleet; a mismatch raises, so the module ERRORs and CI
fails rather than shipping curves anchored to a divergent datapath.

Rows carry the metrics twice: human-greppable in ``derived`` and
machine-readable under the ``point`` key of ``BENCH_reliability.json``.

BENCH_TINY=1 (CI smoke) shrinks to 2 patients / short records / a 2-point
BER grid.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import tiny
from repro.core.classifier import HDCConfig
from repro.reliability import faults as rel_faults
from repro.reliability import sweep

VARIANTS = ("dense", "sparse_naive", "sparse_compim", "sparse_opt")


def _config() -> dict:
    base = HDCConfig(dim=256, segments=8, window=128)
    if tiny():
        return dict(
            base_cfg=base, n_patients=2, n_test=1,
            record_kw=dict(pre_s=10.0, ictal_s=14.0, post_s=6.0),
            bers=(0.0, 1e-2), densities=(0.25,), ecc_bers=(0.0, 1e-2),
        )
    return dict(
        base_cfg=base, n_patients=4, n_test=2,
        record_kw=dict(pre_s=16.0, ictal_s=20.0, post_s=8.0),
        bers=(0.0, 1e-3, 3e-3, 1e-2, 3e-2),
        densities=(0.15, 0.25, 0.35),
        ecc_bers=(0.0, 1e-3, 3e-3, 1e-2),
    )


def _row(point: dict, section: str = "") -> dict:
    name = (f"reliability.{section}{point['variant']}.d{point['density']:g}"
            f".{point['scheme']}.ber{point['ber']:g}")
    derived = (f"acc={point['detection_accuracy']:.2f}"
               f";delay_s={point['mean_delay_s']:.2f}"
               f";fa={point['false_alarm_rate']:.2f}"
               f";disagree={point['frame_disagreement']:.3f}"
               f";ecc_corr={point['ecc_corrected']}"
               f";ecc_uncorr={point['ecc_uncorrectable']}"
               f";ecc_ovh={point['ecc_read_overhead']:.2f}")
    if "zero_ber_bitexact" in point:
        derived += f";bitexact={point['zero_ber_bitexact']}"
    return {"name": name, "us_per_call": "", "derived": derived,
            "point": point}


def _check_bitexact(points: list[dict]) -> None:
    bad = [p for p in points
           if p.get("ber") == 0.0 and not p.get("zero_ber_bitexact")]
    if bad:
        names = [f"{p['variant']}/d{p['density']:g}/{p['scheme']}"
                 for p in bad]
        raise AssertionError(
            "BER=0 fleet not bit-exact with the fault-free step at: "
            + ", ".join(names))


def run() -> list[dict]:
    c = _config()
    rows = []

    # main grid: raw memories, all targets faulted, all four variants
    main = sweep.run_sweep(
        variants=VARIANTS, densities=c["densities"], bers=c["bers"],
        schemes=("none",), targets=("tables", "am", "counts"),
        base_cfg=c["base_cfg"], n_patients=c["n_patients"],
        n_test=c["n_test"], record_kw=c["record_kw"], seed=0)
    _check_bitexact(main)
    rows.extend(_row(p) for p in main)

    # ECC tradeoff: AM-only faults on the paper-optimized design point
    protected = sweep.run_sweep(
        variants=("sparse_opt",), densities=(0.25,), bers=c["ecc_bers"],
        schemes=("none", "parity", "secded"), targets=("am",),
        base_cfg=c["base_cfg"], n_patients=c["n_patients"],
        n_test=c["n_test"], record_kw=c["record_kw"], seed=1)
    _check_bitexact(protected)
    for p in protected:
        p["section"] = "ecc"
    rows.extend(_row(p, section="ecc.") for p in protected)

    # counter-width section: counts-only faults at the sparse VALUE width
    # (ceil(log2(window+1)) bits, all a saturating temporal counter can
    # hold) vs the dense accelerator's full PHYSICAL register file
    # (core.bundling's D x 8-bit counters, counts_bits=8) — the physical
    # word exposes high-order bits whose flips inject O(2^7) count errors,
    # so the sparse binary datapath's narrow counters degrade slower
    wcfg = replace(c["base_cfg"], window=64)  # value width 7 < physical 8
    top_ber = max(c["bers"])
    cw: dict[tuple, dict] = {}
    for cb in (None, 8):
        pts = sweep.run_sweep(
            variants=("sparse_opt", "dense"), densities=(0.25,),
            bers=(0.0, top_ber), schemes=("none",), targets=("counts",),
            base_cfg=wcfg, n_patients=c["n_patients"], n_test=c["n_test"],
            record_kw=c["record_kw"], seed=2, counts_bits=cb)
        _check_bitexact(pts)
        width = rel_faults.counter_bits(
            rel_faults.FaultConfig(counts=0.0, counts_bits=cb).plan(),
            wcfg.window)
        for p in pts:
            p["counts_bits"] = width
        cw.update({(p["variant"], cb, p["ber"]): p for p in pts})
        rows.extend(_row(p, section=f"counts.w{width}.") for p in pts)
    sp = cw[("sparse_opt", None, top_ber)]
    dn = cw[("dense", 8, top_ber)]
    rows.append({
        "name": "reliability.counts.summary", "us_per_call": "",
        "derived": (f"sparse@w{sp['counts_bits']}:acc="
                    f"{sp['detection_accuracy']:.2f},disagree="
                    f"{sp['frame_disagreement']:.3f}"
                    f";dense@w{dn['counts_bits']}:acc="
                    f"{dn['detection_accuracy']:.2f},disagree="
                    f"{dn['frame_disagreement']:.3f}"),
        "point": {
            "ber": top_ber, "window": wcfg.window,
            "sparse_value_width": sp["counts_bits"],
            "dense_physical_width": dn["counts_bits"],
            "sparse_accuracy": sp["detection_accuracy"],
            "dense_accuracy": dn["detection_accuracy"],
            "sparse_frame_disagreement": sp["frame_disagreement"],
            "dense_frame_disagreement": dn["frame_disagreement"],
        },
    })

    # summary: worst BER's accuracy floor per variant + SECDED recovery
    by_var = {
        v: [p for p in main if p["variant"] == v and p["ber"] == max(c["bers"])]
        for v in VARIANTS}
    floor = ";".join(
        f"{v}={min(p['detection_accuracy'] for p in by_var[v]):.2f}"
        for v in VARIANTS)
    top = max(c["ecc_bers"])
    raw = next(p for p in protected
               if p["scheme"] == "none" and p["ber"] == top)
    sec = next(p for p in protected
               if p["scheme"] == "secded" and p["ber"] == top)
    rows.append({
        "name": "reliability.summary", "us_per_call": "",
        "derived": (f"acc_floor@ber{max(c['bers']):g}[{floor}]"
                    f";secded@ber{top:g}:disagree="
                    f"{raw['frame_disagreement']:.3f}"
                    f"->{sec['frame_disagreement']:.3f}"
                    f";ecc_read_ovh={sec['ecc_read_overhead']:.2f}"),
        "point": {
            "ecc_ber": top,
            "raw_frame_disagreement": raw["frame_disagreement"],
            "secded_frame_disagreement": sec["frame_disagreement"],
            "secded_recovers": bool(sec["frame_disagreement"]
                                    <= raw["frame_disagreement"]),
            "secded_read_overhead": sec["ecc_read_overhead"],
            "secded_read_energy_nj": sec["ecc_read_energy_nj"],
            "accuracy_floor": {
                v: float(min(p["detection_accuracy"] for p in by_var[v]))
                for v in VARIANTS},
        },
    })
    assert np.isfinite(sec["ecc_read_energy_nj"])
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
