"""Fleet serving throughput: StreamingFleet vs looped SeizureSessions.

The looped baseline is the pre-fleet serving shape — one Python object and
one jit dispatch per stream per service interval.  The fleet advances ALL
streams in one jitted step.  For S in {1, 64, 1024} (window-length chunks,
one decision per stream per push) we report sessions-per-second, decisions
per second and per-decision latency, plus the fleet/baseline speedup row the
acceptance gate reads from BENCH_fleet.json.

BENCH_TINY=1 (CI smoke) shrinks to S in {1, 8} on a small geometry.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.serve.engine import SeizureSession
from repro.serve.fleet import StreamingFleet


def _config() -> tuple[HDCConfig, tuple[int, ...], int]:
    if tiny():
        cfg = HDCConfig(dim=256, segments=8, channels=16, window=64,
                        temporal_threshold=8)
        return cfg, (1, 8), 1
    return HDCConfig(), (1, 64, 1024), 1


def _trained(cfg: HDCConfig) -> HDCPipeline:
    rng = np.random.default_rng(0)
    codes = jnp.asarray(
        rng.integers(0, cfg.codes, (1, 4 * cfg.window, cfg.channels), np.uint8))
    labels = np.asarray(rng.integers(0, 2, (1, 4), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    return HDCPipeline.init(jax.random.PRNGKey(42), cfg).train_one_shot(
        codes, jnp.asarray(labels))


def _time(fn, iters: int) -> float:
    """Median wall-time (s) over iters calls (fn must consume its outputs)."""
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run() -> list[dict]:
    cfg, s_list, iters = _config()
    pipe = _trained(cfg)
    rng = np.random.default_rng(1)
    chunk = rng.integers(0, cfg.codes, (cfg.window, cfg.channels), np.uint8)
    rows = []
    for s in s_list:
        sessions = [SeizureSession(pipe) for _ in range(s)]
        chunks = [chunk] * s

        def run_baseline():
            for sess, c in zip(sessions, chunks):
                assert len(sess.push(c)) == 1

        def run_fleet():
            out = fleet.push(chunks)
            assert len(out[0]) == 1

        run_baseline()  # warmup (jit compiles shared across sessions)
        t_base = _time(run_baseline, iters)
        fleet = StreamingFleet({"p": pipe}, ["p"] * s, buckets=(cfg.window,))
        run_fleet()  # warmup (one compile for the single bucket)
        t_fleet = _time(run_fleet, max(iters, 3))

        for name, t in (("baseline_loop", t_base), ("fleet", t_fleet)):
            rows.append({
                "name": f"fleet.S{s}.{name}",
                "us_per_call": f"{t * 1e6:.0f}",
                "derived": (f"sessions/s={s / t:.1f}"
                            f";decisions/s={s / t:.1f}"
                            f";us/decision={t * 1e6 / s:.1f}"),
            })
        rows.append({
            "name": f"fleet.S{s}.speedup",
            "us_per_call": "",
            "derived": (f"{t_base / t_fleet:.2f}x sessions/s vs looped "
                        f"SeizureSession baseline"),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
