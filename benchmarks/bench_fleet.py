"""Fleet serving throughput: StreamingFleet vs looped SeizureSessions.

The looped baseline is the pre-fleet serving shape — one Python object and
one jit dispatch per stream per service interval.  The fleet advances ALL
streams in cache-tiled jitted steps (code/packed/bit-plane domain, see
serve/fleet.py).  For S in {1, 64, 1024} (window-length chunks, one decision
per stream per push) we report sessions-per-second, decisions per second and
per-decision latency, plus the fleet/baseline speedup row the CI
perf-regression gate reads from BENCH_fleet.json, and a ``fleet_codes`` row
for the zero-scatter pre-stacked ``push_codes`` ingest path.

At the largest S the module additionally reports a PER-STAGE breakdown of
the steady-state push (``stage_ingest`` / ``stage_spatial`` /
``stage_temporal`` / ``stage_am`` rows): each stage is timed as its own
jitted sub-benchmark on one session tile and scaled by the tile count, and
its share of the measured push time rides in the ``derived`` column — the
committed artifact behind the "spatial stage no longer dominant" claim
(the CI gate bounds the spatial share, see check_fleet_regression.py).

Methodology: both sides run the SAME repeat count and statistic (min over
iters — on this shared container scheduler bursts inflate single samples
3-10x and noise only ever adds, so the minimum estimates the true cost;
medians flaked the CI gate) and block on device results explicitly
(``jax.block_until_ready`` on the fleet's raw rounds; the baseline's
decisions are host arrays already) — no reliance on implicit syncs — and
each fleet's cold first push (jit trace + compile) is reported as its own
``*_compile`` row, never mixed into the steady-state timing.

BENCH_TINY=1 (CI smoke) shrinks to S in {1, 8} on a small geometry.
"""

from __future__ import annotations

import os
import time

# multiple CPU "devices" let the fleet round-robin its session tiles over
# all cores.  Only effective when this module is the first jax-backend user
# in the process — run ``-m benchmarks.run fleet`` (or list fleet first,
# like CI's bench-smoke does) for multi-device numbers; the ``devices`` row
# records what the run actually got.  Deliberately NOT set in run.py: the
# other modules' committed baselines were measured without forced host
# devices, and their environment should stay as-measured.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.cpu_count() or 1}"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.serve.engine import SeizureSession
from repro.serve.fleet import StreamingFleet


def _config() -> tuple[HDCConfig, tuple[int, ...], int]:
    if tiny():
        cfg = HDCConfig(dim=256, segments=8, channels=16, window=64,
                        temporal_threshold=8)
        return cfg, (1, 8), 3
    return HDCConfig(), (1, 64, 1024), 7


def _trained(cfg: HDCConfig) -> HDCPipeline:
    rng = np.random.default_rng(0)
    codes = jnp.asarray(
        rng.integers(0, cfg.codes, (1, 4 * cfg.window, cfg.channels), np.uint8))
    labels = np.asarray(rng.integers(0, 2, (1, 4), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    return HDCPipeline.init(jax.random.PRNGKey(42), cfg).train_one_shot(
        codes, jnp.asarray(labels))


def _time(fn, iters: int) -> float:
    """Min wall-time (s) over iters calls (fn must block on its results).

    Min, not median: this container is a shared 2-vCPU box whose scheduler
    bursts inflate individual samples 3-10x, and noise only ever ADDS time
    — the minimum is the standard robust estimator of the true cost, and
    every row (baseline, fleet, stages) uses the same statistic, so the
    ratio rows the CI gate reads stay comparable.
    """
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _stage_rows(fleet: StreamingFleet, batch: np.ndarray, s: int,
                iters: int) -> list[dict]:
    """Per-stage sub-benchmarks of one steady-state push at fleet scale S.

    The stage callables come from ``StreamingFleet.stage_probes`` — they
    live next to the step implementation, so refactors of the fleet's tile
    internals keep the probes in sync; this module only times them.  The
    reference push and the stages are sampled INTERLEAVED (one round-robin
    cycle per iteration, min over iterations): a scheduler burst longer
    than one cycle inflates every term together, so the share ratios the
    CI gate reads stay stable where separately-timed medians flaked.
    Stages overlap/fuse inside the real step, so shares need not sum
    to 100%.
    """
    probes = fleet.stage_probes(batch)

    def push_once():
        jax.block_until_ready(
            [r.tiles for r in fleet.push_codes_raw(batch)])

    push_once()  # warm
    samples: dict[str, list[float]] = {"push": []}
    for name, _ in probes.items():
        samples[name] = []
    for _ in range(iters):
        for name, fn in [("push", push_once)] + [
                (n, f) for n, (f, _) in probes.items()]:
            t0 = time.perf_counter()
            fn()
            samples[name].append(time.perf_counter() - t0)
    t_push = min(samples["push"])
    rows = []
    for name, (fn, scale) in probes.items():
        t = min(samples[name]) * scale
        how = "host, 1 round" if name == "ingest" else f"device, x{scale} tiles"
        rows.append({
            "name": f"fleet.S{s}.stage_{name}",
            "us_per_call": f"{t * 1e6:.0f}",
            "derived": (f"share={100 * t / t_push:.1f}% of steady-state "
                        f"push ({how})"),
        })
    return rows


def run() -> list[dict]:
    cfg, s_list, iters = _config()
    pipe = _trained(cfg)
    rng = np.random.default_rng(1)
    chunk = rng.integers(0, cfg.codes, (cfg.window, cfg.channels), np.uint8)
    rows = [{
        "name": "fleet.devices",
        "us_per_call": "",
        "derived": (f"n={len(jax.devices())} (session tiles round-robin "
                    "across local devices)"),
    }]
    for s in s_list:
        sessions = [SeizureSession(pipe) for _ in range(s)]
        chunks = [chunk] * s

        def run_baseline():
            for sess, c in zip(sessions, chunks):
                assert len(sess.push(c)) == 1  # decisions are host arrays

        run_baseline()  # warmup (jit compiles shared across sessions)
        t_base = _time(run_baseline, iters)

        fleet = StreamingFleet({"p": pipe}, ["p"] * s, buckets=(cfg.window,))
        batch = np.broadcast_to(chunk, (s, *chunk.shape))

        def run_fleet():
            rounds = fleet.push_raw(chunks)
            jax.block_until_ready([r.tiles for r in rounds])
            assert rounds[0].n_emit[0] == 1

        def run_fleet_codes():
            rounds = fleet.push_codes_raw(batch)
            jax.block_until_ready([r.tiles for r in rounds])
            assert rounds[0].n_emit[0] == 1

        t_compile = _time(run_fleet, 1)  # cold: jit trace + compile + run
        run_fleet()  # one warm push so the timed calls are pure steady state
        t_fleet = _time(run_fleet, iters)
        run_fleet_codes()
        t_codes = _time(run_fleet_codes, iters)

        for name, t in (("baseline_loop", t_base), ("fleet", t_fleet),
                        ("fleet_codes", t_codes)):
            rows.append({
                "name": f"fleet.S{s}.{name}",
                "us_per_call": f"{t * 1e6:.0f}",
                "derived": (f"sessions/s={s / t:.1f}"
                            f";decisions/s={s / t:.1f}"
                            f";us/decision={t * 1e6 / s:.1f}"),
            })
        rows.append({
            "name": f"fleet.S{s}.fleet_compile",
            "us_per_call": f"{t_compile * 1e6:.0f}",
            "derived": (f"cold first push (trace+compile+run); steady-state "
                        f"push={t_fleet * 1e6:.0f}us"),
        })
        rows.append({
            "name": f"fleet.S{s}.speedup",
            "us_per_call": "",
            "derived": (f"{t_base / t_fleet:.2f}x sessions/s vs looped "
                        f"SeizureSession baseline"),
        })
        rows.append({
            # ".speedup" suffix so the CI regression gate ratio-checks the
            # push_codes ingest fast path too
            "name": f"fleet.S{s}.codes.speedup",
            "us_per_call": "",
            "derived": (f"{t_base / t_codes:.2f}x sessions/s vs looped "
                        f"baseline (pre-stacked push_codes ingest)"),
        })
        if s == s_list[-1]:  # per-stage breakdown at fleet scale
            rows.extend(_stage_rows(fleet, batch, s, iters))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
