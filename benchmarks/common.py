"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (us) of a jitted call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tiny() -> bool:
    """True when BENCH_TINY is set: modules shrink to CI-smoke-sized configs."""
    return os.environ.get("BENCH_TINY", "") not in ("", "0")


def emit(rows: list[dict]):
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")


def write_bench_json(
    out_dir: str, module: str, rows: list[dict], *, error: str | None = None
) -> str:
    """Write BENCH_<module>.json next to the CSV stream; returns the path.

    The JSON mirrors the CSV rows plus an ok/error status, so the perf
    trajectory is machine-readable (CI uploads these as artifacts).
    """
    payload = {
        "module": module,
        "status": "error" if error else "ok",
        "rows": rows,
        "error": error,
    }
    path = os.path.join(out_dir, f"BENCH_{module}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
