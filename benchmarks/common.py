"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (us) of a jitted call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: list[dict]):
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
