"""HDC inference-pipeline throughput: naive bit-domain vs CompIM
position-domain vs fused Pallas-kernel path vs dense HDC, all through the
unified `HDCPipeline` (variant x backend dispatch).

This is the TPU-side §Perf benchmark for the paper's technique: the CompIM
insight on TPU = 18.3x smaller IM working set and no one-hot decode.  On this
CPU container the kernel backend runs in interpret mode (slow Python), so the
honest wall-clock comparison is between the pure-XLA pipelines; the kernel
path's value is the HBM-traffic reduction reported in §Roofline.  Derived =
predictions/s and bytes/prediction (analytic working-set model)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call, tiny
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg

BATCH = 8           # streams
T = 1024            # cycles (4 frames)


def _bytes_per_prediction(variant: str, cfg) -> float:
    """Analytic HBM traffic per prediction (one 256-cycle frame, 64 ch)."""
    c, w = cfg.channels, cfg.window
    if variant == "dense":
        im_bits = cfg.dim
    elif variant == "sparse_naive":
        im_bits = cfg.dim
    else:  # position domain
        im_bits = cfg.segments * 7
    per_cycle = c * (6 / 8 + im_bits / 8)     # LBP code in + IM entry
    frame_out = cfg.dim / 8 + 8               # packed HV + scores
    return per_cycle * w + frame_out


def run() -> list[dict]:
    if tiny():  # CI smoke: small geometry, random codes (no patient synth)
        cfg = HDCConfig(dim=256, segments=8, channels=16, window=64,
                        temporal_threshold=8)
        batch, t = 2, 2 * cfg.window
        rng = np.random.default_rng(0)
        codes = jnp.asarray(
            rng.integers(0, cfg.codes, (batch, t, cfg.channels), np.uint8))
    else:
        cfg = HDCConfig()
        batch, t = BATCH, T
        pat = ieeg.make_patient(11, n_seizures=1)
        codes = jnp.asarray(
            jnp.tile(jnp.asarray(pat.records[0].codes[None, :t]), (batch, 1, 1)))
    preds_per_call = batch * (t // cfg.window)
    rows = []

    variants = {
        "sparse_naive": dataclasses.replace(cfg, variant="sparse_naive",
                                            spatial_threshold=1),
        "sparse_compim": dataclasses.replace(cfg, variant="sparse_compim"),
    }
    for name, vcfg in variants.items():
        # init per variant so sparse_naive gets its precomputed packed IM
        pipe = HDCPipeline.init(jax.random.PRNGKey(42), vcfg)

        def fn(c, _p=pipe):
            return _p.encode_frames(c)

        # the naive bit-domain pipeline runs ~300 s/call on 1 CPU core: one
        # timed iteration is plenty (jit is deterministic)
        iters = 1 if name == "sparse_naive" else 3
        us = time_call(fn, codes, warmup=1, iters=iters)
        rows.append({"name": f"throughput.{name}",
                     "us_per_call": f"{us:.0f}",
                     "derived": (f"pred/s={preds_per_call / (us * 1e-6):.0f}"
                                 f";bytes/pred={_bytes_per_prediction(name, cfg):.0f}")})

    dense = HDCPipeline.init(jax.random.PRNGKey(7),
                             dataclasses.replace(cfg, variant="dense"))
    us = time_call(lambda c: dense.encode_frames(c), codes)
    rows.append({"name": "throughput.dense",
                 "us_per_call": f"{us:.0f}",
                 "derived": (f"pred/s={preds_per_call / (us * 1e-6):.0f}"
                             f";bytes/pred={_bytes_per_prediction('dense', cfg):.0f}")})

    naive_b = _bytes_per_prediction("sparse_naive", cfg)
    comp_b = _bytes_per_prediction("sparse_compim", cfg)
    rows.append({"name": "throughput.compim_traffic_reduction",
                 "us_per_call": "",
                 "derived": f"{naive_b / comp_b:.2f}x fewer bytes/pred "
                            "(ASIC IM compression: 1024b->56b = 18.3x)"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
