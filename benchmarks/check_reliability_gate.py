"""CI correctness gate: fault machinery at BER=0 must be a no-op.

Reads BENCH_reliability.json (bench_reliability.py) and checks every
degradation-sweep point with ``ber == 0.0``: injecting zero bit errors —
with or without ECC — must leave fleet decisions bit-exact with the
unmodified step (the sweep records this as ``zero_ber_bitexact``).  A
BER=0 point that changes decisions means the fault-injection datapath
itself perturbs the computation, which would poison every nonzero-BER
curve built on it.

Fails (exit 1) when any BER=0 point is not bit-exact, and also when NO
BER=0 points exist — a sweep that silently dropped its control points
would otherwise pass vacuously.

Usage::

    python -m benchmarks.check_reliability_gate bench-artifacts/BENCH_reliability.json
"""

from __future__ import annotations

import argparse
import json
import sys


def zero_ber_points(path: str) -> list[dict]:
    """The ``point`` dicts of all BER=0 sweep rows in the bench JSON."""
    with open(path) as f:
        rows = json.load(f)["rows"]
    return [r["point"] for r in rows
            if r.get("point", {}).get("ber") == 0.0]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json",
                    help="BENCH_reliability.json from this run")
    args = ap.parse_args(argv)

    zero = zero_ber_points(args.bench_json)
    if not zero:
        print(f"no BER=0 points in {args.bench_json} — the sweep lost its "
              "control points, gate would pass vacuously", file=sys.stderr)
        return 1

    bad = [p for p in zero if not p.get("zero_ber_bitexact")]
    for p in zero:
        print(f"{p['variant']}/d{p['density']}/{p['scheme']}: "
              f"bitexact={p['zero_ber_bitexact']}")
    if bad:
        print(f"{len(bad)} BER=0 point(s) not bit-exact", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
