"""Paper Table I: SotA comparison — our modeled design point vs the cited
implementations (values from the paper's table; ours from the cost model)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hwmodel
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg

CITED = [
    # name, app, type, tech_nm, area_mm2, energy_per_predict_nJ, energy_per_channel_nJ
    ("elhosary_tbiocas19", "EEG seizure", "SVM", 65, 0.09, 841.6, 36.59),
    ("oleary_isscc20", "iEEG brain state", "decision tree", 65, 1.95, 36.0, 4.5),
    ("menon_tbiocas22", "emotion recognition", "dense HDC", 28, 0.068, 39.1, 0.183),
]


def run() -> list[dict]:
    # variant="sparse_naive" precomputes the packed IM tables, which the
    # eager hwmodel sweep reads repeatedly (params are key-deterministic
    # and identical across sparse variants)
    cfg = HDCConfig(variant="sparse_naive", spatial_threshold=1)
    params = HDCPipeline.init(jax.random.PRNGKey(42), cfg).params
    codes = jnp.asarray(ieeg.make_patient(11, n_seizures=1).records[0].codes[:2048])
    es, asc = hwmodel.calibration_factors(params, codes, cfg)
    r = hwmodel.report("sparse_opt", params, codes, cfg, e_scale=es, a_scale=asc)
    rows = [{
        "name": "table1.ours_sparse_hdc_16nm",
        "us_per_call": f"{r['latency_us_at_10mhz']:.1f}",
        "derived": (f"A={r['area_total_mm2']:.3f}mm2"
                    f";E/pred={r['energy_total_nj']:.1f}nJ"
                    f";E/ch={r['energy_per_channel_nj']:.3f}nJ"
                    " (paper: 0.059mm2;12.5nJ;0.195nJ)"),
    }]
    for name, app, typ, tech, area, epred, ech in CITED:
        rows.append({"name": f"table1.{name}",
                     "us_per_call": "",
                     "derived": (f"type={typ};tech={tech}nm;A={area}mm2"
                                 f";E/pred={epred}nJ;E/ch={ech}nJ")})
    ours_ech = r["energy_per_channel_nj"]
    rows.append({"name": "table1.energy_per_channel_rank",
                 "us_per_call": "",
                 "derived": f"ours={ours_ech:.3f}nJ vs best cited 0.183nJ "
                            "(paper: comparable to dense-HDC emotion chip)"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
