"""CI perf-regression gate for the fleet and cold-start benchmarks.

Compares the ``fleet.*.speedup`` rows of a freshly produced BENCH_fleet.json
against a committed reference and fails (exit 1) when any matching row's
fleet-vs-baseline speedup regressed by more than ``--tolerance`` (default
25%).  Speedups are RATIOS of two timings from the same process on the same
machine, so they transfer across runner hardware far better than absolute
times; the committed CI reference (benchmarks/BENCH_fleet_tiny.json) uses
the BENCH_TINY geometry so the gate stays stable on small shared runners.

Row families the REFERENCE does not know (new benchmarks land ahead of
their reference refresh) are reported as warnings and skipped — the gate
fails only on KNOWN rows that regressed, went missing, or stopped parsing.
The committed reference itself is held to strict parsing: it is a curated
artifact, and a malformed row there is a repo bug, not a perf signal.

The gate also reads the fresh run's per-stage breakdown
(``fleet.*.stage_*`` rows, another same-process ratio): the code-domain
datapath's whole point is that the spatial gather+bundle stops dominating
the step, so a fresh ``stage_spatial`` share above ``--max-spatial-share``
(default 50% of steady-state push time) fails the gate.

With ``--coldstart-fresh``/``--coldstart-reference`` the same known-row
speedup machinery additionally gates BENCH_coldstart.json's
``coldstart.*.speedup`` ratio rows (warm-cache / serialized-executable vs
process-fresh trace+compile, see bench_coldstart.py), and the run's
``coldstart.bitexact`` and ``coldstart.fallback`` status rows must start
with ``ok`` — a fast cold start that changed decisions, or a stale
artifact that did not fall back to JIT, is a correctness bug, not a perf
win.

``--churn-fresh``/``--churn-reference`` do the same for the elastic-fleet
churn benchmark (bench_churn.py): the ``churn.*.speedup`` ratio rows
(fleet vs looped baseline under an identical Poisson churn trace, plus
the churn-vs-steady-state throughput retention) gate like any other
known-row family, and the ``churn.norecompile`` / ``churn.recovery``
status rows must start with ``ok`` — an admission path that recompiles,
or a restore+replay that changes decisions, defeats the elasticity
subsystem's whole contract.

``--channelfault-fresh``/``--channelfault-reference`` gate the electrode
fault benchmark (bench_channelfault.py): the ``channelfault.*.speedup``
rows are accuracy RETENTION ratios (quarantined fleet / clean fleet) —
another same-process ratio, so the committed tiny reference holds the
graceful-degradation floor — and the ``channelfault.maskparity`` /
``channelfault.gracefuldeg`` status rows must start with ``ok``: an
all-live mask that changes decisions (broken program identity), a masked
encode diverging from the reduced-channel oracle, or a sparse-variant
accuracy cliff at 1-2 failed channels all fail CI.

Usage::

    python -m benchmarks.check_fleet_regression FRESH.json REFERENCE.json \
        [--tolerance 0.25] [--max-spatial-share 0.5] \
        [--coldstart-fresh BENCH_coldstart.json \
         --coldstart-reference benchmarks/BENCH_coldstart_tiny.json] \
        [--churn-fresh BENCH_churn.json \
         --churn-reference benchmarks/BENCH_churn_tiny.json] \
        [--channelfault-fresh BENCH_channelfault.json \
         --channelfault-reference benchmarks/BENCH_channelfault_tiny.json]
"""

from __future__ import annotations

import argparse
import json
import re
import sys

_SPEEDUP = re.compile(r"^([0-9.]+)x ")
_SHARE = re.compile(r"^share=([0-9.]+)% ")

# rows whose derived string must start with "ok" for the gate to pass
COLDSTART_STATUS_ROWS = ("coldstart.bitexact", "coldstart.fallback")
CHURN_STATUS_ROWS = ("churn.norecompile", "churn.recovery")
CHANNELFAULT_STATUS_ROWS = ("channelfault.maskparity",
                            "channelfault.gracefuldeg")


def _load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("status") != "ok":
        raise SystemExit(f"{path}: benchmark status is not ok: "
                         f"{payload.get('error')}")
    return payload


def speedups(path: str, *, prefix: str = "fleet.", strict: bool = True
             ) -> tuple[dict[str, float], dict[str, dict]]:
    """``<prefix>*.speedup`` rows -> ``({name: speedup}, {name: bad_row})``.

    ``strict`` (the committed reference) raises on an unparseable row;
    the fresh run parses leniently and returns bad rows separately —
    whether one fails the gate depends on whether the reference knows it.
    """
    payload = _load(path)
    out: dict[str, float] = {}
    bad: dict[str, dict] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if not (name.startswith(prefix) and name.endswith(".speedup")):
            continue
        m = _SPEEDUP.match(row.get("derived", ""))
        if not m:
            if strict:
                raise SystemExit(f"{path}: unparseable speedup row {row!r}")
            bad[name] = row
            continue
        out[name] = float(m.group(1))
    return out, bad


def stage_shares(path: str) -> tuple[dict[str, float], dict[str, dict]]:
    """``fleet.*.stage_*`` rows -> fractional share of steady-state push
    (plus the rows whose derived string did not parse)."""
    payload = _load(path)
    out: dict[str, float] = {}
    bad: dict[str, dict] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if not (name.startswith("fleet.") and ".stage_" in name):
            continue
        m = _SHARE.match(row.get("derived", ""))
        if not m:
            bad[name] = row
            continue
        out[name] = float(m.group(1)) / 100.0
    return out, bad


def status_rows(path: str, names: tuple[str, ...]) -> dict[str, str]:
    """The derived strings of the named status rows (missing rows absent)."""
    payload = _load(path)
    want = set(names)
    return {row["name"]: row.get("derived", "")
            for row in payload.get("rows", []) if row.get("name") in want}


def gate_speedups(fresh_path: str, ref_path: str, *, prefix: str,
                  tolerance: float) -> list[str]:
    """Known-row speedup comparison; returns the failed row names."""
    fresh, fresh_bad = speedups(fresh_path, prefix=prefix, strict=False)
    ref, _ = speedups(ref_path, prefix=prefix)
    if not ref:
        print(f"{ref_path}: no {prefix}*.speedup rows — the committed "
              "reference is empty, the gate would pass vacuously",
              file=sys.stderr)
        return [f"{prefix}<empty reference>"]
    for name in sorted((set(fresh) | set(fresh_bad)) - set(ref)):
        print(f"warning: {name}: not in reference {ref_path}; "
              "skipping (refresh the committed reference to gate it)",
              file=sys.stderr)

    failed = []
    for name in sorted(ref):
        if name in fresh_bad:
            print(f"{name}: unparseable fresh row "
                  f"{fresh_bad[name]!r} -> FAILED")
            failed.append(name)
            continue
        if name not in fresh:
            print(f"{name}: in reference but missing from fresh run "
                  "-> FAILED")
            failed.append(name)
            continue
        floor = ref[name] * (1.0 - tolerance)
        status = "OK" if fresh[name] >= floor else "REGRESSED"
        print(f"{name}: fresh {fresh[name]:.2f}x vs reference "
              f"{ref[name]:.2f}x (floor {floor:.2f}x) -> {status}")
        if fresh[name] < floor:
            failed.append(name)
    return failed


def gate_status_rows(fresh_path: str,
                     names: tuple[str, ...]) -> list[str]:
    """The named status rows must exist and start with "ok"."""
    failed = []
    rows = status_rows(fresh_path, names)
    for name in names:
        derived = rows.get(name)
        if derived is None:
            print(f"{name}: missing from {fresh_path} -> FAILED")
            failed.append(name)
            continue
        ok = derived.startswith("ok")
        print(f"{name}: {derived} -> {'OK' if ok else 'FAILED'}")
        if not ok:
            failed.append(name)
    return failed


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="BENCH_fleet.json from this run")
    ap.add_argument("reference", help="committed reference BENCH_fleet.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--max-spatial-share", type=float, default=0.5,
                    help="fail when the fresh stage_spatial share of the "
                         "steady-state push exceeds this (default 0.5)")
    ap.add_argument("--coldstart-fresh", default=None,
                    help="BENCH_coldstart.json from this run (enables the "
                         "cold-start ratio + correctness gate)")
    ap.add_argument("--coldstart-reference", default=None,
                    help="committed cold-start reference "
                         "(benchmarks/BENCH_coldstart_tiny.json)")
    ap.add_argument("--churn-fresh", default=None,
                    help="BENCH_churn.json from this run (enables the "
                         "elastic-fleet churn ratio + lifecycle gate)")
    ap.add_argument("--churn-reference", default=None,
                    help="committed churn reference "
                         "(benchmarks/BENCH_churn_tiny.json)")
    ap.add_argument("--channelfault-fresh", default=None,
                    help="BENCH_channelfault.json from this run (enables "
                         "the electrode-fault retention + parity gate)")
    ap.add_argument("--channelfault-reference", default=None,
                    help="committed channel-fault reference "
                         "(benchmarks/BENCH_channelfault_tiny.json)")
    args = ap.parse_args(argv)
    if (args.coldstart_fresh is None) != (args.coldstart_reference is None):
        ap.error("--coldstart-fresh and --coldstart-reference go together")
    if (args.churn_fresh is None) != (args.churn_reference is None):
        ap.error("--churn-fresh and --churn-reference go together")
    if (args.channelfault_fresh is None) != \
            (args.channelfault_reference is None):
        ap.error("--channelfault-fresh and --channelfault-reference "
                 "go together")

    failed = gate_speedups(args.fresh, args.reference,
                           prefix="fleet.", tolerance=args.tolerance)

    shares, shares_bad = stage_shares(args.fresh)
    for name in sorted(shares_bad):
        print(f"warning: {name}: unparseable stage row "
              f"{shares_bad[name]!r}; skipping", file=sys.stderr)
    spatial = {n: v for n, v in shares.items() if n.endswith("stage_spatial")}
    if not spatial:
        print("no fleet.*.stage_spatial row in fresh run "
              "(per-stage breakdown missing)", file=sys.stderr)
        return 1
    for name, share in sorted(shares.items()):
        note = ""
        if name in spatial:
            ok = share <= args.max_spatial_share
            note = (f" (cap {args.max_spatial_share:.0%}) -> "
                    f"{'OK' if ok else 'DOMINANT'}")
            if not ok:
                failed.append(name)
        print(f"{name}: {share:.1%} of steady-state push{note}")

    if args.coldstart_fresh:
        failed += gate_speedups(args.coldstart_fresh,
                                args.coldstart_reference,
                                prefix="coldstart.",
                                tolerance=args.tolerance)
        failed += gate_status_rows(args.coldstart_fresh,
                                   COLDSTART_STATUS_ROWS)

    if args.churn_fresh:
        failed += gate_speedups(args.churn_fresh, args.churn_reference,
                                prefix="churn.", tolerance=args.tolerance)
        failed += gate_status_rows(args.churn_fresh, CHURN_STATUS_ROWS)

    if args.channelfault_fresh:
        failed += gate_speedups(args.channelfault_fresh,
                                args.channelfault_reference,
                                prefix="channelfault.",
                                tolerance=args.tolerance)
        failed += gate_status_rows(args.channelfault_fresh,
                                   CHANNELFAULT_STATUS_ROWS)

    if failed:
        print(f"fleet perf gate failed: {', '.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
