"""CI perf-regression gate for the fleet benchmark.

Compares the ``fleet.*.speedup`` rows of a freshly produced BENCH_fleet.json
against a committed reference and fails (exit 1) when any matching row's
fleet-vs-baseline speedup regressed by more than ``--tolerance`` (default
25%).  Speedups are RATIOS of two timings from the same process on the same
machine, so they transfer across runner hardware far better than absolute
times; the committed CI reference (benchmarks/BENCH_fleet_tiny.json) uses
the BENCH_TINY geometry so the gate stays stable on small shared runners.

The gate also reads the fresh run's per-stage breakdown
(``fleet.*.stage_*`` rows, another same-process ratio): the code-domain
datapath's whole point is that the spatial gather+bundle stops dominating
the step, so a fresh ``stage_spatial`` share above ``--max-spatial-share``
(default 50% of steady-state push time) fails the gate.

Usage::

    python -m benchmarks.check_fleet_regression FRESH.json REFERENCE.json \
        [--tolerance 0.25] [--max-spatial-share 0.5]
"""

from __future__ import annotations

import argparse
import json
import re
import sys

_SPEEDUP = re.compile(r"^([0-9.]+)x ")
_SHARE = re.compile(r"^share=([0-9.]+)% ")


def speedups(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("status") != "ok":
        raise SystemExit(f"{path}: benchmark status is not ok: "
                         f"{payload.get('error')}")
    out: dict[str, float] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if not (name.startswith("fleet.") and name.endswith(".speedup")):
            continue
        m = _SPEEDUP.match(row.get("derived", ""))
        if not m:
            raise SystemExit(f"{path}: unparseable speedup row {row!r}")
        out[name] = float(m.group(1))
    return out


def stage_shares(path: str) -> dict[str, float]:
    """``fleet.*.stage_*`` rows -> fractional share of steady-state push."""
    with open(path) as f:
        payload = json.load(f)
    out: dict[str, float] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if not (name.startswith("fleet.") and ".stage_" in name):
            continue
        m = _SHARE.match(row.get("derived", ""))
        if not m:
            raise SystemExit(f"{path}: unparseable stage row {row!r}")
        out[name] = float(m.group(1)) / 100.0
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="BENCH_fleet.json from this run")
    ap.add_argument("reference", help="committed reference BENCH_fleet.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--max-spatial-share", type=float, default=0.5,
                    help="fail when the fresh stage_spatial share of the "
                         "steady-state push exceeds this (default 0.5)")
    args = ap.parse_args(argv)

    fresh = speedups(args.fresh)
    ref = speedups(args.reference)
    common = sorted(set(fresh) & set(ref))
    if not common:
        print(f"no overlapping fleet.*.speedup rows between {args.fresh} "
              f"({sorted(fresh)}) and {args.reference} ({sorted(ref)})",
              file=sys.stderr)
        return 1

    failed = []
    for name in common:
        floor = ref[name] * (1.0 - args.tolerance)
        status = "OK" if fresh[name] >= floor else "REGRESSED"
        print(f"{name}: fresh {fresh[name]:.2f}x vs reference "
              f"{ref[name]:.2f}x (floor {floor:.2f}x) -> {status}")
        if fresh[name] < floor:
            failed.append(name)

    shares = stage_shares(args.fresh)
    spatial = {n: v for n, v in shares.items() if n.endswith("stage_spatial")}
    if not spatial:
        print("no fleet.*.stage_spatial row in fresh run "
              "(per-stage breakdown missing)", file=sys.stderr)
        return 1
    for name, share in sorted(shares.items()):
        note = ""
        if name in spatial:
            ok = share <= args.max_spatial_share
            note = (f" (cap {args.max_spatial_share:.0%}) -> "
                    f"{'OK' if ok else 'DOMINANT'}")
            if not ok:
                failed.append(name)
        print(f"{name}: {share:.1%} of steady-state push{note}")

    if failed:
        print(f"fleet perf gate failed: {', '.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
