"""CI perf-regression gate for the fleet benchmark.

Compares the ``fleet.*.speedup`` rows of a freshly produced BENCH_fleet.json
against a committed reference and fails (exit 1) when any matching row's
fleet-vs-baseline speedup regressed by more than ``--tolerance`` (default
25%).  Speedups are RATIOS of two timings from the same process on the same
machine, so they transfer across runner hardware far better than absolute
times; the committed CI reference (benchmarks/BENCH_fleet_tiny.json) uses
the BENCH_TINY geometry so the gate stays stable on small shared runners.

Row families the REFERENCE does not know (new benchmarks land ahead of
their reference refresh) are reported as warnings and skipped — the gate
fails only on KNOWN rows that regressed, went missing, or stopped parsing.
The committed reference itself is held to strict parsing: it is a curated
artifact, and a malformed row there is a repo bug, not a perf signal.

The gate also reads the fresh run's per-stage breakdown
(``fleet.*.stage_*`` rows, another same-process ratio): the code-domain
datapath's whole point is that the spatial gather+bundle stops dominating
the step, so a fresh ``stage_spatial`` share above ``--max-spatial-share``
(default 50% of steady-state push time) fails the gate.

Usage::

    python -m benchmarks.check_fleet_regression FRESH.json REFERENCE.json \
        [--tolerance 0.25] [--max-spatial-share 0.5]
"""

from __future__ import annotations

import argparse
import json
import re
import sys

_SPEEDUP = re.compile(r"^([0-9.]+)x ")
_SHARE = re.compile(r"^share=([0-9.]+)% ")


def speedups(path: str, *, strict: bool = True
             ) -> tuple[dict[str, float], dict[str, dict]]:
    """``fleet.*.speedup`` rows -> ``({name: speedup}, {name: bad_row})``.

    ``strict`` (the committed reference) raises on an unparseable row;
    the fresh run parses leniently and returns bad rows separately —
    whether one fails the gate depends on whether the reference knows it.
    """
    with open(path) as f:
        payload = json.load(f)
    if payload.get("status") != "ok":
        raise SystemExit(f"{path}: benchmark status is not ok: "
                         f"{payload.get('error')}")
    out: dict[str, float] = {}
    bad: dict[str, dict] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if not (name.startswith("fleet.") and name.endswith(".speedup")):
            continue
        m = _SPEEDUP.match(row.get("derived", ""))
        if not m:
            if strict:
                raise SystemExit(f"{path}: unparseable speedup row {row!r}")
            bad[name] = row
            continue
        out[name] = float(m.group(1))
    return out, bad


def stage_shares(path: str) -> tuple[dict[str, float], dict[str, dict]]:
    """``fleet.*.stage_*`` rows -> fractional share of steady-state push
    (plus the rows whose derived string did not parse)."""
    with open(path) as f:
        payload = json.load(f)
    out: dict[str, float] = {}
    bad: dict[str, dict] = {}
    for row in payload.get("rows", []):
        name = row.get("name", "")
        if not (name.startswith("fleet.") and ".stage_" in name):
            continue
        m = _SHARE.match(row.get("derived", ""))
        if not m:
            bad[name] = row
            continue
        out[name] = float(m.group(1)) / 100.0
    return out, bad


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="BENCH_fleet.json from this run")
    ap.add_argument("reference", help="committed reference BENCH_fleet.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--max-spatial-share", type=float, default=0.5,
                    help="fail when the fresh stage_spatial share of the "
                         "steady-state push exceeds this (default 0.5)")
    args = ap.parse_args(argv)

    fresh, fresh_bad = speedups(args.fresh, strict=False)
    ref, _ = speedups(args.reference)
    if not ref:
        print(f"{args.reference}: no fleet.*.speedup rows — the committed "
              "reference is empty, the gate would pass vacuously",
              file=sys.stderr)
        return 1
    for name in sorted((set(fresh) | set(fresh_bad)) - set(ref)):
        print(f"warning: {name}: not in reference {args.reference}; "
              "skipping (refresh the committed reference to gate it)",
              file=sys.stderr)

    failed = []
    for name in sorted(ref):
        if name in fresh_bad:
            print(f"{name}: unparseable fresh row "
                  f"{fresh_bad[name]!r} -> FAILED")
            failed.append(name)
            continue
        if name not in fresh:
            print(f"{name}: in reference but missing from fresh run "
                  "-> FAILED")
            failed.append(name)
            continue
        floor = ref[name] * (1.0 - args.tolerance)
        status = "OK" if fresh[name] >= floor else "REGRESSED"
        print(f"{name}: fresh {fresh[name]:.2f}x vs reference "
              f"{ref[name]:.2f}x (floor {floor:.2f}x) -> {status}")
        if fresh[name] < floor:
            failed.append(name)

    shares, shares_bad = stage_shares(args.fresh)
    for name in sorted(shares_bad):
        print(f"warning: {name}: unparseable stage row "
              f"{shares_bad[name]!r}; skipping", file=sys.stderr)
    spatial = {n: v for n, v in shares.items() if n.endswith("stage_spatial")}
    if not spatial:
        print("no fleet.*.stage_spatial row in fresh run "
              "(per-stage breakdown missing)", file=sys.stderr)
        return 1
    for name, share in sorted(shares.items()):
        note = ""
        if name in spatial:
            ok = share <= args.max_spatial_share
            note = (f" (cap {args.max_spatial_share:.0%}) -> "
                    f"{'OK' if ok else 'DOMINANT'}")
            if not ok:
                failed.append(name)
        print(f"{name}: {share:.1%} of steady-state push{note}")

    if failed:
        print(f"fleet perf gate failed: {', '.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
